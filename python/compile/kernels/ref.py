"""Pure-jnp oracles for every Pallas kernel.

These are the L1 correctness references: each function computes the same
mathematical result as its multi-strided Pallas counterpart in
``multistride.py`` using plain ``jax.numpy`` ops, with no Pallas, no custom
blocking, and no manual scheduling. ``python/tests`` asserts allclose
between the two across randomized shapes (hypothesis).
"""

import jax.numpy as jnp


def mxv(a, x):
    """y = A · x."""
    return a @ x


def tmxv(a, y):
    """x = Aᵀ · y (the paper's Listing 1 / gemvermxv1 / isolated doitgen)."""
    return a.T @ y


def bicg(a, r, p):
    """BiCG sub-kernel: s = Aᵀ·r, q = A·p."""
    return a.T @ r, a @ p


def gemverouter(a, u1, v1, u2, v2):
    """Double rank-1 update: A + u1·v1ᵀ + u2·v2ᵀ."""
    return a + jnp.outer(u1, v1) + jnp.outer(u2, v2)


def gemversum(x, z):
    """Vector sum update: x + z."""
    return x + z


def gemver(a, u1, v1, u2, v2, y, z, x, w, alpha, beta):
    """The full PolyBench gemver kernel (four parts composed)."""
    a2 = gemverouter(a, u1, v1, u2, v2)
    x1 = x + beta * (a2.T @ y)
    x2 = gemversum(x1, z)
    w1 = w + alpha * (a2 @ x2)
    return a2, x2, w1


def conv3x3(img, w):
    """Valid-mode 3×3 convolution (correlation, like the paper's stencil)."""
    h, wd = img.shape
    acc = jnp.zeros((h - 2, wd - 2), dtype=img.dtype)
    for di in range(3):
        for dj in range(3):
            acc = acc + w[di, dj] * img[di : di + h - 2, dj : dj + wd - 2]
    return acc


def jacobi2d(a):
    """One 5-point Jacobi sweep over the interior; borders copied."""
    a = jnp.asarray(a)
    interior = 0.2 * (
        a[1:-1, 1:-1] + a[1:-1, :-2] + a[1:-1, 2:] + a[:-2, 1:-1] + a[2:, 1:-1]
    )
    return a.at[1:-1, 1:-1].set(interior)


def doitgen(a1, c4):
    """Isolated doitgen inner step: sum_p = Σ_s A1[s] · C4[s, p]."""
    return a1 @ c4
