"""L1 — multi-strided Pallas kernels.

§Hardware-Adaptation (DESIGN.md §4): the paper's x86 transformation primes
multiple cache-prefetch streams by unrolling over a non-contiguous axis.
TPUs have no hardware prefetcher; the analogue is the **HBM→VMEM copy
schedule**. Each kernel here takes a ``stride_unroll`` parameter ``S``: one
grid step processes a *group of S rows* concurrently, so S independent HBM
row streams are in flight per step (Pallas/Mosaic double-buffers the block
DMA across steps). ``S = 1`` is the single-strided baseline — same FLOPs,
one row stream at a time.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime loads (see /opt/xla-example/README.md).

Every kernel is checked against the pure-jnp oracles in ``ref.py`` by
``python/tests/test_kernels.py`` (pytest + hypothesis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True


def _check_rows(m, s, name):
    if m % s != 0:
        raise ValueError(f"{name}: row count {m} not divisible by stride unroll {s}")


# ---------------------------------------------------------------------------
# mxv — y = A·x (and gemvermxv2): stride unroll over rows of A.
# ---------------------------------------------------------------------------


def mxv(a, x, *, stride_unroll=4):
    """Multi-strided dense matrix-vector product.

    Grid step *g* loads rows ``[g·S, (g+1)·S)`` of A as one (S, N) VMEM
    block — S concurrent HBM row streams, the Listing-2 schedule.
    """
    m, n = a.shape
    s = stride_unroll
    _check_rows(m, s, "mxv")

    def kernel(a_ref, x_ref, o_ref):
        o_ref[...] = jnp.sum(a_ref[...] * x_ref[...][None, :], axis=1)

    return pl.pallas_call(
        kernel,
        grid=(m // s,),
        in_specs=[
            pl.BlockSpec((s, n), lambda g: (g, 0)),
            pl.BlockSpec((n,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((s,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((m,), a.dtype),
        interpret=INTERPRET,
    )(a, x)


# ---------------------------------------------------------------------------
# tmxv — x = Aᵀ·y (gemvermxv1 / isolated doitgen): stride unroll over the
# reduction rows; the output block accumulates across grid steps.
# ---------------------------------------------------------------------------


def tmxv(a, y, *, stride_unroll=4):
    """Multi-strided transposed matrix-vector product (Listing 1/2)."""
    m, n = a.shape
    s = stride_unroll
    _check_rows(m, s, "tmxv")

    def kernel(a_ref, y_ref, o_ref):
        g = pl.program_id(0)

        @pl.when(g == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.sum(a_ref[...] * y_ref[...][:, None], axis=0)

    return pl.pallas_call(
        kernel,
        grid=(m // s,),
        in_specs=[
            pl.BlockSpec((s, n), lambda g: (g, 0)),
            pl.BlockSpec((s,), lambda g: (g,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda g: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=INTERPRET,
    )(a, y)


# ---------------------------------------------------------------------------
# bicg — s = Aᵀ·r and q = A·p in a single multi-strided pass over A.
# ---------------------------------------------------------------------------


def bicg(a, r, p, *, stride_unroll=4):
    """BiCG sub-kernel: one sweep of A feeds both reductions, exactly like
    the paper's fused loop (Table 1: n+2 load streams)."""
    m, n = a.shape
    s = stride_unroll
    _check_rows(m, s, "bicg")

    def kernel(a_ref, r_ref, p_ref, s_ref, q_ref):
        g = pl.program_id(0)

        @pl.when(g == 0)
        def _init():
            s_ref[...] = jnp.zeros_like(s_ref)

        blk = a_ref[...]
        s_ref[...] += jnp.sum(blk * r_ref[...][:, None], axis=0)
        q_ref[...] = blk @ p_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(m // s,),
        in_specs=[
            pl.BlockSpec((s, n), lambda g: (g, 0)),
            pl.BlockSpec((s,), lambda g: (g,)),
            pl.BlockSpec((n,), lambda g: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n,), lambda g: (0,)),
            pl.BlockSpec((s,), lambda g: (g,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), a.dtype),
            jax.ShapeDtypeStruct((m,), a.dtype),
        ],
        interpret=INTERPRET,
    )(a, r, p)


# ---------------------------------------------------------------------------
# gemverouter — A += u1·v1ᵀ + u2·v2ᵀ: stride unroll over updated rows.
# ---------------------------------------------------------------------------


def gemverouter(a, u1, v1, u2, v2, *, stride_unroll=4):
    """Double rank-1 update with S row streams per grid step."""
    m, n = a.shape
    s = stride_unroll
    _check_rows(m, s, "gemverouter")

    def kernel(a_ref, u1_ref, v1_ref, u2_ref, v2_ref, o_ref):
        o_ref[...] = (
            a_ref[...]
            + u1_ref[...][:, None] * v1_ref[...][None, :]
            + u2_ref[...][:, None] * v2_ref[...][None, :]
        )

    return pl.pallas_call(
        kernel,
        grid=(m // s,),
        in_specs=[
            pl.BlockSpec((s, n), lambda g: (g, 0)),
            pl.BlockSpec((s,), lambda g: (g,)),
            pl.BlockSpec((n,), lambda g: (0,)),
            pl.BlockSpec((s,), lambda g: (g,)),
            pl.BlockSpec((n,), lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((s, n), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=INTERPRET,
    )(a, u1, v1, u2, v2)


# ---------------------------------------------------------------------------
# gemversum — x = x + z: 1-D, loop-blocked into S partitions (Table 1 LB).
# ---------------------------------------------------------------------------


def gemversum(x, z, *, stride_unroll=4):
    """Vector sum update; the 1-D axis is loop-blocked so each grid step
    advances S partition streams (the paper's LB transformation)."""
    (n,) = x.shape
    s = stride_unroll
    _check_rows(n, s, "gemversum")
    part = n // s
    x2 = x.reshape(s, part)
    z2 = z.reshape(s, part)

    def kernel(x_ref, z_ref, o_ref):
        o_ref[...] = x_ref[...] + z_ref[...]

    # Grid walks the partition axis; every step touches all S partitions at
    # the same offset — S concurrent streams.
    blk = min(part, 512)
    steps = part // blk if part % blk == 0 else 1
    if part % blk != 0:
        blk = part
    out = pl.pallas_call(
        kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((s, blk), lambda g: (0, g)),
            pl.BlockSpec((s, blk), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((s, blk), lambda g: (0, g)),
        out_shape=jax.ShapeDtypeStruct((s, part), x.dtype),
        interpret=INTERPRET,
    )(x2, z2)
    return out.reshape(n)


# ---------------------------------------------------------------------------
# conv — 3×3 valid convolution: S output-row streams per grid step, input
# window loaded as an (S+2)-row dynamic slice (rows overlap between steps,
# the n+2-load-stream pattern of Table 1).
# ---------------------------------------------------------------------------


def conv3x3(img, w, *, stride_unroll=4):
    """Multi-strided 3×3 stencil."""
    h, wd = img.shape
    oh, ow = h - 2, wd - 2
    s = stride_unroll
    _check_rows(oh, s, "conv3x3")

    def kernel(img_ref, w_ref, o_ref):
        g = pl.program_id(0)
        x = pl.load(img_ref, (pl.ds(g * s, s + 2), slice(None)))
        wv = w_ref[...]
        acc = jnp.zeros((s, ow), dtype=o_ref.dtype)
        for di in range(3):
            for dj in range(3):
                acc += wv[di, dj] * jax.lax.dynamic_slice(x, (di, dj), (s, ow))
        o_ref[...] = acc

    return pl.pallas_call(
        kernel,
        grid=(oh // s,),
        in_specs=[
            pl.BlockSpec((h, wd), lambda g: (0, 0)),  # full image; window DMA'd
            pl.BlockSpec((3, 3), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((s, ow), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((oh, ow), img.dtype),
        interpret=INTERPRET,
    )(img, w)


# ---------------------------------------------------------------------------
# jacobi2d — one 5-point sweep, interior only; borders handled at L2.
# ---------------------------------------------------------------------------


def jacobi2d_interior(a, *, stride_unroll=5):
    """Interior of one Jacobi sweep with S row streams per grid step."""
    h, w = a.shape
    ih, iw = h - 2, w - 2
    s = stride_unroll
    _check_rows(ih, s, "jacobi2d")

    def kernel(a_ref, o_ref):
        g = pl.program_id(0)
        x = pl.load(a_ref, (pl.ds(g * s, s + 2), slice(None)))
        c = jax.lax.dynamic_slice(x, (1, 1), (s, iw))
        west = jax.lax.dynamic_slice(x, (1, 0), (s, iw))
        east = jax.lax.dynamic_slice(x, (1, 2), (s, iw))
        north = jax.lax.dynamic_slice(x, (0, 1), (s, iw))
        south = jax.lax.dynamic_slice(x, (2, 1), (s, iw))
        o_ref[...] = 0.2 * (c + west + east + north + south)

    return pl.pallas_call(
        kernel,
        grid=(ih // s,),
        in_specs=[pl.BlockSpec((h, w), lambda g: (0, 0))],
        out_specs=pl.BlockSpec((s, iw), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((ih, iw), a.dtype),
        interpret=INTERPRET,
    )(a)


def jacobi2d(a, *, stride_unroll=5):
    """Full Jacobi step: interior via the Pallas kernel, borders copied."""
    a = jnp.asarray(a)
    interior = jacobi2d_interior(a, stride_unroll=stride_unroll)
    return a.at[1:-1, 1:-1].set(interior)


# Isolated doitgen is tmxv by construction (§6.1 of the paper).
doitgen = functools.partial(tmxv)
