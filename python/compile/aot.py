"""AOT bridge: lower every L2 model to HLO **text** artifacts.

Interchange format is HLO text, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once via ``make artifacts``; the Rust binary then loads
``artifacts/<name>.hlo.txt`` through PJRT and never touches Python again.

The example shapes below are the AOT contract with the Rust side — keep in
sync with ``rust/src/main.rs::validate`` and
``rust/tests/runtime_integration.rs``.
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# name -> (fn, example args). Shapes chosen small (artifact compile time)
# but structured: row counts divide the kernels' stride_unroll.
ARTIFACTS = {
    "mxv": (model.mxv, (_s(64, 128), _s(128))),
    "bicg": (model.bicg, (_s(64, 128), _s(64), _s(128))),
    "conv": (model.conv, (_s(34, 66), _s(3, 3))),
    "jacobi2d": (model.jacobi2d, (_s(32, 64),)),
    "doitgen": (model.doitgen, (_s(64,), _s(64, 128))),
    "gemver": (
        model.gemver,
        (_s(64, 64), _s(64), _s(64), _s(64), _s(64), _s(64), _s(64), _s(64), _s(64)),
    ),
}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: pathlib.Path, only=None) -> list[pathlib.Path]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for name, (fn, args) in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")
        written.append(path)
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    build(pathlib.Path(args.out_dir), set(args.only) if args.only else None)


if __name__ == "__main__":
    main()
