"""L2 — the JAX compute graphs the Rust runtime executes.

Each function here is the *model layer*: a jitted JAX computation whose
memory-bound hot spots are the L1 Pallas kernels in
``compile/kernels/multistride.py``. ``compile/aot.py`` lowers these once to
HLO text; Python never runs on the Rust request path.

All functions return tuples (the AOT bridge lowers with
``return_tuple=True``; the Rust side unwraps in order).
"""

import jax.numpy as jnp

from .kernels import multistride as ms


def mxv(a, x):
    """y = A·x through the multi-strided Pallas kernel."""
    return (ms.mxv(a, x),)


def bicg(a, r, p):
    """BiCG sub-kernel: (s, q) in one fused multi-strided sweep of A."""
    s, q = ms.bicg(a, r, p)
    return (s, q)


def conv(img, w):
    """3×3 valid convolution."""
    return (ms.conv3x3(img, w),)


def jacobi2d(a):
    """One Jacobi sweep (interior Pallas kernel + border copy)."""
    return (ms.jacobi2d(a),)


def doitgen(a1, c4):
    """Isolated doitgen step (transposed MxV)."""
    return (ms.doitgen(c4, a1),)


def gemver(a, u1, v1, u2, v2, y, z, x, w):
    """The full gemver kernel: all four parts composed from the L1 kernels,
    mirroring how §6.4 reassembles the compute kernel from its individually
    tuned steps (α = β = 1 like PolyBench's defaults scaled)."""
    alpha = jnp.float32(1.5)
    beta = jnp.float32(1.2)
    a2 = ms.gemverouter(a, u1, v1, u2, v2)
    x1 = x + beta * ms.tmxv(a2, y)
    x2 = ms.gemversum(x1, z)
    w1 = w + alpha * ms.mxv(a2, x2)
    return (a2, x2, w1)
