"""L1 correctness: every multi-strided Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and stride-unroll factors; the key property is the
paper's own invariant — multi-striding is a *schedule* change, so the
numeric result must be identical (up to fp reassociation) to the
single-strided and pure-jnp computations for every configuration.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import multistride as ms
from compile.kernels import ref

RNG = np.random.default_rng(0xC0FFEE)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


def close(a, b, tol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


# --- fixed-shape smoke (fast, always run) ----------------------------------


class TestFixedShapes:
    def test_mxv(self):
        a, x = rand(16, 32), rand(32)
        close(ms.mxv(a, x), ref.mxv(a, x))

    def test_tmxv(self):
        a, y = rand(16, 32), rand(16)
        close(ms.tmxv(a, y), ref.tmxv(a, y))

    def test_bicg(self):
        a, r, p = rand(16, 32), rand(16), rand(32)
        s, q = ms.bicg(a, r, p)
        s_ref, q_ref = ref.bicg(a, r, p)
        close(s, s_ref)
        close(q, q_ref)

    def test_gemverouter(self):
        a, u1, v1, u2, v2 = rand(16, 24), rand(16), rand(24), rand(16), rand(24)
        close(ms.gemverouter(a, u1, v1, u2, v2), ref.gemverouter(a, u1, v1, u2, v2))

    def test_gemversum(self):
        x, z = rand(256), rand(256)
        close(ms.gemversum(x, z), ref.gemversum(x, z))

    def test_conv3x3(self):
        img, w = rand(18, 34), rand(3, 3)
        close(ms.conv3x3(img, w), ref.conv3x3(img, w))

    def test_jacobi2d(self):
        a = rand(22, 34)
        close(ms.jacobi2d(a), ref.jacobi2d(a))

    def test_doitgen(self):
        a1, c4 = rand(16), rand(16, 32)
        close(ms.doitgen(c4, a1), ref.doitgen(a1, c4))


# --- the headline invariant: schedules don't change numerics ----------------


class TestStrideUnrollInvariance:
    """Multi-striding is a pure schedule transformation (§5.1): every
    stride-unroll factor must produce the same values."""

    def test_mxv_all_strides(self):
        a, x = rand(24, 16), rand(16)
        base = np.asarray(ms.mxv(a, x, stride_unroll=1))
        for s in (2, 3, 4, 6, 8, 12, 24):
            close(ms.mxv(a, x, stride_unroll=s), base)

    def test_tmxv_all_strides(self):
        a, y = rand(24, 16), rand(24)
        base = np.asarray(ms.tmxv(a, y, stride_unroll=1))
        for s in (2, 3, 4, 6, 8, 12, 24):
            close(ms.tmxv(a, y, stride_unroll=s), base)

    def test_conv_all_strides(self):
        img, w = rand(26, 20), rand(3, 3)
        base = np.asarray(ms.conv3x3(img, w, stride_unroll=1))
        for s in (2, 3, 4, 6, 8, 12, 24):
            close(ms.conv3x3(img, w, stride_unroll=s), base)

    def test_indivisible_stride_rejected(self):
        a, x = rand(10, 8), rand(8)
        with pytest.raises(ValueError, match="not divisible"):
            ms.mxv(a, x, stride_unroll=4)


# --- hypothesis sweeps -------------------------------------------------------

dims = st.integers(min_value=1, max_value=8)


@settings(max_examples=25, deadline=None)
@given(mb=dims, nb=dims, s=st.sampled_from([1, 2, 4]))
def test_mxv_hypothesis(mb, nb, s):
    m, n = mb * 4, nb * 4
    a, x = rand(m, n), rand(n)
    close(ms.mxv(a, x, stride_unroll=s), ref.mxv(a, x))


@settings(max_examples=25, deadline=None)
@given(mb=dims, nb=dims, s=st.sampled_from([1, 2, 4]))
def test_bicg_hypothesis(mb, nb, s):
    m, n = mb * 4, nb * 4
    a, r, p = rand(m, n), rand(m), rand(n)
    s_got, q_got = ms.bicg(a, r, p, stride_unroll=s)
    s_ref, q_ref = ref.bicg(a, r, p)
    close(s_got, s_ref, tol=5e-4)
    close(q_got, q_ref, tol=5e-4)


@settings(max_examples=20, deadline=None)
@given(hb=st.integers(2, 6), wb=st.integers(1, 6), s=st.sampled_from([1, 2, 4]))
def test_conv_hypothesis(hb, wb, s):
    h, w = hb * 4 + 2, wb * 8 + 2  # interior divisible by 4
    img, wts = rand(h, w), rand(3, 3)
    close(ms.conv3x3(img, wts, stride_unroll=s), ref.conv3x3(img, wts))


@settings(max_examples=20, deadline=None)
@given(hb=st.integers(2, 6), wb=st.integers(1, 6))
def test_jacobi_hypothesis(hb, wb):
    h, w = hb * 5 + 2, wb * 8 + 2
    a = rand(h, w)
    close(ms.jacobi2d(a, stride_unroll=5), ref.jacobi2d(a))


@settings(max_examples=20, deadline=None)
@given(nb=st.integers(1, 16), s=st.sampled_from([1, 2, 4, 8]))
def test_gemversum_hypothesis(nb, s):
    n = nb * 8
    x, z = rand(n), rand(n)
    close(ms.gemversum(x, z, stride_unroll=s), ref.gemversum(x, z))
