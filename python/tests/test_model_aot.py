"""L2 + AOT pipeline tests: model graphs compose the kernels correctly and
every artifact lowers to parseable HLO text with stable entry shapes."""

import numpy as np
import jax
import pytest

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(7)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


class TestModelGraphs:
    def test_gemver_composition_matches_reference(self):
        n = 64
        a, u1, v1, u2, v2 = rand(n, n), rand(n), rand(n), rand(n), rand(n)
        y, z, x, w = rand(n), rand(n), rand(n), rand(n)
        a2, x2, w1 = model.gemver(a, u1, v1, u2, v2, y, z, x, w)
        ra, rx, rw = ref.gemver(
            a, u1, v1, u2, v2, y, z, x, w, np.float32(1.5), np.float32(1.2)
        )
        np.testing.assert_allclose(a2, ra, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(x2, rx, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(w1, rw, rtol=1e-2, atol=1e-2)

    def test_models_return_tuples(self):
        out = model.mxv(rand(16, 32), rand(32))
        assert isinstance(out, tuple) and len(out) == 1

    def test_jacobi_preserves_borders(self):
        a = rand(32, 64)
        (b,) = model.jacobi2d(a)
        np.testing.assert_array_equal(np.asarray(b)[0], a[0])
        np.testing.assert_array_equal(np.asarray(b)[-1], a[-1])
        np.testing.assert_array_equal(np.asarray(b)[:, 0], a[:, 0])


class TestAotLowering:
    @pytest.mark.parametrize("name", sorted(aot.ARTIFACTS))
    def test_lowers_to_hlo_text(self, name):
        fn, args = aot.ARTIFACTS[name]
        lowered = jax.jit(fn).lower(*args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text
        # Text (not proto) is the interchange contract with Rust.
        assert "f32" in text

    def test_build_writes_files(self, tmp_path):
        written = aot.build(tmp_path, only={"mxv"})
        assert len(written) == 1
        assert written[0].name == "mxv.hlo.txt"
        assert written[0].read_text().startswith("HloModule")

    def test_artifact_shapes_are_the_rust_contract(self):
        # rust/src/main.rs::validate and rust/tests assume these shapes.
        assert aot.ARTIFACTS["mxv"][1][0].shape == (64, 128)
        assert aot.ARTIFACTS["bicg"][1][0].shape == (64, 128)
        assert aot.ARTIFACTS["conv"][1][0].shape == (34, 66)
        assert aot.ARTIFACTS["jacobi2d"][1][0].shape == (32, 64)
