//! Quickstart: transform one kernel, sweep its striding space, report the
//! multi-striding speedup.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{best_point, figure6};
use multistride::kernels::library::kernel_by_name;
use multistride::transform::{critical_access, stride_profile, transform, StridingConfig};

fn main() -> multistride::Result<()> {
    let machine = coffee_lake();
    let budget = 24 * 1024 * 1024; // 24 MiB (2x the modeled L3)

    // 1. The kernel: y[i] += A[i][j] * x[j], straight from Table 1.
    let kernel = kernel_by_name("mxv", budget).expect("library kernel");
    println!("kernel: {} — {}", kernel.name, kernel.description);

    // 2. The §5.1 transformation machinery, step by step.
    let (acc, axis) = critical_access(&kernel.spec)?;
    println!(
        "critical access: {}[..] — contiguous axis: loop `{}`",
        kernel.spec.arrays[kernel.spec.accesses[acc].array].name,
        kernel.spec.loops[axis].name
    );
    let t = transform(&kernel.spec, StridingConfig::new(4, 2))?;
    let prof = stride_profile(&t);
    println!(
        "at stride unroll 4: {} load streams, {} store streams, {} load/store streams",
        prof.loads, prof.stores, prof.loadstores
    );

    // 3. Sweep the optimization space on the simulated Coffee Lake.
    println!("\nsweeping striding configurations (this simulates every access)...");
    let points = figure6(machine, "mxv", budget, 12, true);
    let best = best_point(&points).expect("feasible config");
    let best_single = points
        .iter()
        .filter(|p| p.feasible && p.config.stride_unroll == 1)
        .max_by(|a, b| a.throughput_gib.total_cmp(&b.throughput_gib))
        .expect("single-strided baseline");

    println!(
        "best single-strided: portion unroll {:2}          -> {:6.2} GiB/s",
        best_single.config.portion_unroll, best_single.throughput_gib
    );
    println!(
        "best multi-strided:  {} strides x portion {:2}    -> {:6.2} GiB/s",
        best.config.stride_unroll, best.config.portion_unroll, best.throughput_gib
    );
    println!(
        "multi-striding speedup: {:.2}x (the paper reports up to 1.58x for mxv)",
        best.throughput_gib / best_single.throughput_gib
    );
    Ok(())
}
