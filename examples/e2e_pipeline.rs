//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! 1. **Transform + simulate (L3)** — enumerate the striding space of the
//!    paper's kernels on the simulated Coffee Lake, pick each kernel's best
//!    multi-strided configuration, and report the paper's headline metric
//!    (multi-strided speedup over the best single-strided configuration and
//!    over the reference-implementation models).
//! 2. **Execute numerically (L2/L1 via PJRT)** — load the AOT-compiled
//!    JAX/Pallas artifacts (`make artifacts`) for the same kernels, run
//!    them on real data through the Rust PJRT runtime, validate every
//!    result against pure-Rust oracles, and measure request throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{figure7, summarize_kernel};
use multistride::runtime::{oracle, ArtifactRegistry, Runtime};
use multistride::util::Rng;

fn main() -> multistride::Result<()> {
    let machine = coffee_lake();
    let budget = 24 * 1024 * 1024u64;
    println!("=== stage 1: multi-striding pipeline on simulated {} ===\n", machine.name);

    let mut headline = Vec::new();
    for kernel in ["mxv", "bicg", "conv", "jacobi2d"] {
        let s = summarize_kernel(machine, kernel, budget, 10);
        println!(
            "{kernel:>9}: best multi-strided s={} p={} -> {:.2} GiB/s  ({:.2}x over best single-strided)",
            s.best_multi.config.stride_unroll,
            s.best_multi.config.portion_unroll,
            s.best_multi.throughput_gib,
            s.multi_over_single()
        );
        headline.push((kernel, s.multi_over_single()));
        for row in figure7(machine, kernel, budget, 10) {
            println!(
                "{:>9}  vs {:<24} {:>6.2} GiB/s -> speedup {:.2}x",
                "",
                row.reference.label(),
                row.reference_gib,
                row.speedup()
            );
        }
    }

    println!("\n=== stage 2: numeric execution of the same kernels via PJRT ===\n");
    let reg = ArtifactRegistry::new(ArtifactRegistry::default_dir());
    if reg.list().is_empty() {
        println!("no artifacts found in {:?} — run `make artifacts` first.", reg.dir());
        println!("stage 1 completed; stage 2 skipped.");
        return Ok(());
    }
    let mut rt = Runtime::new()?;
    println!("PJRT platform: {}", rt.platform());
    for name in reg.list() {
        rt.load(&name, &reg.path_for(&name))?;
    }

    let mut rng = Rng::new(0xE2E);
    let mut rand_vec = |len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.f64() as f32 - 0.5).collect()
    };

    // mxv — also measure request throughput over a batch of executions.
    let (m, n) = (64usize, 128usize);
    let a = rand_vec(m * n);
    let x = rand_vec(n);
    let want = oracle::mxv(&a, &x, m, n);
    let reps = 200u32;
    let t0 = Instant::now();
    let mut got = Vec::new();
    for _ in 0..reps {
        got = rt.execute_f32("mxv", &[(&a, &[m as i64, n as i64]), (&x, &[n as i64])])?[0].clone();
    }
    let secs = t0.elapsed().as_secs_f64();
    let err = oracle::max_rel_err(&got, &want);
    println!(
        "mxv artifact: {reps} executions in {:.3} s ({:.0} req/s), max rel err {err:.2e}",
        secs,
        reps as f64 / secs
    );
    multistride::ensure!(err < 1e-3, "mxv numeric mismatch");

    // bicg + conv + jacobi2d numeric validation.
    let r = rand_vec(m);
    let p = rand_vec(n);
    let out = rt.execute_f32(
        "bicg",
        &[(&a, &[m as i64, n as i64]), (&r, &[m as i64]), (&p, &[n as i64])],
    )?;
    let (s_want, q_want) = oracle::bicg(&a, &r, &p, m, n);
    multistride::ensure!(oracle::max_rel_err(&out[0], &s_want) < 1e-3, "bicg s mismatch");
    multistride::ensure!(oracle::max_rel_err(&out[1], &q_want) < 1e-3, "bicg q mismatch");
    println!("bicg artifact: OK");

    let (h, w) = (34usize, 66usize);
    let img = rand_vec(h * w);
    let wts = rand_vec(9);
    let got = &rt.execute_f32("conv", &[(&img, &[h as i64, w as i64]), (&wts, &[3, 3])])?[0];
    let mut w9 = [0f32; 9];
    w9.copy_from_slice(&wts);
    multistride::ensure!(
        oracle::max_rel_err(got, &oracle::conv3x3(&img, &w9, h, w)) < 1e-3,
        "conv mismatch"
    );
    println!("conv artifact: OK");

    let (h, w) = (32usize, 64usize);
    let aj = rand_vec(h * w);
    let got = &rt.execute_f32("jacobi2d", &[(&aj, &[h as i64, w as i64])])?[0];
    multistride::ensure!(
        oracle::max_rel_err(got, &oracle::jacobi2d(&aj, h, w)) < 1e-3,
        "jacobi2d mismatch"
    );
    println!("jacobi2d artifact: OK");

    println!("\n=== e2e summary ===");
    for (k, gain) in &headline {
        println!("{k:>9}: multi-striding speedup {gain:.2}x (simulated)");
    }
    println!("all PJRT-executed kernels numerically validated against oracles.");
    println!("e2e pipeline OK");
    Ok(())
}
