//! Native probe: run *real* single- vs multi-strided memory sweeps on the
//! host CPU. Whatever machine executes this, its actual hardware prefetcher
//! sees the paper's access patterns — a live cross-check of the simulated
//! effect (the host prefetcher cannot be MSR-toggled from user space, which
//! is why the simulator stays the primary vehicle).
//!
//! ```sh
//! cargo run --release --example native_probe [-- <buffer MiB>]
//! ```

use multistride::native::NativeProbe;

fn main() {
    let mib: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(256);
    let probe = NativeProbe { bytes: mib * 1024 * 1024, reps: 5 };
    println!("host probe: {} MiB buffer, median of {} reps\n", mib, probe.reps);
    println!(
        "{:>8} | {:>11} {:>11} {:>11}",
        "strides", "read GiB/s", "write GiB/s", "copy GiB/s"
    );
    let mut base = None;
    for p in probe.run(&[1, 2, 4, 8, 16, 32]) {
        println!(
            "{:>8} | {:>11.2} {:>11.2} {:>11.2}",
            p.strides, p.read_gib_s, p.write_gib_s, p.copy_gib_s
        );
        if p.strides == 1 {
            base = Some(p);
        }
    }
    if let Some(b) = base {
        println!(
            "\n(read gain of the best multi-strided configuration over single-strided\n\
             indicates how much this host's prefetcher benefits from multi-striding;\n\
             single-strided baseline: {:.2} GiB/s)",
            b.read_gib_s
        );
    }
}
