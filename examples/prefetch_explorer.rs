//! Prefetch explorer: the §4 micro-benchmark analysis in one binary —
//! throughput, stall cycles, hit ratios and streamer statistics for every
//! stride count, with the prefetcher MSR-style switch flipped both ways —
//! followed by the tuner acting on that analysis: instead of merely
//! *enumerating* the variant space, it **selects** from it (successive
//! halving with the simulator as cost model) and serves the second
//! request from the persistent plan cache.
//!
//! ```sh
//! cargo run --release --example prefetch_explorer [-- <machine>]
//! ```

use multistride::config::{MachinePreset, ScaleConfig};
use multistride::coordinator::experiments::{run_micro, EngineCache, MICRO_STRIDES};
use multistride::kernels::micro::MicroOp;
use multistride::report::figures::render_search_trace;
use multistride::tune::{PlanCache, Tuner};

fn main() {
    let machine = std::env::args()
        .nth(1)
        .and_then(|n| MachinePreset::from_name(&n))
        .unwrap_or(MachinePreset::CoffeeLake)
        .config();
    let bytes = ScaleConfig::default().micro_bytes;
    println!(
        "machine: {} ({:.1} GHz, model roofline {:.2} GiB/s)\narray: {} MiB\n",
        machine.name,
        machine.freq_ghz,
        machine.model_peak_gib(),
        bytes >> 20
    );

    println!(
        "{:>8} {:>4} | {:>9} | {:>10} {:>10} {:>10} | {:>6} {:>6} {:>6} | {:>8} {:>9}",
        "strides", "pf", "GiB/s", "stalls(M)", "L2miss(M)", "L3miss(M)", "L1hit", "L2hit", "L3hit",
        "streams", "prefetches"
    );
    for prefetch in [true, false] {
        for &s in &MICRO_STRIDES {
            let p = run_micro(machine, MicroOp::LoadAligned, s, bytes, prefetch, false);
            let c = &p.result.counters;
            println!(
                "{:>8} {:>4} | {:>9.2} | {:>10.1} {:>10.1} {:>10.1} | {:>6.3} {:>6.3} {:>6.3} | {:>8} {:>9}",
                s,
                if prefetch { "on" } else { "off" },
                p.throughput_gib,
                c.stalls_total as f64 / 1e6,
                c.stalls_l2_miss as f64 / 1e6,
                c.stalls_l3_miss as f64 / 1e6,
                p.result.l1.hit_ratio(),
                p.result.l2.hit_ratio(),
                p.result.l3.hit_ratio(),
                p.result.streamer.streams_allocated,
                p.result.streamer.prefetches_issued,
            );
        }
        println!();
    }
    println!("reading: multi-striding raises GiB/s and L2/L3 hit ratios and cuts stalls");
    println!("only while the prefetcher is on — the paper's central causal claim.");

    // Selection, not just enumeration: let the tuner pick mxv's variant
    // with the simulator as cost model, then serve the plan from cache.
    let budget = 8 * 1024 * 1024u64;
    let dir = std::env::temp_dir()
        .join(format!("multistride_explorer_plans_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache = PlanCache::new(&dir);
    let tuner = Tuner::new(machine, budget);
    let mut engines = EngineCache::new();

    let cold = tuner.tune(&mut engines, &cache, "mxv", false).expect("tune mxv");
    println!(
        "\ntuned mxv at {} MiB: chose s={} p={} -> {:.2} GiB/s predicted \
         ({} probe + {} full simulations, {:.1} M simulated accesses)",
        budget >> 20,
        cold.plan.config.stride_unroll,
        cold.plan.config.portion_unroll,
        cold.plan.predicted_gib,
        cold.plan.probe_runs,
        cold.plan.full_runs,
        cold.plan.search_sim_accesses as f64 / 1e6
    );
    print!("{}", render_search_trace("mxv", &cold.steps));

    let hit = tuner.tune(&mut engines, &cache, "mxv", false).expect("tune mxv again");
    println!(
        "second request: cache hit = {}, identical plan = {} (zero simulations)",
        hit.cache_hit,
        hit.plan.serialize() == cold.plan.serialize()
    );
    std::fs::remove_dir_all(&dir).ok();
}
