//! Golden determinism: the refactored pipeline engine must reproduce the
//! seed's monolithic engine **bit-identically** — every counter, every
//! cache/DRAM/TLB/WC/streamer statistic — on a fixed grid of
//! (workload, striding, prefetch, machine) configurations.
//!
//! The `reference` module below is the pre-refactor `sim/engine.rs` step
//! logic preserved verbatim (trimmed to the paths `run` exercises), built
//! on the same public `mem`/`prefetch`/`trace` models. Keeping it as an
//! executable oracle proves bit-identity by construction instead of
//! trusting hand-recorded counter values.

use multistride::config::{cascade_lake, coffee_lake, zen2, MachineConfig};
use multistride::kernels::library::kernel_by_name;
use multistride::kernels::micro::{MicroBench, MicroOp};
use multistride::sim::{Engine, EngineConfig, RunResult};
use multistride::trace::KernelTrace;
use multistride::transform::{transform, StridingConfig};

/// The seed engine, preserved as the golden oracle.
mod reference {
    use std::collections::{HashMap, VecDeque};
    use std::hash::{BuildHasherDefault, Hasher};

    use multistride::mem::addr;
    use multistride::mem::dram::DramOp;
    use multistride::mem::{Cache, Dram, Tlb, WriteCombineBuffer};
    use multistride::prefetch::{DcuNextLine, IpStride, Observation, PrefetchReq, Streamer};
    use multistride::sim::{Counters, EngineConfig, RunResult};
    use multistride::trace::{Access, Op};

    const TICKS: u64 = 4;

    #[derive(Default)]
    pub struct LineHasher(u64);

    impl Hasher for LineHasher {
        #[inline]
        fn finish(&self) -> u64 {
            self.0
        }
        fn write(&mut self, bytes: &[u8]) {
            for &b in bytes {
                self.0 = (self.0 ^ b as u64).wrapping_mul(0x9e3779b97f4a7c15);
            }
        }
        #[inline]
        fn write_u64(&mut self, v: u64) {
            let h = v.wrapping_mul(0x9e3779b97f4a7c15);
            self.0 = h ^ (h >> 29);
        }
    }

    type LineMap<V> = HashMap<u64, V, BuildHasherDefault<LineHasher>>;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum FillDest {
        Demand,
        PrefetchL2,
        PrefetchL1,
    }

    #[derive(Debug, Clone, Copy)]
    struct Fill {
        complete_ticks: u64,
        dest: FillDest,
        #[allow(dead_code)]
        stream: u32,
        dirty: bool,
        demanded: bool,
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
    enum Depth {
        L1Hit,
        L2Hit,
        L3Hit,
        Dram,
    }

    pub struct ReferenceEngine {
        cfg: EngineConfig,
        l1: Cache,
        l2: Cache,
        l3: Cache,
        tlb: Tlb,
        dram: Dram,
        wc: WriteCombineBuffer,
        streamer: Streamer,
        dcu: DcuNextLine,
        ipstride: IpStride,
        inflight: LineMap<Fill>,
        lfb: Vec<u64>,
        stream_outstanding: Vec<Vec<u64>>,
        retire_ring: VecDeque<u64>,
        issue_ticks_cursor: u64,
        issue_cost: u64,
        last_retire: u64,
        counters: Counters,
        pf_scratch: Vec<PrefetchReq>,
        sweep_counter: u32,
        outstanding_clean_counter: u32,
    }

    impl ReferenceEngine {
        pub fn new(cfg: EngineConfig) -> Self {
            let m = &cfg.machine;
            let mut tlb_cfg = m.tlb;
            tlb_cfg.huge_pages = cfg.huge_pages;
            let table = cfg.prefetch.streamer.table_size as usize;
            Self {
                l1: Cache::new(m.l1),
                l2: Cache::new(m.l2),
                l3: Cache::new(m.l3),
                tlb: Tlb::new(tlb_cfg),
                dram: Dram::new(m.dram),
                wc: WriteCombineBuffer::new(m.wc),
                streamer: Streamer::new(cfg.prefetch.streamer),
                dcu: DcuNextLine::new(cfg.prefetch.dcu),
                ipstride: IpStride::new(cfg.prefetch.ipstride),
                inflight: LineMap::with_capacity_and_hasher(1024, Default::default()),
                lfb: Vec::with_capacity(m.lfb_entries as usize + 1),
                stream_outstanding: vec![Vec::new(); table],
                retire_ring: VecDeque::with_capacity(m.window_accesses as usize + 1),
                issue_ticks_cursor: 0,
                issue_cost: TICKS / m.issue_per_cycle as u64,
                last_retire: 0,
                counters: Counters::default(),
                pf_scratch: Vec::with_capacity(64),
                sweep_counter: 0,
                outstanding_clean_counter: 0,
                cfg,
            }
        }

        pub fn run(&mut self, trace: impl IntoIterator<Item = Access>) -> RunResult {
            for acc in trace {
                self.step(acc);
            }
            self.fence();
            self.result()
        }

        fn step(&mut self, acc: Access) {
            let window = self.cfg.machine.window_accesses as usize;
            let mut t_issue = self.issue_ticks_cursor;
            if self.retire_ring.len() >= window {
                let gate = self.retire_ring[self.retire_ring.len() - window];
                if gate > t_issue {
                    t_issue = gate;
                }
            }

            let tlb_pen = self.tlb.translate(acc.addr);
            self.counters.tlb_cycles += tlb_pen;
            let t_ready_base = t_issue + tlb_pen * TICKS;

            let (data_ready, depth) = if acc.op == Op::StoreNt {
                self.step_nt_store(acc, t_ready_base)
            } else {
                self.step_cached(acc, t_ready_base)
            };

            self.counters.accesses += 1;
            if acc.op.is_store() {
                self.counters.bytes_written += acc.size as u64;
            } else {
                self.counters.bytes_read += acc.size as u64;
            }

            let retire = data_ready.max(self.last_retire);
            let gap = retire.saturating_sub(self.last_retire);
            let busy = self.issue_cost;
            if gap > busy {
                let stall = (gap - busy) / TICKS;
                self.counters.stalls_total += stall;
                self.counters.stalls_mem_any += stall;
                match depth {
                    Depth::L1Hit => {}
                    Depth::L2Hit => self.counters.stalls_l1d_miss += stall,
                    Depth::L3Hit => {
                        self.counters.stalls_l1d_miss += stall;
                        self.counters.stalls_l2_miss += stall;
                    }
                    Depth::Dram => {
                        self.counters.stalls_l1d_miss += stall;
                        self.counters.stalls_l2_miss += stall;
                        self.counters.stalls_l3_miss += stall;
                    }
                }
            }
            self.last_retire = retire;
            self.retire_ring.push_back(retire);
            if self.retire_ring.len() > window {
                self.retire_ring.pop_front();
            }
            self.issue_ticks_cursor = t_issue + self.issue_cost;

            self.sweep_counter += 1;
            if self.sweep_counter >= 512 {
                self.sweep_counter = 0;
                self.sweep_completed(self.last_retire);
            }
        }

        fn step_cached(&mut self, acc: Access, t: u64) -> (u64, Depth) {
            let m = self.cfg.machine;
            let (first, last) = addr::lines_touched(acc.addr, acc.size);
            let is_store = acc.op.is_store();
            let mut ready = t + m.l1_lat * TICKS;
            let mut depth = Depth::L1Hit;

            let mut line = first;
            loop {
                let (r, d) = self.touch_line(line, acc.ip, is_store, t);
                if r > ready {
                    ready = r;
                }
                if d > depth {
                    depth = d;
                }
                if line == last {
                    break;
                }
                line += 1;
            }
            (ready, depth)
        }

        fn touch_line(&mut self, line: u64, ip: u32, is_store: bool, t: u64) -> (u64, Depth) {
            let m = self.cfg.machine;
            let pf = self.cfg.prefetch;

            if let Some(f) = self.inflight.get(&line).copied() {
                if f.complete_ticks <= t {
                    self.inflight.remove(&line);
                    if f.dest != FillDest::PrefetchL2 {
                        self.install_fill(line, f);
                    }
                }
            }

            if self.l1.demand_lookup(line) {
                if is_store {
                    self.l1.mark_dirty(line);
                }
                if pf.enabled {
                    self.observe_l1(line, ip, false, is_store, t);
                }
                return (t + m.l1_lat * TICKS, Depth::L1Hit);
            }
            if pf.enabled {
                self.observe_l1(line, ip, true, is_store, t);
            }

            if let Some(f) = self.inflight.get_mut(&line) {
                let complete = f.complete_ticks;
                let dest = f.dest;
                let already_demanded = f.demanded;
                f.dirty |= is_store;
                f.demanded = true;
                self.counters.prefetch_merges += 1;
                if already_demanded {
                    self.l1.stats.demand_hits += 1;
                    self.l1.stats.demand_misses -= 1;
                    return (complete.max(t + m.l1_lat * TICKS), Depth::L1Hit);
                }
                return match dest {
                    FillDest::Demand | FillDest::PrefetchL1 => {
                        self.l1.stats.demand_hits += 1;
                        self.l1.stats.demand_misses -= 1;
                        (complete.max(t + m.l1_lat * TICKS), Depth::L1Hit)
                    }
                    FillDest::PrefetchL2 => {
                        self.l2.stats.demand_misses += 1;
                        self.l3.stats.demand_misses += 1;
                        if is_store {
                            self.l2.mark_dirty(line);
                        }
                        self.observe_l2(line, is_store, false, t);
                        (complete.max(t + m.l2_lat * TICKS), Depth::Dram)
                    }
                };
            }

            if self.l2.demand_lookup(line) {
                self.observe_l2(line, is_store, true, t);
                self.fill_l1(line, is_store);
                return (t + m.l2_lat * TICKS, Depth::L2Hit);
            }
            self.observe_l2(line, is_store, false, t);

            if self.l3.demand_lookup(line) {
                self.fill_l2(line, false, false);
                self.fill_l1(line, is_store);
                return (t + m.l3_lat * TICKS, Depth::L3Hit);
            }

            let mut t_eff = t;
            if self.lfb.len() >= m.lfb_entries as usize {
                let (idx, &earliest) =
                    self.lfb.iter().enumerate().min_by_key(|(_, &c)| c).expect("lfb non-empty");
                self.lfb.swap_remove(idx);
                if earliest > t_eff {
                    t_eff = earliest;
                }
            }
            let complete_cycles = self.dram.access(t_eff / TICKS, line, DramOp::Read);
            let complete = complete_cycles * TICKS + m.l3_lat * TICKS / 2;
            self.lfb.push(complete);
            self.counters.dram_demand_lines += 1;
            self.inflight.insert(
                line,
                Fill {
                    complete_ticks: complete,
                    dest: FillDest::Demand,
                    stream: u32::MAX,
                    dirty: is_store,
                    demanded: true,
                },
            );
            (complete, Depth::Dram)
        }

        fn observe_l1(&mut self, line: u64, ip: u32, miss: bool, store: bool, t: u64) {
            let pf = self.cfg.prefetch;
            if !pf.dcu_enabled && !pf.ipstride_enabled {
                return;
            }
            let obs = Observation { line, ip, miss, store };
            self.pf_scratch.clear();
            if pf.dcu_enabled {
                self.dcu.observe(obs, &mut self.pf_scratch);
            }
            if pf.ipstride_enabled {
                self.ipstride.observe(obs, &mut self.pf_scratch);
            }
            let reqs = std::mem::take(&mut self.pf_scratch);
            for r in &reqs {
                self.issue_prefetch(*r, t);
            }
            self.pf_scratch = reqs;
        }

        fn observe_l2(&mut self, line: u64, store: bool, l2_hit: bool, t: u64) {
            let pf = self.cfg.prefetch;
            if !pf.enabled {
                return;
            }
            self.pf_scratch.clear();
            if pf.streamer_enabled {
                self.outstanding_clean_counter += 1;
                if self.outstanding_clean_counter >= 32 {
                    self.outstanding_clean_counter = 0;
                    for s in &mut self.stream_outstanding {
                        s.retain(|&c| c > t);
                    }
                }
                let outstanding = &self.stream_outstanding;
                let obs = Observation { line, ip: 0, miss: true, store };
                self.streamer.observe(
                    obs,
                    |slot| {
                        outstanding
                            .get(slot as usize)
                            .map_or(0, |v| v.iter().filter(|&&c| c > t).count() as u32)
                    },
                    &mut self.pf_scratch,
                );
            }
            if pf.adjacent_enabled && !l2_hit {
                let pair = line ^ 1;
                self.pf_scratch.push(PrefetchReq { line: pair, stream: u32::MAX, to_l1: false });
            }
            let reqs = std::mem::take(&mut self.pf_scratch);
            for r in &reqs {
                self.issue_prefetch(*r, t);
            }
            self.pf_scratch = reqs;
        }

        fn issue_prefetch(&mut self, req: PrefetchReq, t: u64) {
            let m = self.cfg.machine;
            let line = req.line;
            if self.inflight.contains_key(&line) {
                return;
            }
            if req.to_l1 {
                if self.l1.contains(line) {
                    return;
                }
                let complete = if self.l2.contains(line) {
                    t + m.l2_lat * TICKS
                } else if self.l3.contains(line) {
                    t + m.l3_lat * TICKS
                } else {
                    self.dram.access(t / TICKS, line, DramOp::Read) * TICKS
                };
                self.counters.prefetch_lines += 1;
                self.inflight.insert(
                    line,
                    Fill {
                        complete_ticks: complete,
                        dest: FillDest::PrefetchL1,
                        stream: req.stream,
                        dirty: false,
                        demanded: false,
                    },
                );
                return;
            }
            if self.l2.contains(line) {
                return;
            }
            if self.l3.contains(line) {
                self.fill_l2(line, true, false);
                return;
            }
            let complete = self.dram.access(t / TICKS, line, DramOp::Read) * TICKS;
            self.counters.prefetch_lines += 1;
            if let Some(slot) = self.stream_outstanding.get_mut(req.stream as usize) {
                slot.push(complete);
            }
            self.fill_l3_prefetch(line);
            self.fill_l2(line, true, false);
            self.inflight.insert(
                line,
                Fill {
                    complete_ticks: complete,
                    dest: FillDest::PrefetchL2,
                    stream: req.stream,
                    dirty: false,
                    demanded: false,
                },
            );
        }

        fn sweep_completed(&mut self, t: u64) {
            let mut landed: Vec<(u64, Fill)> = Vec::new();
            self.inflight.retain(|&line, f| {
                if f.complete_ticks <= t {
                    landed.push((line, *f));
                    false
                } else {
                    true
                }
            });
            for (line, f) in landed {
                if f.dest != FillDest::PrefetchL2 {
                    self.install_fill(line, f);
                }
            }
        }

        fn install_fill(&mut self, line: u64, f: Fill) {
            match f.dest {
                FillDest::Demand => {
                    self.fill_l3(line);
                    self.fill_l2(line, false, false);
                    self.fill_l1(line, f.dirty);
                }
                FillDest::PrefetchL2 => {
                    self.fill_l3_prefetch(line);
                    self.fill_l2(line, true, f.dirty);
                }
                FillDest::PrefetchL1 => {
                    self.fill_l2(line, true, false);
                    self.fill_l1(line, f.dirty);
                }
            }
        }

        fn fill_l1(&mut self, line: u64, dirty: bool) {
            if let Some(ev) = self.l1.insert(line, false, dirty) {
                if ev.dirty {
                    self.l2.mark_dirty(ev.line);
                }
            }
        }

        fn fill_l2(&mut self, line: u64, prefetch: bool, dirty: bool) {
            if let Some(ev) = self.l2.insert(line, prefetch, dirty) {
                if ev.dirty {
                    self.l3.mark_dirty(ev.line);
                }
            }
        }

        fn fill_l3(&mut self, line: u64) {
            self.fill_l3_inner(line, false);
        }

        fn fill_l3_prefetch(&mut self, line: u64) {
            self.fill_l3_inner(line, true);
        }

        fn fill_l3_inner(&mut self, line: u64, prefetch: bool) {
            if let Some(ev) = self.l3.insert(line, prefetch, false) {
                let mut dirty = ev.dirty;
                dirty |= self.l1.invalidate(ev.line);
                dirty |= self.l2.invalidate(ev.line);
                if dirty {
                    self.dram.access(self.last_retire / TICKS, ev.line, DramOp::WriteLine);
                }
            }
        }

        fn step_nt_store(&mut self, acc: Access, t: u64) -> (u64, Depth) {
            let m = self.cfg.machine;
            let line = addr::line_of(acc.addr);
            if self.l1.contains(line) {
                self.l1.invalidate(line);
            }
            if self.l2.contains(line) {
                self.l2.invalidate(line);
            }
            if self.l3.contains(line) {
                self.l3.invalidate(line);
            }
            if let Some(flush) = self.wc.store(t / TICKS, acc.addr, acc.size) {
                let op = if flush.full { DramOp::WriteLine } else { DramOp::WritePartial };
                self.dram.access(flush.at, flush.line, op);
            }
            let backlog_ticks = (self.dram.next_free() * TICKS).saturating_sub(t);
            let allowed = 64 * TICKS * m.wc.entries as u64;
            let ready =
                if backlog_ticks > allowed { t + (backlog_ticks - allowed) } else { t } + TICKS;
            (ready, if backlog_ticks > allowed { Depth::Dram } else { Depth::L1Hit })
        }

        fn fence(&mut self) {
            let t = self.last_retire.max(self.issue_ticks_cursor);
            let mut done = t;
            self.sweep_completed(u64::MAX);
            for flush in self.wc.drain(t / TICKS) {
                let op = if flush.full { DramOp::WriteLine } else { DramOp::WritePartial };
                let c = self.dram.access(flush.at, flush.line, op) * TICKS;
                done = done.max(c);
            }
            for f in self.inflight.values() {
                if f.dest == FillDest::Demand {
                    done = done.max(f.complete_ticks);
                }
            }
            done = done.max(self.dram.next_free() * TICKS);
            if done > self.last_retire {
                let stall = (done - self.last_retire) / TICKS;
                self.counters.stalls_total += stall;
                self.counters.stalls_mem_any += stall;
            }
            self.last_retire = done;
        }

        fn result(&self) -> RunResult {
            let mut c = self.counters;
            c.cycles = self.last_retire / TICKS;
            RunResult {
                counters: c,
                l1: self.l1.stats,
                l2: self.l2.stats,
                l3: self.l3.stats,
                dram: self.dram.stats,
                wc: self.wc.stats,
                tlb: self.tlb.stats,
                streamer: self.streamer.stats,
                freq_ghz: self.cfg.machine.freq_ghz,
            }
        }
    }
}

use reference::ReferenceEngine;

const MIB: u64 = 1 << 20;

/// Assert two results agree on every counter and statistic.
fn assert_golden(label: &str, got: &RunResult, want: &RunResult) {
    assert_eq!(got.counters, want.counters, "{label}: counters diverged");
    assert_eq!(got.l1, want.l1, "{label}: L1 stats diverged");
    assert_eq!(got.l2, want.l2, "{label}: L2 stats diverged");
    assert_eq!(got.l3, want.l3, "{label}: L3 stats diverged");
    assert_eq!(got.dram, want.dram, "{label}: DRAM stats diverged");
    assert_eq!(got.wc, want.wc, "{label}: WC stats diverged");
    assert_eq!(got.tlb, want.tlb, "{label}: TLB stats diverged");
    assert_eq!(got.streamer, want.streamer, "{label}: streamer stats diverged");
}

fn check_micro(
    label: &str,
    machine: MachineConfig,
    op: MicroOp,
    strides: u32,
    prefetch: bool,
    interleaved: bool,
) {
    let mut bench = MicroBench::new(op, strides, 2 * MIB);
    if interleaved {
        bench = bench.interleaved();
    }
    let cfg = EngineConfig::new(machine).with_prefetch(prefetch).with_huge_pages(true);
    let got = Engine::new(cfg).run(bench.trace());
    let want = ReferenceEngine::new(cfg).run(bench.trace());
    assert_golden(label, &got, &want);
}

fn check_kernel(label: &str, machine: MachineConfig, kernel: &str, s: u32, p: u32, prefetch: bool) {
    let pk = kernel_by_name(kernel, 2 * MIB).expect("library kernel");
    let t = transform(&pk.spec, StridingConfig::new(s, p)).expect("feasible config");
    let kt = KernelTrace::new(t);
    let cfg = EngineConfig::new(machine).with_prefetch(prefetch).with_huge_pages(false);
    let got = Engine::new(cfg).run(kt.iter());
    let want = ReferenceEngine::new(cfg).run(kt.iter());
    assert_golden(label, &got, &want);
}

#[test]
fn micro_counters_match_seed_engine() {
    let m = coffee_lake();
    for (op, strides, pf, inter) in [
        (MicroOp::LoadAligned, 1, true, false),
        (MicroOp::LoadAligned, 16, true, false),
        (MicroOp::LoadAligned, 16, false, false),
        (MicroOp::LoadUnaligned, 4, true, false),
        (MicroOp::StoreAligned, 8, true, false),
        (MicroOp::StoreNt, 16, true, false),
        (MicroOp::StoreNt, 16, true, true),
        (MicroOp::CopyAligned, 8, true, false),
    ] {
        check_micro(
            &format!("{op:?} s={strides} pf={pf} inter={inter}"),
            m,
            op,
            strides,
            pf,
            inter,
        );
    }
}

#[test]
fn micro_counters_match_on_all_machines() {
    for m in [coffee_lake(), cascade_lake(), zen2()] {
        for pf in [true, false] {
            check_micro(&format!("{} pf={pf}", m.name), m, MicroOp::LoadAligned, 8, pf, false);
        }
    }
}

#[test]
fn micro_counters_match_with_dcu_engines_enabled() {
    // The DCU next-line + IP-stride paths are off in the calibrated
    // presets; force them on so the L1-engine plumbing is golden-checked.
    let mut m = coffee_lake();
    m.prefetch.dcu_enabled = true;
    m.prefetch.ipstride_enabled = true;
    check_micro("dcu+ipstride", m, MicroOp::LoadAligned, 4, true, false);
}

#[test]
fn kernel_counters_match_seed_engine() {
    let m = coffee_lake();
    check_kernel("mxv s=4 p=2", m, "mxv", 4, 2, true);
    check_kernel("mxv s=2 p=2 pf=off", m, "mxv", 2, 2, false);
    check_kernel("bicg s=2 p=2", m, "bicg", 2, 2, true);
    check_kernel("jacobi2d s=2 p=1", m, "jacobi2d", 2, 1, true);
    check_kernel("writeback s=4 p=1", m, "writeback", 4, 1, true);
    check_kernel("mxv s=4 p=1 zen2", zen2(), "mxv", 4, 1, true);
}

#[test]
fn reused_engine_matches_seed_engine_across_a_sweep() {
    // The coordinator's reuse path (prepare between points) must stay on
    // the golden trajectory too, not just fresh constructions.
    let m = coffee_lake();
    let mut reused: Option<Engine> = None;
    for (strides, pf) in [(1u32, true), (8, true), (8, false), (32, true)] {
        let bench = MicroBench::new(MicroOp::LoadAligned, strides, 2 * MIB);
        let cfg = EngineConfig::new(m).with_prefetch(pf).with_huge_pages(true);
        match &mut reused {
            Some(e) => e.prepare(cfg),
            None => reused = Some(Engine::new(cfg)),
        }
        let got = reused.as_mut().expect("engine present").run(bench.trace());
        let want = ReferenceEngine::new(cfg).run(bench.trace());
        assert_golden(&format!("reuse s={strides} pf={pf}"), &got, &want);
    }
}
