//! End-to-end pins for the plan-serving daemon.
//!
//! The load-bearing contract is **byte identity**: the body of
//! `GET /plan` must be the exact bytes `repro tune` wrote to the plan
//! file for the same `(kernel, machine, budget, prefetch)` identity —
//! through the pool, off the disk, or tuned on demand. The plan
//! format's bit-identical serialize→parse→serialize round trip makes
//! this checkable with `assert_eq!` on raw bytes, and these tests check
//! it at both the library seam (`PlanService::plan_bytes`) and over a
//! real socket.
//!
//! Tuning here runs at a deliberately tiny 2 MiB budget so the searches
//! finish in test time; the identity triple math is budget-independent.

use std::sync::Arc;

use multistride::config::MachinePreset;
use multistride::coordinator::experiments::EngineCache;
use multistride::exec::ResultStore;
use multistride::serve::{
    Client, HttpServer, MissPolicy, PlanService, PlanSource, Policy, Request, ServerControl,
};
use multistride::tune::plan::budget_class;
use multistride::tune::{PlanCache, Tuner};

const BUDGET: u64 = 2 * 1024 * 1024;

fn tmp(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("multistride_serve_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Tune `kernel` into `plans` the way `repro tune` does, and return the
/// plan file's bytes.
fn tune_to_disk(plans: &PlanCache, kernel: &str) -> Vec<u8> {
    let cfg = MachinePreset::CoffeeLake.config();
    let tuner = Tuner::new(cfg, BUDGET);
    let store = ResultStore::ephemeral();
    let mut engines = EngineCache::new();
    let out = tuner.tune_on(&store, &mut engines, plans, kernel, false).expect("tune succeeds");
    assert!(!out.cache_hit, "fresh plans dir must search");
    let path = plans.path_for(kernel, cfg.name, true, budget_class(BUDGET));
    std::fs::read(&path).expect("tuner persisted the plan file")
}

#[test]
fn served_plan_bytes_are_identical_to_the_tuners() {
    let dir = tmp("identity");
    let plans = PlanCache::new(&dir);
    let file_bytes = tune_to_disk(&plans, "mxv");

    let service = PlanService::new(
        1 << 20,
        Policy::Lru,
        MissPolicy::NotFound,
        plans,
        ResultStore::ephemeral(),
    );
    let cold = service.plan_bytes("mxv", "coffee-lake", BUDGET, true).expect("plan resolves");
    assert_eq!(cold.source, PlanSource::Disk, "first serve reads through to disk");
    assert_eq!(*cold.bytes, file_bytes, "served bytes == the tuner's plan file");

    let warm = service.plan_bytes("mxv", "coffee-lake", BUDGET, true).expect("plan resolves");
    assert_eq!(warm.source, PlanSource::Pool, "second serve is a pool hit");
    assert_eq!(*warm.bytes, file_bytes);

    let s = service.stats();
    assert_eq!((s.pool.hits, s.pool.misses, s.disk_loads), (1, 1, 1));
    assert_eq!(s.tunes, 0, "an on-miss-404 service never tunes");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn http_surface_serves_plans_counters_stats_and_clean_errors() {
    let dir = tmp("http");
    let plans = PlanCache::new(&dir);
    let file_bytes = tune_to_disk(&plans, "mxv");

    let service = Arc::new(PlanService::new(
        1 << 20,
        Policy::Sieve,
        MissPolicy::NotFound,
        plans,
        ResultStore::ephemeral(),
    ));
    let server = HttpServer::bind(0).expect("bind port 0");
    let port = server.port();
    let ctl = ServerControl::new(None);
    let handler = {
        let service = service.clone();
        Arc::new(move |req: &Request| service.handle(req))
    };
    let srv_ctl = ctl.clone();
    let join = std::thread::spawn(move || server.serve(handler, srv_ctl));

    // One keep-alive connection carries the whole scripted session.
    let mut c = Client::connect(port).expect("connect");
    let (status, body) = c.get("/healthz").unwrap();
    assert_eq!((status, body.as_slice()), (200, b"ok\n".as_slice()));

    let plan_url = format!("/plan?kernel=mxv&machine=coffee-lake&budget={BUDGET}");
    let (status, cold) = c.get(&plan_url).unwrap();
    assert_eq!(status, 200);
    assert_eq!(cold, file_bytes, "cold HTTP serve == the tuner's plan file");
    let (status, warm) = c.get(&plan_url).unwrap();
    assert_eq!(status, 200);
    assert_eq!(warm, cold, "warm (pool) serve is byte-identical");

    let (status, counters) =
        c.get(&format!("/counters?kernel=mxv&machine=coffee-lake&budget={BUDGET}")).unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(counters).unwrap();
    for needle in ["kernel=mxv", "predicted_gib_s=", "l1_hit=", "budget_class="] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }

    let (status, stats) = c.get("/stats").unwrap();
    assert_eq!(status, 200);
    let line = String::from_utf8(stats).unwrap();
    assert!(line.starts_with("[serve] "), "got: {line}");
    assert!(line.contains("pool hits: 1"), "got: {line}");

    // Error grammar: every malformed or unresolvable request gets a
    // clean status, and the connection survives for the next request.
    for (url, want) in [
        ("/plan?kernel=mxv", 400),                                     // missing machine+budget
        (&*format!("/plan?kernel=mxv&machine=quantum&budget={BUDGET}"), 400), // unknown machine
        (&*format!("/plan?kernel=mxv&machine=coffee-lake&budget={BUDGET}&prefetch=banana"), 400),
        ("/plan?kernel=mxv&machine=coffee-lake&budget=lots", 400),     // non-numeric budget
        (&*format!("/plan?kernel=nope&machine=coffee-lake&budget={BUDGET}"), 404), // unknown kernel
        (&*format!("/plan?kernel=bicg&machine=coffee-lake&budget={BUDGET}"), 404), // untuned
        (&*format!("/plan?kernel=mxv&machine=coffee-lake&budget={BUDGET}&prefetch=off"), 404),
        ("/nope", 404),                                                // unknown route
    ] {
        let (status, _) = c.get(url).unwrap();
        assert_eq!(status, want, "for {url}");
    }

    // Drop the client first: the server's drain loop waits for active
    // connections, and an idle keep-alive one would pin it until the
    // read timeout.
    drop(c);
    ctl.request_stop();
    join.join().unwrap().unwrap();
    let s = service.stats();
    assert!(s.not_found >= 2, "miss-policy 404s are counted");
    assert!(s.bad_requests >= 3, "malformed requests are counted");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn thundering_herd_tunes_once_and_serves_identical_bytes() {
    let dir = tmp("herd");
    let service = Arc::new(PlanService::new(
        1 << 20,
        Policy::Clock,
        MissPolicy::Tune,
        PlanCache::new(&dir),
        ResultStore::ephemeral(),
    ));
    let threads: Vec<_> = (0..6)
        .map(|_| {
            let service = service.clone();
            std::thread::spawn(move || {
                service.plan_bytes("mxv", "coffee-lake", BUDGET, true).expect("herd request")
            })
        })
        .collect();
    let bodies: Vec<_> = threads.into_iter().map(|t| t.join().expect("no panic")).collect();
    for served in &bodies[1..] {
        assert_eq!(*served.bytes, *bodies[0].bytes, "every herd member sees the same plan");
    }
    let s = service.stats();
    assert_eq!(s.tunes, 1, "single-flight: the herd runs exactly one search");
    assert!(
        bodies.iter().filter(|b| b.source == PlanSource::Tuned).count() <= 2,
        "at most the winning flight (plus a rare racing revalidation) reports Tuned"
    );
    // The on-demand plan also landed on disk, exactly as `repro tune`
    // would have written it.
    let plans = PlanCache::new(&dir);
    let path = plans.path_for(
        "mxv",
        MachinePreset::CoffeeLake.config().name,
        true,
        budget_class(BUDGET),
    );
    let file_bytes = std::fs::read(&path).expect("on-demand tune persisted the plan");
    assert_eq!(*bodies[0].bytes, file_bytes, "served bytes == persisted plan file");
    std::fs::remove_dir_all(&dir).ok();
}
