//! Differential property test for the SoA cache (§Perf overhaul).
//!
//! `mem::Cache` stores its ways struct-of-arrays with validity folded into
//! a sentinel tag and an O(ways) Tree-PLRU victim walk. This test pins its
//! observable behavior — every return value, every statistic, the resident
//! set — against a deliberately naive array-of-structs reference model that
//! re-implements the pre-SoA semantics line for line (padded `Entry`
//! records, iterator-style victim picks, the same xorshift RNG), across
//! random insert/lookup/dirty/invalidate sequences and all three
//! replacement policies. The golden-determinism suite already pins the
//! *engine* bit-for-bit; this covers the cache surface directly, including
//! op interleavings (e.g. invalidate-then-refill) the engine rarely emits.

use multistride::mem::{Cache, CacheConfig, Replacement};
use multistride::util::proptest::{check, Config};
use multistride::util::Rng;

// ---- naive AoS reference model -----------------------------------------

#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    tag: u64,
    valid: bool,
    dirty: bool,
    prefetched: bool,
    referenced: bool,
    stamp: u64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct RefStats {
    demand_hits: u64,
    demand_misses: u64,
    prefetch_hits: u64,
    evictions: u64,
    dirty_evictions: u64,
    unused_prefetch_evictions: u64,
    prefetch_installs: u64,
}

/// The pre-SoA cache, kept as simple as possible: one `Entry` per way,
/// linear scans everywhere, the halving-walk PLRU pick.
struct RefCache {
    cfg: CacheConfig,
    set_mask: u64,
    n_slices: u64,
    shift: u32,
    entries: Vec<Entry>,
    clock: u64,
    rng: u64,
    stats: RefStats,
}

impl RefCache {
    fn new(cfg: CacheConfig) -> Self {
        let n_sets = cfg.n_sets();
        let sets_per_slice = n_sets & n_sets.wrapping_neg();
        Self {
            cfg,
            set_mask: sets_per_slice - 1,
            n_slices: n_sets / sets_per_slice,
            shift: sets_per_slice.trailing_zeros(),
            entries: vec![Entry::default(); (n_sets * cfg.ways as u64) as usize],
            clock: 0,
            rng: 0x9e3779b97f4a7c15,
            stats: RefStats::default(),
        }
    }

    fn set_range(&self, line: u64) -> std::ops::Range<usize> {
        let within = line & self.set_mask;
        let set = if self.n_slices == 1 {
            within
        } else {
            ((line >> self.shift) & 3) % self.n_slices * (self.set_mask + 1) + within
        };
        let base = set as usize * self.cfg.ways as usize;
        base..base + self.cfg.ways as usize
    }

    fn demand_lookup(&mut self, line: u64) -> bool {
        self.clock += 1;
        let clock = self.clock;
        for e in &mut self.entries[self.set_range(line)] {
            if e.valid && e.tag == line {
                e.stamp = clock;
                if e.prefetched && !e.referenced {
                    self.stats.prefetch_hits += 1;
                }
                e.referenced = true;
                self.stats.demand_hits += 1;
                return true;
            }
        }
        self.stats.demand_misses += 1;
        false
    }

    fn contains(&self, line: u64) -> bool {
        self.entries[self.set_range(line)].iter().any(|e| e.valid && e.tag == line)
    }

    fn mark_dirty(&mut self, line: u64) {
        for e in &mut self.entries[self.set_range(line)] {
            if e.valid && e.tag == line {
                e.dirty = true;
                return;
            }
        }
    }

    /// Returns `Some((victim_line, dirty, unused_prefetch))` on eviction.
    fn insert(&mut self, line: u64, prefetch: bool, dirty: bool) -> Option<(u64, bool, bool)> {
        self.clock += 1;
        let clock = self.clock;
        if prefetch {
            self.stats.prefetch_installs += 1;
        }
        let range = self.set_range(line);
        for e in &mut self.entries[range.clone()] {
            if e.valid && e.tag == line {
                e.stamp = clock;
                e.dirty |= dirty;
                if !prefetch {
                    e.referenced = true;
                }
                return None;
            }
        }
        for e in &mut self.entries[range.clone()] {
            if !e.valid {
                *e = Entry {
                    tag: line,
                    valid: true,
                    dirty,
                    prefetched: prefetch,
                    referenced: !prefetch,
                    stamp: clock,
                };
                return None;
            }
        }
        let victim_off = match self.cfg.replacement {
            Replacement::Lru => {
                let mut best = 0usize;
                let mut best_stamp = u64::MAX;
                for (i, e) in self.entries[range.clone()].iter().enumerate() {
                    if e.stamp < best_stamp {
                        best_stamp = e.stamp;
                        best = i;
                    }
                }
                best
            }
            Replacement::TreePlru => {
                // The seed's halving walk: descend into the half whose max
                // stamp is older (ties left), then take the older leaf.
                let ways = self.cfg.ways as usize;
                let slice = &self.entries[range.clone()];
                let (mut lo, mut hi) = (0usize, ways);
                while hi - lo > 1 {
                    let mid = (lo + hi) / 2;
                    let left_max = slice[lo..mid].iter().map(|e| e.stamp).max().unwrap();
                    let right_max = slice[mid..hi].iter().map(|e| e.stamp).max().unwrap();
                    if left_max <= right_max {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                let mut best = 0usize;
                let mut best_stamp = u64::MAX;
                for (i, e) in slice.iter().enumerate().take(hi).skip(lo) {
                    if e.stamp < best_stamp {
                        best_stamp = e.stamp;
                        best = i;
                    }
                }
                best
            }
            Replacement::Random => {
                self.rng ^= self.rng << 13;
                self.rng ^= self.rng >> 7;
                self.rng ^= self.rng << 17;
                (self.rng % self.cfg.ways as u64) as usize
            }
        };
        let idx = range.start + victim_off;
        let victim = self.entries[idx];
        self.stats.evictions += 1;
        if victim.dirty {
            self.stats.dirty_evictions += 1;
        }
        let unused = victim.prefetched && !victim.referenced;
        if unused {
            self.stats.unused_prefetch_evictions += 1;
        }
        self.entries[idx] = Entry {
            tag: line,
            valid: true,
            dirty,
            prefetched: prefetch,
            referenced: !prefetch,
            stamp: clock,
        };
        Some((victim.tag, victim.dirty, unused))
    }

    fn invalidate(&mut self, line: u64) -> bool {
        for e in &mut self.entries[self.set_range(line)] {
            if e.valid && e.tag == line {
                let dirty = e.dirty;
                e.valid = false;
                return dirty;
            }
        }
        false
    }

    fn resident_lines(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }
}

// ---- the differential driver --------------------------------------------

/// Geometries under test: tiny power-of-two sets, wider PLRU-friendly
/// associativity, and two non-power-of-two (sliced) set counts — including
/// an odd way count so the PLRU halving walk sees uneven halves.
const GEOMETRIES: [(u64, u32); 4] = [(512, 2), (2048, 8), (1536, 2), (1152, 3)];
const POLICIES: [Replacement; 3] = [Replacement::Lru, Replacement::TreePlru, Replacement::Random];

#[derive(Debug, Clone, Copy)]
struct Case {
    geometry: usize,
    policy: usize,
    seed: u64,
    ops: u32,
}

fn run_case(c: &Case) -> bool {
    let (size, ways) = GEOMETRIES[c.geometry];
    let cfg = CacheConfig::new(size, ways, POLICIES[c.policy]);
    let mut soa = Cache::new(cfg);
    let mut aos = RefCache::new(cfg);
    let mut rng = Rng::new(c.seed);
    // A small line universe (a few multiples of the set count) forces
    // aliasing, evictions and reinsertion of previously invalidated lines.
    let universe = cfg.n_sets() * ways as u64 * 3;
    for _ in 0..c.ops {
        let line = rng.below(universe);
        match rng.below(8) {
            0..=3 => {
                let prefetch = rng.below(3) == 0;
                let dirty = rng.below(3) == 0;
                let got = soa.insert(line, prefetch, dirty);
                let want = aos.insert(line, prefetch, dirty);
                let got = got.map(|e| (e.line, e.dirty, e.unused_prefetch));
                if got != want {
                    return false;
                }
            }
            4 | 5 => {
                if soa.demand_lookup(line) != aos.demand_lookup(line) {
                    return false;
                }
            }
            6 => {
                soa.mark_dirty(line);
                aos.mark_dirty(line);
            }
            _ => {
                if soa.invalidate(line) != aos.invalidate(line) {
                    return false;
                }
            }
        }
        if soa.contains(line) != aos.contains(line) {
            return false;
        }
    }
    // End-state agreement: statistics, residency, full-universe membership.
    let s = soa.stats;
    let got = RefStats {
        demand_hits: s.demand_hits,
        demand_misses: s.demand_misses,
        prefetch_hits: s.prefetch_hits,
        evictions: s.evictions,
        dirty_evictions: s.dirty_evictions,
        unused_prefetch_evictions: s.unused_prefetch_evictions,
        prefetch_installs: s.prefetch_installs,
    };
    if got != aos.stats {
        return false;
    }
    if soa.resident_lines() != aos.resident_lines() {
        return false;
    }
    (0..universe).all(|l| soa.contains(l) == aos.contains(l))
}

#[test]
fn soa_cache_matches_naive_reference_model() {
    check(
        Config { cases: 96, seed: 0x5CA1AB1E },
        |r, size| Case {
            geometry: r.below(GEOMETRIES.len() as u64) as usize,
            policy: r.below(POLICIES.len() as u64) as usize,
            seed: r.next_u64(),
            // Op count ramps with the size hint so shrinking finds small
            // counterexamples first.
            ops: 16 + size * 40,
        },
        run_case,
    );
}

/// `reset` must restore post-construction behavior exactly (including the
/// replacement RNG): a reset cache replays a fresh reference model.
#[test]
fn reset_cache_matches_fresh_reference_model() {
    let cfg = CacheConfig::new(1536, 2, Replacement::Random);
    let mut soa = Cache::new(cfg);
    let mut rng = Rng::new(0xD1FF);
    for _ in 0..4096 {
        soa.insert(rng.below(256), rng.below(2) == 0, rng.below(2) == 0);
    }
    soa.reset();
    assert_eq!(soa.resident_lines(), 0);
    assert_eq!(soa.stats, Default::default());
    let mut aos = RefCache::new(cfg);
    let mut rng = Rng::new(0xFEED);
    for _ in 0..4096 {
        let line = rng.below(256);
        let prefetch = rng.below(2) == 0;
        let got = soa.insert(line, prefetch, false).map(|e| (e.line, e.dirty, e.unused_prefetch));
        assert_eq!(got, aos.insert(line, prefetch, false), "replay diverged post-reset");
    }
}
