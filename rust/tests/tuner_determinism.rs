//! Tuner determinism wall + the pruned-search acceptance criterion.
//!
//! Same seed discipline as `golden_determinism`: every input is pinned
//! (the search itself uses no randomness), so
//!
//! * two cold searches of the same request — fresh caches, fresh or
//!   reused engines — must produce **byte-identical** plans;
//! * a cache hit must return the exact plan the cold search persisted;
//! * on every paper kernel, the pruned search must select the *same
//!   winner* as the exhaustive `variant_sweep` while running **strictly
//!   fewer full-budget simulations**, and the winner's predicted
//!   throughput must be bit-identical to the sweep's measurement.

use std::path::PathBuf;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::{self as exp, EngineCache};
use multistride::kernels::library::paper_kernels;
use multistride::tune::{search, PlanCache, SearchParams, Tuner, Verdict};

const MIB: u64 = 1 << 20;
/// Small but ≥ the smoke floor: probe and full rungs sit in the same
/// (cache-resident) regime at this scale, as they do beyond-L3 at the
/// default scale — see `tune::search::probe_budget`.
const BUDGET: u64 = 2 * MIB;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("multistride_tuner_det_{tag}_{}", std::process::id()))
}

#[test]
fn fresh_cold_searches_are_byte_identical_and_hits_serve_them_exactly() {
    let m = coffee_lake();
    let (d1, d2) = (tmp("a"), tmp("b"));
    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d2).ok();
    let (c1, c2) = (PlanCache::new(&d1), PlanCache::new(&d2));
    let tuner = Tuner::new(m, BUDGET);
    // One warm engine threaded through many searches on one side, fresh
    // engines per search on the other: reuse must not leak into plans.
    let mut warm = EngineCache::new();
    for kernel in ["mxv", "triad", "3mm", "jacobi1d"] {
        let a = tuner.tune(&mut warm, &c1, kernel, false).unwrap();
        let b = tuner.tune(&mut EngineCache::new(), &c2, kernel, false).unwrap();
        assert!(!a.cache_hit && !b.cache_hit);
        assert_eq!(
            a.plan.serialize(),
            b.plan.serialize(),
            "{kernel}: two fresh cold searches must be byte-identical"
        );
        let hit = tuner.tune(&mut warm, &c1, kernel, false).unwrap();
        assert!(hit.cache_hit, "{kernel}: second request must be a cache hit");
        assert!(hit.steps.is_empty(), "{kernel}: a hit runs no search");
        assert_eq!(
            hit.plan.serialize(),
            a.plan.serialize(),
            "{kernel}: the hit must return the exact plan the cold search produced"
        );
    }
    std::fs::remove_dir_all(&d1).ok();
    std::fs::remove_dir_all(&d2).ok();
}

#[test]
fn pruned_search_matches_exhaustive_winner_on_every_paper_kernel() {
    let m = coffee_lake();
    let params = SearchParams::default();
    for pk in paper_kernels(BUDGET) {
        // Exhaustive: the full variant family at full budget (what
        // `repro universe` simulates), winner by best_point.
        let points =
            exp::variant_sweep_for(m, BUDGET, params.portion, true, &[pk.name.clone()]);
        let best = exp::best_point(&points)
            .unwrap_or_else(|| panic!("{}: no feasible point", pk.name));
        let exhaustive_sims = points.iter().filter(|p| p.feasible).count();

        let out = search(&mut EngineCache::new(), m, &pk.name, BUDGET, true, &params)
            .unwrap_or_else(|e| panic!("{}: search failed: {e}", pk.name));

        assert_eq!(
            (out.plan.config.stride_unroll, out.plan.config.portion_unroll),
            (best.config.stride_unroll, best.config.portion_unroll),
            "{}: pruned search must select the exhaustive winner",
            pk.name
        );
        assert_eq!(
            out.plan.predicted_gib.to_bits(),
            best.throughput_gib.to_bits(),
            "{}: the winner's prediction IS the sweep's measurement",
            pk.name
        );
        assert!(
            (out.plan.full_runs as usize) < exhaustive_sims,
            "{}: {} full-budget sims must be strictly fewer than the exhaustive {}",
            pk.name,
            out.plan.full_runs,
            exhaustive_sims
        );
        // The trace accounts for every family member exactly once per rung
        // it visited, and names a single winner.
        assert_eq!(
            out.steps.iter().filter(|s| matches!(s.verdict, Verdict::Winner)).count(),
            1,
            "{}",
            pk.name
        );
        let visited: usize = out
            .steps
            .iter()
            .filter(|s| s.rung == 0)
            .count();
        assert_eq!(
            visited,
            points.len(),
            "{}: every family member is visible in the rung-0 trace (gated or probed)",
            pk.name
        );
    }
}

#[test]
fn force_reproduces_the_cached_plan_bit_for_bit() {
    let m = coffee_lake();
    let dir = tmp("force");
    std::fs::remove_dir_all(&dir).ok();
    let cache = PlanCache::new(&dir);
    let tuner = Tuner::new(m, BUDGET);
    let mut engines = EngineCache::new();
    let cold = tuner.tune(&mut engines, &cache, "mxv", false).unwrap();
    let forced = tuner.tune(&mut engines, &cache, "mxv", true).unwrap();
    assert!(!forced.cache_hit);
    assert_eq!(forced.plan.serialize(), cold.plan.serialize());
    // The persisted file equals the serialized plan byte-for-byte.
    let path = cache.path_for("mxv", m.name, true, cold.plan.budget_class);
    assert_eq!(std::fs::read_to_string(path).unwrap(), cold.plan.serialize());
    std::fs::remove_dir_all(&dir).ok();
}
