//! Integration wall for the dynamic fleet (`multistride::grid`):
//!
//! * a store populated by a coordinator + N workers is record-identical
//!   to a single-host cold run — the PR's byte-identity contract;
//! * a worker that vanishes mid-batch (the chaos `abandon_after` knob)
//!   loses no points and duplicates none;
//! * a worker that goes silent while holding a lease gets its batch
//!   requeued after `lease_ms`;
//! * a worker whose plan disagrees with the coordinator's is refused at
//!   the handshake instead of polluting the store.
//!
//! Everything runs on loopback with port 0 and `std::thread::scope`:
//! the coordinator drains in one scoped thread while workers (or a raw
//! misbehaving client) run in others.

use std::collections::HashMap;
use std::net::TcpStream;
use std::time::Duration;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::EngineCache;
use multistride::exec::format::encode_result_bin;
use multistride::exec::{simulate, ResultStore, SimPoint};
use multistride::grid::proto::{plan_fingerprint, read_frame, write_frame, Frame, PROTO_VERSION};
use multistride::grid::{run_worker, Coordinator, CoordinatorConfig, WorkerConfig};
use multistride::kernels::micro::MicroOp;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("multistride_fleet_{tag}_{}", std::process::id()))
}

/// A small all-unique plan: six micro points, one per stride count.
fn plan() -> Vec<SimPoint> {
    (1..=6u32)
        .map(|s| SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, s, 1 << 20, true, false))
        .collect()
}

/// Reference records from a plain single-host cold run: key → the exact
/// bytes `ResultStore::insert` would append for it.
fn single_host_records(points: &[SimPoint]) -> HashMap<u64, Vec<u8>> {
    let mut engines = EngineCache::new();
    points
        .iter()
        .map(|p| {
            let r = simulate(&mut engines, p).expect("micro point simulates");
            (p.key(), encode_result_bin(&r).to_vec())
        })
        .collect()
}

fn worker_cfg(batch: u32) -> WorkerConfig {
    WorkerConfig { batch, local_workers: 2, max_batches: None, abandon_after: None }
}

/// Tentpole acceptance: coordinator + 2 workers populate a store whose
/// per-key records are bit-identical to a single-host cold run, and a
/// fresh process over that store resolves the whole plan from disk.
#[test]
fn fleet_populated_store_is_record_identical_to_single_host() {
    let dir = tmp("identity");
    std::fs::remove_dir_all(&dir).ok();
    let points = plan();
    let reference = single_host_records(&points);

    let coord = Coordinator::bind(0).expect("bind port 0");
    let port = coord.port();
    let store = ResultStore::persistent(&dir);
    let cfg = CoordinatorConfig { lease_ms: 30_000, batch: 2 };
    let report = std::thread::scope(|scope| {
        let drain = scope.spawn(|| coord.run(&store, &points, &cfg));
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let points = &points;
                scope.spawn(move || {
                    let local = ResultStore::ephemeral();
                    run_worker("127.0.0.1", port, &local, points, &worker_cfg(2))
                })
            })
            .collect();
        for w in workers {
            w.join().expect("worker thread").expect("worker run");
        }
        drain.join().expect("coordinator thread").expect("fleet drain")
    });
    assert_eq!(report.plan_points, points.len());
    assert_eq!(report.already_present, 0);
    assert_eq!(report.results, points.len() as u64, "every point arrives exactly once");
    assert_eq!(report.workers, 2);
    drop(store);

    // A fresh store over the fleet-written directory serves the whole
    // plan from disk, and every record matches the single-host bytes.
    let reopened = ResultStore::persistent(&dir);
    for p in &points {
        let r = reopened.lookup(p.key()).expect("fleet-populated store resolves every key");
        assert_eq!(
            encode_result_bin(&r).to_vec(),
            reference[&p.key()],
            "record for key {:#018x} must be bit-identical to a single-host run",
            p.key()
        );
    }
    assert_eq!(reopened.stats().disk_hits, points.len() as u64);
    std::fs::remove_dir_all(&dir).ok();
}

/// Chaos wall: a worker that takes a batch and drops the connection
/// without returning it never loses a point — the coordinator requeues
/// the lease and a healthy sibling finishes the plan, with zero
/// duplicate appends.
#[test]
fn a_worker_crash_mid_batch_loses_and_duplicates_nothing() {
    let points = plan();
    let reference = single_host_records(&points);

    let coord = Coordinator::bind(0).expect("bind port 0");
    let port = coord.port();
    let store = ResultStore::ephemeral();
    // Generous lease: the requeue must come from the observed
    // disconnect, not from an expiry racing the healthy worker.
    let cfg = CoordinatorConfig { lease_ms: 120_000, batch: 2 };
    let report = std::thread::scope(|scope| {
        let drain = scope.spawn(|| coord.run(&store, &points, &cfg));
        let crasher = {
            let points = &points;
            scope.spawn(move || {
                let local = ResultStore::ephemeral();
                let cfg = WorkerConfig { abandon_after: Some(1), ..worker_cfg(2) };
                run_worker("127.0.0.1", port, &local, points, &cfg)
            })
        };
        let crashed = crasher.join().expect("crasher thread").expect("scripted crash is clean");
        assert!(crashed.abandoned);
        assert_eq!(crashed.points, 0, "an abandoned batch returns nothing");
        let healthy = {
            let points = &points;
            scope.spawn(move || {
                let local = ResultStore::ephemeral();
                run_worker("127.0.0.1", port, &local, points, &worker_cfg(2))
            })
        };
        healthy.join().expect("healthy thread").expect("healthy worker run");
        drain.join().expect("coordinator thread").expect("fleet drain")
    });
    assert_eq!(report.results, points.len() as u64, "no point lost to the crash");
    assert_eq!(report.duplicates, 0, "no point appended twice");
    assert!(report.reassigned >= 1, "the abandoned lease must requeue: {report:?}");
    assert_eq!(store.stats().disk_writes, 0, "ephemeral store never touches disk");
    for p in &points {
        let r = store.lookup(p.key()).expect("every key lands despite the crash");
        assert_eq!(encode_result_bin(&r).to_vec(), reference[&p.key()]);
    }
}

/// A silent worker — handshake, lease a batch, then nothing — stalls
/// the plan only until `lease_ms`; the reaper requeues its keys and a
/// healthy worker completes the drain.
#[test]
fn a_stalled_lease_is_reassigned_after_the_timeout() {
    let points = plan();
    let keys: Vec<u64> = points.iter().map(|p| p.key()).collect();
    let fingerprint = plan_fingerprint(&keys);

    let coord = Coordinator::bind(0).expect("bind port 0");
    let port = coord.port();
    let store = ResultStore::ephemeral();
    let cfg = CoordinatorConfig { lease_ms: 100, batch: 2 };
    let report = std::thread::scope(|scope| {
        let drain = scope.spawn(|| coord.run(&store, &points, &cfg));

        // A raw client that takes a lease and goes silent, holding the
        // connection open so only the timeout can free its keys.
        let mut stalled = TcpStream::connect(("127.0.0.1", port)).expect("connect");
        write_frame(&mut stalled, &Frame::Hello { version: PROTO_VERSION, fingerprint })
            .expect("hello");
        match read_frame(&mut stalled).expect("welcome") {
            Frame::Welcome { .. } => {}
            other => panic!("expected WELCOME, got {other:?}"),
        }
        write_frame(&mut stalled, &Frame::Request { max_points: 2 }).expect("request");
        match read_frame(&mut stalled).expect("batch") {
            Frame::Batch { keys, .. } => assert!(!keys.is_empty()),
            other => panic!("expected BATCH, got {other:?}"),
        }
        std::thread::sleep(Duration::from_millis(150)); // let the lease expire

        let healthy = {
            let points = &points;
            scope.spawn(move || {
                let local = ResultStore::ephemeral();
                run_worker("127.0.0.1", port, &local, points, &worker_cfg(2))
            })
        };
        healthy.join().expect("healthy thread").expect("healthy worker run");
        let report = drain.join().expect("coordinator thread").expect("fleet drain");
        drop(stalled);
        report
    });
    assert_eq!(report.results, points.len() as u64);
    assert!(report.reassigned >= 1, "the stalled lease must expire and requeue: {report:?}");
    for k in &keys {
        assert!(store.lookup(*k).is_some(), "key {k:#018x} missing after reassignment");
    }
}

/// The fingerprint handshake: a worker whose flags derive a different
/// plan is refused before any batch moves, then a matching worker
/// drains the plan normally.
#[test]
fn a_mismatched_plan_is_refused_at_the_handshake() {
    let points = plan();
    let wrong_plan: Vec<SimPoint> = points[..3].to_vec();

    let coord = Coordinator::bind(0).expect("bind port 0");
    let port = coord.port();
    let store = ResultStore::ephemeral();
    let cfg = CoordinatorConfig::default();
    std::thread::scope(|scope| {
        let drain = scope.spawn(|| coord.run(&store, &points, &cfg));
        let err = {
            let local = ResultStore::ephemeral();
            run_worker("127.0.0.1", port, &local, &wrong_plan, &worker_cfg(2))
                .expect_err("mismatched plan must be refused")
        };
        assert!(err.to_string().contains("fingerprint"), "got: {err}");
        let healthy = {
            let points = &points;
            scope.spawn(move || {
                let local = ResultStore::ephemeral();
                run_worker("127.0.0.1", port, &local, points, &worker_cfg(8))
            })
        };
        healthy.join().expect("healthy thread").expect("healthy worker run");
        let report = drain.join().expect("coordinator thread").expect("fleet drain");
        assert_eq!(report.results, points.len() as u64);
        assert_eq!(report.workers, 1, "the refused worker never completed the handshake");
    });
}

/// A coordinator over a fully warm store returns without waiting for
/// any worker — the CLI's non-hanging path, and the reason a rerun of
/// a finished fleet is instant.
#[test]
fn a_warm_store_drains_without_any_worker() {
    let points = plan();
    let store = ResultStore::ephemeral();
    let mut engines = EngineCache::new();
    for p in &points {
        let r = simulate(&mut engines, p).expect("simulates");
        store.insert(p.key(), std::sync::Arc::new(r));
    }
    let coord = Coordinator::bind(0).expect("bind port 0");
    let report =
        coord.run(&store, &points, &CoordinatorConfig::default()).expect("instant drain");
    assert_eq!(report.already_present, points.len());
    assert_eq!(report.results, 0);
    assert_eq!(report.workers, 0);
}
