//! Property tests for the write-combining buffer (`mem::writebuffer`) and
//! the DRAM model (`mem::dram`) under randomized op streams.
//!
//! Each model is pinned against an independently written naive reference
//! (slot scans and `HashMap`s instead of the tuned structures), the same
//! differential pattern as `tests/cache_differential.rs` /
//! `tests/tlb_differential.rs`, plus direct invariants: WC drain ordering
//! and full/partial classification, DRAM row-hit/row-miss accounting,
//! channel-occupancy bookkeeping and service-queue monotonicity.

use std::collections::HashMap;

use multistride::mem::dram::{DramOp, DramStats};
use multistride::mem::{Dram, DramConfig, WriteCombineBuffer, WriteCombineConfig};
use multistride::util::proptest::{check, Config};
use multistride::util::Rng;

// ---- naive WC-buffer reference model -------------------------------------

#[derive(Debug, Clone, Copy)]
struct RefBuf {
    line: u64,
    filled: u16,
    stamp: u64,
}

/// Slot-free reference: a plain list of open buffers with explicit LRU.
/// Replicates the pinned seed semantics exactly, including the quirk that
/// a full-line store arriving at a full pool reports the LRU victim
/// flushed while leaving it resident (the golden engine oracle depends on
/// this behavior, so the reference must too).
struct RefWc {
    capacity: usize,
    bufs: Vec<RefBuf>,
    clock: u64,
    stores: u64,
    full_flushes: u64,
    partial_flushes: u64,
}

impl RefWc {
    fn new(capacity: u32) -> Self {
        Self {
            capacity: capacity as usize,
            bufs: Vec::new(),
            clock: 0,
            stores: 0,
            full_flushes: 0,
            partial_flushes: 0,
        }
    }

    /// Returns `(line, full, at)` like `WcFlush`.
    fn store(&mut self, now: u64, addr: u64, size: u32) -> Option<(u64, bool, u64)> {
        self.clock += 1;
        self.stores += 1;
        let line = addr >> 6;
        let offset = (addr & 63) as u32;
        let first_chunk = offset / 4;
        let chunks = size.div_ceil(4);
        let mask: u16 = (((1u32 << chunks) - 1) << first_chunk) as u16;

        if let Some(i) = self.bufs.iter().position(|b| b.line == line) {
            self.bufs[i].filled |= mask;
            self.bufs[i].stamp = self.clock;
            if self.bufs[i].filled == u16::MAX {
                self.bufs.remove(i);
                self.full_flushes += 1;
                return Some((line, true, now));
            }
            return None;
        }

        let mut victim = None;
        if self.bufs.len() == self.capacity {
            let (i, _) = self
                .bufs
                .iter()
                .enumerate()
                .min_by_key(|(_, b)| b.stamp)
                .expect("pool non-empty");
            self.partial_flushes += 1;
            victim = Some((self.bufs[i].line, false, now));
            if mask != u16::MAX {
                self.bufs.remove(i);
            }
            // Quirk: with a full-line store the victim is *reported*
            // flushed but stays resident (mirrors the seed model).
        }
        if mask == u16::MAX {
            self.full_flushes += 1;
            return victim.or(Some((line, true, now)));
        }
        self.bufs.push(RefBuf { line, filled: mask, stamp: self.clock });
        victim
    }

    fn open_lines(&self) -> Vec<(u64, bool)> {
        self.bufs.iter().map(|b| (b.line, b.filled == u16::MAX)).collect()
    }
}

#[derive(Debug, Clone, Copy)]
struct WcCase {
    entries: u32,
    seed: u64,
    ops: u32,
    /// Number of distinct line streams the stores interleave over.
    streams: u64,
}

fn run_wc_case(c: &WcCase) -> bool {
    let mut real = WriteCombineBuffer::new(WriteCombineConfig { entries: c.entries });
    let mut naive = RefWc::new(c.entries);
    let mut rng = Rng::new(c.seed);
    for op in 0..c.ops {
        let now = op as u64 * 3;
        let stream = rng.below(c.streams);
        // A 4-byte-aligned store that never splits its 64-byte line.
        let chunks = 1 + rng.below(16);
        let first = rng.below(17 - chunks);
        let addr = stream * (1 << 20) + rng.below(2) * 64 + first * 4;
        let size = (chunks * 4) as u32;
        let got = real.store(now, addr, size).map(|f| (f.line, f.full, f.at));
        let want = naive.store(now, addr, size);
        if got != want {
            return false;
        }
        if real.open_buffers() != naive.bufs.len() {
            return false;
        }
        if real.open_buffers() > c.entries as usize {
            return false;
        }
    }
    let s = real.stats;
    if (s.stores, s.full_flushes, s.partial_flushes)
        != (naive.stores, naive.full_flushes, naive.partial_flushes)
    {
        return false;
    }
    // Drain: every open buffer flushes exactly once at `now`, with the
    // full flag iff all 16 chunks were written; afterwards the pool is
    // empty. (Order is the pool's slot order; compare as sets.)
    let now = 1 << 30;
    let flushed = real.drain(now);
    let mut got: Vec<(u64, bool)> = flushed.iter().map(|f| (f.line, f.full)).collect();
    let mut want = naive.open_lines();
    got.sort_unstable();
    want.sort_unstable();
    got == want
        && flushed.iter().all(|f| f.at == now)
        && real.open_buffers() == 0
        && real.drain(now).is_empty()
}

#[test]
fn writebuffer_matches_naive_reference_model() {
    check(
        Config { cases: 96, seed: 0x77CBFF },
        |r, size| WcCase {
            entries: [1u32, 2, 4, 10][r.below(4) as usize],
            seed: r.next_u64(),
            ops: 16 + size * 30,
            // Sometimes fewer streams than buffers (grouped-style, no
            // pressure), sometimes far more (interleaved-style thrash).
            streams: 1 + r.below(24),
        },
        run_wc_case,
    );
}

/// Drain ordering: buffers drain in pool-slot order, which for a
/// never-evicted fill sequence is allocation order.
#[test]
fn drain_preserves_allocation_order_without_pressure() {
    let mut w = WriteCombineBuffer::new(WriteCombineConfig { entries: 8 });
    let lines = [7u64, 3, 11, 5];
    for &l in &lines {
        assert!(w.store(0, l * 64, 32).is_none(), "half-filled: stays open");
    }
    let drained: Vec<u64> = w.drain(9).iter().map(|f| f.line).collect();
    assert_eq!(drained, lines, "slot order == allocation order when nothing evicts");
    assert!(w.drain(9).is_empty());
}

// ---- naive DRAM reference model ------------------------------------------

/// Independent recomputation of the DRAM timing on `HashMap`s.
struct RefDram {
    cfg: DramConfig,
    open: HashMap<u64, u64>,
    next_free: u64,
    stats: DramStats,
}

impl RefDram {
    fn new(cfg: DramConfig) -> Self {
        Self { cfg, open: HashMap::new(), next_free: 0, stats: DramStats::default() }
    }

    fn access(&mut self, now: u64, line: u64, op: DramOp) -> u64 {
        let frame = line / (self.cfg.row_bytes / 64);
        let bank = frame % self.cfg.banks as u64;
        let row = frame / self.cfg.banks as u64;
        let row_hit = self.open.get(&bank) == Some(&row);
        if row_hit {
            self.stats.row_hits += 1;
        } else {
            self.stats.row_misses += 1;
            self.open.insert(bank, row);
        }
        let latency = if row_hit { self.cfg.row_hit_cycles } else { self.cfg.row_miss_cycles };
        let occupancy = match op {
            DramOp::Read => self.cfg.service_cycles,
            DramOp::WriteLine => self.cfg.write_service_cycles,
            DramOp::WritePartial => self.cfg.write_service_cycles * self.cfg.partial_write_penalty,
        };
        match op {
            DramOp::Read => self.stats.reads += 1,
            _ => self.stats.writes += 1,
        }
        let start = self.next_free.max(now);
        self.next_free = start + occupancy;
        self.stats.busy_cycles += occupancy;
        start + latency
    }
}

#[derive(Debug, Clone, Copy)]
struct DramCase {
    seed: u64,
    ops: u32,
    /// Line universe: small enough to revisit rows, large enough to span
    /// many banks/rows.
    lines: u64,
}

fn run_dram_case(c: &DramCase) -> bool {
    let cfg = DramConfig::default();
    let mut real = Dram::new(cfg);
    let mut naive = RefDram::new(cfg);
    let mut rng = Rng::new(c.seed);
    let mut now = 0u64;
    let mut min_done = 0u64;
    for _ in 0..c.ops {
        // Time sometimes idles past the queue, sometimes piles onto it.
        now += match rng.below(4) {
            0 => 0,
            1 => rng.below(8),
            2 => rng.below(64),
            _ => rng.below(4096),
        };
        let line = rng.below(c.lines);
        let op = match rng.below(4) {
            0 | 1 => DramOp::Read,
            2 => DramOp::WriteLine,
            _ => DramOp::WritePartial,
        };
        let got = real.access(now, line, op);
        let want = naive.access(now, line, op);
        if got != want {
            return false;
        }
        // Completion is never before issue + the cheapest latency.
        if got < now + cfg.row_hit_cycles {
            return false;
        }
        // The service queue never runs backwards.
        if real.next_free() < min_done {
            return false;
        }
        min_done = real.next_free();
        if real.next_free() != naive.next_free {
            return false;
        }
    }
    let s = real.stats;
    if s != naive.stats {
        return false;
    }
    // Accounting invariants: every access classified exactly once, and the
    // channel occupancy is the sum of per-op service times.
    // Lower bound: partial writes occupy strictly longer than full ones.
    let expect_busy = s.reads * cfg.service_cycles + s.writes * cfg.write_service_cycles;
    s.row_hits + s.row_misses == s.reads + s.writes && s.busy_cycles >= expect_busy
}

#[test]
fn dram_matches_naive_reference_model() {
    check(
        Config { cases: 96, seed: 0xD12A },
        |r, size| DramCase {
            seed: r.next_u64(),
            ops: 32 + size * 40,
            lines: [64u64, 1024, 1 << 16][r.below(3) as usize],
        },
        run_dram_case,
    );
}

/// Row accounting: a sequential sweep is one miss per row and hits
/// elsewhere; a same-bank ping-pong is all misses after the first pair.
#[test]
fn row_hit_miss_accounting_directed() {
    let cfg = DramConfig::default();
    let lines_per_row = cfg.row_bytes / 64;

    let mut d = Dram::new(cfg);
    for l in 0..lines_per_row * 8 {
        d.access(0, l, DramOp::Read);
    }
    assert_eq!(d.stats.row_misses, 8);
    assert_eq!(d.stats.row_hits, lines_per_row * 8 - 8);

    let mut d = Dram::new(cfg);
    let other = cfg.banks as u64 * lines_per_row; // same bank, next row
    for _ in 0..64 {
        d.access(0, 0, DramOp::Read);
        d.access(0, other, DramOp::Read);
    }
    assert_eq!(d.stats.row_hits, 0, "alternating rows of one bank never hit");
    assert_eq!(d.stats.row_misses, 128);
}

/// `reset` restores post-construction behavior exactly for both models.
#[test]
fn reset_replays_fresh() {
    let cfg = DramConfig::default();
    let mut real = Dram::new(cfg);
    let mut rng = Rng::new(0x0D5);
    for i in 0..4096 {
        real.access(i, rng.below(1 << 20), DramOp::Read);
    }
    real.reset();
    assert_eq!(real.stats, DramStats::default());
    let mut naive = RefDram::new(cfg);
    let mut rng = Rng::new(0x5D0);
    let mut now = 0;
    for _ in 0..4096 {
        now += rng.below(32);
        let line = rng.below(1 << 20);
        assert_eq!(
            real.access(now, line, DramOp::WriteLine),
            naive.access(now, line, DramOp::WriteLine),
            "replay diverged post-reset"
        );
    }

    let mut real = WriteCombineBuffer::new(WriteCombineConfig::default());
    let mut rng = Rng::new(0xCC);
    for i in 0..4096 {
        real.store(i, rng.below(256) * 32, 32);
    }
    real.reset();
    assert_eq!(real.open_buffers(), 0);
    let mut naive = RefWc::new(WriteCombineConfig::default().entries);
    let mut rng = Rng::new(0xDD);
    for i in 0..4096 {
        let addr = rng.below(256) * 32;
        let got = real.store(i, addr, 32).map(|f| (f.line, f.full, f.at));
        assert_eq!(got, naive.store(i, addr, 32), "WC replay diverged post-reset");
    }
}
