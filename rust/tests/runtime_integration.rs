//! Integration: Rust PJRT runtime executes the AOT artifacts and matches
//! the pure-Rust oracles. Requires `make artifacts`; tests skip (pass with
//! a notice) when the artifact directory is absent so `cargo test` works in
//! a fresh checkout.

use multistride::runtime::{oracle, ArtifactRegistry, Runtime};
use multistride::util::Rng;

fn registry() -> Option<ArtifactRegistry> {
    let reg = ArtifactRegistry::new(ArtifactRegistry::default_dir());
    if reg.list().is_empty() {
        eprintln!("skipping runtime integration: no artifacts (run `make artifacts`)");
        None
    } else {
        Some(reg)
    }
}

fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.f64() as f32 - 0.5).collect()
}

#[test]
fn artifacts_compile_on_pjrt_cpu() {
    let Some(reg) = registry() else { return };
    let mut rt = Runtime::new().expect("PJRT cpu client");
    for name in reg.list() {
        rt.load(&name, &reg.path_for(&name))
            .unwrap_or_else(|e| panic!("load {name}: {e:#}"));
    }
    assert!(rt.loaded().len() >= 4, "expected the core kernels: {:?}", rt.loaded());
}

#[test]
fn mxv_artifact_matches_oracle() {
    let Some(reg) = registry() else { return };
    if !reg.has("mxv") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load("mxv", &reg.path_for("mxv")).unwrap();
    let (m, n) = (64usize, 128usize);
    let mut rng = Rng::new(1);
    let a = rand_vec(&mut rng, m * n);
    let x = rand_vec(&mut rng, n);
    let got = &rt.execute_f32("mxv", &[(&a, &[m as i64, n as i64]), (&x, &[n as i64])]).unwrap()[0];
    let want = oracle::mxv(&a, &x, m, n);
    assert!(oracle::max_rel_err(got, &want) < 5e-3);
}

#[test]
fn bicg_artifact_matches_oracle() {
    let Some(reg) = registry() else { return };
    if !reg.has("bicg") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load("bicg", &reg.path_for("bicg")).unwrap();
    let (m, n) = (64usize, 128usize);
    let mut rng = Rng::new(2);
    let a = rand_vec(&mut rng, m * n);
    let r = rand_vec(&mut rng, m);
    let p = rand_vec(&mut rng, n);
    let out = rt
        .execute_f32("bicg", &[(&a, &[m as i64, n as i64]), (&r, &[m as i64]), (&p, &[n as i64])])
        .unwrap();
    let (s_want, q_want) = oracle::bicg(&a, &r, &p, m, n);
    assert!(oracle::max_rel_err(&out[0], &s_want) < 5e-3);
    assert!(oracle::max_rel_err(&out[1], &q_want) < 5e-3);
}

#[test]
fn conv_artifact_matches_oracle() {
    let Some(reg) = registry() else { return };
    if !reg.has("conv") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load("conv", &reg.path_for("conv")).unwrap();
    let (h, w) = (34usize, 66usize);
    let mut rng = Rng::new(3);
    let img = rand_vec(&mut rng, h * w);
    let wts = rand_vec(&mut rng, 9);
    let got =
        &rt.execute_f32("conv", &[(&img, &[h as i64, w as i64]), (&wts, &[3, 3])]).unwrap()[0];
    let mut w9 = [0f32; 9];
    w9.copy_from_slice(&wts);
    let want = oracle::conv3x3(&img, &w9, h, w);
    assert!(oracle::max_rel_err(got, &want) < 5e-3);
}

#[test]
fn jacobi_artifact_matches_oracle_and_preserves_borders() {
    let Some(reg) = registry() else { return };
    if !reg.has("jacobi2d") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load("jacobi2d", &reg.path_for("jacobi2d")).unwrap();
    let (h, w) = (32usize, 64usize);
    let mut rng = Rng::new(4);
    let a = rand_vec(&mut rng, h * w);
    let got = &rt.execute_f32("jacobi2d", &[(&a, &[h as i64, w as i64])]).unwrap()[0];
    let want = oracle::jacobi2d(&a, h, w);
    assert!(oracle::max_rel_err(got, &want) < 5e-3);
    // Borders untouched.
    assert_eq!(&got[..w], &a[..w]);
}

#[test]
fn executions_are_deterministic() {
    let Some(reg) = registry() else { return };
    if !reg.has("mxv") {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    rt.load("mxv", &reg.path_for("mxv")).unwrap();
    let (m, n) = (64usize, 128usize);
    let mut rng = Rng::new(5);
    let a = rand_vec(&mut rng, m * n);
    let x = rand_vec(&mut rng, n);
    let r1 = rt.execute_f32("mxv", &[(&a, &[m as i64, n as i64]), (&x, &[n as i64])]).unwrap();
    let r2 = rt.execute_f32("mxv", &[(&a, &[m as i64, n as i64]), (&x, &[n as i64])]).unwrap();
    assert_eq!(r1[0], r2[0]);
}
