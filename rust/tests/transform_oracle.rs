//! Transform-correctness oracle over the whole kernel registry.
//!
//! The §5.1 multi-striding rewrite may only *reorder* a dependence-free
//! iteration space. Two independent pins enforce that for every kernel in
//! the universe (Table 1 + extended) and every derived variant S ∈ {2,4,8}:
//!
//! 1. **Trace permutation** — the multi-strided variant's access trace is
//!    an exact permutation of the single-stride baseline trace: the same
//!    multiset of (address, load/store) pairs, at the same multiplicities,
//!    and full coverage of the critical access's iteration image.
//! 2. **Numeric bit-identity** — executing each variant under the
//!    order-independent interpreter of `kernels::reference::interp`
//!    (commutative wrapping-add semantics, deterministic synthetic inputs)
//!    produces memory bit-identical to the untransformed source nest.
//!
//! Loop extents are shrunk (to multiples that keep every family stride
//! divisor exact, so no extent trimming perturbs the domain) to keep full
//! traces and element-level interpretation cheap.

use std::collections::HashMap;

use multistride::kernels::library::all_kernels;
use multistride::kernels::reference::interp;
use multistride::kernels::spec::{AccessMode, KernelSpec};
use multistride::trace::KernelTrace;
use multistride::transform::{variant_set, Transformed, VariantSet, VEC_ELEMS};

/// Cap loop extents so full traces and element-level interpretation stay
/// cheap. Caps are multiples of 64, so every family config (S ∈ {1,2,4,8},
/// portion 1) divides the domain exactly and the transform trims nothing.
fn shrunk(mut spec: KernelSpec) -> KernelSpec {
    let cap = if spec.loops.len() == 1 { 4096 } else { 128 };
    for l in &mut spec.loops {
        l.extent = l.extent.min(cap);
    }
    spec
}

/// Multiset of (address, is_store) pairs of a full trace.
fn trace_multiset(t: &Transformed) -> HashMap<(u64, bool), i64> {
    let mut counts: HashMap<(u64, bool), i64> = HashMap::new();
    for a in KernelTrace::new(t.clone()).iter() {
        *counts.entry((a.addr, a.op.is_store())).or_insert(0) += 1;
    }
    counts
}

/// Every address the critical access touches over the (vector-granular)
/// iteration domain of `t`, paired with whether it is read / written.
fn critical_image(t: &Transformed) -> Vec<(u64, AccessMode)> {
    let spec = &t.spec;
    let acc = &spec.accesses[t.critical];
    let mut out = Vec::new();
    let extents: Vec<u64> = spec.loops.iter().map(|l| l.extent).collect();
    let mut vals = vec![0u64; extents.len()];
    if extents.iter().any(|&e| e == 0) {
        return out;
    }
    loop {
        if let Some(addr) = spec.address(acc, &vals) {
            out.push((addr, acc.mode));
        }
        // Odometer, vector axis in steps of one vector slot.
        let mut i = extents.len();
        loop {
            if i == 0 {
                return out;
            }
            i -= 1;
            let step = if i == t.vector_loop { VEC_ELEMS } else { 1 };
            vals[i] += step;
            if vals[i] < extents[i] {
                break;
            }
            vals[i] = 0;
        }
    }
}

fn family(spec: &KernelSpec) -> VariantSet {
    variant_set(&shrunk(spec.clone()), 1)
        .unwrap_or_else(|e| panic!("{}: family must derive: {e}", spec.name))
}

#[test]
fn multistrided_traces_are_permutations_of_the_baseline() {
    for pk in all_kernels(2 << 20) {
        let set = family(&pk.spec);
        let base = &set.baseline().transformed;
        let want = trace_multiset(base);
        assert!(!want.is_empty(), "{}: baseline trace empty", pk.name);
        for v in set.multi() {
            // Same iteration domain: nothing was trimmed away.
            assert_eq!(
                v.transformed.spec.loops.iter().map(|l| l.extent).product::<u64>(),
                base.spec.loops.iter().map(|l| l.extent).product::<u64>(),
                "{} S={}: domain changed",
                pk.name,
                v.strides()
            );
            let mut remaining = want.clone();
            let mut total = 0u64;
            for a in KernelTrace::new(v.transformed.clone()).iter() {
                total += 1;
                let slot = remaining.get_mut(&(a.addr, a.op.is_store())).unwrap_or_else(|| {
                    panic!(
                        "{} S={}: access {:#x} ({:?}) not in baseline",
                        pk.name, v.strides(), a.addr, a.op
                    )
                });
                *slot -= 1;
            }
            assert_eq!(
                total,
                want.values().sum::<i64>() as u64,
                "{} S={}: trace length differs",
                pk.name,
                v.strides()
            );
            assert!(
                remaining.values().all(|&c| c == 0),
                "{} S={}: multiset multiplicities differ",
                pk.name,
                v.strides()
            );
        }
    }
}

#[test]
fn baseline_covers_the_critical_access_image() {
    for pk in all_kernels(2 << 20) {
        let set = family(&pk.spec);
        let base = &set.baseline().transformed;
        let counts = trace_multiset(base);
        for (addr, mode) in critical_image(base) {
            let (need_load, need_store) = match mode {
                AccessMode::Read => (true, false),
                AccessMode::Write => (false, true),
                AccessMode::ReadWrite => (true, true),
            };
            if need_load {
                assert!(
                    counts.get(&(addr, false)).copied().unwrap_or(0) > 0,
                    "{}: critical load of {addr:#x} missing",
                    pk.name
                );
            }
            if need_store {
                assert!(
                    counts.get(&(addr, true)).copied().unwrap_or(0) > 0,
                    "{}: critical store of {addr:#x} missing",
                    pk.name
                );
            }
        }
    }
}

#[test]
fn numeric_execution_is_bit_identical_across_variants() {
    for pk in all_kernels(2 << 20) {
        let spec = shrunk(pk.spec.clone());
        let want = interp::execute_source(&spec);
        assert!(!want.is_empty(), "{}: source execution wrote nothing", pk.name);
        let set = family(&pk.spec);
        for v in &set.variants {
            let got = interp::execute_transformed(&v.transformed);
            assert_eq!(
                got, want,
                "{} S={}: transformed execution diverged from source order",
                pk.name, v.strides()
            );
        }
    }
}
