//! The chaos wall: hundreds of seeded fault schedules driven through
//! the persistent store, the crash-recovery path, and the grid merge —
//! over a thousand schedules in a default `cargo test` run.
//!
//! Three invariants hold across every schedule:
//!
//! 1. **Never wrong bytes** — any record the store serves, even while
//!    faults are still firing, is bit-identical to what was stored;
//! 2. **Always self-heal to a miss** — damage surfaces as at most one
//!    recoverable error, after which the key misses and can be
//!    re-stored on clean I/O;
//! 3. **Grid = single host** — a plan run as disjoint shards and merged
//!    is bit-identical to the same plan run on one host, and re-merging
//!    is a no-op.
//!
//! Every schedule is a pure function of its seed (`exec::vfs::FaultIo`),
//! so a failure here replays exactly. `MULTISTRIDE_CHAOS_SCHEDULES`
//! overrides the per-wall schedule count (CI's chaos-smoke job runs a
//! reduced wall; the default counts sum to 1040).

use std::path::PathBuf;
use std::sync::Arc;

use multistride::config::coffee_lake;
use multistride::exec::format::{decode_result_bin, serialize_result, RESULT_BIN_BYTES};
use multistride::exec::grid::{self, ShardSpec};
use multistride::exec::segment::SegmentStore;
use multistride::exec::vfs::{FaultIo, FaultPlan, RealIo, StoreIo};
use multistride::exec::{lifecycle, Planner, ResultStore, SimPoint};
use multistride::kernels::micro::MicroOp;
use multistride::sim::RunResult;
use multistride::util::Rng;

/// Small roll size so every schedule exercises segment rolling.
const ROLL: u64 = 1 << 10;

fn schedules(default: u64) -> u64 {
    std::env::var("MULTISTRIDE_CHAOS_SCHEDULES")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("multistride_chaos_{tag}_{}", std::process::id()))
}

/// Synthetic records: random payload bytes decoded through the binary
/// twin, so the stored bytes are adversarial rather than simulator-shaped.
/// Keys are distinct within one batch.
fn synth_records(rng: &mut Rng, n: usize) -> Vec<(u64, RunResult)> {
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let key = rng.next_u64();
        if !seen.insert(key) {
            continue;
        }
        let mut bytes = [0u8; RESULT_BIN_BYTES];
        for b in bytes.iter_mut() {
            *b = rng.below(256) as u8;
        }
        out.push((key, decode_result_bin(&bytes).expect("length is exact")));
    }
    out
}

/// Wall 1 — the store fault wall: populate and read back through a
/// seeded fault injector; whatever the store serves must be bit-exact,
/// and a clean reopen must heal every damaged key to a servable miss.
#[test]
fn store_wall_never_serves_wrong_bytes_and_heals_on_clean_io() {
    let dir = tmp("store_wall");
    let n = schedules(640);
    for seed in 0..n {
        std::fs::remove_dir_all(&dir).ok();
        let mut rng = Rng::new(0xC4A05 ^ seed);
        let records = synth_records(&mut rng, 12);
        let truth: Vec<(u64, String)> =
            records.iter().map(|(k, r)| (*k, serialize_result(*k, r))).collect();
        let fio = Arc::new(FaultIo::seeded(seed));
        let io: Arc<dyn StoreIo> = fio.clone();

        // Populate under faults: individual appends may fail; that is
        // the point.
        let mut st = SegmentStore::open_with(&dir, ROLL, Arc::clone(&io));
        for (k, r) in &records {
            let _ = st.append_result(*k, 1, r);
        }
        let _ = st.flush_index();
        drop(st);

        // Invariant 1: a second store over the same directory — faults
        // still firing — never returns wrong bytes for a key it serves.
        let mut faulty = SegmentStore::open_with(&dir, ROLL, Arc::clone(&io));
        for (k, want) in &truth {
            if let Some(Ok(got)) = faulty.lookup_result(*k) {
                assert_eq!(
                    &serialize_result(*k, &got),
                    want,
                    "seed {seed}: served wrong bytes for key {k:016x}"
                );
            }
        }
        drop(faulty);

        // Lifecycle under fire: compaction may fail, but never panics
        // and never plants wrong bytes (re-checked just below).
        if seed % 3 == 0 {
            let _ = lifecycle::compact_with(Arc::clone(&io), &dir);
        }

        // Invariant 2: on clean I/O every key serves the exact truth
        // bytes or heals to a miss — damage may surface one recoverable
        // error, after which the key misses.
        let mut clean = SegmentStore::open_with(&dir, ROLL, Arc::new(RealIo));
        for (k, want) in &truth {
            match clean.lookup_result(*k) {
                Some(Ok(got)) => assert_eq!(
                    &serialize_result(*k, &got),
                    want,
                    "seed {seed}: clean reopen served wrong bytes for {k:016x}"
                ),
                Some(Err(_)) => assert!(
                    clean.lookup_result(*k).is_none(),
                    "seed {seed}: corrupt record for {k:016x} must heal to a miss"
                ),
                None => {}
            }
        }
        assert!(fio.op_count() > 0, "seed {seed}: the schedule saw no I/O");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Wall 2 — the crash wall: the process dies after exactly `k` I/O
/// operations mid-populate. Whatever landed must serve bit-exact on a
/// clean reopen, re-storing the missing keys completes the set, and the
/// heal is durable across another reopen.
#[test]
fn crash_wall_recovers_bit_exact_after_every_crash_point() {
    let dir = tmp("crash_wall");
    let n = schedules(200);
    for seed in 0..n {
        std::fs::remove_dir_all(&dir).ok();
        let mut rng = Rng::new(0xDEAD ^ (seed << 8));
        let records = synth_records(&mut rng, 8);

        let io: Arc<dyn StoreIo> =
            Arc::new(FaultIo::new(Arc::new(RealIo), FaultPlan::crash_after(seed % 40)));
        let mut dying = SegmentStore::open_with(&dir, ROLL, io);
        for (k, r) in &records {
            let _ = dying.append_result(*k, 1, r);
        }
        let _ = dying.flush_index();
        drop(dying); // the "crash": the process never runs another op

        let mut healed = SegmentStore::open_with(&dir, ROLL, Arc::new(RealIo));
        for (k, r) in &records {
            let want = serialize_result(*k, r);
            match healed.lookup_result(*k) {
                Some(Ok(got)) => assert_eq!(
                    serialize_result(*k, &got),
                    want,
                    "seed {seed}: survivor {k:016x} diverged"
                ),
                Some(Err(_)) => assert!(
                    healed.lookup_result(*k).is_none(),
                    "seed {seed}: torn record {k:016x} must heal to a miss"
                ),
                None => {}
            }
            if healed.lookup_result(*k).is_none() {
                healed.append_result(*k, 2, r).expect("clean I/O re-stores");
            }
        }
        healed.flush_index().expect("clean I/O flushes the index");
        drop(healed);

        let mut reopened = SegmentStore::open_with(&dir, ROLL, Arc::new(RealIo));
        for (k, r) in &records {
            let got = reopened
                .lookup_result(*k)
                .unwrap_or_else(|| panic!("seed {seed}: {k:016x} lost after heal"))
                .unwrap_or_else(|e| panic!("seed {seed}: {k:016x} corrupt after heal: {e}"));
            assert_eq!(
                serialize_result(*k, &got),
                serialize_result(*k, r),
                "seed {seed}: healed bytes differ for {k:016x}"
            );
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Wall 3 — the merge wall: a faulted merge may fail or stop short, but
/// never plants wrong bytes or manufactures conflicts; a clean retry
/// converges, and a second clean pass is a pure no-op.
#[test]
fn merge_wall_converges_under_faults_without_conflicts() {
    let base = tmp("merge_wall");
    let n = schedules(200);
    for seed in 0..n {
        std::fs::remove_dir_all(&base).ok();
        let a = base.join("shard-a");
        let b = base.join("shard-b");
        let dst = base.join("merged");
        let mut rng = Rng::new(0x3E26E ^ (seed << 4));
        let records = synth_records(&mut rng, 10);

        let mut sa = SegmentStore::open_with(&a, ROLL, Arc::new(RealIo));
        let mut sb = SegmentStore::open_with(&b, ROLL, Arc::new(RealIo));
        for (k, r) in &records {
            let st = if grid::shard_of(*k, 2) == 1 { &mut sa } else { &mut sb };
            st.append_result(*k, 1, r).expect("clean populate");
        }
        sa.flush_index().expect("flush shard-a");
        sb.flush_index().expect("flush shard-b");
        drop((sa, sb));

        // A faulted merge attempt: any outcome but a panic or bad bytes.
        let sources = vec![a.clone(), b.clone()];
        let fio = Arc::new(FaultIo::seeded(0x9A17 ^ seed));
        let _ = grid::merge_with(fio, &sources, &dst);

        // Nothing wrong may have landed in the destination.
        let mut check = SegmentStore::open_with(&dst, ROLL, Arc::new(RealIo));
        for (k, r) in &records {
            if let Some(Ok(got)) = check.lookup_result(*k) {
                assert_eq!(
                    serialize_result(*k, &got),
                    serialize_result(*k, r),
                    "seed {seed}: faulted merge planted wrong bytes for {k:016x}"
                );
            }
        }
        drop(check);

        // A clean retry converges with zero conflicts and the full set.
        let report = grid::merge(&sources, &dst).expect("clean merge succeeds");
        assert!(report.is_clean(), "seed {seed}: clean merge must not conflict");
        let mut merged = SegmentStore::open_with(&dst, ROLL, Arc::new(RealIo));
        for (k, r) in &records {
            let got = merged
                .lookup_result(*k)
                .unwrap_or_else(|| panic!("seed {seed}: {k:016x} missing after clean merge"))
                .expect("record reads clean");
            assert_eq!(
                serialize_result(*k, &got),
                serialize_result(*k, r),
                "seed {seed}: merged bytes differ for {k:016x}"
            );
        }
        drop(merged);

        // A second clean pass is a pure no-op.
        let again = grid::merge(&sources, &dst).expect("re-merge succeeds");
        assert_eq!(
            (again.merged, again.already_present),
            (0, records.len() as u64),
            "seed {seed}: re-merge must be a no-op"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}

/// A same-key/different-bytes conflict is quarantined and reported: the
/// destination copy wins, the losing copy is preserved on the side, and
/// the `is_clean` exit gate goes red — on every merge attempt, because
/// a conflict never silently resolves.
#[test]
fn merge_quarantines_conflicts_and_keeps_the_destination_copy() {
    let base = tmp("quarantine");
    std::fs::remove_dir_all(&base).ok();
    let src = base.join("src");
    let dst = base.join("dst");
    let mut rng = Rng::new(0x0C0F);
    let recs = synth_records(&mut rng, 2);
    let key = recs[0].0;
    let kept = &recs[0].1;
    let clash = &recs[1].1;
    assert_ne!(serialize_result(key, kept), serialize_result(key, clash));

    let mut d = SegmentStore::open_with(&dst, ROLL, Arc::new(RealIo));
    d.append_result(key, 1, kept).unwrap();
    d.flush_index().unwrap();
    drop(d);
    let mut s = SegmentStore::open_with(&src, ROLL, Arc::new(RealIo));
    s.append_result(key, 1, clash).unwrap();
    s.flush_index().unwrap();
    drop(s);

    let report = grid::merge(&[src.clone()], &dst).unwrap();
    assert_eq!((report.merged, report.conflicts), (0, 1));
    assert!(!report.is_clean(), "a conflict must fail the clean gate");

    // The destination copy is untouched…
    let mut d = SegmentStore::open_with(&dst, ROLL, Arc::new(RealIo));
    let got = d.lookup_result(key).expect("still present").unwrap();
    assert_eq!(serialize_result(key, &got), serialize_result(key, kept));
    drop(d);
    // …and the loser is preserved in quarantine, not discarded.
    let qdir = dst.join(grid::QUARANTINE_DIR);
    let quarantined = std::fs::read_dir(&qdir).unwrap().count();
    assert_eq!(quarantined, 1, "exactly one quarantined record");

    let again = grid::merge(&[src], &dst).unwrap();
    assert_eq!(again.conflicts, 1, "re-merge reports the conflict again");
    std::fs::remove_dir_all(&base).ok();
}

/// Wall 4 — the telemetry wall: `--trace` exports go through the same
/// `StoreIo` seam, so the fault injector covers them too. A faulted
/// trace write may fail, but it never panics, never corrupts a result
/// store sharing the directory, and never loses the span buffer — the
/// export snapshots rather than drains, so a clean retry always lands
/// a parseable trace.
#[test]
fn trace_wall_faulted_exports_fail_clean_and_never_touch_results() {
    use multistride::obs;
    use multistride::obs::trace::{parse_chrome_trace, write_chrome_trace_with};

    let base = tmp("trace_wall");
    std::fs::remove_dir_all(&base).ok();

    // A store populated on clean I/O shares the directory tree with the
    // trace artifacts; no schedule may disturb it.
    let mut rng = Rng::new(0x7ACE);
    let records = synth_records(&mut rng, 6);
    let store_dir = base.join("results");
    let mut st = SegmentStore::open_with(&store_dir, ROLL, Arc::new(RealIo));
    for (k, r) in &records {
        st.append_result(*k, 1, r).expect("clean populate");
    }
    st.flush_index().expect("clean flush");
    drop(st);

    // At least one span is in the buffer regardless of test ordering.
    {
        let _probe = obs::span("obs_chaos_probe");
    }

    let n = schedules(100);
    for seed in 0..n {
        let trace = base.join(format!("trace-{seed}.json"));
        let io: Arc<dyn StoreIo> = Arc::new(FaultIo::seeded(0x7AC3 ^ seed));
        match write_chrome_trace_with(&io, &trace) {
            Ok(written) => {
                assert!(written > 0, "seed {seed}: the probe span must be in the snapshot");
                let body = std::fs::read_to_string(&trace)
                    .unwrap_or_else(|e| panic!("seed {seed}: Ok write must be readable: {e}"));
                let events = parse_chrome_trace(&body)
                    .unwrap_or_else(|e| panic!("seed {seed}: Ok write must parse: {e:#}"));
                assert!(
                    events.len() >= written,
                    "seed {seed}: {} event(s) for {written} span(s) written",
                    events.len()
                );
            }
            Err(_) => {
                // A failed export loses nothing: the buffer still holds
                // the spans and a clean retry writes a parseable trace.
                assert!(
                    obs::span::snapshot().iter().any(|s| s.name == "obs_chaos_probe"),
                    "seed {seed}: a failed write must not drain the span buffer"
                );
                let retry: Arc<dyn StoreIo> = Arc::new(RealIo);
                let written = write_chrome_trace_with(&retry, &trace)
                    .unwrap_or_else(|e| panic!("seed {seed}: clean retry must land: {e:#}"));
                assert!(written > 0, "seed {seed}: retry wrote an empty trace");
            }
        }
        std::fs::remove_file(&trace).ok();
    }

    // Telemetry never bleeds into results: every record still serves
    // bit-exact on clean I/O.
    let mut check = SegmentStore::open_with(&store_dir, ROLL, Arc::new(RealIo));
    for (k, r) in &records {
        let got = check
            .lookup_result(*k)
            .unwrap_or_else(|| panic!("{k:016x} missing after the trace wall"))
            .expect("record reads clean");
        assert_eq!(
            serialize_result(*k, &got),
            serialize_result(*k, r),
            "trace writes disturbed stored result {k:016x}"
        );
    }
    std::fs::remove_dir_all(&base).ok();
}

/// The flagship grid invariant: a plan run as two disjoint shards on
/// separate stores, then merged, is bit-identical to the same plan run
/// on a single host — and the planner serves the merged store with zero
/// fresh engine runs.
#[test]
fn two_shard_grid_merge_matches_single_host_bit_for_bit() {
    let base = tmp("grid_bitident");
    std::fs::remove_dir_all(&base).ok();
    let m = coffee_lake();
    let mut points = Vec::new();
    for pf in [true, false] {
        for s in [1u32, 2, 4, 8, 16, 32] {
            points.push(SimPoint::micro(m, MicroOp::LoadAligned, s, 1 << 20, pf, false));
        }
    }
    let distinct: std::collections::HashSet<u64> = points.iter().map(|p| p.key()).collect();
    assert_eq!(distinct.len(), points.len(), "this plan has no duplicate keys");

    // Single host.
    let single_store = ResultStore::persistent(base.join("single"));
    let single = Planner::new(&single_store).run(&points).unwrap();
    let want: Vec<String> =
        points.iter().zip(&single).map(|(p, r)| serialize_result(p.key(), r)).collect();
    drop(single_store);

    // Two shards, each on its own store, each writing its manifest.
    let dirs = [base.join("shard-1"), base.join("shard-2")];
    let mut owned_total = 0;
    for (i, dir) in dirs.iter().enumerate() {
        let shard = ShardSpec::new(i as u32 + 1, 2).unwrap();
        let store = ResultStore::persistent(dir);
        let report = grid::run_shard(&store, shard, &points).unwrap();
        assert_eq!(report.plan_points, points.len() as u64);
        owned_total += report.owned;
        let manifest = grid::load_manifest(&RealIo, &report.manifest).unwrap();
        assert_eq!(manifest.keys.len() as u64, report.owned);
        assert!(manifest.keys.iter().all(|&k| shard.owns(k)), "manifest matches partition");
    }
    assert_eq!(owned_total, points.len() as u64, "shards partition the plan exactly");

    // Merge the shards and serve the full plan with zero engine runs.
    let merged_dir = base.join("merged");
    let sources = dirs.to_vec();
    let report = grid::merge(&sources, &merged_dir).unwrap();
    assert!(report.is_clean());
    assert_eq!(report.merged, points.len() as u64);
    assert_eq!(report.manifests_seen, 2, "both shard manifests validated");
    let merged_store = ResultStore::persistent(&merged_dir);
    let served = Planner::new(&merged_store).run(&points).unwrap();
    assert_eq!(merged_store.stats().engine_runs, 0, "merged grid run is fully warm");
    for ((p, w), r) in points.iter().zip(&want).zip(&served) {
        assert_eq!(
            &serialize_result(p.key(), r),
            w,
            "grid+merge diverged from single host on {}",
            p.label()
        );
    }

    // Re-merging is a no-op.
    let again = grid::merge(&sources, &merged_dir).unwrap();
    assert_eq!((again.merged, again.already_present), (0, points.len() as u64));
    std::fs::remove_dir_all(&base).ok();
}
