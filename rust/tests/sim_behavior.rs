//! Behavioral tests of the simulation engine: the paper's headline
//! effects (multi-striding gains, hit-ratio shapes, NT-store collapse),
//! plus the reuse (`reset`/`prepare`) and prefetcher-plugin contracts of
//! the refactored pipeline. Moved out of `sim/engine.rs` when the engine
//! was decomposed — everything here drives the public API only.

use multistride::config::{cascade_lake, coffee_lake};
use multistride::prefetch::{
    Observation, PrefetchContext, PrefetchEngine, PrefetchLevel, PrefetchReq,
};
use multistride::sim::{Engine, EngineConfig};
use multistride::trace::{Access, Op};

fn engine(prefetch: bool) -> Engine {
    Engine::new(EngineConfig::new(coffee_lake()).with_prefetch(prefetch).with_huge_pages(true))
}

/// Sequential aligned 32 B loads over `bytes` of memory.
fn seq_loads(bytes: u64) -> impl Iterator<Item = Access> {
    (0..bytes / 32).map(|i| Access::new(i * 32, Op::Load, 32, (i % 32) as u32))
}

/// `n` concurrent strides covering `bytes` total, grouped arrangement,
/// 32 unroll slots. Stride spans use an odd line count so concurrent
/// streams spread over cache sets (the non-power-of-two §4 setup).
fn strided_loads(bytes: u64, n: u64) -> Vec<Access> {
    let stride_bytes = ((bytes / n / 64) | 1) * 64;
    let per = stride_bytes / 32; // vectors per stride
    let unrolls_per_stride = 32 / n.min(32);
    let mut out = Vec::new();
    let mut pos = 0u64;
    while pos < per {
        for s in 0..n {
            for u in 0..unrolls_per_stride {
                if pos + u < per {
                    let ip = (s * unrolls_per_stride + u) as u32;
                    out.push(Access::new(s * stride_bytes + (pos + u) * 32, Op::Load, 32, ip));
                }
            }
        }
        pos += unrolls_per_stride;
    }
    out
}

const MIB: u64 = 1 << 20;

#[test]
fn sequential_read_beats_prefetch_off() {
    let bytes = 8 * MIB;
    let mut on = engine(true);
    let r_on = on.run(seq_loads(bytes));
    let mut off = engine(false);
    let r_off = off.run(seq_loads(bytes));
    assert!(
        r_on.throughput_gib() > r_off.throughput_gib() * 1.2,
        "prefetch on {:.2} GiB/s must beat off {:.2} GiB/s",
        r_on.throughput_gib(),
        r_off.throughput_gib()
    );
}

#[test]
fn multi_stride_beats_single_stride_with_prefetch() {
    let bytes = 16 * MIB;
    let mut e1 = engine(true);
    let r1 = e1.run(strided_loads(bytes, 1));
    let mut e8 = engine(true);
    let r8 = e8.run(strided_loads(bytes, 8));
    assert!(
        r8.throughput_gib() > r1.throughput_gib() * 1.1,
        "8 strides {:.2} must beat 1 stride {:.2}",
        r8.throughput_gib(),
        r1.throughput_gib()
    );
}

#[test]
fn multi_stride_does_not_help_without_prefetch() {
    let bytes = 16 * MIB;
    let mut e1 = engine(false);
    let r1 = e1.run(strided_loads(bytes, 1));
    let mut e8 = engine(false);
    let r8 = e8.run(strided_loads(bytes, 8));
    assert!(
        r8.throughput_gib() <= r1.throughput_gib() * 1.05,
        "without prefetch 8 strides {:.2} must not beat 1 stride {:.2}",
        r8.throughput_gib(),
        r1.throughput_gib()
    );
}

#[test]
fn l1_hit_ratio_is_half_for_streaming_reads() {
    let mut e = engine(true);
    let r = e.run(seq_loads(8 * MIB));
    let ratio = r.l1.hit_ratio();
    assert!((ratio - 0.5).abs() < 0.02, "Figure 4: L1 hit ratio pinned at 0.5, got {ratio:.3}");
}

#[test]
fn l2_hit_ratio_rises_with_strides() {
    let bytes = 16 * MIB;
    let mut e1 = engine(true);
    let r1 = e1.run(strided_loads(bytes, 1));
    let mut e16 = engine(true);
    let r16 = e16.run(strided_loads(bytes, 16));
    assert!(
        r16.l2.hit_ratio() > r1.l2.hit_ratio() + 0.1,
        "L2 hit ratio must rise: 1-stride {:.3} vs 16-stride {:.3}",
        r1.l2.hit_ratio(),
        r16.l2.hit_ratio()
    );
}

#[test]
fn prefetch_off_zeroes_l2_l3_hit_ratio() {
    let mut e = engine(false);
    let r = e.run(seq_loads(8 * MIB));
    assert!(r.l2.hit_ratio() < 0.05, "no reuse, no prefetch => no L2 hits");
    assert!(r.l3.hit_ratio() < 0.05);
}

#[test]
fn counters_satisfy_subset_invariant() {
    for pf in [false, true] {
        for n in [1, 4, 16] {
            let mut e = engine(pf);
            let r = e.run(strided_loads(8 * MIB, n));
            assert!(r.counters.subset_invariant_holds(), "pf={pf} n={n}: {:?}", r.counters);
        }
    }
}

#[test]
fn stores_consume_write_bandwidth() {
    // Footprint must dwarf the 12 MiB L3 so most dirty lines actually
    // write back (at 60 MiB, ~80% of lines are evicted dirty).
    let bytes = 60 * MIB;
    let mut e = engine(true);
    let loads = e.run(seq_loads(bytes)).throughput_gib();
    let mut e2 = engine(true);
    let stores = e2
        .run((0..bytes / 32).map(|i| Access::new(i * 32, Op::Store, 32, (i % 32) as u32)))
        .throughput_gib();
    assert!(
        stores < loads * 0.85,
        "RFO+writeback store stream {stores:.2} must trail read stream {loads:.2}"
    );
}

#[test]
fn nt_store_grouped_beats_interleaved_many_strides() {
    let bytes = 8 * MIB;
    let n = 16u64;
    let per = bytes / n; // bytes per stride
    // Grouped: finish each line before next stride touches anything.
    let mut grouped = Vec::new();
    let mut interleaved = Vec::new();
    let vectors_per_stride = per / 32;
    for v in 0..vectors_per_stride {
        for s in 0..n {
            interleaved.push(Access::new(s * per + v * 32, Op::StoreNt, 32, s as u32));
        }
    }
    for chunk in 0..vectors_per_stride / 2 {
        for s in 0..n {
            for half in 0..2u64 {
                grouped.push(Access::new(
                    s * per + chunk * 64 + half * 32,
                    Op::StoreNt,
                    32,
                    s as u32,
                ));
            }
        }
    }
    let mut eg = engine(true);
    let tg = eg.run(grouped).throughput_gib();
    let mut ei = engine(true);
    let ti = ei.run(interleaved).throughput_gib();
    assert!(
        tg > ti * 2.0,
        "grouped NT {tg:.2} GiB/s must dwarf interleaved NT {ti:.2} GiB/s (write-combining)"
    );
}

#[test]
fn unaligned_loads_slightly_slower() {
    let bytes = 8 * MIB;
    let mut ea = engine(true);
    let ta = ea.run(seq_loads(bytes)).throughput_gib();
    let mut eu = engine(true);
    let tu = eu
        .run((0..bytes / 32 - 1).map(|i| Access::new(i * 32 + 4, Op::LoadU, 32, (i % 32) as u32)))
        .throughput_gib();
    assert!(tu < ta, "unaligned {tu:.2} must trail aligned {ta:.2}");
    assert!(tu > ta * 0.7, "but not by much");
}

#[test]
fn throughput_below_model_roofline() {
    let m = coffee_lake();
    let mut e = engine(true);
    let r = e.run(strided_loads(16 * MIB, 16));
    assert!(r.throughput_gib() <= m.model_peak_gib() * 1.001);
}

#[test]
fn warmup_then_measure_keeps_cache_state() {
    let mut e = engine(true);
    // Warm with the first 4 MiB...
    e.warmup(seq_loads(4 * MIB));
    // ...measure re-reading the same 4 MiB minus what L3 can hold: the
    // first 12 MiB fit nowhere fully, but re-reading 4 MiB after warmup
    // finds a good chunk in L3 (12 MiB L3, nothing else touched).
    let r = e.run(seq_loads(4 * MIB));
    assert!(r.l3.hit_ratio() > 0.5, "warm L3 must serve re-read, ratio {:.3}", r.l3.hit_ratio());
}

#[test]
fn reset_restores_cold_state() {
    let mut e = engine(true);
    e.run(seq_loads(MIB));
    e.reset();
    let r = e.run(seq_loads(MIB));
    assert_eq!(r.l3.hit_ratio(), 0.0, "cold again after reset");
}

// ---- engine reuse (`prepare`) ------------------------------------------

/// Field-by-field comparison of two runs (RunResult has f64s, so no Eq).
fn assert_results_identical(a: &multistride::sim::RunResult, b: &multistride::sim::RunResult) {
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.l1, b.l1);
    assert_eq!(a.l2, b.l2);
    assert_eq!(a.l3, b.l3);
    assert_eq!(a.dram, b.dram);
    assert_eq!(a.wc, b.wc);
    assert_eq!(a.tlb, b.tlb);
    assert_eq!(a.streamer, b.streamer);
}

#[test]
fn prepare_reuse_is_bit_identical_with_fresh_engines() {
    let m = coffee_lake();
    let configs = [
        EngineConfig::new(m).with_prefetch(true).with_huge_pages(true),
        EngineConfig::new(m).with_prefetch(false).with_huge_pages(true),
        EngineConfig::new(m).with_prefetch(true).with_huge_pages(false),
        EngineConfig::new(m).with_prefetch(true).with_huge_pages(true),
    ];
    let mut reused = Engine::new(configs[0]);
    for cfg in configs {
        reused.prepare(cfg);
        let got = reused.run(strided_loads(2 * MIB, 4));
        let mut fresh = Engine::new(cfg);
        let want = fresh.run(strided_loads(2 * MIB, 4));
        assert_results_identical(&got, &want);
    }
}

#[test]
fn prepare_across_machines_rebuilds() {
    let mut e = Engine::new(EngineConfig::new(coffee_lake()).with_prefetch(true));
    e.run(strided_loads(MIB, 2));
    let cfg = EngineConfig::new(cascade_lake()).with_prefetch(true);
    e.prepare(cfg);
    let got = e.run(strided_loads(2 * MIB, 4));
    let want = Engine::new(cfg).run(strided_loads(2 * MIB, 4));
    assert_results_identical(&got, &want);
}

// ---- prefetcher plugins -------------------------------------------------

/// A trait-only engine that never requests anything: registering it must
/// not perturb the simulation.
struct InertPrefetcher;

impl PrefetchEngine for InertPrefetcher {
    fn name(&self) -> &'static str {
        "inert"
    }
    fn level(&self) -> PrefetchLevel {
        PrefetchLevel::L2
    }
    fn observe(&mut self, _: Observation, _: &PrefetchContext<'_>, _: &mut Vec<PrefetchReq>) {}
    fn reset(&mut self) {}
}

/// A toy next-N-lines L2 engine, registered purely through the public
/// trait — the "new prefetcher model without touching the engine"
/// contract of the refactor.
struct NextLines(u64);

impl PrefetchEngine for NextLines {
    fn name(&self) -> &'static str {
        "next-lines"
    }
    fn level(&self) -> PrefetchLevel {
        PrefetchLevel::L2
    }
    fn observe(&mut self, obs: Observation, ctx: &PrefetchContext<'_>, out: &mut Vec<PrefetchReq>) {
        if !ctx.level_hit {
            for k in 1..=self.0 {
                out.push(PrefetchReq { line: obs.line + k, stream: u32::MAX, to_l1: false });
            }
        }
    }
    fn reset(&mut self) {}
}

#[test]
fn inert_plugin_changes_nothing() {
    let mut plain = engine(true);
    let want = plain.run(seq_loads(2 * MIB));
    let mut with_plugin = engine(true);
    with_plugin.register_prefetcher(Box::new(InertPrefetcher));
    let got = with_plugin.run(seq_loads(2 * MIB));
    assert_results_identical(&got, &want);
}

#[test]
fn custom_prefetcher_plugs_in_and_prefetches() {
    // Baseline: prefetching "on" but every built-in engine disabled.
    let m = coffee_lake();
    let mut cfg = EngineConfig::new(m).with_huge_pages(true);
    cfg.prefetch.streamer_enabled = false;
    cfg.prefetch.adjacent_enabled = false;
    let mut off = Engine::new(cfg);
    let r_off = off.run(seq_loads(4 * MIB));
    assert_eq!(r_off.counters.prefetch_lines, 0, "no engines => no prefetches");

    let mut with_plugin = Engine::new(cfg);
    with_plugin.register_prefetcher(Box::new(NextLines(24)));
    let r_on = with_plugin.run(seq_loads(4 * MIB));
    assert!(r_on.counters.prefetch_lines > 0, "plugged-in engine must issue prefetches");
    assert!(
        r_on.throughput_gib() > r_off.throughput_gib(),
        "24-deep lookahead must beat the LFB-limited baseline: {:.2} vs {:.2}",
        r_on.throughput_gib(),
        r_off.throughput_gib()
    );

    // The master MSR-style switch still gates registered plugins.
    let mut gated = Engine::new(cfg.with_prefetch(false));
    gated.register_prefetcher(Box::new(NextLines(24)));
    let r_gated = gated.run(seq_loads(4 * MIB));
    assert_eq!(r_gated.counters.prefetch_lines, 0, "master switch off gates plugins");
}
