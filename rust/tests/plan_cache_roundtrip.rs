//! Property wall for the on-disk plan format (`tune::plan`):
//!
//! * serialize → parse → serialize is **bit-identical** for randomized
//!   [`TunedPlan`]s, including the identity fields (spec hash, machine
//!   fingerprint) and arbitrary-bit-pattern floats (NaN, ±inf,
//!   subnormals);
//! * a corrupted or truncated plan file is rejected with a recoverable
//!   error — never a panic, and never a silently-different plan;
//! * the [`PlanCache`] file layer preserves both properties through disk;
//! * driven through seeded fault schedules on the `StoreIo` seam, torn
//!   writes and failed renames stay recoverable misses — the cache
//!   never serves a partial or stale plan, and a dead disk degrades to
//!   errors and empty listings, never panics.

use std::sync::Arc;

use multistride::exec::vfs::{FaultIo, FaultPlan, RealIo, StoreIo};
use multistride::trace::Arrangement;
use multistride::transform::StridingConfig;
use multistride::tune::{PlanCache, TunedPlan};
use multistride::util::proptest::{check, Config};
use multistride::util::Rng;

/// Random printable name: alphanumerics plus the separators real kernel
/// and machine names use (kernel names feed file paths, so no slashes).
fn rand_name(r: &mut Rng, max_len: u64, file_safe: bool) -> String {
    const SAFE: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789";
    const LOOSE: &[u8] = b"abcdefghijklmnopqrstuvwxyz ABC-XYZ_0123456789().";
    let chars = if file_safe { SAFE } else { LOOSE };
    let len = r.range(1, max_len) as usize;
    (0..len).map(|_| chars[r.below(chars.len() as u64) as usize] as char).collect()
}

fn rand_plan(r: &mut Rng, size: u32) -> TunedPlan {
    let arrangement =
        if r.chance(0.5) { Arrangement::Grouped } else { Arrangement::Interleaved };
    TunedPlan {
        kernel: rand_name(r, 2 + size as u64 / 8, true),
        machine: rand_name(r, 2 + size as u64 / 4, false),
        machine_fingerprint: r.next_u64(),
        spec_hash: r.next_u64(),
        budget_class: r.below(64) as u32,
        budget_bytes: r.next_u64() >> r.below(40),
        prefetch: r.chance(0.5),
        config: StridingConfig {
            stride_unroll: r.range(1, 64) as u32,
            portion_unroll: r.range(1, 64) as u32,
            eliminate_redundant: r.chance(0.5),
            arrangement,
        },
        // Raw bit patterns: NaNs, infinities and subnormals must all
        // survive, which is exactly why floats are stored as bits.
        predicted_gib: f64::from_bits(r.next_u64()),
        winner_probe_gib: f64::from_bits(r.next_u64()),
        baseline_probe_gib: f64::from_bits(r.next_u64()),
        predicted_accesses_per_sec: f64::from_bits(r.next_u64()),
        l1_hit: f64::from_bits(r.next_u64()),
        l2_hit: f64::from_bits(r.next_u64()),
        l3_hit: f64::from_bits(r.next_u64()),
        probe_runs: r.below(1 << 16) as u32,
        full_runs: r.below(1 << 16) as u32,
        search_sim_accesses: r.next_u64(),
    }
}

#[test]
fn serialize_parse_serialize_is_bit_identical() {
    check(
        Config { cases: 256, seed: 0x9_1A_57_1D },
        rand_plan,
        |p| {
            let s = p.serialize();
            let parsed = match TunedPlan::parse(&s) {
                Ok(q) => q,
                Err(_) => return false,
            };
            parsed.serialize() == s
        },
    );
}

#[test]
fn every_truncation_is_rejected_not_panicking() {
    let mut r = Rng::new(0x7A0);
    let p = rand_plan(&mut r, 50);
    let s = p.serialize();
    // Exhaustive over one plan (every byte boundary that is also a char
    // boundary — the format is ASCII, so that is every byte).
    assert!(s.is_ascii(), "format stays ASCII; truncation test slices bytes");
    for cut in 0..s.len() {
        assert!(
            TunedPlan::parse(&s[..cut]).is_err(),
            "truncation at {cut}/{} must be rejected",
            s.len()
        );
    }
}

#[test]
fn random_single_byte_flips_are_rejected() {
    check(
        Config { cases: 192, seed: 0xF11B },
        |r, size| {
            let p = rand_plan(r, size);
            let s = p.serialize();
            let pos = r.below(s.len() as u64) as usize;
            let old = s.as_bytes()[pos];
            // Flip to a different printable ASCII byte so the result is
            // still valid UTF-8 (the fs layer rejects non-UTF-8 uploads
            // before parse even runs).
            let mut new = old;
            while new == old {
                new = 0x20 + (r.below(95)) as u8;
            }
            let mut bytes = s.clone().into_bytes();
            bytes[pos] = new;
            (String::from_utf8(bytes).expect("printable ASCII"), pos)
        },
        |(tampered, _pos)| TunedPlan::parse(tampered).is_err(),
    );
}

#[test]
fn disk_roundtrip_through_the_cache_is_exact() {
    let dir = std::env::temp_dir()
        .join(format!("multistride_plan_roundtrip_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache = PlanCache::new(&dir);
    let mut r = Rng::new(0xD15C);
    for case in 0..32 {
        let p = rand_plan(&mut r, 1 + case * 3);
        cache.store(&p).unwrap();
        let q = cache
            .load(&p.kernel, &p.machine, p.prefetch, p.budget_class)
            .unwrap()
            .expect("stored plan loads");
        assert_eq!(p.serialize(), q.serialize(), "disk round trip is bit-identical");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_file_on_disk_is_a_recoverable_error() {
    let dir = std::env::temp_dir()
        .join(format!("multistride_plan_corrupt_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let cache = PlanCache::new(&dir);
    let mut r = Rng::new(0xBAD);
    let p = rand_plan(&mut r, 40);
    let path = cache.store(&p).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();

    // Truncated file.
    std::fs::write(&path, &text[..text.len() * 2 / 3]).unwrap();
    assert!(cache.load(&p.kernel, &p.machine, p.prefetch, p.budget_class).is_err());

    // Appended garbage.
    std::fs::write(&path, format!("{text}extra junk\n")).unwrap();
    assert!(cache.load(&p.kernel, &p.machine, p.prefetch, p.budget_class).is_err());

    // Entirely foreign content.
    std::fs::write(&path, "hello world").unwrap();
    assert!(cache.load(&p.kernel, &p.machine, p.prefetch, p.budget_class).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------------
// Fault-injected cache I/O (the `exec::vfs::StoreIo` seam)
// ---------------------------------------------------------------------------

/// Torn temp-file writes, injected ENOSPC and failed renames make
/// `store` fail loudly, and whatever state they leave behind, a clean
/// load sees either the complete plan or nothing — never a partial one.
#[test]
fn torn_plan_writes_are_recoverable_misses_never_partial_serves() {
    let dir =
        std::env::temp_dir().join(format!("multistride_plan_torn_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut r = Rng::new(0x70A9);
    let p = rand_plan(&mut r, 40);
    let (mut stored_ok, mut store_failed) = (0u32, 0u32);
    for seed in 0..100u64 {
        let io: Arc<dyn StoreIo> = Arc::new(FaultIo::seeded(seed));
        match PlanCache::with_io(&dir, io).store(&p) {
            Ok(_) => stored_ok += 1,
            Err(_) => store_failed += 1,
        }
        let clean = PlanCache::new(&dir);
        match clean.load(&p.kernel, &p.machine, p.prefetch, p.budget_class) {
            Ok(Some(q)) => assert_eq!(p.serialize(), q.serialize(), "seed {seed}: partial"),
            Ok(None) => assert_eq!(stored_ok, 0, "seed {seed}: a stored plan vanished"),
            Err(e) => panic!("seed {seed}: atomic store leaked a broken plan file: {e}"),
        }
    }
    assert!(stored_ok > 0, "some schedules must let the store through");
    assert!(store_failed > 0, "some schedules must break the store");
    std::fs::remove_dir_all(&dir).ok();
}

/// Same-key rewrites under fault schedules: a load always returns
/// exactly the last successfully stored plan — a failed rewrite leaves
/// the previous plan fully intact (never a blend, never a loss).
#[test]
fn faulted_rewrites_serve_the_last_stored_plan_never_a_blend() {
    let dir =
        std::env::temp_dir().join(format!("multistride_plan_rewrite_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut r = Rng::new(0xA17E);
    let base = rand_plan(&mut r, 30);
    PlanCache::new(&dir).store(&base).unwrap();
    let mut latest = base.serialize();
    for seed in 0..100u64 {
        // A same-key update differing in the tuned fields.
        let mut next = rand_plan(&mut r, 30);
        next.kernel = base.kernel.clone();
        next.machine = base.machine.clone();
        next.prefetch = base.prefetch;
        next.budget_class = base.budget_class;
        let io: Arc<dyn StoreIo> = Arc::new(FaultIo::seeded(0x51A1E ^ seed));
        if PlanCache::with_io(&dir, io).store(&next).is_ok() {
            latest = next.serialize();
        }
        let got = PlanCache::new(&dir)
            .load(&base.kernel, &base.machine, base.prefetch, base.budget_class)
            .expect("the plan file is never left unreadable")
            .expect("the plan file is never lost");
        assert_eq!(got.serialize(), latest, "seed {seed}: served a stale or blended plan");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// A disk that fails every operation degrades to recoverable errors and
/// empty listings — no panics, and crucially no stale serves.
#[test]
fn a_dead_disk_degrades_to_errors_and_empty_listings() {
    let dir =
        std::env::temp_dir().join(format!("multistride_plan_dead_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut r = Rng::new(0xD1ED);
    let p = rand_plan(&mut r, 30);
    PlanCache::new(&dir).store(&p).unwrap();
    let dead: Arc<dyn StoreIo> = Arc::new(FaultIo::new(Arc::new(RealIo), FaultPlan::dead_disk()));
    let cache = PlanCache::with_io(&dir, dead);
    assert!(
        cache.load(&p.kernel, &p.machine, p.prefetch, p.budget_class).is_err(),
        "a dead disk is a recoverable error, not a stale serve"
    );
    assert!(cache.store(&p).is_err(), "storing to a dead disk fails loudly");
    assert!(cache.list().is_empty(), "listing a dead disk degrades to empty");
    std::fs::remove_dir_all(&dir).ok();
}
