//! Edge-case and robustness integration tests: degenerate traces, state
//! reuse, determinism, and cross-machine sanity — the failure-injection
//! side of the suite.

use multistride::config::{cascade_lake, coffee_lake, zen2, MachinePreset};
use multistride::coordinator::experiments::{run_kernel, run_micro};
use multistride::coordinator::parallel_map;
use multistride::kernels::library::{kernel_by_name, paper_kernels};
use multistride::kernels::micro::{MicroBench, MicroOp};
use multistride::sim::{Engine, EngineConfig};
use multistride::trace::{Access, KernelTrace, Op};
use multistride::transform::{transform, StridingConfig};

const MIB: u64 = 1 << 20;

#[test]
fn empty_trace_is_zero_cycles() {
    let mut e = Engine::new(EngineConfig::new(coffee_lake()));
    let r = e.run(std::iter::empty::<Access>());
    assert_eq!(r.counters.accesses, 0);
    assert_eq!(r.counters.cycles, 0);
    assert_eq!(r.throughput_gib(), 0.0);
}

#[test]
fn single_access_completes() {
    let mut e = Engine::new(EngineConfig::new(coffee_lake()));
    let r = e.run([Access::new(0, Op::Load, 32, 0)]);
    assert_eq!(r.counters.accesses, 1);
    assert!(r.counters.cycles > 0, "one cold miss costs real cycles");
    assert!(r.counters.subset_invariant_holds());
}

#[test]
fn repeated_fence_is_idempotent() {
    let mut e = Engine::new(EngineConfig::new(coffee_lake()));
    for i in 0..1000u64 {
        e.step(Access::new(i * 32, Op::Load, 32, 0));
    }
    e.fence();
    let c1 = e.result().counters.cycles;
    e.fence();
    let c2 = e.result().counters.cycles;
    assert_eq!(c1, c2, "second fence with nothing outstanding adds no time");
}

#[test]
fn high_addresses_do_not_overflow() {
    // Near the top of the 32-bit-immediate-addressable region the paper
    // uses (and beyond).
    let base = (1u64 << 40) - 4096;
    let mut e = Engine::new(EngineConfig::new(coffee_lake()));
    let r = e.run((0..1024u64).map(|i| Access::new(base + i * 32, Op::Load, 32, 0)));
    assert_eq!(r.counters.accesses, 1024);
    assert!(r.counters.subset_invariant_holds());
}

#[test]
fn deterministic_across_runs() {
    let bytes = 4 * MIB;
    let run = || {
        let b = MicroBench::new(MicroOp::CopyAligned, 8, bytes);
        let mut e = Engine::new(EngineConfig::new(coffee_lake()).with_huge_pages(true));
        let r = e.run(b.trace());
        (r.counters.cycles, r.counters.stalls_total, r.dram.reads, r.dram.writes)
    };
    assert_eq!(run(), run(), "simulation must be fully deterministic");
}

#[test]
fn all_machines_run_all_micro_ops() {
    for m in [coffee_lake(), cascade_lake(), zen2()] {
        for op in MicroOp::all() {
            let p = run_micro(m, op, 4, 2 * MIB, true, false);
            assert!(
                p.throughput_gib > 0.1 && p.throughput_gib <= m.model_peak_gib() * 2.5,
                "{} / {:?}: {:.2} GiB/s out of sane range",
                m.name,
                op,
                p.throughput_gib
            );
        }
    }
}

#[test]
fn all_kernels_simulate_on_all_machines() {
    for preset in MachinePreset::all() {
        let m = preset.config();
        for pk in paper_kernels(4 * MIB) {
            let p = run_kernel(m, &pk.name, 4 * MIB, StridingConfig::new(2, 2), true)
                .expect("library kernel");
            assert!(p.feasible, "{} on {}", pk.name, m.name);
            assert!(
                p.throughput_gib > 0.1,
                "{} on {}: {:.3} GiB/s",
                pk.name,
                m.name,
                p.throughput_gib
            );
        }
    }
}

#[test]
fn unknown_kernel_returns_none() {
    assert!(run_kernel(coffee_lake(), "nope", MIB, StridingConfig::new(1, 1), true).is_none());
}

#[test]
fn trace_iterator_is_fused_after_end() {
    let k = kernel_by_name("writeback", MIB).unwrap();
    let t = transform(&k.spec, StridingConfig::new(2, 1)).unwrap();
    let kt = KernelTrace::new(t);
    let mut it = kt.iter();
    let n = (&mut it).count();
    assert!(n > 0);
    assert!(it.next().is_none());
    assert!(it.next().is_none(), "stays exhausted");
}

#[test]
fn parallel_map_matches_serial() {
    let jobs: Vec<u32> = (0..37).collect();
    let serial: Vec<u64> = jobs.iter().map(|&j| (j as u64) * 3 + 1).collect();
    let parallel = parallel_map(jobs, 5, |&j| (j as u64) * 3 + 1);
    assert_eq!(serial, parallel);
}

#[test]
fn warmup_reset_cycle_is_stable() {
    // warmup -> measure -> reset -> warmup -> measure gives the same
    // measurement (the paper's repetition protocol relies on this).
    let bytes = 2 * MIB;
    let measure = |e: &mut Engine| {
        let b = MicroBench::new(MicroOp::LoadAligned, 4, bytes);
        e.warmup(b.trace());
        let r = e.run(b.trace());
        r.counters.cycles
    };
    let mut e = Engine::new(EngineConfig::new(coffee_lake()).with_huge_pages(true));
    let c1 = measure(&mut e);
    e.reset();
    let c2 = measure(&mut e);
    assert_eq!(c1, c2);
}

#[test]
fn interleaved_and_grouped_touch_same_data() {
    let bytes = 2 * MIB;
    let g = MicroBench::new(MicroOp::StoreNt, 8, bytes);
    let i = MicroBench::new(MicroOp::StoreNt, 8, bytes).interleaved();
    let mut ga: Vec<u64> = g.trace().map(|a| a.addr).collect();
    let mut ia: Vec<u64> = i.trace().map(|a| a.addr).collect();
    ga.sort_unstable();
    ia.sort_unstable();
    assert_eq!(ga, ia);
}

#[test]
fn nt_loads_behave_like_plain_loads_on_wb_memory() {
    // §3/§4.3: vmovntdqa on write-back memory ignores the NT hint.
    let bytes = 4 * MIB;
    let a = run_micro(coffee_lake(), MicroOp::LoadAligned, 8, bytes, true, false);
    let nt = run_micro(coffee_lake(), MicroOp::LoadNt, 8, bytes, true, false);
    assert!((a.throughput_gib - nt.throughput_gib).abs() < 0.25);
}

#[test]
fn zero_sized_kernel_budget_rejected_gracefully() {
    // A budget too small for any row structure must fail in transform, not
    // panic downstream.
    let k = kernel_by_name("mxv", 1 << 12).unwrap();
    // (square_extent clamps to 1024; stride 32 over 1024 rows still fine —
    // but portion unroll beyond the row length must error.)
    let r = transform(&k.spec, StridingConfig::new(1, 4096));
    assert!(r.is_err());
}
