//! Integration pins for the observability layer (`multistride::obs`):
//!
//! * the `--trace` counter snapshot is **deterministic** — two identical
//!   cold runs fold to byte-identical JSON;
//! * the `[exec]` / `[serve]` summary lines render from the metrics
//!   registry, so a counter renamed or dropped from the fold breaks
//!   these tests before it silently drifts from `GET /metrics`;
//! * `write_trace_artifacts` produces a trace the dependency-free
//!   parser (and Perfetto) can load, plus the counter sibling.
//!
//! Exact-value assertions use private [`Registry`] instances: the test
//! binary is multi-threaded and the global registry is shared.

use std::path::PathBuf;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::EngineCache;
use multistride::exec::{simulate, ExecStats, SimPoint};
use multistride::kernels::micro::MicroOp;
use multistride::obs::export::{json_snapshot, parse_json_snapshot};
use multistride::obs::trace::parse_chrome_trace;
use multistride::obs::{self, Registry};
use multistride::report::figures;
use multistride::serve::{MissPolicy, Policy, ServeStats};

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("multistride_obs_{tag}_{}", std::process::id()))
}

fn point(strides: u32) -> SimPoint {
    SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, strides, 1 << 20, true, false)
}

/// Satellite 4: the counter snapshot from two identical cold runs is
/// byte-identical. The simulator is deterministic and the snapshot
/// excludes every timing source, so nothing wall-clock can leak in.
#[test]
fn identical_cold_runs_fold_to_byte_identical_snapshots() {
    let run = || {
        let reg = Registry::new();
        let mut engines = EngineCache::new();
        for strides in [1u32, 2, 4] {
            let r = simulate(&mut engines, &point(strides)).expect("micro point simulates");
            obs::fold_run_result_into(&reg, &r);
        }
        json_snapshot(&reg.snapshot())
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "counter snapshots must be byte-identical across reruns");
    assert!(first.contains("\"sim_accesses_total\""), "got: {first}");
    assert!(first.contains("\"sim_engine_runs_total\": 3"), "got: {first}");
    // And the snapshot survives its own line grammar.
    let entries = parse_json_snapshot(&first).expect("snapshot parses");
    assert!(entries.iter().any(|(n, v)| n == "sim_engine_runs_total" && *v == 3));
}

/// Satellite 2 anti-drift: the `[exec]` line is rendered from the
/// registry fold, and every ExecStats field surfaces under its label.
/// Distinct prime-ish values make a swapped pair impossible to miss.
#[test]
fn exec_summary_renders_every_folded_field() {
    let stats = ExecStats {
        requests: 101,
        mem_hits: 31,
        disk_hits: 17,
        legacy_hits: 7,
        misses: 53,
        deduped: 11,
        engine_runs: 47,
        disk_writes: 43,
        corrupt_discards: 5,
        verified_hits: 3,
        disk_errors: 13,
        dropped_unsimulatable: 2,
        degraded: true,
    };
    let reg = Registry::new();
    // Pool and fleet counters fold at their own stage boundaries; seed
    // them here so the single formatter proves it renders every family.
    reg.counter_set("pool_jobs_claimed_total", 89);
    reg.counter_set("pool_steals_total", 23);
    reg.counter_set("grid_fleet_drains_total", 1);
    reg.counter_set("grid_results_received_total", 67);
    reg.counter_set("grid_workers_total", 2);
    reg.counter_set("grid_lease_reassignments_total", 1);
    let snap = obs::fold_exec_stats(&reg, &stats);
    let line = figures::render_exec_summary_from(&snap, None);
    assert!(line.starts_with("[exec] "), "got: {line}");
    assert!(line.contains("sim points: 101 requests"), "got: {line}");
    assert!(line.contains("engine runs: 47"), "got: {line}");
    assert!(line.contains("store hits: 48 (mem 31 / disk 17)"), "got: {line}");
    assert!(line.contains("deduped: 11"), "got: {line}");
    assert!(line.contains("written: 43"), "got: {line}");
    assert!(line.contains("legacy-shard hits: 7"), "got: {line}");
    assert!(line.contains("corrupt discards: 5"), "got: {line}");
    assert!(line.contains("disk errors: 13"), "got: {line}");
    assert!(line.contains("unsimulatable hits dropped: 2"), "got: {line}");
    assert!(line.contains("debug-verified hits: 3"), "got: {line}");
    assert!(line.contains("PERSISTENT TIER DISABLED"), "got: {line}");
    assert!(line.contains("results dir: (none"), "got: {line}");
    assert!(line.contains("pool: 89 job(s) claimed / 23 steal(s)"), "got: {line}");
    assert!(line.contains("fleet: 67 result(s) from 2 worker(s), 1 re-lease(s)"), "got: {line}");
    assert!(line.ends_with('\n'), "the summary is a complete greppable line");
}

/// The pool and fleet segments are conditional: a store-only command
/// that never spun the pool keeps the historic `[exec]` line shape, so
/// CI greps and old log diffs stay valid.
#[test]
fn exec_summary_omits_pool_and_fleet_segments_when_idle() {
    let stats = ExecStats { requests: 4, mem_hits: 4, ..ExecStats::default() };
    let reg = Registry::new();
    let snap = obs::fold_exec_stats(&reg, &stats);
    let line = figures::render_exec_summary_from(&snap, None);
    assert!(!line.contains("pool:"), "got: {line}");
    assert!(!line.contains("fleet:"), "got: {line}");
}

/// Scheduling-shaped counters (steal counts, lease churn) are visible
/// to a live scraper but never reach the deterministic `--trace`
/// snapshot — otherwise two identical cold runs could differ by thread
/// timing alone.
#[test]
fn scheduling_counters_stay_out_of_the_deterministic_snapshot() {
    let reg = Registry::new();
    reg.counter_set("pool_jobs_claimed_total", 12);
    reg.counter_set("pool_steals_total", 5);
    reg.counter_set("grid_batches_granted_total", 3);
    reg.counter_set("grid_results_received_total", 12);
    let snap = reg.snapshot();
    let json = json_snapshot(&snap);
    assert!(json.contains("\"pool_jobs_claimed_total\": 12"), "got: {json}");
    assert!(json.contains("\"grid_results_received_total\": 12"), "got: {json}");
    assert!(!json.contains("pool_steals_total"), "got: {json}");
    assert!(!json.contains("grid_batches_granted_total"), "got: {json}");
    let prom = multistride::obs::export::prometheus_text(&snap);
    assert!(prom.contains("pool_steals_total 5\n"), "got: {prom}");
    assert!(prom.contains("grid_batches_granted_total 3\n"), "got: {prom}");
}

/// Same pin for the `[serve]` line — CI's serve-smoke job greps `pool
/// hits:` and `tunes:` out of it, so the registry-rendered form must
/// keep every figure.
#[test]
fn serve_summary_renders_every_folded_field() {
    let stats = ServeStats {
        pool: multistride::serve::PoolStats {
            requests: 200,
            hits: 150,
            misses: 50,
            insertions: 23,
            evictions: 19,
            rejected_oversize: 3,
            current_bytes: 4096,
            current_entries: 29,
            capacity_bytes: 65536,
        },
        policy: Policy::Sieve,
        on_miss: MissPolicy::Tune,
        disk_loads: 37,
        tunes: 41,
        tune_failures: 2,
        single_flight_waits: 5,
        not_found: 59,
        bad_requests: 61,
    };
    let reg = Registry::new();
    let snap = obs::fold_serve_stats(&reg, &stats);
    let line =
        figures::render_serve_summary_from(&snap, stats.policy.cli_name(), stats.on_miss.cli_name());
    assert!(line.starts_with("[serve] "), "got: {line}");
    assert!(line.contains("requests: 200"), "got: {line}");
    assert!(line.contains("pool hits: 150 (75.0%)"), "got: {line}");
    assert!(line.contains("misses: 50"), "got: {line}");
    assert!(line.contains("disk plans: 37"), "got: {line}");
    assert!(line.contains("tunes: 41"), "got: {line}");
    assert!(line.contains("404s: 59"), "got: {line}");
    assert!(line.contains("400s: 61"), "got: {line}");
    assert!(line.contains("evictions: 19"), "got: {line}");
    assert!(line.contains("pool: 4096/65536 B in 29 entry(ies)"), "got: {line}");
    assert!(line.contains("policy: sieve"), "got: {line}");
    assert!(line.contains("on-miss: tune"), "got: {line}");
    assert!(line.contains("tune failures: 2"), "got: {line}");
    assert!(line.contains("single-flight waits: 5"), "got: {line}");
    assert!(line.contains("oversize rejects: 3"), "got: {line}");
}

/// End to end through the library surface `main` uses: record spans,
/// write both artifacts, and read them back with the same parsers
/// `repro obs report` runs.
#[test]
fn trace_artifacts_round_trip_through_the_report_parsers() {
    let dir = tmp("artifacts");
    std::fs::remove_dir_all(&dir).ok();
    {
        let _outer = obs::span("obs_test_outer");
        let _inner = obs::span("obs_test_inner");
    }
    // The snapshot parser refuses an empty file, and nothing else in
    // this test binary folds into the global registry.
    obs::global().counter_add("obs_test_probe_total", 1);
    let trace = dir.join("run.json");
    let arts = obs::write_trace_artifacts(&trace).expect("artifacts write");
    assert_eq!(arts.trace, trace);
    assert_eq!(arts.counters, dir.join("run.counters.json"));
    assert!(arts.spans >= 2, "both guards must have recorded, got {}", arts.spans);

    let body = std::fs::read_to_string(&trace).unwrap();
    let events = parse_chrome_trace(&body).expect("trace parses");
    assert_eq!(events.len(), arts.spans, "one event per recorded span");
    for name in ["obs_test_outer", "obs_test_inner"] {
        assert!(events.iter().any(|e| e.name == name), "{name} missing from trace");
    }

    let counters = std::fs::read_to_string(&arts.counters).unwrap();
    let entries = parse_json_snapshot(&counters).expect("counter snapshot parses");
    assert!(!entries.is_empty(), "global registry has folded at least span bookkeeping");
    std::fs::remove_dir_all(&dir).ok();
}

/// The span aggregation `repro obs report` renders: totals roll up by
/// name and sort by total time descending.
#[test]
fn span_aggregation_feeds_the_report_table() {
    let aggs = obs::span::aggregate([("merge", 50u64), ("shard", 400), ("merge", 150), ("probe", 9)]);
    let table = figures::render_span_report(&aggs);
    assert!(table.contains("Top spans"), "got: {table}");
    let shard = table.find("shard").unwrap();
    let merge = table.find("merge").unwrap();
    let probe = table.find("probe").unwrap();
    assert!(shard < merge && merge < probe, "rows sort by total time desc:\n{table}");
    assert!(table.contains("0.400"), "shard total ms, got: {table}");
    assert!(table.contains("100"), "merge mean us, got: {table}");
}
