//! Exit-code pins for the `repro` binary's store and grid surfaces.
//!
//! The contract scripts and CI gate on:
//!
//! * `0` — clean run (`store verify` found nothing wrong; `store merge`
//!   applied or skipped every record without conflicts);
//! * `1` — the operation ran but found real trouble (unhealed
//!   corruption, quarantined merge conflicts, an unusable grid setup);
//! * `2` — the invocation itself is malformed (unknown subcommand,
//!   missing required flags).
//!
//! These tests drive the actual binary (`CARGO_BIN_EXE_repro`), not the
//! library, so the process boundary — argv parsing, stream routing,
//! exit status — is what is pinned.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};
use std::sync::Arc;

use multistride::exec::format::{decode_result_bin, RESULT_BIN_BYTES};
use multistride::exec::segment::SegmentStore;
use multistride::exec::vfs::RealIo;
use multistride::util::Rng;

fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro")).args(args).output().expect("repro spawns")
}

fn code(out: &Output) -> i32 {
    out.status.code().expect("repro exits rather than dying on a signal")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("multistride_cli_{tag}_{}", std::process::id()))
}

/// Fill a store directory with `n` synthetic records; returns their keys.
fn populate(dir: &Path, rng: &mut Rng, n: usize) -> Vec<u64> {
    let mut st = SegmentStore::open_with(dir, 1 << 20, Arc::new(RealIo));
    let mut keys = Vec::new();
    for _ in 0..n {
        let key = rng.next_u64();
        let mut bytes = [0u8; RESULT_BIN_BYTES];
        for b in bytes.iter_mut() {
            *b = rng.below(256) as u8;
        }
        st.append_result(key, 1, &decode_result_bin(&bytes).unwrap()).unwrap();
        keys.push(key);
    }
    st.flush_index().unwrap();
    keys
}

#[test]
fn store_verify_exits_zero_on_clean_and_one_on_corruption() {
    let dir = tmp("verify");
    std::fs::remove_dir_all(&dir).ok();
    let mut rng = Rng::new(0xCB1);
    populate(&dir, &mut rng, 3);
    let dirs = dir.to_str().unwrap();
    let clean = repro(&["store", "verify", "--results", dirs, "--smoke"]);
    assert_eq!(code(&clean), 0, "clean store must verify green\n{}", stderr(&clean));

    // A corrupt legacy shard is real, reportable damage: exit 1.
    std::fs::create_dir_all(dir.join("ab")).unwrap();
    std::fs::write(dir.join("ab").join("00ab4dbadc0ffee0.simres"), "not a result").unwrap();
    let bad = repro(&["store", "verify", "--results", dirs, "--smoke"]);
    assert_eq!(code(&bad), 1, "unhealed corruption must exit nonzero");
    assert!(stderr(&bad).contains("FAILED"), "failure is announced on stderr");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn store_merge_exit_codes_gate_on_conflicts() {
    let base = tmp("merge");
    std::fs::remove_dir_all(&base).ok();
    let (a, b, c, dst) = (base.join("a"), base.join("b"), base.join("c"), base.join("dst"));
    let mut rng = Rng::new(0x9E5);
    let keys_a = populate(&a, &mut rng, 3);
    populate(&b, &mut rng, 3);
    let (astr, bstr) = (a.to_str().unwrap(), b.to_str().unwrap());
    let dstr = dst.to_str().unwrap();

    let first = repro(&["store", "merge", astr, bstr, "--into", dstr]);
    assert_eq!(code(&first), 0, "disjoint merge is clean\n{}", stderr(&first));
    assert!(stdout(&first).contains("6 record(s) merged"), "got: {}", stdout(&first));

    let again = repro(&["store", "merge", astr, bstr, "--into", dstr]);
    assert_eq!(code(&again), 0, "re-merge stays clean");
    assert!(stdout(&again).contains("0 record(s) merged"), "re-merge must be a no-op");

    // Same key, different bytes: the quarantine gate goes red.
    let mut st = SegmentStore::open_with(&c, 1 << 20, Arc::new(RealIo));
    let mut bytes = [0u8; RESULT_BIN_BYTES];
    for x in bytes.iter_mut() {
        *x = rng.below(256) as u8;
    }
    st.append_result(keys_a[0], 1, &decode_result_bin(&bytes).unwrap()).unwrap();
    st.flush_index().unwrap();
    drop(st);
    let conflicted = repro(&["store", "merge", c.to_str().unwrap(), "--into", dstr]);
    assert_eq!(code(&conflicted), 1, "quarantined conflicts must exit nonzero");
    assert!(stderr(&conflicted).contains("CONFLICTS"), "got: {}", stderr(&conflicted));
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn store_cli_grammar_errors_exit_two() {
    assert_eq!(code(&repro(&["store", "merge", "a", "b"])), 2, "--into is required");
    assert_eq!(code(&repro(&["store", "merge", "--into", "d"])), 2, "one SRC is required");
    assert_eq!(code(&repro(&["store", "merge", "a", "--smoke", "--into", "d"])), 2);
    assert_eq!(code(&repro(&["store", "gc"])), 2, "gc without a bound is refused");
    assert_eq!(code(&repro(&["store", "frobnicate"])), 2, "unknown subcommand");
    assert_eq!(code(&repro(&["store"])), 2, "missing subcommand");
}

/// Every flag that takes a value, with the value missing, must exit 2
/// through `usage()` — not panic (exit 101 + backtrace). `repro all
/// --results` used to do exactly that.
#[test]
fn missing_flag_values_exit_two_without_panicking() {
    const VALUE_FLAGS: &[&str] = &[
        "--machine",
        "--kernel",
        "--max-total",
        "--csv",
        "--artifacts",
        "--config",
        "--plans",
        "--results",
        "--shard",
        "--trace",
    ];
    for flag in VALUE_FLAGS {
        let out = repro(&["all", flag]);
        assert_eq!(code(&out), 2, "{flag} with no value must exit 2\n{}", stderr(&out));
        let err = stderr(&out);
        assert!(err.contains("needs a value"), "{flag}: got: {err}");
        assert!(err.contains("usage:"), "{flag}: usage text must be printed\ngot: {err}");
        assert!(!err.contains("panicked"), "{flag}: no panic may reach the boundary\ngot: {err}");
    }
}

#[test]
fn non_numeric_max_total_exits_two_without_panicking() {
    let out = repro(&["all", "--max-total", "foo"]);
    assert_eq!(code(&out), 2, "got: {}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("--max-total needs a number"), "got: {err}");
    assert!(err.contains("usage:"), "got: {err}");
    assert!(!err.contains("panicked"), "got: {err}");
}

#[test]
fn unknown_option_exits_two_with_usage() {
    let out = repro(&["all", "--frobnicate"]);
    assert_eq!(code(&out), 2);
    assert!(stderr(&out).contains("usage:"), "got: {}", stderr(&out));
}

/// Anti-rot: every subcommand `parse_store_cli` accepts must appear in
/// the usage text, along with every top-level command `main` dispatches
/// — PRs 6–7 shipped `gc` and `merge` without updating `usage()`, and
/// nothing caught it.
#[test]
fn usage_text_lists_every_store_subcommand_and_command() {
    let out = repro(&[]);
    assert_eq!(code(&out), 2, "bare `repro` is a malformed invocation");
    let usage = stderr(&out);
    for sub in multistride::exec::lifecycle::STORE_SUBCOMMANDS {
        assert!(usage.contains(sub), "store subcommand {sub:?} missing from usage:\n{usage}");
    }
    for cmd in [
        "table1", "table2", "figure2", "figure3", "figure4", "figure5", "figure6", "figure7",
        "sweep", "universe", "tune", "native", "validate", "run", "all", "grid", "store", "serve",
        "obs",
    ] {
        assert!(usage.contains(cmd), "command {cmd:?} missing from usage:\n{usage}");
    }
}

#[test]
fn serve_cli_grammar_errors_exit_two() {
    for bad in [
        &["serve", "--port"][..],
        &["serve", "--port", "notaport"],
        &["serve", "--pool-bytes", "0"],
        &["serve", "--policy", "mru"],
        &["serve", "--on-miss", "panic"],
        &["serve", "--max-requests", "many"],
    ] {
        let out = repro(bad);
        assert_eq!(code(&out), 2, "{bad:?} must exit 2\n{}", stderr(&out));
        assert!(!stderr(&out).contains("panicked"), "{bad:?}: got: {}", stderr(&out));
    }
    // --cold + --results stays mutually exclusive through the serve path.
    let out = repro(&["serve", "--cold", "--results", "r", "--max-requests", "1"]);
    assert_eq!(code(&out), 2, "got: {}", stderr(&out));
}

/// `repro obs` follows the same 2-for-grammar / 1-for-trouble split as
/// the store surface, and a real `--trace` run produces a report the
/// command can render.
#[test]
fn obs_cli_grammar_and_report_round_trip() {
    assert_eq!(code(&repro(&["obs"])), 2, "missing subcommand");
    assert_eq!(code(&repro(&["obs", "frobnicate"])), 2, "unknown subcommand");
    let no_trace = repro(&["obs", "report"]);
    assert_eq!(code(&no_trace), 2, "report without --trace is malformed");
    assert!(stderr(&no_trace).contains("--trace"), "got: {}", stderr(&no_trace));

    let gone = repro(&["obs", "report", "--trace", "/nonexistent/trace.json"]);
    assert_eq!(code(&gone), 1, "an unreadable trace file is real trouble, not a grammar error");
    assert!(!stderr(&gone).contains("panicked"), "got: {}", stderr(&gone));

    // End to end: a traced smoke run writes both artifacts, and the
    // report renders spans plus the deterministic counter table.
    let dir = tmp("obs");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let trace = dir.join("trace.json");
    let traces = trace.to_str().unwrap();
    let run = repro(&["figure2", "--smoke", "--cold", "--trace", traces]);
    assert_eq!(code(&run), 0, "traced smoke run must stay green\n{}", stderr(&run));
    assert!(stdout(&run).contains("[obs] trace:"), "got: {}", stdout(&run));
    assert!(trace.is_file(), "trace file must exist");
    assert!(dir.join("trace.counters.json").is_file(), "counter sibling must exist");

    let report = repro(&["obs", "report", "--trace", traces]);
    assert_eq!(code(&report), 0, "got: {}", stderr(&report));
    let text = stdout(&report);
    assert!(text.contains("Top spans"), "got: {text}");
    assert!(text.contains("engine_run"), "got: {text}");
    assert!(text.contains("Counters"), "got: {text}");
    assert!(text.contains("sim_accesses_total"), "got: {text}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The fleet roles follow the same split: a malformed invocation —
/// above all a bad `--connect` — is exit 2 through `usage()`, before
/// any socket is touched.
#[test]
fn grid_fleet_cli_grammar_errors_exit_two() {
    for bad in [
        &["grid", "worker", "--smoke"][..], // --connect is required
        &["grid", "worker", "--connect"],
        &["grid", "worker", "--connect", "nohost"],
        &["grid", "worker", "--connect", ":7879"],
        &["grid", "worker", "--connect", "host:"],
        &["grid", "worker", "--connect", "host:0"],
        &["grid", "worker", "--connect", "host:notaport"],
        &["grid", "coordinator", "--port", "notaport"],
        &["grid", "coordinator", "--lease-ms", "soon"],
    ] {
        let out = repro(bad);
        assert_eq!(code(&out), 2, "{bad:?} must exit 2\n{}", stderr(&out));
        let err = stderr(&out);
        assert!(err.contains("usage:"), "{bad:?}: usage must be printed\ngot: {err}");
        assert!(!err.contains("panicked"), "{bad:?}: got: {err}");
    }
    let usage = stderr(&repro(&[]));
    assert!(usage.contains("grid coordinator"), "got:\n{usage}");
    assert!(usage.contains("grid worker --connect"), "got:\n{usage}");
}

/// Runtime trouble on the fleet surface is exit 1: a well-formed
/// `--connect` whose coordinator is unreachable, or a coordinator
/// pointed at a store it cannot append to.
#[test]
fn grid_fleet_runtime_trouble_exits_one() {
    // Port 1 is privileged and unbound: the dial is refused immediately.
    let out = repro(&["grid", "worker", "--connect", "127.0.0.1:1", "--cold", "--smoke"]);
    assert_eq!(code(&out), 1, "unreachable coordinator must exit 1\n{}", stderr(&out));
    let err = stderr(&out);
    assert!(err.contains("error:"), "got: {err}");
    assert!(err.contains("coordinator"), "got: {err}");
    assert!(!err.contains("panicked"), "got: {err}");

    let cold = repro(&["grid", "coordinator", "--cold", "--smoke"]);
    assert_eq!(code(&cold), 1, "a coordinator needs a persistent store\n{}", stderr(&cold));
    assert!(stderr(&cold).contains("persistent"), "got: {}", stderr(&cold));
}

#[test]
fn grid_requires_a_shard_spec_and_a_persistent_store() {
    let dir = tmp("grid");
    std::fs::remove_dir_all(&dir).ok();
    let dirs = dir.to_str().unwrap();
    let missing = repro(&["grid", "--smoke", "--results", dirs]);
    assert_eq!(code(&missing), 1, "grid without --shard must fail");
    assert!(stderr(&missing).contains("--shard"), "got: {}", stderr(&missing));

    let bad = repro(&["grid", "--shard", "3/2", "--smoke", "--results", dirs]);
    assert_eq!(code(&bad), 1, "an out-of-range shard index must fail");

    let cold = repro(&["grid", "--shard", "1/2", "--smoke", "--cold"]);
    assert_eq!(code(&cold), 1, "grid over an ephemeral store must fail");
    assert!(stderr(&cold).contains("persistent"), "got: {}", stderr(&cold));
    std::fs::remove_dir_all(&dir).ok();
}
