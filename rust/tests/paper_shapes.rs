//! Integration: the headline *shapes* of the paper's evaluation, end to
//! end through transform → trace → simulator. These are the claims
//! EXPERIMENTS.md reports; sizes use the smoke scale to stay fast.

use multistride::config::{coffee_lake, ScaleConfig};
use multistride::coordinator::experiments::{
    best_point, figure6, run_kernel, run_micro, run_reference, summarize_kernel,
};
use multistride::kernels::micro::MicroOp;
use multistride::kernels::reference::Reference;
use multistride::transform::StridingConfig;

const MIB: u64 = 1 << 20;

#[test]
fn micro_reads_gain_with_prefetch_and_strides() {
    // Figure 2 top-left: multi-strided reads beat single-strided by tens of
    // percent with the prefetcher on.
    let m = coffee_lake();
    let bytes = ScaleConfig::smoke().micro_bytes;
    let s1 = run_micro(m, MicroOp::LoadAligned, 1, bytes, true, false).throughput_gib;
    let s16 = run_micro(m, MicroOp::LoadAligned, 16, bytes, true, false).throughput_gib;
    let gain = s16 / s1;
    assert!(
        (1.15..=1.8).contains(&gain),
        "16-stride read gain {gain:.2} out of the paper's band (paper: 1.33)"
    );
}

#[test]
fn micro_reads_do_not_gain_without_prefetch() {
    // Figure 2 bottom-left: no improvement, slight decline.
    let m = coffee_lake();
    let bytes = ScaleConfig::smoke().micro_bytes;
    let s1 = run_micro(m, MicroOp::LoadAligned, 1, bytes, false, false).throughput_gib;
    let s16 = run_micro(m, MicroOp::LoadAligned, 16, bytes, false, false).throughput_gib;
    assert!(s16 <= s1 * 1.02, "pf-off: {s16:.2} must not beat {s1:.2}");
}

#[test]
fn interleaved_nt_stores_collapse() {
    // Figure 2 middle: interleaved NT stores beyond the WC pool plateau at
    // a small fraction of the roofline (paper: ~1.74 GiB/s).
    let m = coffee_lake();
    let bytes = ScaleConfig::smoke().micro_bytes;
    let grouped = run_micro(m, MicroOp::StoreNt, 16, bytes, true, false).throughput_gib;
    let inter = run_micro(m, MicroOp::StoreNt, 16, bytes, true, true).throughput_gib;
    assert!(
        inter < grouped * 0.3,
        "interleaved NT {inter:.2} must collapse vs grouped {grouped:.2}"
    );
}

#[test]
fn pow2_arrays_kill_multistriding() {
    // Figure 5: power-of-two total size + power-of-two stride count puts
    // every stride in the same cache sets. The damage grows with stride
    // count (the paper: stalls double at 4 strides, +477% at 32; L3 misses
    // +560% at 32): L2 conflicts expose latency at moderate counts, L3
    // thrash collapses throughput at high counts.
    let m = coffee_lake();
    let scale = ScaleConfig::smoke();
    let good =
        run_micro(m, MicroOp::LoadAligned, 32, scale.micro_bytes, true, false).throughput_gib;
    let bad =
        run_micro(m, MicroOp::LoadAligned, 32, scale.micro_pow2_bytes, true, false).throughput_gib;
    assert!(
        bad < good * 0.85,
        "pow2 collisions must hurt at 32 strides: {bad:.2} vs non-pow2 {good:.2}"
    );
    // And the pow2 stall count exceeds the non-pow2 one already at 8.
    let s_good = run_micro(m, MicroOp::LoadAligned, 8, scale.micro_bytes, true, false)
        .result
        .counters
        .stalls_total;
    let s_bad = run_micro(m, MicroOp::LoadAligned, 8, scale.micro_pow2_bytes, true, false)
        .result
        .counters
        .stalls_total;
    assert!(
        s_bad > s_good,
        "pow2 must raise stall cycles at 8 strides: {s_bad} vs {s_good}"
    );
}

#[test]
fn hit_ratios_follow_figure4() {
    let m = coffee_lake();
    let bytes = ScaleConfig::smoke().micro_bytes;
    let p1 = run_micro(m, MicroOp::LoadAligned, 1, bytes, true, false);
    let p16 = run_micro(m, MicroOp::LoadAligned, 16, bytes, true, false);
    // L1 pinned at 0.5 for both.
    assert!((p1.result.l1.hit_ratio() - 0.5).abs() < 0.03);
    assert!((p16.result.l1.hit_ratio() - 0.5).abs() < 0.03);
    // L2 ratio rises with strides.
    assert!(p16.result.l2.hit_ratio() > p1.result.l2.hit_ratio());
    // Prefetch off: L2/L3 ratios ~0.
    let off = run_micro(m, MicroOp::LoadAligned, 16, bytes, false, false);
    assert!(off.result.l2.hit_ratio() < 0.05);
    assert!(off.result.l3.hit_ratio() < 0.05);
}

#[test]
fn stall_cycles_track_throughput_inverse() {
    // Figure 3: total stalls fall as strides rise (while throughput rises).
    let m = coffee_lake();
    let bytes = ScaleConfig::smoke().micro_bytes;
    let p1 = run_micro(m, MicroOp::LoadAligned, 1, bytes, true, false);
    let p16 = run_micro(m, MicroOp::LoadAligned, 16, bytes, true, false);
    assert!(p16.result.counters.stalls_total < p1.result.counters.stalls_total);
    assert!(p1.result.counters.subset_invariant_holds());
    assert!(p16.result.counters.subset_invariant_holds());
}

#[test]
fn mxv_multistrided_beats_single_strided() {
    // Figure 6 (mxv): the paper reports up to 1.58x over the best
    // single-strided configuration.
    let m = coffee_lake();
    let s = summarize_kernel(m, "mxv", 16 * MIB, 8);
    let gain = s.multi_over_single();
    assert!(
        gain > 1.05,
        "multi-striding must beat single-striding on mxv: {gain:.3}"
    );
    assert!(
        s.best_multi.config.stride_unroll >= 2 && s.best_multi.config.stride_unroll <= 16,
        "best at a moderate stride count (paper: 1-10): {:?}",
        s.best_multi.config
    );
}

#[test]
fn kernel_sweep_gains_vanish_without_prefetch() {
    // Figure 6 top-right (bicg pf-off): no significant effect.
    let m = coffee_lake();
    let pts_on = figure6(m, "bicg", 8 * MIB, 6, true);
    let pts_off = figure6(m, "bicg", 8 * MIB, 6, false);
    let best_on = best_point(&pts_on).unwrap();
    let single_on: f64 = pts_on
        .iter()
        .filter(|p| p.feasible && p.config.stride_unroll == 1)
        .map(|p| p.throughput_gib)
        .fold(0.0, f64::max);
    let best_off = best_point(&pts_off).unwrap();
    let single_off: f64 = pts_off
        .iter()
        .filter(|p| p.feasible && p.config.stride_unroll == 1)
        .map(|p| p.throughput_gib)
        .fold(0.0, f64::max);
    let gain_on = best_on.throughput_gib / single_on;
    let gain_off = best_off.throughput_gib / single_off;
    assert!(
        gain_on > gain_off,
        "prefetcher drives the multi-striding gain: on {gain_on:.3} vs off {gain_off:.3}"
    );
    // "no significant effect" (§6.3) — allow modest noise from DRAM
    // row-locality differences between schedules at smoke scale.
    assert!(gain_off < 1.25, "pf-off gain must be insignificant: {gain_off:.3}");
}

#[test]
fn multistrided_mxv_beats_reference_models() {
    // Figure 7 shape: the tuned multi-strided mxv beats the MKL/OpenBLAS
    // schedule models (which beat naive CLang).
    let m = coffee_lake();
    let budget = 16 * MIB;
    let s = summarize_kernel(m, "mxv", budget, 8);
    let mkl = run_reference(m, "mxv", budget, Reference::Mkl).unwrap();
    let clang = run_reference(m, "mxv", budget, Reference::Clang).unwrap();
    assert!(
        s.best_multi.throughput_gib > mkl,
        "multi-strided {:.2} must beat MKL model {mkl:.2}",
        s.best_multi.throughput_gib
    );
    assert!(mkl > clang, "MKL model {mkl:.2} must beat scalar CLang {clang:.2}");
}

#[test]
fn infeasible_region_matches_register_budget() {
    let m = coffee_lake();
    // mxv at stride 16, portion 4: 16 accumulators + … > 16 ymm.
    let p = run_kernel(m, "mxv", 8 * MIB, StridingConfig::new(16, 4), true).unwrap();
    assert!(!p.feasible);
    let p = run_kernel(m, "mxv", 8 * MIB, StridingConfig::new(4, 2), true).unwrap();
    assert!(p.feasible);
}
