//! Differential property test for the two-level TLB (`mem::tlb`).
//!
//! Same pattern as `tests/cache_differential.rs`: `mem::Tlb` (flat entry
//! arrays, shared probe/fill helpers) is pinned against a deliberately
//! naive reference model — per-set `Vec`s of entries, linear scans,
//! explicit LRU bookkeeping — across random access sequences. Every
//! `translate` return value (dTLB hit / STLB hit / full walk latency) and
//! every statistic must agree, across small set-aliased geometries that
//! force capacity evictions, a single-set L1, an STLB smaller than the
//! working set, and both page sizes (4 KiB / 2 MiB huge pages), including
//! page-boundary-straddling address patterns.

use multistride::mem::{Tlb, TlbConfig};
use multistride::util::proptest::{check, Config};
use multistride::util::Rng;

const PAGE: u64 = 4096;
const HUGE: u64 = 2 * 1024 * 1024;

// ---- naive per-set reference model ---------------------------------------

#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    page: u64,
    valid: bool,
    stamp: u64,
}

struct RefTlb {
    cfg: TlbConfig,
    l1: Vec<Vec<Entry>>,
    l2: Vec<Vec<Entry>>,
    clock: u64,
    page_shift: u32,
    accesses: u64,
    l1_misses: u64,
    walks: u64,
}

impl RefTlb {
    fn new(cfg: TlbConfig) -> Self {
        let l1_sets = (cfg.l1_entries / cfg.l1_ways) as usize;
        let l2_sets = (cfg.l2_entries / cfg.l2_ways) as usize;
        Self {
            cfg,
            l1: vec![vec![Entry::default(); cfg.l1_ways as usize]; l1_sets],
            l2: vec![vec![Entry::default(); cfg.l2_ways as usize]; l2_sets],
            clock: 0,
            page_shift: if cfg.huge_pages { 21 } else { 12 },
            accesses: 0,
            l1_misses: 0,
            walks: 0,
        }
    }

    fn probe(set: &mut [Entry], page: u64, clock: u64) -> bool {
        for e in set {
            if e.valid && e.page == page {
                e.stamp = clock;
                return true;
            }
        }
        false
    }

    fn fill(set: &mut [Entry], page: u64, clock: u64) {
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for (i, e) in set.iter().enumerate() {
            if e.valid && e.page == page {
                return;
            }
            if !e.valid {
                victim = i;
                break;
            }
            if e.stamp < best {
                best = e.stamp;
                victim = i;
            }
        }
        set[victim] = Entry { page, valid: true, stamp: clock };
    }

    fn translate(&mut self, addr: u64) -> u64 {
        self.accesses += 1;
        self.clock += 1;
        let page = addr >> self.page_shift;
        let s1 = (page % self.l1.len() as u64) as usize;
        if Self::probe(&mut self.l1[s1], page, self.clock) {
            return 0;
        }
        self.l1_misses += 1;
        let s2 = (page % self.l2.len() as u64) as usize;
        if Self::probe(&mut self.l2[s2], page, self.clock) {
            Self::fill(&mut self.l1[s1], page, self.clock);
            return self.cfg.stlb_hit_cycles;
        }
        self.walks += 1;
        Self::fill(&mut self.l2[s2], page, self.clock);
        Self::fill(&mut self.l1[s1], page, self.clock);
        self.cfg.walk_cycles
    }
}

// ---- the differential driver --------------------------------------------

/// Geometries: tiny set-aliased L1, a single-set L1, an STLB smaller than
/// the page universe (capacity evictions through both levels), and the
/// Coffee Lake shape. All set counts are powers of two (a `Tlb::new`
/// invariant), which makes `page % sets == page & (sets - 1)`, so the
/// naive modulo model and the masked implementation must agree.
const GEOMETRIES: [(u32, u32, u32, u32); 4] =
    [(8, 4, 32, 4), (4, 4, 16, 8), (64, 4, 64, 16), (64, 4, 1536, 12)];

fn cfg_for(geometry: usize, huge: bool) -> TlbConfig {
    let (e1, w1, e2, w2) = GEOMETRIES[geometry];
    TlbConfig {
        l1_entries: e1,
        l1_ways: w1,
        l2_entries: e2,
        l2_ways: w2,
        stlb_hit_cycles: 7,
        walk_cycles: 70,
        huge_pages: huge,
    }
}

#[derive(Debug, Clone, Copy)]
struct Case {
    geometry: usize,
    huge: bool,
    seed: u64,
    ops: u32,
}

fn run_case(c: &Case) -> bool {
    let cfg = cfg_for(c.geometry, c.huge);
    let mut real = Tlb::new(cfg);
    let mut naive = RefTlb::new(cfg);
    let mut rng = Rng::new(c.seed);
    let page_bytes = if c.huge { HUGE } else { PAGE };
    // More page streams than the STLB can hold forces capacity evictions
    // through both levels; the stride spacing aliases sets.
    let streams = (cfg.l2_entries as u64) * 2;
    for _ in 0..c.ops {
        let addr = match rng.below(4) {
            // A strided page stream (aliases sets when spacing is even).
            0 => rng.below(streams) * 2 * page_bytes + rng.below(page_bytes),
            // Page-boundary edges: the last/first bytes around a boundary.
            1 => {
                let boundary = (1 + rng.below(streams)) * page_bytes;
                boundary - 1 + rng.below(2)
            }
            // Dense low pages (re-references that should hit).
            2 => rng.below(4 * page_bytes),
            // Far random address.
            _ => rng.below(1 << 40),
        };
        if real.translate(addr) != naive.translate(addr) {
            return false;
        }
        let s = real.stats;
        if (s.accesses, s.l1_misses, s.walks) != (naive.accesses, naive.l1_misses, naive.walks) {
            return false;
        }
    }
    true
}

#[test]
fn tlb_matches_naive_reference_model() {
    check(
        Config { cases: 96, seed: 0x71B_D1FF },
        |r, size| Case {
            geometry: r.below(GEOMETRIES.len() as u64) as usize,
            huge: r.below(2) == 0,
            seed: r.next_u64(),
            ops: 32 + size * 60,
        },
        run_case,
    );
}

/// Directed capacity sweep: touching twice the STLB's page capacity in
/// sequence, twice over, must walk on every touch of the second round in
/// both models — and the models must agree access-for-access.
#[test]
fn capacity_sweep_walks_agree() {
    for huge in [false, true] {
        let cfg = cfg_for(2, huge); // 64-entry STLB
        let page_bytes = if huge { HUGE } else { PAGE };
        let mut real = Tlb::new(cfg);
        let mut naive = RefTlb::new(cfg);
        let pages = cfg.l2_entries as u64 * 2;
        for round in 0..2 {
            for p in 0..pages {
                let a = p * page_bytes;
                assert_eq!(real.translate(a), naive.translate(a), "round {round} page {p}");
            }
        }
        assert_eq!(real.stats.walks, naive.walks);
        assert!(
            real.stats.walks >= pages + pages / 2,
            "LRU cannot retain a working set twice the capacity: {} walks",
            real.stats.walks
        );
    }
}

/// Page-size edge: 4 KiB-page streams that straddle a 2 MiB huge-page
/// frame collapse to one translation with huge pages on. Both models must
/// agree on the exact walk count either way.
#[test]
fn huge_page_collapse_agrees() {
    for huge in [false, true] {
        let cfg = cfg_for(3, huge);
        let mut real = Tlb::new(cfg);
        let mut naive = RefTlb::new(cfg);
        for a in (0..8 * HUGE).step_by(PAGE as usize) {
            assert_eq!(real.translate(a), naive.translate(a));
        }
        assert_eq!(real.stats.walks, naive.walks);
        if huge {
            assert_eq!(real.stats.walks, 8, "one walk per huge page");
        } else {
            assert_eq!(real.stats.walks, 8 * (HUGE / PAGE), "one walk per 4 KiB page");
        }
    }
}

/// `reset` restores post-construction behavior exactly: a reset TLB
/// replays a fresh reference model.
#[test]
fn reset_tlb_matches_fresh_reference_model() {
    let cfg = cfg_for(0, false);
    let mut real = Tlb::new(cfg);
    let mut rng = Rng::new(0xF00D);
    for _ in 0..2048 {
        real.translate(rng.below(1 << 30));
    }
    real.reset();
    assert_eq!(real.stats, Default::default());
    let mut naive = RefTlb::new(cfg);
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..2048 {
        let a = rng.below(1 << 30);
        assert_eq!(real.translate(a), naive.translate(a), "replay diverged post-reset");
    }
}
