//! Result-store wall: the persistent tier must be a *transparent* cache.
//!
//! Properties pinned here, mirroring `plan_cache_roundtrip.rs` for the
//! execution layer:
//!
//! * the `multistride-simresult v1` format round-trips **bit-exactly**
//!   for randomized results (every counter, and the one float as IEEE
//!   bits — NaN/±inf/−0.0 included), its fixed-width binary twin
//!   reconstructs the identical serialization, and the segment tier
//!   serves back exactly the bytes it stored;
//! * every crash/corruption shape — truncated segment tails, a torn
//!   index, mid-compaction kill states, corrupt/truncated/mis-keyed
//!   legacy shards, mixed old-format/segment directories — degrades to
//!   **self-healing misses** that re-serve bit-identical results, never
//!   to panics or wrong data;
//! * a parallel `repro all`-shaped plan — micro grids and kernel
//!   families with deliberate overlap — returns results bit-identical to
//!   serial cold execution, and a warm store serves the same plan with
//!   **zero** fresh engine runs.

use std::path::PathBuf;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::EngineCache;
use multistride::exec::format::{
    decode_result_bin, encode_result_bin, parse_result, serialize_result, RESULT_BIN_BYTES,
};
use multistride::exec::segment::INDEX_FILE;
use multistride::exec::{lifecycle, Planner, ResultStore, SimPoint};
use multistride::kernels::micro::MicroOp;
use multistride::sim::RunResult;
use multistride::transform::StridingConfig;
use multistride::util::Rng;

const MIB: u64 = 1 << 20;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("multistride_store_rt_{tag}_{}", std::process::id()))
}

/// A randomized result: every field independently random so any
/// swapped/dropped field in the format shows up as a mismatch.
fn random_result(rng: &mut Rng) -> RunResult {
    let freq_ghz = match rng.below(6) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => -0.0,
        4 => f64::from_bits(rng.next_u64()),
        _ => rng.f64() * 5.0,
    };
    // Mix magnitudes: small counts, u64::MAX-range counts, zeros.
    let mut n = |_label: &str| match rng.below(4) {
        0 => 0,
        1 => rng.below(1 << 20),
        2 => rng.next_u64() >> 20,
        _ => rng.next_u64(),
    };
    RunResult {
        counters: multistride::sim::Counters {
            cycles: n("cycles"),
            stalls_total: n("st"),
            stalls_mem_any: n("sm"),
            stalls_l1d_miss: n("s1"),
            stalls_l2_miss: n("s2"),
            stalls_l3_miss: n("s3"),
            accesses: n("acc"),
            bytes_read: n("br"),
            bytes_written: n("bw"),
            dram_demand_lines: n("ddl"),
            prefetch_lines: n("pl"),
            prefetch_merges: n("pm"),
            tlb_cycles: n("tc"),
        },
        l1: multistride::mem::cache::CacheStats {
            demand_hits: n("h"),
            demand_misses: n("m"),
            prefetch_hits: n("p"),
            evictions: n("e"),
            dirty_evictions: n("d"),
            unused_prefetch_evictions: n("u"),
            prefetch_installs: n("i"),
        },
        l2: multistride::mem::cache::CacheStats {
            demand_hits: n("h"),
            demand_misses: n("m"),
            prefetch_hits: n("p"),
            evictions: n("e"),
            dirty_evictions: n("d"),
            unused_prefetch_evictions: n("u"),
            prefetch_installs: n("i"),
        },
        l3: multistride::mem::cache::CacheStats {
            demand_hits: n("h"),
            demand_misses: n("m"),
            prefetch_hits: n("p"),
            evictions: n("e"),
            dirty_evictions: n("d"),
            unused_prefetch_evictions: n("u"),
            prefetch_installs: n("i"),
        },
        dram: multistride::mem::dram::DramStats {
            reads: n("r"),
            writes: n("w"),
            row_hits: n("rh"),
            row_misses: n("rm"),
            busy_cycles: n("bc"),
        },
        wc: multistride::mem::writebuffer::WcStats {
            stores: n("s"),
            full_flushes: n("f"),
            partial_flushes: n("p"),
        },
        tlb: multistride::mem::tlb::TlbStats {
            accesses: n("a"),
            l1_misses: n("l"),
            walks: n("w"),
        },
        streamer: multistride::prefetch::streamer::StreamerStats {
            observations: n("o"),
            streams_allocated: n("sa"),
            streams_evicted: n("se"),
            streams_evicted_untrained: n("su"),
            prefetches_issued: n("pi"),
            page_carries: n("pc"),
        },
        freq_ghz,
    }
}

#[test]
fn randomized_format_roundtrip_is_bit_exact() {
    let mut rng = Rng::new(0x5708E);
    for i in 0..200 {
        let r = random_result(&mut rng);
        let key = rng.next_u64();
        let s = serialize_result(key, &r);
        let (got_key, q) = parse_result(&s)
            .unwrap_or_else(|e| panic!("round {i}: parse failed: {e}\n{s}"));
        assert_eq!(got_key, key, "round {i}");
        assert_eq!(s, serialize_result(got_key, &q), "round {i}: not bit-identical");
    }
}

#[test]
fn randomized_binary_twin_reconstructs_the_text_serialization() {
    let mut rng = Rng::new(0xB117);
    for i in 0..200 {
        let r = random_result(&mut rng);
        let bin = encode_result_bin(&r);
        assert_eq!(bin.len(), RESULT_BIN_BYTES);
        let q = decode_result_bin(&bin)
            .unwrap_or_else(|e| panic!("round {i}: binary decode failed: {e}"));
        let key = rng.next_u64();
        assert_eq!(
            serialize_result(key, &r),
            serialize_result(key, &q),
            "round {i}: binary twin must reconstruct the exact text serialization"
        );
        assert_eq!(bin.to_vec(), encode_result_bin(&q).to_vec(), "round {i}: re-encode differs");
    }
}

#[test]
fn segment_tier_serves_the_exact_bytes_it_stored() {
    let dir = tmp("bytes");
    std::fs::remove_dir_all(&dir).ok();
    let point = SimPoint::micro(coffee_lake(), MicroOp::CopyNt, 4, MIB, true, false);
    let store = ResultStore::persistent(&dir);
    let fresh = store.get_or_run(&mut EngineCache::new(), &point).unwrap();
    // The record's payload in the segment file is the binary twin of the
    // fresh result, byte for byte.
    let (seg_path, offset, len) = store.segment_location(point.key()).expect("record located");
    assert_eq!(len as usize, RESULT_BIN_BYTES);
    let seg_bytes = std::fs::read(&seg_path).unwrap();
    let payload = &seg_bytes[offset as usize..offset as usize + len as usize];
    let decoded = decode_result_bin(payload).expect("payload decodes in place");
    assert_eq!(
        serialize_result(point.key(), &decoded),
        serialize_result(point.key(), &fresh),
        "segment payload is the binary serialization of the fresh result"
    );
    drop(store);
    // A second store (cold memory tier) re-reads and re-serializes to
    // the identical bytes, with zero engine runs.
    let reread = ResultStore::persistent(&dir);
    let served = reread.get_or_run(&mut EngineCache::new(), &point).unwrap();
    assert_eq!(serialize_result(point.key(), &served), serialize_result(point.key(), &fresh));
    assert_eq!(reread.stats().engine_runs, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_flipped_and_mis_keyed_legacy_shards_are_misses_and_self_heal() {
    let dir = tmp("corrupt");
    std::fs::remove_dir_all(&dir).ok();
    let point = SimPoint::kernel(coffee_lake(), "mxv", MIB, StridingConfig::new(2, 1), true)
        .unwrap();
    let store = ResultStore::persistent(&dir);
    let good = store.get_or_run(&mut EngineCache::new(), &point).unwrap();
    let good_bytes = serialize_result(point.key(), &good);
    let shard = store.write_legacy_shard(point.key(), &good).unwrap();
    let seg_file = store.segment_location(point.key()).unwrap().0;
    drop(store);
    // Strip the segment tier so only the legacy tree remains — this test
    // pins the PR-5 fallback read path.
    std::fs::remove_file(&seg_file).unwrap();
    std::fs::remove_file(dir.join(INDEX_FILE)).unwrap();

    // Exhaustive-ish truncation.
    for cut in [0, 1, 10, good_bytes.len() / 2, good_bytes.len() - 1] {
        std::fs::write(&shard, &good_bytes[..cut]).unwrap();
        let s = ResultStore::persistent(&dir);
        assert!(s.lookup(point.key()).is_none(), "cut at {cut} must miss");
        assert_eq!(s.stats().corrupt_discards, 1, "cut at {cut}");
    }

    // Random single-byte flips: the checksum (or the UTF-8 read, or the
    // strict field walk) must catch every one.
    let mut rng = Rng::new(0xF11);
    for round in 0..40 {
        let mut bytes = good_bytes.clone().into_bytes();
        let i = rng.below(bytes.len() as u64) as usize;
        let flip = 1u8 << rng.below(8);
        bytes[i] ^= flip;
        if bytes == good_bytes.as_bytes() {
            continue; // zero flip cannot happen (1<<k != 0), but stay safe
        }
        std::fs::write(&shard, &bytes).unwrap();
        let s = ResultStore::persistent(&dir);
        assert!(
            s.lookup(point.key()).is_none(),
            "round {round}: flipped bit {flip:#x} at byte {i} must miss"
        );
    }

    // Mis-keyed: a valid shard copied under another point's path.
    let other = SimPoint::kernel(coffee_lake(), "mxv", MIB, StridingConfig::new(4, 1), true)
        .unwrap();
    assert_ne!(point.key(), other.key());
    std::fs::write(&shard, &good_bytes).unwrap();
    let smuggle_store = ResultStore::persistent(&dir);
    let other_shard = smuggle_store.write_legacy_shard(other.key(), &good).unwrap();
    std::fs::copy(&shard, &other_shard).unwrap();
    drop(smuggle_store);
    let s = ResultStore::persistent(&dir);
    assert!(s.lookup(other.key()).is_none(), "smuggled shard must not serve");
    drop(s);
    std::fs::remove_file(&other_shard).unwrap();

    // Self-heal: a corrupted shard degrades to a miss; the re-simulated
    // result is bit-identical and lands in the segment tier, which then
    // shadows the still-corrupt shard for good.
    std::fs::write(&shard, "garbage").unwrap();
    let healing = ResultStore::persistent(&dir);
    let healed = healing.get_or_run(&mut EngineCache::new(), &point).unwrap();
    assert_eq!(serialize_result(point.key(), &healed), good_bytes);
    assert_eq!(healing.stats().engine_runs, 1);
    drop(healing);
    let warm = ResultStore::persistent(&dir);
    let served = warm.get_or_run(&mut EngineCache::new(), &point).unwrap();
    assert_eq!(serialize_result(point.key(), &served), good_bytes);
    let ws = warm.stats();
    assert_eq!((ws.engine_runs, ws.legacy_hits), (0, 0), "segment record shadows the bad shard");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_segment_tail_degrades_to_a_self_healing_miss() {
    let dir = tmp("seg_tail");
    std::fs::remove_dir_all(&dir).ok();
    let m = coffee_lake();
    let p1 = SimPoint::micro(m, MicroOp::LoadAligned, 2, MIB, true, false);
    let p2 = SimPoint::micro(m, MicroOp::LoadAligned, 8, MIB, true, false);
    let store = ResultStore::persistent(&dir);
    let mut engines = EngineCache::new();
    let r1 = store.get_or_run(&mut engines, &p1).unwrap();
    let r2 = store.get_or_run(&mut engines, &p2).unwrap();
    let seg_file = store.segment_location(p1.key()).unwrap().0;
    assert_eq!(seg_file, store.segment_location(p2.key()).unwrap().0, "one segment");
    drop(store);

    // Kill-during-append: the tail record loses its last 5 bytes. The
    // index says the segment covers more than the file holds, so the
    // open distrusts it, rescans, seals the torn tail, and keeps p1.
    let bytes = std::fs::read(&seg_file).unwrap();
    std::fs::write(&seg_file, &bytes[..bytes.len() - 5]).unwrap();

    let warm = ResultStore::persistent(&dir);
    // Two discard events: the index's coverage claim is distrusted, then
    // the rescan hits the torn record itself.
    assert!(warm.stats().corrupt_discards >= 1, "torn tail detected at open");
    let got1 = warm.lookup(p1.key()).expect("intact head record still serves");
    assert_eq!(serialize_result(p1.key(), &got1), serialize_result(p1.key(), &r1));
    let healed = warm.get_or_run(&mut engines, &p2).unwrap();
    assert_eq!(
        serialize_result(p2.key(), &healed),
        serialize_result(p2.key(), &r2),
        "re-simulated tail record must be bit-identical"
    );
    assert_eq!(warm.stats().engine_runs, 1, "exactly the torn record re-simulates");
    drop(warm);

    // The heal is durable: a third store serves both from disk.
    let third = ResultStore::persistent(&dir);
    assert!(third.lookup(p1.key()).is_some() && third.lookup(p2.key()).is_some());
    assert_eq!(third.stats().engine_runs, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_index_falls_back_to_segment_scans_with_zero_engine_runs() {
    let dir = tmp("torn_index");
    std::fs::remove_dir_all(&dir).ok();
    let m = coffee_lake();
    let points: Vec<SimPoint> = [1u32, 2, 4, 8, 16, 32]
        .iter()
        .map(|&s| SimPoint::micro(m, MicroOp::CopyAligned, s, MIB, true, false))
        .collect();
    let store = ResultStore::persistent(&dir);
    let cold = Planner::new(&store).run(&points).unwrap();
    drop(store);

    // Tear the index mid-byte; the open must fall back to full scans.
    let index = dir.join(INDEX_FILE);
    let mut bytes = std::fs::read(&index).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&index, &bytes).unwrap();

    let warm = ResultStore::persistent(&dir);
    let served = Planner::new(&warm).run(&points).unwrap();
    for ((p, a), b) in points.iter().zip(&cold).zip(&served) {
        assert_eq!(
            serialize_result(p.key(), a),
            serialize_result(p.key(), b),
            "scan-rebuilt store diverged on {}",
            p.label()
        );
    }
    assert_eq!(warm.stats().engine_runs, 0, "a torn index never costs engine runs");
    warm.flush(); // rewrite a good index
    drop(warm);
    let reopened = ResultStore::persistent(&dir);
    assert!(reopened.lookup(points[0].key()).is_some());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mid_compaction_kill_states_serve_identically_and_recompact() {
    let dir = tmp("kill_compact");
    std::fs::remove_dir_all(&dir).ok();
    let m = coffee_lake();
    let points: Vec<SimPoint> = [1u32, 4, 32]
        .iter()
        .map(|&s| SimPoint::micro(m, MicroOp::StoreAligned, s, MIB, false, false))
        .collect();
    let store = ResultStore::persistent(&dir);
    let cold = Planner::new(&store).run(&points).unwrap();
    let seg0 = store.segment_location(points[0].key()).unwrap().0;
    drop(store);

    // A compaction killed after rewriting but before deleting the source
    // leaves the same records duplicated across two segments, and an
    // index that predates both. Fabricate exactly that state.
    let seg1 = seg0.with_file_name(multistride::exec::segment::segment_file_name(1));
    std::fs::copy(&seg0, &seg1).unwrap();
    std::fs::remove_file(dir.join(INDEX_FILE)).unwrap();

    let warm = ResultStore::persistent(&dir);
    let served = Planner::new(&warm).run(&points).unwrap();
    for ((p, a), b) in points.iter().zip(&cold).zip(&served) {
        assert_eq!(
            serialize_result(p.key(), a),
            serialize_result(p.key(), b),
            "duplicated-segment store diverged on {}",
            p.label()
        );
    }
    assert_eq!(warm.stats().engine_runs, 0);
    drop(warm);

    // Re-running compaction from the kill state converges: duplicates
    // fold to one live copy each and the result still serves bit-exact.
    let report = lifecycle::compact(&dir).unwrap();
    assert_eq!(report.rewritten, points.len() as u64);
    let after = ResultStore::persistent(&dir);
    let again = Planner::new(&after).run(&points).unwrap();
    for ((p, a), b) in points.iter().zip(&cold).zip(&again) {
        assert_eq!(serialize_result(p.key(), a), serialize_result(p.key(), b));
    }
    assert_eq!(after.stats().engine_runs, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mixed_legacy_and_segment_directories_serve_then_migrate() {
    let dir = tmp("mixed");
    std::fs::remove_dir_all(&dir).ok();
    let m = coffee_lake();
    let seg_point = SimPoint::micro(m, MicroOp::LoadUnaligned, 4, MIB, true, false);
    let old_point = SimPoint::micro(m, MicroOp::LoadUnaligned, 16, MIB, true, false);
    let mut engines = EngineCache::new();

    // The old point's result exists only as a PR-5 file-per-point shard;
    // the new point's only as a segment record.
    let oracle = ResultStore::ephemeral();
    let old_result = oracle.get_or_run(&mut engines, &old_point).unwrap();
    let store = ResultStore::persistent(&dir);
    let seg_result = store.get_or_run(&mut engines, &seg_point).unwrap();
    store.write_legacy_shard(old_point.key(), &old_result).unwrap();
    drop(store);

    let want_seg = serialize_result(seg_point.key(), &seg_result);
    let want_old = serialize_result(old_point.key(), &old_result);
    let warm = ResultStore::persistent(&dir);
    let got_seg = warm.lookup(seg_point.key()).expect("segment record serves");
    let got_old = warm.lookup(old_point.key()).expect("legacy shard serves");
    assert_eq!(serialize_result(seg_point.key(), &got_seg), want_seg);
    assert_eq!(serialize_result(old_point.key(), &got_old), want_old);
    let ws = warm.stats();
    assert_eq!((ws.engine_runs, ws.disk_hits, ws.legacy_hits), (0, 2, 1));
    drop(warm);

    // `repro store compact` folds the shard into the segment tier.
    let report = lifecycle::compact(&dir).unwrap();
    assert_eq!(report.migrated_legacy, 1);
    assert_eq!(report.deleted_legacy, 1);
    let stats = lifecycle::dir_stats(&dir);
    assert_eq!((stats.legacy_files, stats.live_records), (0, 2));

    let migrated = ResultStore::persistent(&dir);
    let a = migrated.lookup(seg_point.key()).expect("still serves");
    let b = migrated.lookup(old_point.key()).expect("migrated record serves");
    assert_eq!(serialize_result(seg_point.key(), &a), want_seg);
    assert_eq!(serialize_result(old_point.key(), &b), want_old);
    let ms = migrated.stats();
    assert_eq!((ms.engine_runs, ms.legacy_hits), (0, 0), "migration leaves no legacy reads");
    std::fs::remove_dir_all(&dir).ok();
}

/// A `repro all`-shaped batch at unit scale: micro grid points (with the
/// figure3_4 subset duplicated, as `repro all` requests it twice) plus
/// kernel variant families at two portion levels (universe overlaps
/// figure6, as in the real driver).
fn repro_all_shaped_batch() -> Vec<SimPoint> {
    let m = coffee_lake();
    let mut points = Vec::new();
    for prefetch in [true, false] {
        for op in [MicroOp::LoadAligned, MicroOp::StoreNt, MicroOp::CopyAligned] {
            for strides in [1, 4, 32] {
                points.push(SimPoint::micro(m, op, strides, MIB, prefetch, false));
            }
        }
        // The figure3_4 re-request of figure2's aligned-load series.
        for strides in [1, 4, 32] {
            points.push(SimPoint::micro(m, MicroOp::LoadAligned, strides, MIB, prefetch, false));
        }
    }
    for kernel in ["mxv", "init", "3mm"] {
        for s in [1u32, 2, 4, 8] {
            for portion in [1u32, 2] {
                let cfg = StridingConfig::new(s, portion);
                // Only enqueue what a sweep would: transformable points.
                if let Ok(p) = SimPoint::kernel(m, kernel, MIB, cfg, true) {
                    if multistride::transform::transform(
                        &multistride::kernels::library::kernel_by_name(kernel, MIB)
                            .unwrap()
                            .spec,
                        cfg,
                    )
                    .is_ok()
                    {
                        points.push(p);
                    }
                }
            }
        }
        // Universe re-visits the portion-2 family.
        for s in [1u32, 2, 4, 8] {
            let cfg = StridingConfig::new(s, 2);
            if let Ok(p) = SimPoint::kernel(m, kernel, MIB, cfg, true) {
                if multistride::transform::transform(
                    &multistride::kernels::library::kernel_by_name(kernel, MIB).unwrap().spec,
                    cfg,
                )
                .is_ok()
                {
                    points.push(p);
                }
            }
        }
    }
    points
}

#[test]
fn parallel_plan_matches_serial_cold_execution_bit_for_bit() {
    let dir = tmp("parallel");
    std::fs::remove_dir_all(&dir).ok();
    let points = repro_all_shaped_batch();
    let distinct: std::collections::HashSet<u64> = points.iter().map(|p| p.key()).collect();
    assert!(
        distinct.len() < points.len(),
        "the batch must contain overlap to be repro-all-shaped"
    );

    let serial_store = ResultStore::ephemeral();
    let serial = Planner::new(&serial_store).with_workers(1).run(&points).unwrap();
    assert_eq!(serial_store.stats().engine_runs, distinct.len() as u64);

    let par_store = ResultStore::persistent(&dir);
    let parallel = Planner::new(&par_store).with_workers(8).run(&points).unwrap();
    assert_eq!(par_store.stats().engine_runs, distinct.len() as u64);
    for ((p, a), b) in points.iter().zip(&serial).zip(&parallel) {
        assert_eq!(
            serialize_result(p.key(), a),
            serialize_result(p.key(), b),
            "parallel vs serial diverged on {}",
            p.label()
        );
    }

    // Warm pass over the persistent tier: a fresh store instance serves
    // the whole plan from disk with zero fresh engine runs, and the
    // summary counters expose exactly that economy.
    let warm_store = ResultStore::persistent(&dir);
    let warm = Planner::new(&warm_store).with_workers(8).run(&points).unwrap();
    let s = warm_store.stats();
    assert_eq!(s.engine_runs, 0, "warm plan performs strictly fewer (zero) engine runs");
    assert_eq!(s.disk_hits, distinct.len() as u64);
    assert_eq!(s.deduped, (points.len() - distinct.len()) as u64);
    for ((p, a), b) in points.iter().zip(&serial).zip(&warm) {
        assert_eq!(
            serialize_result(p.key(), a),
            serialize_result(p.key(), b),
            "warm vs cold diverged on {}",
            p.label()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
