//! Result-store wall: the persistent tier must be a *transparent* cache.
//!
//! Three properties pinned here, mirroring `plan_cache_roundtrip.rs` for
//! the execution layer:
//!
//! * the `multistride-simresult v1` format round-trips **bit-exactly**
//!   for randomized results (every counter, and the one float as IEEE
//!   bits — NaN/±inf/−0.0 included), and the disk tier serves back the
//!   exact bytes it stored;
//! * corrupt, truncated, byte-flipped or mis-keyed shards degrade to
//!   **misses** (recoverable, self-healing), never to panics or wrong
//!   results;
//! * a parallel `repro all`-shaped plan — micro grids and kernel
//!   families with deliberate overlap — returns results bit-identical to
//!   serial cold execution, and a warm store serves the same plan with
//!   **zero** fresh engine runs.

use std::path::PathBuf;

use multistride::config::coffee_lake;
use multistride::coordinator::experiments::EngineCache;
use multistride::exec::format::{parse_result, serialize_result};
use multistride::exec::{Planner, ResultStore, SimPoint};
use multistride::kernels::micro::MicroOp;
use multistride::sim::RunResult;
use multistride::transform::StridingConfig;
use multistride::util::Rng;

const MIB: u64 = 1 << 20;

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("multistride_store_rt_{tag}_{}", std::process::id()))
}

/// A randomized result: every field independently random so any
/// swapped/dropped field in the format shows up as a mismatch.
fn random_result(rng: &mut Rng) -> RunResult {
    let freq_ghz = match rng.below(6) {
        0 => f64::NAN,
        1 => f64::INFINITY,
        2 => f64::NEG_INFINITY,
        3 => -0.0,
        4 => f64::from_bits(rng.next_u64()),
        _ => rng.f64() * 5.0,
    };
    // Mix magnitudes: small counts, u64::MAX-range counts, zeros.
    let mut n = |_label: &str| match rng.below(4) {
        0 => 0,
        1 => rng.below(1 << 20),
        2 => rng.next_u64() >> 20,
        _ => rng.next_u64(),
    };
    RunResult {
        counters: multistride::sim::Counters {
            cycles: n("cycles"),
            stalls_total: n("st"),
            stalls_mem_any: n("sm"),
            stalls_l1d_miss: n("s1"),
            stalls_l2_miss: n("s2"),
            stalls_l3_miss: n("s3"),
            accesses: n("acc"),
            bytes_read: n("br"),
            bytes_written: n("bw"),
            dram_demand_lines: n("ddl"),
            prefetch_lines: n("pl"),
            prefetch_merges: n("pm"),
            tlb_cycles: n("tc"),
        },
        l1: multistride::mem::cache::CacheStats {
            demand_hits: n("h"),
            demand_misses: n("m"),
            prefetch_hits: n("p"),
            evictions: n("e"),
            dirty_evictions: n("d"),
            unused_prefetch_evictions: n("u"),
            prefetch_installs: n("i"),
        },
        l2: multistride::mem::cache::CacheStats {
            demand_hits: n("h"),
            demand_misses: n("m"),
            prefetch_hits: n("p"),
            evictions: n("e"),
            dirty_evictions: n("d"),
            unused_prefetch_evictions: n("u"),
            prefetch_installs: n("i"),
        },
        l3: multistride::mem::cache::CacheStats {
            demand_hits: n("h"),
            demand_misses: n("m"),
            prefetch_hits: n("p"),
            evictions: n("e"),
            dirty_evictions: n("d"),
            unused_prefetch_evictions: n("u"),
            prefetch_installs: n("i"),
        },
        dram: multistride::mem::dram::DramStats {
            reads: n("r"),
            writes: n("w"),
            row_hits: n("rh"),
            row_misses: n("rm"),
            busy_cycles: n("bc"),
        },
        wc: multistride::mem::writebuffer::WcStats {
            stores: n("s"),
            full_flushes: n("f"),
            partial_flushes: n("p"),
        },
        tlb: multistride::mem::tlb::TlbStats {
            accesses: n("a"),
            l1_misses: n("l"),
            walks: n("w"),
        },
        streamer: multistride::prefetch::streamer::StreamerStats {
            observations: n("o"),
            streams_allocated: n("sa"),
            streams_evicted: n("se"),
            streams_evicted_untrained: n("su"),
            prefetches_issued: n("pi"),
            page_carries: n("pc"),
        },
        freq_ghz,
    }
}

#[test]
fn randomized_format_roundtrip_is_bit_exact() {
    let mut rng = Rng::new(0x5708E);
    for i in 0..200 {
        let r = random_result(&mut rng);
        let key = rng.next_u64();
        let s = serialize_result(key, &r);
        let (got_key, q) = parse_result(&s)
            .unwrap_or_else(|e| panic!("round {i}: parse failed: {e}\n{s}"));
        assert_eq!(got_key, key, "round {i}");
        assert_eq!(s, serialize_result(got_key, &q), "round {i}: not bit-identical");
    }
}

#[test]
fn disk_tier_serves_the_exact_bytes_it_stored() {
    let dir = tmp("bytes");
    std::fs::remove_dir_all(&dir).ok();
    let point = SimPoint::micro(coffee_lake(), MicroOp::CopyNt, 4, MIB, true, false);
    let store = ResultStore::persistent(&dir);
    let fresh = store.get_or_run(&mut EngineCache::new(), &point).unwrap();
    let shard = store.disk_path(point.key()).unwrap();
    let on_disk = std::fs::read_to_string(&shard).unwrap();
    assert_eq!(
        on_disk,
        serialize_result(point.key(), &fresh),
        "shard bytes are the serialization of the fresh result"
    );
    // A second store (cold memory tier) re-reads and re-serializes to
    // the identical bytes.
    let reread = ResultStore::persistent(&dir);
    let served = reread.get_or_run(&mut EngineCache::new(), &point).unwrap();
    assert_eq!(on_disk, serialize_result(point.key(), &served));
    assert_eq!(reread.stats().engine_runs, 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_flipped_and_mis_keyed_shards_are_misses_and_self_heal() {
    let dir = tmp("corrupt");
    std::fs::remove_dir_all(&dir).ok();
    let point = SimPoint::kernel(coffee_lake(), "mxv", MIB, StridingConfig::new(2, 1), true)
        .unwrap();
    let store = ResultStore::persistent(&dir);
    let good = store.get_or_run(&mut EngineCache::new(), &point).unwrap();
    let good_bytes = serialize_result(point.key(), &good);
    let shard = store.disk_path(point.key()).unwrap();

    // Exhaustive-ish truncation.
    for cut in [0, 1, 10, good_bytes.len() / 2, good_bytes.len() - 1] {
        std::fs::write(&shard, &good_bytes[..cut]).unwrap();
        let s = ResultStore::persistent(&dir);
        assert!(s.lookup(point.key()).is_none(), "cut at {cut} must miss");
        assert_eq!(s.stats().corrupt_discards, 1, "cut at {cut}");
    }

    // Random single-byte flips: the checksum (or the UTF-8 read, or the
    // strict field walk) must catch every one.
    let mut rng = Rng::new(0xF11);
    for round in 0..40 {
        let mut bytes = good_bytes.clone().into_bytes();
        let i = rng.below(bytes.len() as u64) as usize;
        let flip = 1u8 << rng.below(8);
        bytes[i] ^= flip;
        if bytes == good_bytes.as_bytes() {
            continue; // zero flip cannot happen (1<<k != 0), but stay safe
        }
        std::fs::write(&shard, &bytes).unwrap();
        let s = ResultStore::persistent(&dir);
        assert!(
            s.lookup(point.key()).is_none(),
            "round {round}: flipped bit {flip:#x} at byte {i} must miss"
        );
    }

    // Mis-keyed: a valid shard copied under another point's path.
    let other = SimPoint::kernel(coffee_lake(), "mxv", MIB, StridingConfig::new(4, 1), true)
        .unwrap();
    assert_ne!(point.key(), other.key());
    let other_shard = store.disk_path(other.key()).unwrap();
    std::fs::create_dir_all(other_shard.parent().unwrap()).unwrap();
    std::fs::write(&shard, &good_bytes).unwrap();
    std::fs::copy(&shard, &other_shard).unwrap();
    let s = ResultStore::persistent(&dir);
    assert!(s.lookup(other.key()).is_none(), "smuggled shard must not serve");

    // Self-heal: a corrupted shard is rewritten by the next miss, and
    // the healed result is bit-identical to the original.
    std::fs::write(&shard, "garbage").unwrap();
    let healing = ResultStore::persistent(&dir);
    let healed = healing.get_or_run(&mut EngineCache::new(), &point).unwrap();
    assert_eq!(serialize_result(point.key(), &healed), good_bytes);
    assert_eq!(std::fs::read_to_string(&shard).unwrap(), good_bytes);
    std::fs::remove_dir_all(&dir).ok();
}

/// A `repro all`-shaped batch at unit scale: micro grid points (with the
/// figure3_4 subset duplicated, as `repro all` requests it twice) plus
/// kernel variant families at two portion levels (universe overlaps
/// figure6, as in the real driver).
fn repro_all_shaped_batch() -> Vec<SimPoint> {
    let m = coffee_lake();
    let mut points = Vec::new();
    for prefetch in [true, false] {
        for op in [MicroOp::LoadAligned, MicroOp::StoreNt, MicroOp::CopyAligned] {
            for strides in [1, 4, 32] {
                points.push(SimPoint::micro(m, op, strides, MIB, prefetch, false));
            }
        }
        // The figure3_4 re-request of figure2's aligned-load series.
        for strides in [1, 4, 32] {
            points.push(SimPoint::micro(m, MicroOp::LoadAligned, strides, MIB, prefetch, false));
        }
    }
    for kernel in ["mxv", "init", "3mm"] {
        for s in [1u32, 2, 4, 8] {
            for portion in [1u32, 2] {
                let cfg = StridingConfig::new(s, portion);
                // Only enqueue what a sweep would: transformable points.
                if let Ok(p) = SimPoint::kernel(m, kernel, MIB, cfg, true) {
                    if multistride::transform::transform(
                        &multistride::kernels::library::kernel_by_name(kernel, MIB)
                            .unwrap()
                            .spec,
                        cfg,
                    )
                    .is_ok()
                    {
                        points.push(p);
                    }
                }
            }
        }
        // Universe re-visits the portion-2 family.
        for s in [1u32, 2, 4, 8] {
            let cfg = StridingConfig::new(s, 2);
            if let Ok(p) = SimPoint::kernel(m, kernel, MIB, cfg, true) {
                if multistride::transform::transform(
                    &multistride::kernels::library::kernel_by_name(kernel, MIB).unwrap().spec,
                    cfg,
                )
                .is_ok()
                {
                    points.push(p);
                }
            }
        }
    }
    points
}

#[test]
fn parallel_plan_matches_serial_cold_execution_bit_for_bit() {
    let dir = tmp("parallel");
    std::fs::remove_dir_all(&dir).ok();
    let points = repro_all_shaped_batch();
    let distinct: std::collections::HashSet<u64> = points.iter().map(|p| p.key()).collect();
    assert!(
        distinct.len() < points.len(),
        "the batch must contain overlap to be repro-all-shaped"
    );

    let serial_store = ResultStore::ephemeral();
    let serial = Planner::new(&serial_store).with_workers(1).run(&points).unwrap();
    assert_eq!(serial_store.stats().engine_runs, distinct.len() as u64);

    let par_store = ResultStore::persistent(&dir);
    let parallel = Planner::new(&par_store).with_workers(8).run(&points).unwrap();
    assert_eq!(par_store.stats().engine_runs, distinct.len() as u64);
    for ((p, a), b) in points.iter().zip(&serial).zip(&parallel) {
        assert_eq!(
            serialize_result(p.key(), a),
            serialize_result(p.key(), b),
            "parallel vs serial diverged on {}",
            p.label()
        );
    }

    // Warm pass over the persistent tier: a fresh store instance serves
    // the whole plan from disk with zero fresh engine runs, and the
    // summary counters expose exactly that economy.
    let warm_store = ResultStore::persistent(&dir);
    let warm = Planner::new(&warm_store).with_workers(8).run(&points).unwrap();
    let s = warm_store.stats();
    assert_eq!(s.engine_runs, 0, "warm plan performs strictly fewer (zero) engine runs");
    assert_eq!(s.disk_hits, distinct.len() as u64);
    assert_eq!(s.deduped, (points.len() - distinct.len()) as u64);
    for ((p, a), b) in points.iter().zip(&serial).zip(&warm) {
        assert_eq!(
            serialize_result(p.key(), a),
            serialize_result(p.key(), b),
            "warm vs cold diverged on {}",
            p.label()
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
