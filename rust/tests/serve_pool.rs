//! Differential wall for the serving layer's eviction policies and the
//! buffer pool's byte bound.
//!
//! Each production `Replacer` is driven op-for-op against a naive,
//! structurally different reference model (plain `Vec`s, no hash maps,
//! no lazy deletion) over random touch/evict/remove sequences: the
//! victim sequences and lengths must agree exactly. On top of that, a
//! capacity-N mini-cache harness replays skewed access traces through
//! both implementations and compares exact hit counts and eviction
//! order — the accounting the bench's hit-ratio numbers rest on.
//!
//! The pool itself is hammered concurrently: its invariant is that
//! `current_bytes` NEVER exceeds the configured capacity, observable
//! at any instant from any thread.

use std::sync::Arc;

use multistride::serve::pool::BufferPool;
use multistride::serve::replacer::{Policy, Replacer};
use multistride::util::Rng;

// ---------------------------------------------------------------------------
// Naive reference models. Deliberately different structure from the
// production implementations: flat Vecs, eager removal, no maps.
// ---------------------------------------------------------------------------

trait RefModel {
    fn touch(&mut self, key: u64);
    fn remove(&mut self, key: u64);
    fn evict(&mut self) -> Option<u64>;
    fn len(&self) -> usize;
}

/// LRU: recency order held literally — front is oldest.
#[derive(Default)]
struct RefLru {
    order: Vec<u64>,
}

impl RefModel for RefLru {
    fn touch(&mut self, key: u64) {
        self.order.retain(|&k| k != key);
        self.order.push(key);
    }
    fn remove(&mut self, key: u64) {
        self.order.retain(|&k| k != key);
    }
    fn evict(&mut self) -> Option<u64> {
        if self.order.is_empty() {
            None
        } else {
            Some(self.order.remove(0))
        }
    }
    fn len(&self) -> usize {
        self.order.len()
    }
}

/// Clock: a flat ring with an explicit hand index. Entries never move;
/// a new key is inserted just before the hand so the sweep in progress
/// visits it last (the production ring expresses the same thing by
/// rotating spared keys behind a hand pinned at the front).
#[derive(Default)]
struct RefClock {
    slots: Vec<(u64, bool)>,
    hand: usize,
}

impl RefModel for RefClock {
    fn touch(&mut self, key: u64) {
        if let Some(slot) = self.slots.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = true;
            return;
        }
        self.slots.insert(self.hand, (key, true));
        self.hand += 1;
    }
    fn remove(&mut self, key: u64) {
        if let Some(idx) = self.slots.iter().position(|(k, _)| *k == key) {
            self.slots.remove(idx);
            if idx < self.hand {
                self.hand -= 1;
            }
        }
    }
    fn evict(&mut self) -> Option<u64> {
        if self.slots.is_empty() {
            return None;
        }
        loop {
            if self.hand >= self.slots.len() {
                self.hand = 0;
            }
            if self.slots[self.hand].1 {
                self.slots[self.hand].1 = false;
                self.hand += 1;
            } else {
                let (key, _) = self.slots.remove(self.hand);
                return Some(key);
            }
        }
    }
    fn len(&self) -> usize {
        self.slots.len()
    }
}

/// SIEVE: FIFO of (key, visited) with a hand sweeping oldest → newest.
/// Unlike Clock, a spared entry keeps its position (only the bit
/// clears) and new entries always join at the newest end.
#[derive(Default)]
struct RefSieve {
    queue: Vec<(u64, bool)>,
    hand: usize,
}

impl RefModel for RefSieve {
    fn touch(&mut self, key: u64) {
        if let Some(slot) = self.queue.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = true;
            return;
        }
        self.queue.push((key, false));
    }
    fn remove(&mut self, key: u64) {
        if let Some(idx) = self.queue.iter().position(|(k, _)| *k == key) {
            self.queue.remove(idx);
            if idx < self.hand {
                self.hand -= 1;
            }
        }
    }
    fn evict(&mut self) -> Option<u64> {
        if self.queue.is_empty() {
            return None;
        }
        loop {
            if self.hand >= self.queue.len() {
                self.hand = 0;
            }
            if self.queue[self.hand].1 {
                self.queue[self.hand].1 = false;
                self.hand += 1;
            } else {
                let (key, _) = self.queue.remove(self.hand);
                return Some(key);
            }
        }
    }
    fn len(&self) -> usize {
        self.queue.len()
    }
}

fn reference_for(policy: Policy) -> Box<dyn RefModel> {
    match policy {
        Policy::Lru => Box::new(RefLru::default()),
        Policy::Clock => Box::new(RefClock::default()),
        Policy::Sieve => Box::new(RefSieve::default()),
    }
}

// ---------------------------------------------------------------------------
// Differential drivers.
// ---------------------------------------------------------------------------

/// Random op streams: production and reference must agree on every
/// victim and every length, at every step.
#[test]
fn replacers_match_reference_models_on_random_op_streams() {
    for policy in Policy::all() {
        for seed in [0xD1F5u64, 0xBEEF, 0x5EED, 0xACE5, 0x90210] {
            let mut rng = Rng::new(seed ^ policy.cli_name().len() as u64);
            let mut prod = policy.new_replacer();
            let mut refm = reference_for(policy);
            for step in 0..4000 {
                let ctx = format!("{policy:?} seed {seed:#x} step {step}");
                match rng.below(10) {
                    // Touches dominate, over a small universe so keys
                    // collide and re-touch often.
                    0..=5 => {
                        let key = rng.below(24);
                        prod.touch(key);
                        refm.touch(key);
                    }
                    6..=7 => {
                        let got = prod.evict();
                        let want = refm.evict();
                        assert_eq!(got, want, "victim diverged: {ctx}");
                    }
                    8 => {
                        let key = rng.below(24);
                        prod.remove(key);
                        refm.remove(key);
                    }
                    _ => {
                        // Eviction burst: drain a few in a row, the
                        // regime where hand state matters most.
                        for _ in 0..rng.below(4) + 1 {
                            assert_eq!(prod.evict(), refm.evict(), "burst diverged: {ctx}");
                        }
                    }
                }
                assert_eq!(prod.len(), refm.len(), "length diverged: {ctx}");
            }
            // Full drain must agree to the last victim.
            loop {
                let (got, want) = (prod.evict(), refm.evict());
                assert_eq!(got, want, "{policy:?} seed {seed:#x} drain diverged");
                if got.is_none() {
                    break;
                }
            }
        }
    }
}

/// Capacity-N cache harness: exact hit counts and eviction sequences on
/// a skewed (hot-set + scan) trace, production vs reference.
#[test]
fn cache_hit_accounting_matches_reference_models() {
    const CAPACITY: usize = 8;
    for policy in Policy::all() {
        for seed in [0xCAFEu64, 0xF00D, 0x1DEA] {
            let mut rng = Rng::new(seed);
            // 80% of accesses to an 8-key hot set, 20% scanning a
            // 64-key cold tail: distinguishes the three policies while
            // each still must match its own reference exactly.
            let trace: Vec<u64> = (0..3000)
                .map(|_| if rng.below(10) < 8 { rng.below(8) } else { 100 + rng.below(64) })
                .collect();

            let run = |replacer: &mut dyn FnMut(u64) -> (bool, Option<u64>)| {
                let mut hits = 0u64;
                let mut victims = Vec::new();
                for &key in &trace {
                    let (hit, victim) = replacer(key);
                    hits += hit as u64;
                    victims.extend(victim);
                }
                (hits, victims)
            };

            let mut prod = policy.new_replacer();
            let mut prod_resident = std::collections::HashSet::new();
            let (prod_hits, prod_victims) = run(&mut |key| {
                if prod_resident.contains(&key) {
                    prod.touch(key);
                    return (true, None);
                }
                let victim = if prod_resident.len() == CAPACITY {
                    let v = prod.evict().expect("full cache evicts");
                    prod_resident.remove(&v);
                    Some(v)
                } else {
                    None
                };
                prod.touch(key);
                prod_resident.insert(key);
                (false, victim)
            });

            let mut refm = reference_for(policy);
            let mut ref_resident = std::collections::HashSet::new();
            let (ref_hits, ref_victims) = run(&mut |key| {
                if ref_resident.contains(&key) {
                    refm.touch(key);
                    return (true, None);
                }
                let victim = if ref_resident.len() == CAPACITY {
                    let v = refm.evict().expect("full cache evicts");
                    ref_resident.remove(&v);
                    Some(v)
                } else {
                    None
                };
                refm.touch(key);
                ref_resident.insert(key);
                (false, victim)
            });

            assert_eq!(prod_hits, ref_hits, "{policy:?} seed {seed:#x}: hit counts diverged");
            assert_eq!(
                prod_victims, ref_victims,
                "{policy:?} seed {seed:#x}: eviction sequences diverged"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Pool invariants under concurrency.
// ---------------------------------------------------------------------------

/// Eight clients hammering one pool: the byte bound must hold at every
/// observation, from every thread, under every policy.
#[test]
fn pool_never_exceeds_its_byte_bound_under_concurrent_clients() {
    const CAPACITY: u64 = 4096;
    for policy in Policy::all() {
        let pool = Arc::new(BufferPool::new(CAPACITY, policy));
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let pool = pool.clone();
                std::thread::spawn(move || {
                    let mut rng = Rng::new(0xB0B + t as u64);
                    for _ in 0..500 {
                        let key = rng.below(64);
                        if pool.get(key).is_none() {
                            let size = (rng.below(1024) + 1) as usize;
                            pool.insert(key, Arc::new(vec![t as u8; size]));
                        }
                        let s = pool.stats();
                        assert!(
                            s.current_bytes <= CAPACITY,
                            "{policy:?}: pool at {} bytes exceeds bound {CAPACITY}",
                            s.current_bytes
                        );
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("client thread panicked");
        }
        let s = pool.stats();
        assert!(s.current_bytes <= CAPACITY);
        assert_eq!(s.requests, 8 * 500, "{policy:?}: every get is counted");
        assert!(s.insertions > 0 && s.hits > 0, "{policy:?}: the trace exercised both paths");
    }
}

/// Same bound when single values are as large as the whole budget, and
/// oversize values are refused without disturbing residents.
#[test]
fn pool_handles_budget_sized_and_oversize_values() {
    for policy in Policy::all() {
        let pool = BufferPool::new(1000, policy);
        assert!(pool.insert(1, Arc::new(vec![1u8; 1000])), "exactly the budget fits");
        assert_eq!(pool.stats().current_bytes, 1000);
        assert!(!pool.insert(2, Arc::new(vec![2u8; 1001])), "{policy:?}: over budget refused");
        assert!(pool.get(1).is_some(), "{policy:?}: resident survives the refusal");
        assert!(pool.insert(3, Arc::new(vec![3u8; 600])), "evicts 1 to fit");
        let s = pool.stats();
        assert!(s.current_bytes <= 1000);
        assert_eq!(s.rejected_oversize, 1);
        assert_eq!(s.evictions, 1);
    }
}
