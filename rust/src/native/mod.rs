//! Native host memory probes: run *real* single- vs multi-strided sweeps
//! over a large buffer on the machine this repo executes on.
//!
//! This is the live cross-check for the simulator: whatever CPU hosts the
//! run, its hardware prefetcher sees exactly the access patterns of §4 (a
//! fixed unroll budget distributed over n concurrent strides) and the
//! multi-striding effect — or its absence — shows up in wall-clock
//! bandwidth. The probe cannot toggle the prefetcher MSR (unprivileged),
//! which is why the simulator remains the primary reproduction vehicle.
//!
//! The inner loops are written so the compiler keeps them memory-bound:
//! per-stride f32 accumulators (auto-vectorizable), `black_box` sinks, and
//! a data-dependent reduction that cannot be elided.

use std::hint::black_box;

use crate::util::stats::median;
use crate::util::timer::Timer;

/// Probe configuration.
#[derive(Debug, Clone, Copy)]
pub struct NativeProbe {
    /// Buffer size in bytes (defaults well beyond any L3).
    pub bytes: usize,
    /// Measurement repetitions (median reported, like the paper).
    pub reps: u32,
}

impl Default for NativeProbe {
    fn default() -> Self {
        Self { bytes: 512 * 1024 * 1024, reps: 5 }
    }
}

/// Result of one probe configuration.
#[derive(Debug, Clone, Copy)]
pub struct NativePoint {
    pub strides: u32,
    pub read_gib_s: f64,
    pub write_gib_s: f64,
    pub copy_gib_s: f64,
}

impl NativeProbe {
    /// Run read/write/copy probes for each stride count.
    pub fn run(&self, stride_counts: &[u32]) -> Vec<NativePoint> {
        let n_elems = self.bytes / 4;
        let mut src = vec![1.0f32; n_elems];
        let mut dst = vec![0.0f32; n_elems];
        // Touch everything once (page-fault warmup).
        for (i, v) in src.iter_mut().enumerate() {
            *v = (i % 7) as f32;
        }

        stride_counts
            .iter()
            .map(|&s| NativePoint {
                strides: s,
                read_gib_s: self.measure(|| read_strided(&src, s)),
                write_gib_s: self.measure(|| write_strided(&mut dst, s)),
                copy_gib_s: self.measure_copy(&src, &mut dst, s),
            })
            .collect()
    }

    fn measure<F: FnMut() -> f32>(&self, mut f: F) -> f64 {
        // One warmup.
        black_box(f());
        let mut samples = Vec::with_capacity(self.reps as usize);
        for _ in 0..self.reps {
            let t = Timer::start();
            black_box(f());
            samples.push(self.bytes as f64 / (1u64 << 30) as f64 / t.secs());
        }
        median(&samples)
    }

    fn measure_copy(&self, src: &[f32], dst: &mut [f32], s: u32) -> f64 {
        copy_strided(src, dst, s);
        let mut samples = Vec::with_capacity(self.reps as usize);
        for _ in 0..self.reps {
            let t = Timer::start();
            copy_strided(src, dst, s);
            black_box(&dst[0]);
            // A copy moves 2× the buffer (read + write).
            samples.push(2.0 * src.len() as f64 * 4.0 / (1u64 << 30) as f64 / t.secs());
        }
        median(&samples)
    }
}

/// Sum the buffer walking `n` concurrent strides (the §4 read pattern):
/// the buffer splits into `n` contiguous regions advanced in lockstep.
pub fn read_strided(data: &[f32], n: u32) -> f32 {
    let n = n as usize;
    let span = data.len() / n;
    let mut accs = vec![0f32; n];
    // Lockstep walk: iteration i touches element i of every region —
    // exactly n concurrent address streams.
    for i in 0..span {
        for (k, acc) in accs.iter_mut().enumerate() {
            // Safety: k*span + i < n*span <= len.
            *acc += unsafe { *data.get_unchecked(k * span + i) };
        }
    }
    accs.iter().sum()
}

/// Store a constant through `n` concurrent strides.
pub fn write_strided(data: &mut [f32], n: u32) -> f32 {
    let n = n as usize;
    let span = data.len() / n;
    for i in 0..span {
        for k in 0..n {
            unsafe {
                *data.get_unchecked_mut(k * span + i) = 1.0;
            }
        }
    }
    data[0]
}

/// Copy src→dst through `n` concurrent stride pairs.
pub fn copy_strided(src: &[f32], dst: &mut [f32], n: u32) {
    let n = n as usize;
    let len = src.len().min(dst.len());
    let span = len / n;
    for i in 0..span {
        for k in 0..n {
            unsafe {
                *dst.get_unchecked_mut(k * span + i) = *src.get_unchecked(k * span + i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_strided_sums_everything() {
        let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let expect: f32 = data.iter().sum();
        for n in [1, 2, 4, 8] {
            assert_eq!(read_strided(&data, n), expect, "n={n}");
        }
    }

    #[test]
    fn write_strided_covers_buffer() {
        let mut data = vec![0f32; 64];
        write_strided(&mut data, 4);
        assert!(data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn copy_strided_copies() {
        let src: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let mut dst = vec![0f32; 64];
        copy_strided(&src, &mut dst, 8);
        assert_eq!(src, dst);
    }

    #[test]
    fn probe_runs_small() {
        let p = NativeProbe { bytes: 1 << 20, reps: 2 };
        let pts = p.run(&[1, 4]);
        assert_eq!(pts.len(), 2);
        for pt in pts {
            assert!(pt.read_gib_s > 0.0 && pt.write_gib_s > 0.0 && pt.copy_gib_s > 0.0);
        }
    }
}
