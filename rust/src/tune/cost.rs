//! The tuner's cost model: the warm-engine simulator itself, read
//! through the execution layer's result store.
//!
//! [`evaluate_on`] runs one `(kernel, config)` point through the exact §6
//! kernel protocol the sweeps use (the same [`crate::exec::SimPoint`] a
//! sweep would enqueue: default 4 KiB pages, footprint-based throughput)
//! and additionally surfaces the counters a [`super::plan::TunedPlan`]
//! records — simulated accesses/s, per-level hit ratios, and the access
//! count the search charges as its cost. Because the simulator is
//! deterministic and the engine-reuse protocol is bit-identical to fresh
//! construction (`tests/golden_determinism.rs`), a winner's
//! [`CostSample::throughput_gib`] equals the exhaustive sweep's
//! `KernelPoint::throughput_gib` for the same point *exactly* — the
//! tuner's predictions are the sweep's measurements, not an
//! approximation of them.
//!
//! Sharing the store with the sweeps makes that identity *cheap*, not
//! just true: a tune after a sweep at the same budget scores its
//! full-budget rung from stored results, and repeated probe budgets
//! (rung-1 probes re-visited by later requests) never re-run. Search
//! *cost* accounting is unchanged by store hits — [`CostSample::
//! sim_accesses`] comes from the result's counters, which are identical
//! served or fresh — so plans stay byte-identical however warm the store
//! was (`tests/tuner_determinism.rs`).

use crate::config::MachineConfig;
use crate::coordinator::experiments::EngineCache;
use crate::exec::{ResultStore, SimPoint};
use crate::kernels::library::kernel_by_name;
use crate::transform::{is_feasible, transform, StridingConfig};
use crate::{ensure, format_err, Result};

/// One simulated data point of the search.
#[derive(Debug, Clone, Copy)]
pub struct CostSample {
    /// Footprint-based throughput (the sweep's scoring unit).
    pub throughput_gib: f64,
    /// Simulated vector accesses per simulated second.
    pub accesses_per_sec: f64,
    /// Per-level demand hit ratios.
    pub l1_hit: f64,
    pub l2_hit: f64,
    pub l3_hit: f64,
    /// Simulated accesses this run cost (charged to the search budget;
    /// identical whether the result was simulated or served).
    pub sim_accesses: u64,
}

/// [`evaluate_on`] against a throwaway ephemeral store (compatibility
/// surface; the search threads the caller's store through).
pub fn evaluate(
    engines: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Result<CostSample> {
    evaluate_on(&ResultStore::ephemeral(), engines, machine, kernel, budget, config, prefetch)
}

/// Score one configuration of `kernel` at `budget` bytes: served from
/// `store` when present, simulated on the warm per-worker engine (and
/// stored) when not. Errors on unknown kernels, untransformable or
/// register-infeasible configurations — the search layer decides whether
/// that prunes the candidate or merely skips a probe.
pub fn evaluate_on(
    store: &ResultStore,
    engines: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Result<CostSample> {
    let pk = kernel_by_name(kernel, budget)
        .ok_or_else(|| format_err!("unknown kernel {kernel}"))?;
    let t = transform(&pk.spec, config)?;
    ensure!(
        is_feasible(&t, machine.simd_registers),
        "{kernel} s={} p={} exceeds the {}-register file",
        config.stride_unroll,
        config.portion_unroll,
        machine.simd_registers
    );
    // Same throughput convention as run_kernel_on: data size is the
    // allocation (transformed spec footprint), not per-access traffic.
    let footprint = t.spec.footprint();
    let point = SimPoint::kernel_from_spec(machine, kernel, budget, config, prefetch, &pk.spec);
    let result = store.get_or_run(engines, &point)?;
    let cycles = result.counters.cycles;
    let accesses = result.counters.accesses;
    let accesses_per_sec = if cycles == 0 {
        0.0
    } else {
        accesses as f64 / (cycles as f64 / machine.freq_hz())
    };
    Ok(CostSample {
        throughput_gib: machine.gib_per_s(footprint, cycles),
        accesses_per_sec,
        l1_hit: result.l1.hit_ratio(),
        l2_hit: result.l2.hit_ratio(),
        l3_hit: result.l3.hit_ratio(),
        sim_accesses: accesses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::coordinator::experiments::run_kernel;

    const MIB: u64 = 1 << 20;

    #[test]
    fn cost_model_is_the_sweep_simulator_exactly() {
        let m = coffee_lake();
        let cfg = StridingConfig::new(4, 1);
        let sample =
            evaluate(&mut EngineCache::new(), m, "mxv", 2 * MIB, cfg, true).unwrap();
        let point = run_kernel(m, "mxv", 2 * MIB, cfg, true).unwrap();
        assert_eq!(
            sample.throughput_gib.to_bits(),
            point.throughput_gib.to_bits(),
            "tuner score must be bit-identical to the sweep's measurement"
        );
        assert!(sample.sim_accesses > 0);
        assert!(sample.accesses_per_sec > 0.0);
        assert!((0.0..=1.0).contains(&sample.l1_hit));
    }

    #[test]
    fn warm_store_scores_are_bit_identical_and_free() {
        // A sweep-primed store serves the cost model without engine work,
        // and the sample is bit-identical to the cold one.
        let m = coffee_lake();
        let cfg = StridingConfig::new(4, 1);
        let store = ResultStore::ephemeral();
        let cold =
            evaluate_on(&store, &mut EngineCache::new(), m, "mxv", 2 * MIB, cfg, true).unwrap();
        let runs = store.stats().engine_runs;
        assert_eq!(runs, 1);
        let warm =
            evaluate_on(&store, &mut EngineCache::new(), m, "mxv", 2 * MIB, cfg, true).unwrap();
        assert_eq!(store.stats().engine_runs, runs, "served, not re-simulated");
        assert_eq!(cold.throughput_gib.to_bits(), warm.throughput_gib.to_bits());
        assert_eq!(cold.sim_accesses, warm.sim_accesses);
        assert_eq!(cold.l3_hit.to_bits(), warm.l3_hit.to_bits());
    }

    #[test]
    fn infeasible_and_unknown_are_errors_not_panics() {
        let m = coffee_lake();
        assert!(evaluate(
            &mut EngineCache::new(),
            m,
            "mxv",
            2 * MIB,
            StridingConfig::new(16, 4),
            true
        )
        .is_err());
        assert!(evaluate(
            &mut EngineCache::new(),
            m,
            "nope",
            2 * MIB,
            StridingConfig::new(1, 1),
            true
        )
        .is_err());
    }
}
