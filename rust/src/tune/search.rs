//! Successive-halving search over a kernel's derived variant family.
//!
//! The lattice is exactly what `transform::variants` derives: the
//! single-stride baseline plus the `STRIDE_FAMILY` multi-strided variants
//! at [`SearchParams::portion`] portion unrolls — the same family the
//! exhaustive `variant_sweep` simulates in full. The search spends less:
//!
//! 1. **Feasibility gate** (free): register-infeasible variants are
//!    pruned before any simulation, as the sweeps already skip them.
//! 2. **Probe rung**: every surviving candidate runs at a reduced budget
//!    ([`probe_budget`]: `budget / probe_divisor`, floored to 2× the L3
//!    whenever the full run is DRAM-bound, so the probe measures prefetch
//!    behaviour in the same memory regime, not cache residency; capped at
//!    `budget / 2` so a probe is never a full-budget run in disguise).
//! 3. **Pruning rule**: candidates scoring below `best × prune_ratio` at
//!    the probe are dominated and dropped. If none falls below the
//!    cutoff, the rung *minimum* is dropped instead — so whenever the
//!    probe rung scores at least two candidates (always, in practice:
//!    the library's extent floors host every family probe), the final
//!    rung runs strictly fewer full-budget simulations than the
//!    exhaustive sweep. The probe-best is never prunable by either
//!    rule, and a candidate whose probe *fails* (probe-scale spec
//!    cannot host it) advances unscored — it cannot be safely pruned.
//! 4. **Full rung**: survivors run at the full budget; the winner is the
//!    throughput argmax with the same tie-breaking as
//!    `experiments::best_point`.
//!
//! Every candidate visit is recorded as a [`SearchStep`] — score, rung
//! budget, and the verdict (kept or pruned, and why) — so a tuning run is
//! auditable (`repro tune --kernel K` renders the trace). The whole
//! search is deterministic: no randomness anywhere, and the simulator's
//! engine-reuse protocol is bit-identical to fresh construction, so two
//! cold searches of the same request produce byte-identical plans
//! (`tests/tuner_determinism.rs`).

use crate::config::MachineConfig;
use crate::coordinator::experiments::EngineCache;
use crate::exec::ResultStore;
use crate::kernels::library::kernel_by_name;
use crate::transform::{variant_set_on, StridingConfig};
use crate::{ensure, format_err, Result};

use super::cost;
use super::plan::{budget_class, machine_fingerprint, spec_hash, TunedPlan};

/// Knobs of the successive-halving search.
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    /// Portion unrolls of every family member (matches `repro universe`,
    /// which sweeps the family at portion 2).
    pub portion: u32,
    /// Probe budget = full budget / this (before the regime floor).
    pub probe_divisor: u64,
    /// Absolute floor on the probe budget in bytes.
    pub min_probe_bytes: u64,
    /// Probe-rung cutoff: candidates below `best × prune_ratio` are
    /// dominated and dropped before the full-budget rung.
    pub prune_ratio: f64,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self {
            portion: 2,
            probe_divisor: 8,
            min_probe_bytes: 1 << 20,
            prune_ratio: 0.8,
        }
    }
}

/// Why a candidate left (or won) the search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Verdict {
    /// Rejected by the register-pressure gate; never simulated.
    Infeasible,
    /// Dropped at the probe rung: scored below the cutoff, or was the
    /// rung minimum when nothing else fell below it.
    Pruned { cutoff_gib: f64 },
    /// Survived this rung.
    Advanced,
    /// The chosen configuration (full rung only).
    Winner,
}

/// One candidate visit in the search trace.
#[derive(Debug, Clone, Copy)]
pub struct SearchStep {
    pub config: StridingConfig,
    /// 0 = probe rung, 1 = full-budget rung. The feasibility gate records
    /// at rung 0 with `budget` 0 (nothing was simulated).
    pub rung: u32,
    /// Byte budget this visit simulated at (0 for the feasibility gate).
    pub budget: u64,
    /// Score, when the visit actually simulated (`None` for the
    /// feasibility gate and for probes the probe-scale spec cannot host).
    pub score_gib: Option<f64>,
    /// Simulated accesses this visit charged to the search cost.
    pub sim_accesses: u64,
    pub verdict: Verdict,
}

/// A completed cold search: the winning plan plus the audit trace.
#[derive(Debug, Clone)]
pub struct SearchOutcome {
    pub plan: TunedPlan,
    pub steps: Vec<SearchStep>,
}

/// The rung-0 budget for a search (see the module docs for the rule).
pub fn probe_budget(machine: &MachineConfig, budget: u64, params: &SearchParams) -> u64 {
    let mut probe = budget / params.probe_divisor.max(1);
    let regime_floor = 2 * machine.l3.size_bytes;
    // `>=`: at budget == 2×L3 the full run already leaves the LLC, so the
    // floor must engage (capped to budget/2 below, i.e. the L3 boundary).
    if budget >= regime_floor {
        probe = probe.max(regime_floor);
    }
    probe.max(params.min_probe_bytes).min(budget / 2).max(1)
}

/// [`search_on`] against a throwaway ephemeral store (compatibility
/// surface; every sample still flows through the execution layer).
pub fn search(
    engines: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    prefetch: bool,
    params: &SearchParams,
) -> Result<SearchOutcome> {
    search_on(&ResultStore::ephemeral(), engines, machine, kernel, budget, prefetch, params)
}

/// Cold-search the variant family of `kernel` at `budget` bytes on
/// `machine`, using the simulator as cost model — every candidate score
/// read through `store`, so rungs that revisit already-simulated points
/// (a sweep at the same budget, an earlier search's probes) are served,
/// not re-run. The search is deterministic *and store-oblivious*: hits
/// are bit-identical to fresh simulations, so plans come out byte-equal
/// however warm the store is. Never consults or writes the plan cache
/// (that is [`super::Tuner`]'s job).
pub fn search_on(
    store: &ResultStore,
    engines: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    prefetch: bool,
    params: &SearchParams,
) -> Result<SearchOutcome> {
    let pk = kernel_by_name(kernel, budget)
        .ok_or_else(|| format_err!("unknown kernel {kernel}"))?;
    let family = variant_set_on(&pk.spec, params.portion, machine.simd_registers)?;
    let probe = probe_budget(&machine, budget, params);

    let mut steps: Vec<SearchStep> = Vec::new();
    let mut live: Vec<StridingConfig> = Vec::new();
    for v in &family.variants {
        if v.feasible {
            live.push(v.config);
        } else {
            steps.push(SearchStep {
                config: v.config,
                rung: 0,
                budget: 0,
                score_gib: None,
                sim_accesses: 0,
                verdict: Verdict::Infeasible,
            });
        }
    }
    ensure!(!live.is_empty(), "kernel {kernel}: no feasible variant to tune");

    let mut sim_accesses = 0u64;
    let mut probe_runs = 0u32;
    let mut baseline_probe_gib = f64::NAN;
    // (config, probe score) for every candidate that actually probed.
    let mut probe_scores: Vec<(StridingConfig, f64)> = Vec::new();

    // Probe rung — skipped when the feasibility gate already left a
    // single candidate (probing it would decide nothing).
    let survivors: Vec<StridingConfig> = if live.len() == 1 {
        live.clone()
    } else {
        let _rung_span = crate::obs::span("tuner_probe_rung");
        let mut scored: Vec<(StridingConfig, Option<f64>, u64)> = Vec::new();
        for &cfg in &live {
            match cost::evaluate_on(store, engines, machine, kernel, probe, cfg, prefetch) {
                Ok(s) => {
                    probe_runs += 1;
                    sim_accesses += s.sim_accesses;
                    if cfg.stride_unroll == 1 {
                        baseline_probe_gib = s.throughput_gib;
                    }
                    probe_scores.push((cfg, s.throughput_gib));
                    scored.push((cfg, Some(s.throughput_gib), s.sim_accesses));
                }
                Err(e) => {
                    // The probe-scale spec cannot host this config (tiny
                    // extents); advance it unprobed rather than dropping
                    // it silently.
                    eprintln!(
                        "[tune] {kernel} s={} p={}: probe at {probe} B failed ({e}); advancing unprobed",
                        cfg.stride_unroll, cfg.portion_unroll
                    );
                    scored.push((cfg, None, 0));
                }
            }
        }
        let best = scored
            .iter()
            .filter_map(|&(_, s, _)| s)
            .fold(f64::NEG_INFINITY, f64::max);
        let cutoff = best * params.prune_ratio;
        let mut pruned: Vec<bool> = scored
            .iter()
            .map(|&(_, s, _)| matches!(s, Some(v) if v < cutoff))
            .collect();
        // Nothing dominated? Drop the rung minimum so the full rung is
        // always strictly cheaper than the exhaustive sweep.
        if best.is_finite() && !pruned.iter().any(|&p| p) {
            let n_scored = scored.iter().filter(|&&(_, s, _)| s.is_some()).count();
            if n_scored > 1 {
                let min_i = scored
                    .iter()
                    .enumerate()
                    .filter_map(|(j, &(_, s, _))| s.map(|v| (j, v)))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).expect("no NaN scores"))
                    .map(|(j, _)| j)
                    .expect("n_scored > 1");
                pruned[min_i] = true;
            }
        }
        let mut surv = Vec::new();
        for (j, &(cfg, score, acc)) in scored.iter().enumerate() {
            steps.push(SearchStep {
                config: cfg,
                rung: 0,
                budget: probe,
                score_gib: score,
                sim_accesses: acc,
                verdict: if pruned[j] {
                    Verdict::Pruned { cutoff_gib: cutoff }
                } else {
                    Verdict::Advanced
                },
            });
            if !pruned[j] {
                surv.push(cfg);
            }
        }
        surv
    };

    // Full-budget rung.
    let mut full_runs = 0u32;
    let mut finals: Vec<(StridingConfig, cost::CostSample)> = Vec::new();
    {
        let _rung_span = crate::obs::span("tuner_full_rung");
        for &cfg in &survivors {
            let s = cost::evaluate_on(store, engines, machine, kernel, budget, cfg, prefetch)?;
            full_runs += 1;
            sim_accesses += s.sim_accesses;
            steps.push(SearchStep {
                config: cfg,
                rung: 1,
                budget,
                score_gib: Some(s.throughput_gib),
                sim_accesses: s.sim_accesses,
                verdict: Verdict::Advanced,
            });
            finals.push((cfg, s));
        }
    }
    // Same tie-breaking as experiments::best_point: max_by keeps the last
    // maximal element in family order.
    let (winner_cfg, winner) = finals
        .iter()
        .max_by(|a, b| a.1.throughput_gib.partial_cmp(&b.1.throughput_gib).expect("no NaN"))
        .map(|&(c, s)| (c, s))
        .expect("at least one survivor ran at full budget");
    for st in steps.iter_mut() {
        if st.rung == 1 && st.config == winner_cfg {
            st.verdict = Verdict::Winner;
        }
    }

    // Probe-rung scores backing the speedup claim. When the probe rung
    // was skipped entirely (single feasible candidate — necessarily the
    // baseline), the speedup is 1 by definition and both sides carry the
    // full-budget score. A winner that advanced *unprobed* reports NaN
    // instead — `speedup_over_single` then abstains rather than dividing
    // scores from different budgets.
    let (winner_probe_gib, baseline_probe_gib) = if live.len() == 1 {
        (winner.throughput_gib, winner.throughput_gib)
    } else {
        let wp = probe_scores
            .iter()
            .find(|&&(c, _)| c == winner_cfg)
            .map(|&(_, v)| v)
            .unwrap_or(f64::NAN);
        (wp, baseline_probe_gib)
    };

    let plan = TunedPlan {
        kernel: kernel.to_string(),
        machine: machine.name.to_string(),
        machine_fingerprint: machine_fingerprint(&machine, prefetch),
        spec_hash: spec_hash(&pk.spec),
        budget_class: budget_class(budget),
        budget_bytes: budget,
        prefetch,
        config: winner_cfg,
        predicted_gib: winner.throughput_gib,
        winner_probe_gib,
        baseline_probe_gib,
        predicted_accesses_per_sec: winner.accesses_per_sec,
        l1_hit: winner.l1_hit,
        l2_hit: winner.l2_hit,
        l3_hit: winner.l3_hit,
        probe_runs,
        full_runs,
        search_sim_accesses: sim_accesses,
    };
    crate::obs::global().with(|v| {
        v.counter_add("tuner_searches_total", 1);
        v.counter_add("tuner_steps_total", steps.len() as u64);
        v.counter_add("tuner_probe_runs_total", u64::from(probe_runs));
        v.counter_add("tuner_full_runs_total", u64::from(full_runs));
        v.counter_add(
            "tuner_pruned_total",
            steps.iter().filter(|s| matches!(s.verdict, Verdict::Pruned { .. })).count() as u64,
        );
        v.counter_add(
            "tuner_infeasible_total",
            steps.iter().filter(|s| matches!(s.verdict, Verdict::Infeasible)).count() as u64,
        );
        v.counter_add("tuner_search_accesses_total", sim_accesses);
    });
    Ok(SearchOutcome { plan, steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::transform::STRIDE_FAMILY;

    const MIB: u64 = 1 << 20;

    #[test]
    fn probe_budget_stays_under_full_and_respects_regime() {
        let m = coffee_lake();
        let p = SearchParams::default();
        // Small budgets: divisor floor wins, capped at half.
        assert_eq!(probe_budget(&m, 2 * MIB, &p), MIB);
        // DRAM-bound budgets: floored to 2× L3 (24 MiB), capped at half.
        assert_eq!(probe_budget(&m, 48 * MIB, &p), 24 * MIB);
        assert_eq!(probe_budget(&m, 512 * MIB, &p), 64 * MIB);
        // The smoke scale sits exactly at 2× L3: the floor engages and
        // the half-cap leaves the probe at the L3 boundary, not 4× inside.
        assert_eq!(probe_budget(&m, 24 * MIB, &p), 12 * MIB);
        for b in [1, 2 * MIB, 48 * MIB, 512 * MIB] {
            assert!(probe_budget(&m, b, &p) < b.max(2));
        }
    }

    #[test]
    fn search_records_every_candidate_and_picks_a_feasible_winner() {
        let m = coffee_lake();
        let out = search(
            &mut EngineCache::new(),
            m,
            "mxv",
            2 * MIB,
            true,
            &SearchParams::default(),
        )
        .unwrap();
        let fam_len = 1 + STRIDE_FAMILY.len();
        // Every family member appears at the probe rung (mxv is feasible
        // across the whole family).
        let rung0: Vec<_> = out.steps.iter().filter(|s| s.rung == 0).collect();
        assert_eq!(rung0.len(), fam_len);
        assert!(rung0.iter().all(|s| s.score_gib.is_some()));
        // Something was pruned, and strictly fewer full runs than family.
        assert!(out.steps.iter().any(|s| matches!(s.verdict, Verdict::Pruned { .. })));
        assert!((out.plan.full_runs as usize) < fam_len);
        assert_eq!(
            out.steps.iter().filter(|s| matches!(s.verdict, Verdict::Winner)).count(),
            1
        );
        assert!(out.plan.predicted_gib > 0.0);
        assert!(out.plan.search_sim_accesses > 0);
        assert!(out.plan.speedup_over_single().is_some());
    }

    #[test]
    fn infeasible_variants_are_gated_without_simulation() {
        // bicg at S=8 exceeds the 16-register file.
        let m = coffee_lake();
        let out = search(
            &mut EngineCache::new(),
            m,
            "bicg",
            2 * MIB,
            true,
            &SearchParams::default(),
        )
        .unwrap();
        let gated: Vec<_> = out
            .steps
            .iter()
            .filter(|s| matches!(s.verdict, Verdict::Infeasible))
            .collect();
        assert!(!gated.is_empty(), "bicg has an infeasible family member");
        assert!(gated.iter().all(|s| s.sim_accesses == 0 && s.score_gib.is_none()));
        assert!(out.plan.config.stride_unroll != 8 || out.plan.config.portion_unroll != 2);
    }

    #[test]
    fn unknown_kernel_is_an_error() {
        let m = coffee_lake();
        assert!(search(
            &mut EngineCache::new(),
            m,
            "nope",
            2 * MIB,
            true,
            &SearchParams::default()
        )
        .is_err());
    }
}
