//! Auto-tuning planner: search the multi-stride variant space with the
//! simulator as cost model, backed by a persistent plan cache.
//!
//! The paper's transformation is mechanical (`transform::variants`), but
//! *selection* — which family member to run on which machine — was until
//! now an exhaustive sweep whose answer was thrown away. This subsystem
//! makes selection a served artifact: tune once, cache the
//! [`TunedPlan`], and answer every later request for the same
//! `(kernel, machine, budget-class)` from disk.
//!
//! Layering (one module per concern):
//!
//! * [`plan`] — the [`TunedPlan`] record, its bit-exact on-disk format,
//!   and the identity hashes (spec content hash, machine fingerprint,
//!   budget class) that define the staleness contract.
//! * [`cost`] — the cost model: the warm-engine simulator itself, run
//!   under the exact sweep protocol so predictions *are* measurements —
//!   read through the [`crate::exec::ResultStore`], so points a sweep
//!   (or an earlier search) already simulated are served, not re-run.
//! * [`search`] — successive-halving over the derived variant family:
//!   feasibility gate → reduced-budget probe rung → prune dominated
//!   candidates → full-budget rung, with an audit trace of every visit.
//! * [`cache`] — the on-disk [`PlanCache`] under the artifact dir.
//!
//! [`Tuner`] ties them together: consult the cache, validate the stored
//! identity triple, and either serve the hit or cold-search and persist.
//! `coordinator::experiments::{tune_kernel, tune_universe}` fan tuning
//! out across the registry on the worker pool, and `repro tune` is the
//! CLI surface. See ARCHITECTURE.md §Tuner.

pub mod cache;
pub mod cost;
pub mod plan;
pub mod search;

pub use cache::PlanCache;
pub use plan::{budget_class, machine_fingerprint, spec_hash, TunedPlan};
pub use search::{probe_budget, search, search_on, SearchOutcome, SearchParams, SearchStep, Verdict};

use crate::config::MachineConfig;
use crate::coordinator::experiments::EngineCache;
use crate::kernels::library::kernel_by_name;
use crate::{format_err, Result};

/// One tuning request's result: the plan, plus whether it came from the
/// cache (in which case the search trace is empty).
#[derive(Debug, Clone)]
pub struct TuneOutcome {
    pub plan: TunedPlan,
    pub cache_hit: bool,
    pub steps: Vec<SearchStep>,
}

/// A tuning endpoint for one `(machine, budget, prefetch)` context.
#[derive(Debug, Clone, Copy)]
pub struct Tuner {
    pub machine: MachineConfig,
    pub budget: u64,
    pub prefetch: bool,
    pub params: SearchParams,
}

impl Tuner {
    /// Prefetch-on tuner with default search parameters.
    pub fn new(machine: MachineConfig, budget: u64) -> Self {
        Self { machine, budget, prefetch: true, params: SearchParams::default() }
    }

    /// [`Tuner::tune_on`] against a throwaway ephemeral result store
    /// (compatibility surface; the search still flows through the
    /// execution layer, with in-search dedup only).
    pub fn tune(
        &self,
        engines: &mut EngineCache,
        cache: &PlanCache,
        kernel: &str,
        force: bool,
    ) -> Result<TuneOutcome> {
        self.tune_on(&crate::exec::ResultStore::ephemeral(), engines, cache, kernel, force)
    }

    /// Serve a plan for `kernel`: a validated cache hit when possible,
    /// otherwise a cold search whose winner is persisted before
    /// returning. `force` bypasses the cache lookup (the search result
    /// still overwrites the cached plan). The search's cost-model reads
    /// flow through `store`, so points a sweep (or an earlier search)
    /// already simulated are served, not re-run — the resulting plan is
    /// byte-identical either way.
    ///
    /// Cache handling is deliberately forgiving: a stale plan (identity
    /// triple mismatch — see [`plan`]) or an unreadable/corrupt file is
    /// reported on stderr and re-tuned, never served and never fatal.
    pub fn tune_on(
        &self,
        store: &crate::exec::ResultStore,
        engines: &mut EngineCache,
        cache: &PlanCache,
        kernel: &str,
        force: bool,
    ) -> Result<TuneOutcome> {
        let pk = kernel_by_name(kernel, self.budget)
            .ok_or_else(|| format_err!("unknown kernel {kernel}"))?;
        let class = budget_class(self.budget);
        let want_spec = spec_hash(&pk.spec);
        let want_machine = machine_fingerprint(&self.machine, self.prefetch);
        if !force {
            match cache.load(kernel, self.machine.name, self.prefetch, class) {
                Ok(Some(p)) => {
                    if p.spec_hash == want_spec
                        && p.machine_fingerprint == want_machine
                        && p.budget_class == class
                    {
                        return Ok(TuneOutcome { plan: p, cache_hit: true, steps: Vec::new() });
                    }
                    eprintln!(
                        "[tune] stale plan for {kernel} on {} (spec or machine changed) — re-tuning",
                        self.machine.name
                    );
                }
                Ok(None) => {}
                Err(e) => eprintln!("[tune] {e} — re-tuning"),
            }
        }
        let out = search::search_on(
            store,
            engines,
            self.machine,
            kernel,
            self.budget,
            self.prefetch,
            &self.params,
        )?;
        cache.store(&out.plan)?;
        Ok(TuneOutcome { plan: out.plan, cache_hit: false, steps: out.steps })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;

    const MIB: u64 = 1 << 20;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("multistride_tuner_mod_{tag}_{}", std::process::id()))
    }

    #[test]
    fn cold_then_hit_then_force() {
        let dir = tmp("basic");
        std::fs::remove_dir_all(&dir).ok();
        let cache = PlanCache::new(&dir);
        let tuner = Tuner::new(coffee_lake(), 2 * MIB);
        let mut engines = EngineCache::new();

        let cold = tuner.tune(&mut engines, &cache, "mxv", false).unwrap();
        assert!(!cold.cache_hit);
        assert!(!cold.steps.is_empty());

        let hit = tuner.tune(&mut engines, &cache, "mxv", false).unwrap();
        assert!(hit.cache_hit);
        assert!(hit.steps.is_empty());
        assert_eq!(hit.plan.serialize(), cold.plan.serialize(), "hit serves the exact plan");

        let forced = tuner.tune(&mut engines, &cache, "mxv", true).unwrap();
        assert!(!forced.cache_hit, "--force bypasses the cache");
        assert_eq!(forced.plan.serialize(), cold.plan.serialize(), "search is deterministic");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_and_corrupt_plans_are_retuned() {
        let dir = tmp("stale");
        std::fs::remove_dir_all(&dir).ok();
        let cache = PlanCache::new(&dir);
        let tuner = Tuner::new(coffee_lake(), 2 * MIB);
        let mut engines = EngineCache::new();
        let cold = tuner.tune(&mut engines, &cache, "triad", false).unwrap();

        // Stale: valid file, wrong spec hash — must re-search, not serve.
        let mut stale = cold.plan.clone();
        stale.spec_hash ^= 1;
        cache.store(&stale).unwrap();
        let re = tuner.tune(&mut engines, &cache, "triad", false).unwrap();
        assert!(!re.cache_hit, "stale plans are re-tuned, not served");
        assert_eq!(re.plan.serialize(), cold.plan.serialize());
        // ... and the refreshed plan was persisted over the stale one.
        let hit = tuner.tune(&mut engines, &cache, "triad", false).unwrap();
        assert!(hit.cache_hit);

        // Corrupt: garbage on disk — recoverable, re-tuned.
        let path = cache.path_for("triad", "Coffee Lake", true, budget_class(2 * MIB));
        std::fs::write(&path, "not a plan at all").unwrap();
        let re = tuner.tune(&mut engines, &cache, "triad", false).unwrap();
        assert!(!re.cache_hit);
        assert_eq!(re.plan.serialize(), cold.plan.serialize());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_kernel_is_an_error() {
        let cache = PlanCache::new(tmp("unknown"));
        let tuner = Tuner::new(coffee_lake(), 2 * MIB);
        assert!(tuner.tune(&mut EngineCache::new(), &cache, "nope", false).is_err());
    }
}
