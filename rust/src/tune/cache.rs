//! The persistent on-disk plan cache.
//!
//! One file per `(kernel, machine, prefetch, budget-class)` key under a
//! root directory (by default `<artifacts>/plans`, i.e. under the
//! [`crate::runtime::ArtifactRegistry`] dir). File names are a
//! human-readable projection of the key; the *authoritative* identity is
//! the plan's `(spec_hash, machine_fingerprint, budget_class)` triple,
//! which [`super::Tuner`] re-checks on every load — a renamed or copied
//! file can therefore never smuggle a stale plan past the tuner.
//!
//! Durability: [`PlanCache::store`] writes to a temp file and renames
//! over the destination, so a reader never observes a half-written plan;
//! a plan that *is* damaged on disk fails [`TunedPlan::parse`]'s checksum
//! with a recoverable error ([`PlanCache::load`] returns `Err`, never
//! panics), which the tuner treats as a miss and re-tunes.
//!
//! All filesystem traffic goes through the [`StoreIo`] seam (see
//! `exec::vfs`), so `tests/plan_cache_roundtrip.rs` can drive the cache
//! through seeded fault schedules: a torn write or failed rename must
//! stay a recoverable miss, never a stale or partial serve.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use super::plan::TunedPlan;
use crate::exec::vfs::{default_io, with_retry, StoreIo};
use crate::{format_err, Result};

/// Handle to a plan-cache directory (which need not exist yet).
#[derive(Clone)]
pub struct PlanCache {
    dir: PathBuf,
    io: Arc<dyn StoreIo>,
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache").field("dir", &self.dir).finish()
    }
}

impl PlanCache {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self::with_io(dir, default_io())
    }

    /// Like [`PlanCache::new`] but over an explicit I/O backend (the
    /// fault injector in tests; `default_io()` everywhere else).
    pub fn with_io(dir: impl Into<PathBuf>, io: Arc<dyn StoreIo>) -> Self {
        Self { dir: dir.into(), io }
    }

    /// The conventional location under an artifact directory.
    pub fn default_under(artifacts_dir: &Path) -> Self {
        Self::new(artifacts_dir.join("plans"))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path a plan for this key lives at.
    pub fn path_for(
        &self,
        kernel: &str,
        machine: &str,
        prefetch: bool,
        budget_class: u32,
    ) -> PathBuf {
        let slug: String = machine
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c.to_ascii_lowercase() } else { '-' })
            .collect();
        let pf = if prefetch { "pf" } else { "nopf" };
        self.dir.join(format!("{kernel}_{slug}_{pf}_b{budget_class}.plan"))
    }

    /// Load the plan for a key. `Ok(None)` when absent; `Err` (recoverable)
    /// when present but unreadable or corrupt.
    pub fn load(
        &self,
        kernel: &str,
        machine: &str,
        prefetch: bool,
        budget_class: u32,
    ) -> Result<Option<TunedPlan>> {
        let path = self.path_for(kernel, machine, prefetch, budget_class);
        let bytes = match with_retry(|| self.io.read(&path)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format_err!("plan cache: cannot read {path:?}: {e}")),
        };
        let text = String::from_utf8(bytes)
            .map_err(|_| format_err!("plan cache: {path:?}: not valid UTF-8"))?;
        TunedPlan::parse(&text)
            .map(Some)
            .map_err(|e| format_err!("plan cache: {path:?}: {e}"))
    }

    /// Persist a plan under its own key, atomically (temp file + rename).
    /// Parallel tuners write distinct keys, so distinct temp names.
    pub fn store(&self, plan: &TunedPlan) -> Result<PathBuf> {
        with_retry(|| self.io.create_dir_all(&self.dir))
            .map_err(|e| format_err!("plan cache: cannot create {:?}: {e}", self.dir))?;
        let path =
            self.path_for(&plan.kernel, &plan.machine, plan.prefetch, plan.budget_class);
        let tmp = path.with_extension("plan.tmp");
        with_retry(|| self.io.write(&tmp, plan.serialize().as_bytes()))
            .map_err(|e| format_err!("plan cache: cannot write {tmp:?}: {e}"))?;
        with_retry(|| self.io.rename(&tmp, &path)).map_err(|e| {
            format_err!("plan cache: cannot move plan into place at {path:?}: {e}")
        })?;
        Ok(path)
    }

    /// All plan files currently cached (sorted; for benches and CI).
    pub fn list(&self) -> Vec<PathBuf> {
        let mut out = Vec::new();
        if let Ok(entries) = self.io.list_dir(&self.dir) {
            for e in entries {
                let p = self.dir.join(&e.name);
                if !e.is_dir && p.extension().and_then(|x| x.to_str()) == Some("plan") {
                    out.push(p);
                }
            }
        }
        out.sort();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::kernels::library::mxv;
    use crate::transform::StridingConfig;
    use crate::tune::plan::{budget_class, machine_fingerprint, spec_hash};

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("multistride_plancache_{tag}_{}", std::process::id()))
    }

    fn plan() -> TunedPlan {
        TunedPlan {
            kernel: "mxv".into(),
            machine: "Coffee Lake".into(),
            machine_fingerprint: machine_fingerprint(&coffee_lake(), true),
            spec_hash: spec_hash(&mxv(1 << 22).spec),
            budget_class: budget_class(1 << 22),
            budget_bytes: 1 << 22,
            prefetch: true,
            config: StridingConfig::new(8, 2),
            predicted_gib: 10.0,
            winner_probe_gib: 9.0,
            baseline_probe_gib: 4.0,
            predicted_accesses_per_sec: 1e9,
            l1_hit: 0.8,
            l2_hit: 0.4,
            l3_hit: 0.2,
            probe_runs: 4,
            full_runs: 2,
            search_sim_accesses: 1000,
        }
    }

    #[test]
    fn store_then_load_roundtrips() {
        let dir = tmp("roundtrip");
        std::fs::remove_dir_all(&dir).ok();
        let cache = PlanCache::new(&dir);
        let p = plan();
        assert!(cache.load("mxv", "Coffee Lake", true, p.budget_class).unwrap().is_none());
        let path = cache.store(&p).unwrap();
        assert!(path.starts_with(&dir));
        let q = cache
            .load("mxv", "Coffee Lake", true, p.budget_class)
            .unwrap()
            .expect("plan present");
        assert_eq!(p.serialize(), q.serialize());
        assert_eq!(cache.list(), vec![path]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn keys_separate_prefetch_and_class() {
        let cache = PlanCache::new("/nonexistent");
        let a = cache.path_for("mxv", "Coffee Lake", true, 22);
        let b = cache.path_for("mxv", "Coffee Lake", false, 22);
        let c = cache.path_for("mxv", "Coffee Lake", true, 26);
        let d = cache.path_for("mxv", "Zen 2", true, 22);
        assert!(a != b && a != c && a != d && b != c);
        assert!(a.to_string_lossy().ends_with("mxv_coffee-lake_pf_b22.plan"));
    }

    #[test]
    fn corrupt_file_is_a_recoverable_error() {
        let dir = tmp("corrupt");
        std::fs::remove_dir_all(&dir).ok();
        let cache = PlanCache::new(&dir);
        let p = plan();
        let path = cache.store(&p).unwrap();
        // Truncate the stored file mid-way.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        let err = cache.load("mxv", "Coffee Lake", true, p.budget_class);
        assert!(err.is_err(), "corruption must surface as a recoverable error");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_lists_empty() {
        let cache = PlanCache::new("/nonexistent/multistride_plans");
        assert!(cache.list().is_empty());
    }
}
