//! The persistent artifact of a tuning run: a [`TunedPlan`] plus its
//! hand-rolled, dependency-free on-disk format.
//!
//! ## Format (`multistride-tuned-plan v1`)
//!
//! One plan per file: a fixed header line, a fixed-order sequence of
//! `key = value` lines, and a terminating `checksum` line (FNV-1a 64 over
//! every preceding byte). Floating-point fields are serialized as the hex
//! IEEE-754 bit pattern (`{:#018x}` of `f64::to_bits`), never as decimal
//! text, so serialize → parse → serialize is **bit-identical** — the
//! property `tests/plan_cache_roundtrip.rs` pins for randomized plans.
//! The human-readable view of a plan is the `repro tune` table, not the
//! file.
//!
//! ## Invalidation contract
//!
//! A cached plan is only served when all three of its identity fields
//! match the current request:
//!
//! * [`TunedPlan::spec_hash`] — content hash of the (untransformed)
//!   [`KernelSpec`] at the request budget ([`spec_hash`]);
//! * [`TunedPlan::machine_fingerprint`] — hash of the full
//!   [`MachineConfig`] *and* the prefetch enable bit
//!   ([`machine_fingerprint`]), so tuning with the prefetcher off never
//!   masquerades as the prefetch-on plan;
//! * [`TunedPlan::budget_class`] — the power-of-two ceiling class of the
//!   byte budget ([`budget_class`]).
//!
//! Any mismatch means the plan is *stale*: the tuner re-searches and
//! overwrites rather than silently serving it. A corrupted or truncated
//! file fails the checksum (or strict field parse) with a recoverable
//! [`crate::error::Error`] — never a panic — and is likewise re-tuned.

use crate::config::MachineConfig;
use crate::kernels::spec::{AccessMode, KernelSpec};
use crate::trace::Arrangement;
use crate::transform::StridingConfig;
use crate::{ensure, format_err, Result};

/// First line of every plan file; doubles as the format version. Bump it
/// when adding a field (old files then fail the header check and re-tune,
/// which is the intended migration path).
pub const PLAN_HEADER: &str = "multistride-tuned-plan v1";

/// The winning variant of one `(kernel, machine, budget-class)` tuning
/// request, with enough provenance to detect staleness and report search
/// cost.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedPlan {
    /// Kernel name in the registry universe.
    pub kernel: String,
    /// Machine preset name (human key; [`Self::machine_fingerprint`] is
    /// the authoritative identity).
    pub machine: String,
    /// [`machine_fingerprint`] of the machine + prefetch bit tuned on.
    pub machine_fingerprint: u64,
    /// [`spec_hash`] of the untransformed spec at [`Self::budget_bytes`].
    pub spec_hash: u64,
    /// [`budget_class`] of the tuning budget.
    pub budget_class: u32,
    /// Exact byte budget the search ran at.
    pub budget_bytes: u64,
    /// Hardware prefetching enabled during the search.
    pub prefetch: bool,
    /// The chosen variant configuration.
    pub config: StridingConfig,
    /// Winner's full-budget throughput (the simulator's prediction).
    pub predicted_gib: f64,
    /// Winner's probe-rung score. NaN if the winner advanced unprobed
    /// (the probe-scale spec could not host it); equal to
    /// [`Self::predicted_gib`] when the probe rung was skipped entirely
    /// (single-candidate search, where the speedup is 1 by definition).
    pub winner_probe_gib: f64,
    /// Single-stride baseline's probe-rung score (NaN when unavailable).
    /// Speedup is reported probe-vs-probe so both sides share a budget.
    pub baseline_probe_gib: f64,
    /// Winner's simulated vector accesses per simulated second.
    pub predicted_accesses_per_sec: f64,
    /// Winner's cache hit ratios at full budget.
    pub l1_hit: f64,
    pub l2_hit: f64,
    pub l3_hit: f64,
    /// Probe-rung simulations the search ran.
    pub probe_runs: u32,
    /// Full-budget simulations the search ran.
    pub full_runs: u32,
    /// Total simulated accesses spent searching (the search-cost column).
    pub search_sim_accesses: u64,
}

impl TunedPlan {
    /// Predicted speedup of the chosen variant over the single-stride
    /// baseline, measured at the probe rung (both sides share a budget).
    /// `None` when the baseline score is unavailable.
    pub fn speedup_over_single(&self) -> Option<f64> {
        if self.baseline_probe_gib.is_finite()
            && self.winner_probe_gib.is_finite()
            && self.baseline_probe_gib > 0.0
        {
            Some(self.winner_probe_gib / self.baseline_probe_gib)
        } else {
            None
        }
    }

    /// Serialize to the on-disk format (see the module docs).
    pub fn serialize(&self) -> String {
        fn kv(out: &mut String, k: &str, v: impl std::fmt::Display) {
            use std::fmt::Write;
            let _ = writeln!(out, "{k} = {v}");
        }
        let mut out = String::with_capacity(640);
        out.push_str(PLAN_HEADER);
        out.push('\n');
        kv(&mut out, "kernel", &self.kernel);
        kv(&mut out, "machine", &self.machine);
        kv(&mut out, "machine_fingerprint", hex(self.machine_fingerprint));
        kv(&mut out, "spec_hash", hex(self.spec_hash));
        kv(&mut out, "budget_class", self.budget_class);
        kv(&mut out, "budget_bytes", self.budget_bytes);
        kv(&mut out, "prefetch", self.prefetch);
        kv(&mut out, "stride_unroll", self.config.stride_unroll);
        kv(&mut out, "portion_unroll", self.config.portion_unroll);
        kv(&mut out, "eliminate_redundant", self.config.eliminate_redundant);
        kv(&mut out, "arrangement", arrangement_str(self.config.arrangement));
        kv(&mut out, "predicted_gib", hex(self.predicted_gib.to_bits()));
        kv(&mut out, "winner_probe_gib", hex(self.winner_probe_gib.to_bits()));
        kv(&mut out, "baseline_probe_gib", hex(self.baseline_probe_gib.to_bits()));
        kv(&mut out, "predicted_accesses_per_sec", hex(self.predicted_accesses_per_sec.to_bits()));
        kv(&mut out, "l1_hit", hex(self.l1_hit.to_bits()));
        kv(&mut out, "l2_hit", hex(self.l2_hit.to_bits()));
        kv(&mut out, "l3_hit", hex(self.l3_hit.to_bits()));
        kv(&mut out, "probe_runs", self.probe_runs);
        kv(&mut out, "full_runs", self.full_runs);
        kv(&mut out, "search_sim_accesses", self.search_sim_accesses);
        let sum = fnv64(out.as_bytes());
        kv(&mut out, "checksum", hex(sum));
        out
    }

    /// Parse the on-disk format. Verification order: checksum first (so
    /// any corruption or truncation is one clear error), then the strict
    /// fixed-order field walk. Never panics on malformed input.
    pub fn parse(text: &str) -> Result<TunedPlan> {
        let idx = text
            .rfind("checksum = ")
            .ok_or_else(|| format_err!("plan corrupt: no checksum line (truncated?)"))?;
        ensure!(
            idx == 0 || text[..idx].ends_with('\n'),
            "plan corrupt: checksum marker not at line start"
        );
        let prefix = &text[..idx];
        // The checksum line must be exactly `checksum = 0x<hex>\n` and
        // must end the file — no sloppy trailing bytes, or corruption in
        // the final line could slip past the digest it guards.
        let val = text[idx..]
            .strip_prefix("checksum = ")
            .expect("rfind guarantees the prefix");
        let val = val
            .strip_suffix('\n')
            .ok_or_else(|| format_err!("plan corrupt: checksum line not newline-terminated"))?;
        let want = parse_u64(val)?;
        // Canonical form only: `from_str_radix` is case-insensitive (and
        // the value could be decimal), so a byte of the checksum line —
        // which sits outside the digest it carries — could otherwise be
        // tampered without changing the parsed value.
        ensure!(val == hex(want), "plan corrupt: checksum line not in canonical form");
        ensure!(
            fnv64(prefix.as_bytes()) == want,
            "plan corrupt: checksum mismatch (file edited or truncated)"
        );

        let mut lines = prefix.lines();
        ensure!(
            lines.next() == Some(PLAN_HEADER),
            "plan corrupt or wrong version: expected header {PLAN_HEADER:?}"
        );
        let kernel = expect_field(&mut lines, "kernel")?.to_string();
        let machine = expect_field(&mut lines, "machine")?.to_string();
        let machine_fingerprint = parse_u64(expect_field(&mut lines, "machine_fingerprint")?)?;
        let spec_hash = parse_u64(expect_field(&mut lines, "spec_hash")?)?;
        let budget_class = parse_u32(expect_field(&mut lines, "budget_class")?)?;
        let budget_bytes = parse_u64(expect_field(&mut lines, "budget_bytes")?)?;
        let prefetch = parse_bool(expect_field(&mut lines, "prefetch")?)?;
        let stride_unroll = parse_u32(expect_field(&mut lines, "stride_unroll")?)?;
        let portion_unroll = parse_u32(expect_field(&mut lines, "portion_unroll")?)?;
        let eliminate_redundant = parse_bool(expect_field(&mut lines, "eliminate_redundant")?)?;
        let arrangement = parse_arrangement(expect_field(&mut lines, "arrangement")?)?;
        let predicted_gib = parse_f64(expect_field(&mut lines, "predicted_gib")?)?;
        let winner_probe_gib = parse_f64(expect_field(&mut lines, "winner_probe_gib")?)?;
        let baseline_probe_gib = parse_f64(expect_field(&mut lines, "baseline_probe_gib")?)?;
        let predicted_accesses_per_sec =
            parse_f64(expect_field(&mut lines, "predicted_accesses_per_sec")?)?;
        let l1_hit = parse_f64(expect_field(&mut lines, "l1_hit")?)?;
        let l2_hit = parse_f64(expect_field(&mut lines, "l2_hit")?)?;
        let l3_hit = parse_f64(expect_field(&mut lines, "l3_hit")?)?;
        let probe_runs = parse_u32(expect_field(&mut lines, "probe_runs")?)?;
        let full_runs = parse_u32(expect_field(&mut lines, "full_runs")?)?;
        let search_sim_accesses = parse_u64(expect_field(&mut lines, "search_sim_accesses")?)?;
        ensure!(lines.next().is_none(), "plan corrupt: trailing content after the field block");

        let config = StridingConfig {
            stride_unroll,
            portion_unroll,
            eliminate_redundant,
            arrangement,
        };
        Ok(TunedPlan {
            kernel,
            machine,
            machine_fingerprint,
            spec_hash,
            budget_class,
            budget_bytes,
            prefetch,
            config,
            predicted_gib,
            winner_probe_gib,
            baseline_probe_gib,
            predicted_accesses_per_sec,
            l1_hit,
            l2_hit,
            l3_hit,
            probe_runs,
            full_runs,
            search_sim_accesses,
        })
    }
}

pub(crate) fn hex(v: u64) -> String {
    format!("{v:#018x}")
}

fn arrangement_str(a: Arrangement) -> &'static str {
    match a {
        Arrangement::Grouped => "grouped",
        Arrangement::Interleaved => "interleaved",
    }
}

fn parse_arrangement(s: &str) -> Result<Arrangement> {
    match s {
        "grouped" => Ok(Arrangement::Grouped),
        "interleaved" => Ok(Arrangement::Interleaved),
        other => Err(format_err!("plan corrupt: unknown arrangement {other:?}")),
    }
}

// The field-walk helpers below are shared with `exec::format`, which
// serializes simulation results under the same strict key=value +
// checksum discipline (pub(crate) for that reason).
pub(crate) fn expect_field<'a>(lines: &mut std::str::Lines<'a>, key: &str) -> Result<&'a str> {
    let l = lines
        .next()
        .ok_or_else(|| format_err!("plan truncated before field `{key}`"))?;
    l.strip_prefix(key)
        .and_then(|rest| rest.strip_prefix(" = "))
        .ok_or_else(|| format_err!("plan corrupt: expected field `{key}`, found {l:?}"))
}

// Deliberately no whitespace trimming anywhere below: the serializer
// emits exact values, so any stray byte (e.g. a flipped trailing
// newline) must fail the parse rather than be forgiven.
pub(crate) fn parse_u64(s: &str) -> Result<u64> {
    let parsed = match s.strip_prefix("0x") {
        Some(h) => u64::from_str_radix(h, 16),
        None => s.parse(),
    };
    parsed.map_err(|e| format_err!("plan corrupt: bad number {s:?}: {e}"))
}

pub(crate) fn parse_u32(s: &str) -> Result<u32> {
    let v = parse_u64(s)?;
    u32::try_from(v).map_err(|_| format_err!("plan corrupt: {v} out of u32 range"))
}

fn parse_bool(s: &str) -> Result<bool> {
    match s {
        "true" => Ok(true),
        "false" => Ok(false),
        other => Err(format_err!("plan corrupt: bad bool {other:?}")),
    }
}

pub(crate) fn parse_f64(s: &str) -> Result<f64> {
    Ok(f64::from_bits(parse_u64(s)?))
}

/// FNV-1a 64-bit over a byte slice. Hand-rolled so hashes are stable
/// across processes and Rust versions (std's `DefaultHasher` promises
/// neither) — plan staleness detection depends on that stability.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = Fnv::new();
    h.bytes(bytes);
    h.0
}

/// Structured FNV-1a: length-prefixed strings and little-endian integers,
/// so field boundaries cannot alias. Shared with [`crate::exec`], whose
/// `SimPoint` content keys are built from the same primitives (and must
/// stay process-stable for the same reason plans must).
pub(crate) struct Fnv(u64);

impl Fnv {
    pub(crate) fn new() -> Self {
        Self(0xcbf2_9ce4_8422_2325)
    }
    pub(crate) fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    pub(crate) fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.bytes(&v.to_le_bytes());
    }
    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }
    /// The digest so far.
    pub(crate) fn finish(&self) -> u64 {
        self.0
    }
}

/// Content hash of an (untransformed) kernel spec: loop nest, array
/// layout and every access's affine subscripts. Two specs hash equal iff
/// the trace universe they generate is identical, so a budget change that
/// re-sizes extents — or any library edit — invalidates cached plans.
pub fn spec_hash(spec: &KernelSpec) -> u64 {
    let mut h = Fnv::new();
    h.str(&spec.name);
    h.u64(spec.loops.len() as u64);
    for l in &spec.loops {
        h.str(&l.name);
        h.u64(l.extent);
    }
    h.u64(spec.arrays.len() as u64);
    for a in &spec.arrays {
        h.str(&a.name);
        h.u64(a.dims.len() as u64);
        for &d in &a.dims {
            h.u64(d);
        }
        h.u64(a.elem_bytes as u64);
        h.u64(a.base);
    }
    h.u64(spec.accesses.len() as u64);
    for acc in &spec.accesses {
        h.u64(acc.array as u64);
        h.u64(acc.idx.len() as u64);
        for e in &acc.idx {
            h.u64(e.terms.len() as u64);
            for &(l, c) in &e.terms {
                h.u64(l as u64);
                h.i64(c);
            }
            h.i64(e.offset);
        }
        h.u64(match acc.mode {
            AccessMode::Read => 0,
            AccessMode::Write => 1,
            AccessMode::ReadWrite => 2,
        });
    }
    h.u64(spec.loop_carried_dep as u64);
    h.0
}

/// Fingerprint of everything machine-side that shapes a tuning result:
/// every [`MachineConfig`] field plus the prefetch enable bit of the
/// run. Floats are hashed by bit pattern (their `Debug` rendering is not
/// stable across Rust releases, and the fingerprint must be); the
/// integer/bool/enum remainder goes through `Debug`, which *is* stable
/// for those types.
pub fn machine_fingerprint(m: &MachineConfig, prefetch: bool) -> u64 {
    // Exhaustive destructuring: adding a MachineConfig field breaks this
    // build until the fingerprint learns about it — a new machine knob
    // must invalidate cached plans, never be silently ignored.
    let MachineConfig {
        name,
        vendor,
        model,
        freq_ghz,
        bandwidth_gib,
        mem_channels,
        ram_gib,
        max_fma_gflops,
        l1,
        l2,
        l3,
        l1_lat,
        l2_lat,
        l3_lat,
        dram,
        tlb,
        wc,
        prefetch: machine_prefetch,
        lfb_entries,
        window_accesses,
        issue_per_cycle,
        simd_registers,
    } = *m;
    let mut h = Fnv::new();
    h.str(name);
    h.str(vendor);
    h.str(model);
    h.u64(freq_ghz.to_bits());
    h.u64(bandwidth_gib.to_bits());
    h.u64(max_fma_gflops.to_bits());
    h.str(&format!(
        "{:?}",
        (mem_channels, ram_gib, l1, l2, l3, l1_lat, l2_lat, l3_lat)
    ));
    h.str(&format!(
        "{:?}",
        (
            dram,
            tlb,
            wc,
            machine_prefetch,
            lfb_entries,
            window_accesses,
            issue_per_cycle,
            simd_registers,
        )
    ));
    h.bytes(&[prefetch as u8]);
    h.0
}

/// Power-of-two ceiling class of a byte budget: budgets rounding up to
/// the same power of two share a plan-cache slot (their specs almost
/// always coincide anyway thanks to extent rounding; when they don't,
/// the spec-hash check catches it and re-tunes).
pub fn budget_class(budget_bytes: u64) -> u32 {
    budget_bytes.max(1).next_power_of_two().trailing_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{cascade_lake, coffee_lake};
    use crate::kernels::library::{kernel_by_name, mxv};

    fn sample_plan() -> TunedPlan {
        TunedPlan {
            kernel: "mxv".into(),
            machine: "Coffee Lake".into(),
            machine_fingerprint: machine_fingerprint(&coffee_lake(), true),
            spec_hash: spec_hash(&mxv(1 << 22).spec),
            budget_class: 22,
            budget_bytes: 1 << 22,
            prefetch: true,
            config: StridingConfig::new(8, 2),
            predicted_gib: 12.34,
            winner_probe_gib: 11.0,
            baseline_probe_gib: 5.5,
            predicted_accesses_per_sec: 1.5e9,
            l1_hit: 0.75,
            l2_hit: 0.5,
            l3_hit: 0.25,
            probe_runs: 4,
            full_runs: 2,
            search_sim_accesses: 123_456,
        }
    }

    #[test]
    fn serialize_parse_roundtrip_exact() {
        let p = sample_plan();
        let s = p.serialize();
        let q = TunedPlan::parse(&s).expect("parses");
        assert_eq!(p, q);
        assert_eq!(s, q.serialize(), "round trip is bit-identical");
    }

    #[test]
    fn truncation_is_a_recoverable_error() {
        let s = sample_plan().serialize();
        for cut in [0, 1, PLAN_HEADER.len(), s.len() / 2, s.len() - 2] {
            assert!(TunedPlan::parse(&s[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn edits_fail_the_checksum() {
        let s = sample_plan().serialize();
        let tampered = s.replace("stride_unroll = 8", "stride_unroll = 4");
        assert!(TunedPlan::parse(&tampered).is_err());
    }

    #[test]
    fn spec_hash_tracks_content() {
        let a = kernel_by_name("mxv", 1 << 22).unwrap();
        let b = kernel_by_name("mxv", 1 << 22).unwrap();
        assert_eq!(spec_hash(&a.spec), spec_hash(&b.spec), "same budget, same hash");
        let big = kernel_by_name("mxv", 1 << 26).unwrap();
        assert_ne!(spec_hash(&a.spec), spec_hash(&big.spec), "extents feed the hash");
        let other = kernel_by_name("bicg", 1 << 22).unwrap();
        assert_ne!(spec_hash(&a.spec), spec_hash(&other.spec));
    }

    #[test]
    fn machine_fingerprint_tracks_machine_and_prefetch() {
        let cl = coffee_lake();
        assert_eq!(machine_fingerprint(&cl, true), machine_fingerprint(&coffee_lake(), true));
        assert_ne!(machine_fingerprint(&cl, true), machine_fingerprint(&cl, false));
        assert_ne!(machine_fingerprint(&cl, true), machine_fingerprint(&cascade_lake(), true));
    }

    #[test]
    fn budget_class_is_pow2_ceiling() {
        assert_eq!(budget_class(1), 0);
        assert_eq!(budget_class(4096), 12);
        assert_eq!(budget_class(4097), 13);
        assert_eq!(budget_class(48 * 1024 * 1024), 26);
        assert_eq!(budget_class(40 * 1024 * 1024), 26);
    }

    #[test]
    fn nan_and_inf_survive_the_bits_encoding() {
        let mut p = sample_plan();
        p.baseline_probe_gib = f64::NAN;
        p.winner_probe_gib = f64::INFINITY;
        let s = p.serialize();
        let q = TunedPlan::parse(&s).unwrap();
        assert!(q.baseline_probe_gib.is_nan());
        assert_eq!(q.winner_probe_gib, f64::INFINITY);
        assert_eq!(s, q.serialize());
        assert_eq!(q.speedup_over_single(), None, "NaN baseline yields no speedup claim");
    }
}
