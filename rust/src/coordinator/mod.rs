//! Experiment orchestration.
//!
//! The paper's evaluation is a large parameter sweep: 9 isolated kernels ×
//! ~200 striding configurations × 3 machines, plus the micro-benchmark
//! grids. [`pool::parallel_map_with`] fans configurations out over worker
//! threads (each simulation is independent and single-threaded), giving
//! every worker one [`experiments::EngineCache`] so sweep points reuse the
//! worker's warm [`crate::sim::Engine`] allocation instead of rebuilding
//! caches, TLBs and DRAM state per point; [`experiments`] contains one
//! driver per paper figure/table, returning structured results the
//! [`crate::report`] layer renders.

pub mod experiments;
pub mod pool;

pub use experiments::*;
pub use pool::{parallel_map, parallel_map_with, parallel_map_with_static};
