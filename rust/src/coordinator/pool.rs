//! A small scoped thread pool (no rayon offline): order-preserving
//! parallel map over independent jobs, with optional per-worker state so
//! sweeps can reuse expensive resources (a warm [`crate::sim::Engine`])
//! across the jobs one worker processes.
//!
//! Work distribution is dynamic: every worker owns a contiguous index
//! range and drains it front-to-back; a worker whose range empties
//! steals the upper half of the largest remaining range. Simulation
//! cost per point is wildly uneven (a 3-deep kernel nest costs orders
//! of magnitude more than a short micro run), which is exactly the
//! shape where static chunking leaves a fleet idling behind its
//! slowest chunk. Jobs here are coarse — whole engine runs — so the
//! per-claim mutex is noise next to the work it hands out.
//!
//! The pre-stealing distribution survives as
//! [`parallel_map_with_static`]: the reference the imbalance bench
//! (`benches/grid.rs`) and the differential tests below compare
//! against. Both paths keep the same contract: output in input order,
//! one `init()` state per worker, worker panics propagate.
//!
//! Straggler accounting folds into the metrics registry once per pool
//! run (never per job): `pool_jobs_claimed_total`, `pool_steals_total`,
//! and the per-worker busy-time histogram `pool_worker_busy_us`. Steal
//! counts depend on thread scheduling, so `pool_steals_total` is on the
//! [`crate::obs::export::SCHEDULING_COUNTERS`] list — exported to
//! Prometheus, excluded from the deterministic JSON snapshot.

use std::sync::Mutex;
use std::time::Instant;

/// Number of workers to use: `MULTISTRIDE_THREADS` env var, else the
/// available parallelism, else 4.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("MULTISTRIDE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every job on a pool of `workers` threads, preserving input
/// order in the output. Panics in workers propagate.
pub fn parallel_map<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send + Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    parallel_map_with(jobs, workers, || (), |_state, j| f(j))
}

/// What one pool run did, folded into the registry at pool exit.
struct PoolTally {
    claimed: u64,
    steals: u64,
    /// One busy-time observation per worker, in microseconds.
    busy_us: Vec<u64>,
}

impl PoolTally {
    fn fold(&self) {
        crate::obs::global().with(|v| {
            v.counter_add("pool_jobs_claimed_total", self.claimed);
            v.counter_add("pool_steals_total", self.steals);
            for &us in &self.busy_us {
                v.observe("pool_worker_busy_us", us);
            }
        });
    }
}

/// [`parallel_map`] with per-worker state: every worker thread builds one
/// `S` via `init` and threads it through all jobs it claims.
///
/// Results are collected into per-worker chunk buffers and stitched back
/// into input order at the end — no per-job locking on the result path.
pub fn parallel_map_with<S, J, R, I, F>(jobs: Vec<J>, workers: usize, init: I, f: F) -> Vec<R>
where
    J: Send + Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &J) -> R + Sync,
{
    let (out, tally) = run_dynamic(&jobs, workers, &init, &f);
    tally.fold();
    out
}

/// The dynamic work-stealing core, returning results plus the tally so
/// tests can assert scheduling behaviour without the global registry.
fn run_dynamic<S, J, R, I, F>(jobs: &[J], workers: usize, init: &I, f: &F) -> (Vec<R>, PoolTally)
where
    J: Send + Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return (Vec::new(), PoolTally { claimed: 0, steals: 0, busy_us: Vec::new() });
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        let start = Instant::now();
        let mut state = init();
        let out: Vec<R> = jobs
            .iter()
            .map(|j| {
                let _span = crate::obs::span("pool_task");
                f(&mut state, j)
            })
            .collect();
        let tally = PoolTally {
            claimed: n as u64,
            steals: 0,
            busy_us: vec![start.elapsed().as_micros() as u64],
        };
        return (out, tally);
    }

    // Every job index lives in exactly one `[lo, hi)` range at any
    // moment (or is claimed and in flight), so a worker that scans all
    // ranges empty can exit: whatever remains is being run by someone.
    let ranges: Vec<Mutex<(usize, usize)>> = (0..workers)
        .map(|w| Mutex::new((w * n / workers, (w + 1) * n / workers)))
        .collect();
    let ranges_ref = &ranges;

    // Each worker returns its own (index, result) chunk; joining inside the
    // scope propagates panics.
    let per_worker: Vec<(Vec<(usize, R)>, u64, u64, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    let start = Instant::now();
                    let mut state = init();
                    let mut local = Vec::with_capacity(n / workers + 1);
                    let mut claimed = 0u64;
                    let mut steals = 0u64;
                    loop {
                        let i = {
                            let mut own = ranges_ref[w].lock().expect("pool range lock");
                            if own.0 < own.1 {
                                let i = own.0;
                                own.0 += 1;
                                Some(i)
                            } else {
                                None
                            }
                        };
                        let i = match i {
                            Some(i) => i,
                            None => match steal(ranges_ref, w) {
                                Some(range) => {
                                    steals += 1;
                                    *ranges_ref[w].lock().expect("pool range lock") = range;
                                    continue;
                                }
                                None => break,
                            },
                        };
                        claimed += 1;
                        let r = {
                            let _span = crate::obs::span("pool_task");
                            f(&mut state, &jobs[i])
                        };
                        local.push((i, r));
                    }
                    (local, claimed, steals, start.elapsed().as_micros() as u64)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    let mut tally = PoolTally { claimed: 0, steals: 0, busy_us: Vec::with_capacity(workers) };
    let mut indexed: Vec<(usize, R)> = Vec::with_capacity(n);
    for (chunk, claimed, steals, busy_us) in per_worker {
        tally.claimed += claimed;
        tally.steals += steals;
        tally.busy_us.push(busy_us);
        indexed.extend(chunk);
    }
    debug_assert_eq!(indexed.len(), n, "every job produced exactly one result");
    indexed.sort_unstable_by_key(|&(i, _)| i);
    (indexed.into_iter().map(|(_, r)| r).collect(), tally)
}

/// Take the upper half of the largest remaining range owned by any
/// worker other than `thief`. Locks are taken one at a time — never two
/// together — so thieves cannot deadlock; a victim observed with work
/// may have drained by the time it is re-locked, in which case the
/// scan repeats. `None` means every other range was empty, i.e. all
/// unclaimed work is already in flight.
fn steal(ranges: &[Mutex<(usize, usize)>], thief: usize) -> Option<(usize, usize)> {
    loop {
        let mut best: Option<(usize, usize)> = None; // (victim, remaining)
        for (v, m) in ranges.iter().enumerate() {
            if v == thief {
                continue;
            }
            let (lo, hi) = *m.lock().expect("pool range lock");
            let rem = hi - lo;
            if rem > 0 && best.map_or(true, |(_, r)| rem > r) {
                best = Some((v, rem));
            }
        }
        let (victim, _) = best?;
        let mut vr = ranges[victim].lock().expect("pool range lock");
        let rem = vr.1 - vr.0;
        if rem == 0 {
            continue; // raced to empty between the scan and the re-lock
        }
        let take = (rem + 1) / 2;
        let stolen = (vr.1 - take, vr.1);
        vr.1 = stolen.0;
        return Some(stolen);
    }
}

/// Static per-worker chunking — the pre-stealing distribution, kept as
/// the baseline the imbalance bench and the differential wall compare
/// against. Same output contract as [`parallel_map_with`] (input order,
/// one state per worker, panic propagation); worker `w` owns the
/// contiguous chunk `[w*n/workers, (w+1)*n/workers)` come what may, so
/// a skewed job mix leaves the pool idling behind its heaviest chunk.
pub fn parallel_map_with_static<S, J, R, I, F>(
    jobs: Vec<J>,
    workers: usize,
    init: I,
    f: F,
) -> Vec<R>
where
    J: Send + Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        let mut state = init();
        return jobs.iter().map(|j| f(&mut state, j)).collect();
    }
    let jobs_ref = &jobs;
    let init_ref = &init;
    let f_ref = &f;
    let mut chunks: Vec<Vec<R>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                scope.spawn(move || {
                    let mut state = init_ref();
                    jobs_ref[w * n / workers..(w + 1) * n / workers]
                        .iter()
                        .map(|j| f_ref(&mut state, j))
                        .collect::<Vec<R>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    let mut out = Vec::with_capacity(n);
    for chunk in &mut chunks {
        out.append(chunk);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn preserves_order() {
        let jobs: Vec<u64> = (0..100).collect();
        let out = parallel_map(jobs, 8, |&j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = parallel_map(vec![5], 16, |&j| j);
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn per_worker_state_is_reused_within_a_worker() {
        // Each worker counts the jobs it processed in its state; the sum
        // of all per-job observations of "jobs seen so far by my worker"
        // can only be produced by genuine state reuse.
        let jobs: Vec<u32> = (0..64).collect();
        let out = parallel_map_with(
            jobs,
            4,
            || 0u32,
            |seen, _j| {
                *seen += 1;
                *seen
            },
        );
        assert_eq!(out.len(), 64);
        // At most one fresh state (count == 1) per worker...
        assert!(out.iter().filter(|&&c| c == 1).count() <= 4);
        // ...and by pigeonhole some worker's state counted ≥ 64/4 jobs —
        // impossible without the state surviving across jobs.
        assert!(*out.iter().max().unwrap() >= 16);
    }

    #[test]
    fn state_order_independent_results_match_serial() {
        let jobs: Vec<u32> = (0..37).collect();
        let serial: Vec<u64> = jobs.iter().map(|&j| (j as u64) * 3 + 1).collect();
        let parallel = parallel_map_with(jobs, 5, || (), |_state, &j| (j as u64) * 3 + 1);
        assert_eq!(serial, parallel);
    }

    #[test]
    fn every_job_is_claimed_exactly_once() {
        let jobs: Vec<u64> = (0..257).collect();
        let (out, tally) = run_dynamic(&jobs, 7, &|| (), &|_s, &j| j);
        assert_eq!(out, jobs);
        assert_eq!(tally.claimed, 257, "claims must cover the job list exactly");
        assert_eq!(tally.busy_us.len(), 7, "one busy-time observation per worker");
    }

    /// A steal is forced deterministically: worker 0 owns [0, 2) and its
    /// first job blocks until job 1 has *run* — so worker 0 can never
    /// claim job 1 itself, and the only way the pool finishes is worker 1
    /// draining its own chunk and stealing job 1 out of worker 0's range.
    #[test]
    fn a_blocked_chunk_gets_stolen_from() {
        let job1_done = AtomicBool::new(false);
        let jobs: Vec<usize> = vec![0, 1, 2, 3];
        let (out, tally) = run_dynamic(&jobs, 2, &|| (), &|_s, &j| {
            match j {
                0 => {
                    while !job1_done.load(Ordering::SeqCst) {
                        std::thread::yield_now();
                    }
                }
                1 => job1_done.store(true, Ordering::SeqCst),
                _ => {}
            }
            j * 10
        });
        assert_eq!(out, vec![0, 10, 20, 30]);
        assert!(tally.steals >= 1, "job 1 can only have run via a steal");
        assert_eq!(tally.claimed, 4);
    }

    /// Satellite: dynamic claiming and the static baseline produce
    /// bit-identical output on randomized uneven job mixes, including
    /// the 1-worker and workers>jobs edges.
    #[test]
    fn dynamic_and_static_agree_on_random_uneven_mixes() {
        let mut rng = crate::util::Rng::new(0xD1FF);
        for _trial in 0..6 {
            let n = rng.range(1, 48) as usize;
            // Uneven cost profile: some jobs spin ~64x longer than others.
            let jobs: Vec<u64> = (0..n as u64).map(|j| j | (rng.below(4) << 32)).collect();
            let work = |&j: &u64| {
                let spins = if j >> 32 == 0 { 2_000 } else { 30 };
                let mut acc = j;
                for _ in 0..spins {
                    acc = acc.wrapping_mul(0x100000001b3).rotate_left(7);
                }
                (j & 0xffff_ffff, acc)
            };
            let serial: Vec<(u64, u64)> = jobs.iter().map(work).collect();
            for workers in [1usize, 3, n + 5] {
                let dynamic =
                    parallel_map_with(jobs.clone(), workers, || (), |_s, j| work(j));
                let fixed =
                    parallel_map_with_static(jobs.clone(), workers, || (), |_s, j| work(j));
                assert_eq!(dynamic, serial, "dynamic path diverged at {workers} worker(s)");
                assert_eq!(fixed, serial, "static path diverged at {workers} worker(s)");
            }
        }
    }

    #[test]
    fn static_baseline_keeps_the_edge_contracts() {
        assert!(parallel_map_with_static(Vec::<u32>::new(), 4, || (), |_s, &j| j).is_empty());
        assert_eq!(parallel_map_with_static(vec![7u32], 16, || (), |_s, &j| j), vec![7]);
        // Per-worker state survives across a worker's chunk.
        let out = parallel_map_with_static((0..32).collect::<Vec<u32>>(), 4, || 0u32, |seen, _| {
            *seen += 1;
            *seen
        });
        assert!(*out.iter().max().unwrap() >= 8);
    }
}
