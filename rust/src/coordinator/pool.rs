//! A small scoped thread pool (no rayon offline): order-preserving
//! parallel map over independent jobs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use: `MULTISTRIDE_THREADS` env var, else the
/// available parallelism, else 4.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("MULTISTRIDE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every job on a pool of `workers` threads, preserving input
/// order in the output. Panics in workers propagate.
pub fn parallel_map<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send + Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return jobs.iter().map(|j| f(j)).collect();
    }

    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let jobs_ref = &jobs;
    let f_ref = &f;
    let next_ref = &next;
    let results_ref = &results;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(move || loop {
                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f_ref(&jobs_ref[i]);
                *results_ref[i].lock().expect("result slot poisoned") = Some(r);
            });
        }
    });

    results
        .into_iter()
        .map(|m| m.into_inner().expect("poisoned").expect("worker completed all jobs"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<u64> = (0..100).collect();
        let out = parallel_map(jobs, 8, |&j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = parallel_map(vec![5], 16, |&j| j);
        assert_eq!(out, vec![5]);
    }
}
