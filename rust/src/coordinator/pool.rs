//! A small scoped thread pool (no rayon offline): order-preserving
//! parallel map over independent jobs, with optional per-worker state so
//! sweeps can reuse expensive resources (a warm [`crate::sim::Engine`])
//! across the jobs one worker processes.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of workers to use: `MULTISTRIDE_THREADS` env var, else the
/// available parallelism, else 4.
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("MULTISTRIDE_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every job on a pool of `workers` threads, preserving input
/// order in the output. Panics in workers propagate.
pub fn parallel_map<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send + Sync,
    R: Send,
    F: Fn(&J) -> R + Sync,
{
    parallel_map_with(jobs, workers, || (), |_state, j| f(j))
}

/// [`parallel_map`] with per-worker state: every worker thread builds one
/// `S` via `init` and threads it through all jobs it claims (dynamic
/// work-stealing via an atomic cursor, so load stays balanced).
///
/// Results are collected into per-worker chunk buffers and stitched back
/// into input order at the end — no per-job locking on the hot path.
pub fn parallel_map_with<S, J, R, I, F>(jobs: Vec<J>, workers: usize, init: I, f: F) -> Vec<R>
where
    J: Send + Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        let mut state = init();
        return jobs
            .iter()
            .map(|j| {
                let _span = crate::obs::span("pool_task");
                f(&mut state, j)
            })
            .collect();
    }

    let next = AtomicUsize::new(0);
    let jobs_ref = &jobs;
    let f_ref = &f;
    let init_ref = &init;
    let next_ref = &next;

    // Each worker returns its own (index, result) chunk; joining inside the
    // scope propagates panics.
    let chunks: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || {
                    let mut state = init_ref();
                    let mut local = Vec::with_capacity(n / workers + 1);
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        let r = {
                            let _span = crate::obs::span("pool_task");
                            f_ref(&mut state, &jobs_ref[i])
                        };
                        local.push((i, r));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });

    // Stitch the chunks back into input order.
    let mut indexed: Vec<(usize, R)> = chunks.into_iter().flatten().collect();
    debug_assert_eq!(indexed.len(), n, "every job produced exactly one result");
    indexed.sort_unstable_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<u64> = (0..100).collect();
        let out = parallel_map(jobs, 8, |&j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let out = parallel_map(vec![1, 2, 3], 1, |&j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |&j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = parallel_map(vec![5], 16, |&j| j);
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn per_worker_state_is_reused_within_a_worker() {
        // Each worker counts the jobs it processed in its state; the sum
        // of all per-job observations of "jobs seen so far by my worker"
        // can only be produced by genuine state reuse.
        let jobs: Vec<u32> = (0..64).collect();
        let out = parallel_map_with(
            jobs,
            4,
            || 0u32,
            |seen, _j| {
                *seen += 1;
                *seen
            },
        );
        assert_eq!(out.len(), 64);
        // At most one fresh state (count == 1) per worker...
        assert!(out.iter().filter(|&&c| c == 1).count() <= 4);
        // ...and by pigeonhole some worker's state counted ≥ 64/4 jobs —
        // impossible without the state surviving across jobs.
        assert!(*out.iter().max().unwrap() >= 16);
    }

    #[test]
    fn state_order_independent_results_match_serial() {
        let jobs: Vec<u32> = (0..37).collect();
        let serial: Vec<u64> = jobs.iter().map(|&j| (j as u64) * 3 + 1).collect();
        let parallel = parallel_map_with(jobs, 5, || (), |_state, &j| (j as u64) * 3 + 1);
        assert_eq!(serial, parallel);
    }
}
