//! One driver per paper figure/table. Each driver is a thin
//! **plan-builder + result-formatter** around the execution layer
//! ([`crate::exec`]): it expands its request into a batch of
//! content-addressed [`SimPoint`] jobs, hands the batch to
//! [`Planner::run`] (which dedups against the whole batch and the
//! [`ResultStore`] before scheduling misses over the warm-engine worker
//! pool), and formats the returned [`RunResult`]s into the figure's
//! shape for the [`crate::report`] layer.
//!
//! Every driver exists in two forms: `foo_on(store, …)` executes against
//! a caller-owned store (the CLI threads one store through a whole
//! `repro all` invocation, so overlapping sweeps and the tuner share
//! results), and the historical `foo(…)` signature is a compatibility
//! wrapper over a fresh [`ResultStore::ephemeral`] — same execution
//! path, same results, in-batch dedup only.

use crate::config::{MachineConfig, ScaleConfig};
use crate::exec::{Planner, ResultStore, SimPoint};
use crate::kernels::library::{all_kernels, kernel_by_name};
use crate::kernels::micro::MicroOp;
use crate::kernels::reference::Reference;
use crate::sim::{Engine, EngineConfig, RunResult};
use crate::transform::{
    enumerate_configs, is_feasible, transform, variant_configs, StridingConfig,
};

use super::pool::{default_workers, parallel_map_with};

/// The stride counts the micro-benchmarks sweep (divisors of 32).
pub const MICRO_STRIDES: [u32; 6] = [1, 2, 4, 8, 16, 32];

/// Per-worker engine reuse for config sweeps: one warm [`Engine`] whose
/// cache/TLB/DRAM allocations persist across sweep points. Each point is
/// applied with [`Engine::prepare`], which resets to cold state
/// bit-identically with a fresh construction, so results are unchanged —
/// only the per-point construction cost (hierarchy allocation and zeroing)
/// is gone.
#[derive(Default)]
pub struct EngineCache {
    engine: Option<Engine>,
}

impl EngineCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cold engine for `cfg`, reusing the cached allocation when the
    /// machine matches.
    pub fn engine_for(&mut self, cfg: EngineConfig) -> &mut Engine {
        match &mut self.engine {
            Some(e) => e.prepare(cfg),
            None => self.engine = Some(Engine::new(cfg)),
        }
        self.engine.as_mut().expect("engine present")
    }
}

/// One measured micro-benchmark point.
#[derive(Debug, Clone)]
pub struct MicroPoint {
    pub op: MicroOp,
    pub strides: u32,
    pub interleaved: bool,
    pub prefetch: bool,
    pub throughput_gib: f64,
    pub result: RunResult,
}

/// Format one stored/simulated result as a [`MicroPoint`].
fn micro_point(
    op: MicroOp,
    strides: u32,
    interleaved: bool,
    prefetch: bool,
    result: &RunResult,
) -> MicroPoint {
    MicroPoint {
        op,
        strides,
        interleaved,
        prefetch,
        throughput_gib: result.throughput_gib(),
        result: result.clone(),
    }
}

/// Run one micro-benchmark configuration (§4 protocol: huge pages on).
pub fn run_micro(
    machine: MachineConfig,
    op: MicroOp,
    strides: u32,
    bytes: u64,
    prefetch: bool,
    interleaved: bool,
) -> MicroPoint {
    run_micro_with(&mut EngineCache::new(), machine, op, strides, bytes, prefetch, interleaved)
}

/// [`run_micro`] against a reusable per-worker engine.
pub fn run_micro_with(
    cache: &mut EngineCache,
    machine: MachineConfig,
    op: MicroOp,
    strides: u32,
    bytes: u64,
    prefetch: bool,
    interleaved: bool,
) -> MicroPoint {
    let store = ResultStore::ephemeral();
    run_micro_on(&store, cache, machine, op, strides, bytes, prefetch, interleaved)
}

/// [`run_micro`] through a result store: served when present, simulated
/// (and stored) when not.
#[allow(clippy::too_many_arguments)]
pub fn run_micro_on(
    store: &ResultStore,
    cache: &mut EngineCache,
    machine: MachineConfig,
    op: MicroOp,
    strides: u32,
    bytes: u64,
    prefetch: bool,
    interleaved: bool,
) -> MicroPoint {
    let point = SimPoint::micro(machine, op, strides, bytes, prefetch, interleaved);
    let result = store.get_or_run(cache, &point).expect("micro points always simulate");
    micro_point(op, strides, interleaved, prefetch, &result)
}

/// The micro job tuple the Figure 2/3/4/5 plans expand into.
type MicroJob = (MicroOp, u32, bool, bool);

/// Execute a batch of micro jobs at one array size through the store.
fn micro_batch_on(
    store: &ResultStore,
    machine: MachineConfig,
    bytes: u64,
    jobs: &[MicroJob],
) -> Vec<MicroPoint> {
    let points: Vec<SimPoint> = jobs
        .iter()
        .map(|&(op, s, pf, inter)| SimPoint::micro(machine, op, s, bytes, pf, inter))
        .collect();
    let results = Planner::new(store).run(&points).expect("micro points always simulate");
    jobs.iter()
        .zip(&results)
        .map(|(&(op, s, pf, inter), r)| micro_point(op, s, inter, pf, r))
        .collect()
}

/// Figure 2 / Figure 5: the micro-benchmark throughput grid for one array
/// size. `pow2 = true` reproduces Figure 5's 2-GiB-analog collision setup.
pub fn figure2(machine: MachineConfig, scale: ScaleConfig, pow2: bool) -> Vec<MicroPoint> {
    figure2_on(&ResultStore::ephemeral(), machine, scale, pow2)
}

/// [`figure2`] against a caller-owned result store.
pub fn figure2_on(
    store: &ResultStore,
    machine: MachineConfig,
    scale: ScaleConfig,
    pow2: bool,
) -> Vec<MicroPoint> {
    let bytes = if pow2 { scale.micro_pow2_bytes } else { scale.micro_bytes };
    let mut jobs: Vec<MicroJob> = Vec::new();
    for prefetch in [true, false] {
        for op in MicroOp::all() {
            for &s in &MICRO_STRIDES {
                jobs.push((op, s, prefetch, false));
                // The §4.4 interleaved-NT-store variant.
                if op == MicroOp::StoreNt {
                    jobs.push((op, s, prefetch, true));
                }
            }
        }
    }
    micro_batch_on(store, machine, bytes, &jobs)
}

/// Figure 3 + Figure 4 series: stall cycles and hit ratios for the aligned
/// read micro-benchmark across stride counts, prefetch on/off.
pub fn figure3_4(machine: MachineConfig, scale: ScaleConfig) -> Vec<MicroPoint> {
    figure3_4_on(&ResultStore::ephemeral(), machine, scale)
}

/// [`figure3_4`] against a caller-owned result store. Note the jobs here
/// are a strict subset of [`figure2`]'s grid at the same scale: with a
/// shared store the whole figure is served from figure2's results.
pub fn figure3_4_on(
    store: &ResultStore,
    machine: MachineConfig,
    scale: ScaleConfig,
) -> Vec<MicroPoint> {
    let mut jobs: Vec<MicroJob> = Vec::new();
    for prefetch in [true, false] {
        for &s in &MICRO_STRIDES {
            jobs.push((MicroOp::LoadAligned, s, prefetch, false));
        }
    }
    micro_batch_on(store, machine, scale.micro_bytes, &jobs)
}

/// One point of the Figure 6 kernel sweep.
#[derive(Debug, Clone)]
pub struct KernelPoint {
    pub kernel: String,
    pub config: StridingConfig,
    pub prefetch: bool,
    pub feasible: bool,
    pub throughput_gib: f64,
}

/// Run one kernel configuration through the simulator (§6 protocol:
/// default 4 KiB pages, aligned+interleaved loop bodies kept as generated).
pub fn run_kernel(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    run_kernel_with(&mut EngineCache::new(), machine, kernel, budget, config, prefetch)
}

/// [`run_kernel`] against a reusable per-worker engine.
pub fn run_kernel_with(
    cache: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    run_kernel_on(&ResultStore::ephemeral(), cache, machine, kernel, budget, config, prefetch)
}

/// [`run_kernel`] through a result store. The plan-builder half:
/// validate the kernel exists (`None` otherwise), transform it (`None`
/// when the spec cannot host the config), gate register feasibility
/// (reported without simulating, as the sweeps always have) — and only
/// then consult/run the point. The formatter half scores throughput as
/// *data size / time* with data size = the **allocation** (transformed
/// spec footprint), the same §6.3 convention for every kernel: conv and
/// jacobi2d count their full arrays while sweeping trimmed interiors,
/// and stridedcopy counts its row-pitch pad.
pub fn run_kernel_on(
    store: &ResultStore,
    cache: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    let pk = kernel_by_name(kernel, budget)?;
    let t = transform(&pk.spec, config).ok()?;
    let feasible = is_feasible(&t, machine.simd_registers);
    if !feasible {
        return Some(KernelPoint {
            kernel: kernel.to_string(),
            config,
            prefetch,
            feasible,
            throughput_gib: 0.0,
        });
    }
    let footprint = t.spec.footprint();
    let point = SimPoint::kernel_from_spec(machine, kernel, budget, config, prefetch, &pk.spec);
    let result = store.get_or_run(cache, &point).expect("validated kernel point simulates");
    Some(KernelPoint {
        kernel: kernel.to_string(),
        config,
        prefetch,
        feasible,
        throughput_gib: machine.gib_per_s(footprint, result.counters.cycles),
    })
}

/// The no-silent-coverage policy: a config the kernel's extents cannot
/// host is absent from the sweep, but never silently (every sweep path
/// prints this line, so the policy cannot drift between them).
fn report_skip(ctx: &str, kernel: &str, budget: u64, cfg: StridingConfig) {
    eprintln!(
        "[{ctx}] SKIPPED {kernel} s={} p={} at budget {budget}",
        cfg.stride_unroll, cfg.portion_unroll
    );
}

/// Shared batch plan-builder + formatter behind every kernel sweep
/// ([`figure6_on`], [`variant_sweep_on`] / [`variant_sweep_for_on`],
/// which also back `repro universe`): classify each `(kernel, config)`
/// job as simulate / infeasible / skip, execute the simulate set as one
/// deduplicated batch, and format per-job results in input order.
/// Unknown kernel names fail loudly (a typo'd `--kernel` must not
/// produce an empty sweep).
pub fn kernel_points_on(
    store: &ResultStore,
    machine: MachineConfig,
    ctx: &str,
    budget: u64,
    prefetch: bool,
    jobs: &[(String, StridingConfig)],
) -> Vec<Option<KernelPoint>> {
    enum Slot {
        Sim { idx: usize, footprint: u64 },
        Ready(KernelPoint),
        Skip,
    }
    let mut points: Vec<SimPoint> = Vec::new();
    let mut slots: Vec<Slot> = Vec::with_capacity(jobs.len());
    for (name, cfg) in jobs {
        let pk = kernel_by_name(name, budget)
            .unwrap_or_else(|| panic!("unknown kernel {name}"));
        match transform(&pk.spec, *cfg) {
            Err(_) => {
                report_skip(ctx, name, budget, *cfg);
                slots.push(Slot::Skip);
            }
            Ok(t) if !is_feasible(&t, machine.simd_registers) => {
                slots.push(Slot::Ready(KernelPoint {
                    kernel: name.clone(),
                    config: *cfg,
                    prefetch,
                    feasible: false,
                    throughput_gib: 0.0,
                }));
            }
            Ok(t) => {
                let footprint = t.spec.footprint();
                let point =
                    SimPoint::kernel_from_spec(machine, name, budget, *cfg, prefetch, &pk.spec);
                slots.push(Slot::Sim { idx: points.len(), footprint });
                points.push(point);
            }
        }
    }
    let results =
        Planner::new(store).run(&points).expect("validated kernel points simulate");
    slots
        .into_iter()
        .zip(jobs)
        .map(|(slot, (name, cfg))| match slot {
            Slot::Skip => None,
            Slot::Ready(p) => Some(p),
            Slot::Sim { idx, footprint } => Some(KernelPoint {
                kernel: name.clone(),
                config: *cfg,
                prefetch,
                feasible: true,
                throughput_gib: machine.gib_per_s(footprint, results[idx].counters.cycles),
            }),
        })
        .collect()
}

/// The Figure 6 unroll totals swept (the paper sweeps 1..=50; the default
/// driver covers the same range more sparsely past 12 where divisor pairs
/// explode — override with `max_total` for the full grid).
pub fn figure6_totals(max_total: u32) -> Vec<u32> {
    (1..=max_total.min(12))
        .chain([16, 18, 20, 24, 30, 32, 36, 40, 48, 50])
        .filter(|&t| t <= max_total)
        .collect()
}

/// Figure 6: sweep the striding optimization space of one isolated kernel.
pub fn figure6(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
    prefetch: bool,
) -> Vec<KernelPoint> {
    figure6_on(&ResultStore::ephemeral(), machine, kernel, budget, max_total, prefetch)
}

/// The Figure 6 config set at `max_total` — extracted from
/// [`figure6_on`] so the sharded grid plan ([`repro_all_points`])
/// enumerates exactly the sweep's configurations.
pub fn figure6_configs(max_total: u32) -> Vec<StridingConfig> {
    let mut cfgs: Vec<StridingConfig> = Vec::new();
    for t in figure6_totals(max_total) {
        for c in enumerate_configs(t) {
            if c.total_unrolls() == t {
                cfgs.push(c);
            }
        }
    }
    cfgs.dedup_by_key(|c| (c.stride_unroll, c.portion_unroll));
    cfgs
}

/// [`figure6`] against a caller-owned result store.
pub fn figure6_on(
    store: &ResultStore,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
    prefetch: bool,
) -> Vec<KernelPoint> {
    let jobs: Vec<(String, StridingConfig)> = figure6_configs(max_total)
        .into_iter()
        .map(|c| (kernel.to_string(), c))
        .collect();
    kernel_points_on(store, machine, "figure6", budget, prefetch, &jobs)
        .into_iter()
        .flatten()
        .collect()
}

/// Run one sweep point, printing a visible SKIPPED line when the kernel
/// cannot host the config — the single-point face of the shared
/// no-silent-coverage policy ([`kernel_points_on`] is the batch face).
pub fn run_point_reported(
    cache: &mut EngineCache,
    machine: MachineConfig,
    ctx: &str,
    kernel: &str,
    budget: u64,
    cfg: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    let store = ResultStore::ephemeral();
    run_point_reported_on(&store, cache, machine, ctx, kernel, budget, cfg, prefetch)
}

/// [`run_point_reported`] through a result store.
#[allow(clippy::too_many_arguments)]
pub fn run_point_reported_on(
    store: &ResultStore,
    cache: &mut EngineCache,
    machine: MachineConfig,
    ctx: &str,
    kernel: &str,
    budget: u64,
    cfg: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    let p = run_kernel_on(store, cache, machine, kernel, budget, cfg, prefetch);
    if p.is_none() {
        report_skip(ctx, kernel, budget, cfg);
    }
    p
}

/// Registry-wide variant trajectory: every kernel in the universe runs its
/// derived family — single-stride baseline plus S ∈
/// [`crate::transform::STRIDE_FAMILY`] — at `portion` portion unrolls.
/// This is the sweep behind the per-kernel rows of the perf trajectory
/// JSON and the universe report table.
pub fn variant_sweep(
    machine: MachineConfig,
    budget: u64,
    portion: u32,
    prefetch: bool,
) -> Vec<KernelPoint> {
    variant_sweep_on(&ResultStore::ephemeral(), machine, budget, portion, prefetch)
}

/// [`variant_sweep`] against a caller-owned result store.
pub fn variant_sweep_on(
    store: &ResultStore,
    machine: MachineConfig,
    budget: u64,
    portion: u32,
    prefetch: bool,
) -> Vec<KernelPoint> {
    let names: Vec<String> = all_kernels(budget).iter().map(|k| k.name.clone()).collect();
    variant_sweep_for_on(store, machine, budget, portion, prefetch, &names)
}

/// [`variant_sweep`] restricted to an explicit kernel-name list (tests
/// exercise the sweep mechanics on a cheap subset; the full-universe
/// "every kernel derives its family" invariant is pinned transform-side
/// in `transform::variants`).
pub fn variant_sweep_for(
    machine: MachineConfig,
    budget: u64,
    portion: u32,
    prefetch: bool,
    kernels: &[String],
) -> Vec<KernelPoint> {
    variant_sweep_for_on(&ResultStore::ephemeral(), machine, budget, portion, prefetch, kernels)
}

/// [`variant_sweep_for`] against a caller-owned result store.
pub fn variant_sweep_for_on(
    store: &ResultStore,
    machine: MachineConfig,
    budget: u64,
    portion: u32,
    prefetch: bool,
    kernels: &[String],
) -> Vec<KernelPoint> {
    let mut jobs: Vec<(String, StridingConfig)> = Vec::new();
    for name in kernels {
        for cfg in variant_configs(portion) {
            jobs.push((name.clone(), cfg));
        }
    }
    kernel_points_on(store, machine, "variant_sweep", budget, prefetch, &jobs)
        .into_iter()
        .flatten()
        .collect()
}

/// Pick the best feasible configuration out of a sweep.
pub fn best_point(points: &[KernelPoint]) -> Option<&KernelPoint> {
    points
        .iter()
        .filter(|p| p.feasible)
        .max_by(|a, b| a.throughput_gib.partial_cmp(&b.throughput_gib).expect("no NaN"))
}

/// Best multi-strided vs best single-strided vs no-unroll summary
/// (the green/red lines of Figure 6).
#[derive(Debug, Clone)]
pub struct KernelSummary {
    pub kernel: String,
    pub best_multi: KernelPoint,
    pub best_single: KernelPoint,
    pub no_unroll: KernelPoint,
}

impl KernelSummary {
    /// The §6.3 headline: multi-strided speedup over the best
    /// single-strided configuration.
    pub fn multi_over_single(&self) -> f64 {
        self.best_multi.throughput_gib / self.best_single.throughput_gib
    }
}

/// Summarize a kernel's sweep into the Figure 6 reference lines.
pub fn summarize_kernel(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
) -> KernelSummary {
    summarize_kernel_on(&ResultStore::ephemeral(), machine, kernel, budget, max_total)
}

/// [`summarize_kernel`] against a caller-owned result store (after a
/// warm [`figure6_on`] at the same scale this formats without a single
/// engine run).
pub fn summarize_kernel_on(
    store: &ResultStore,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
) -> KernelSummary {
    let points = figure6_on(store, machine, kernel, budget, max_total, true);
    let best_multi = best_point(&points).expect("at least one feasible config").clone();
    let best_single = points
        .iter()
        .filter(|p| p.feasible && p.config.stride_unroll == 1)
        .max_by(|a, b| a.throughput_gib.partial_cmp(&b.throughput_gib).expect("no NaN"))
        .expect("single-strided configs always feasible")
        .clone();
    let no_unroll = points
        .iter()
        .find(|p| p.config.stride_unroll == 1 && p.config.portion_unroll == 1)
        .expect("no-unroll config present")
        .clone();
    KernelSummary { kernel: kernel.to_string(), best_multi, best_single, no_unroll }
}

/// One Figure 7 comparison row: the best multi-strided kernel against one
/// reference implementation model.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub kernel: String,
    pub reference: Reference,
    pub reference_gib: f64,
    pub multistrided_gib: f64,
}

impl ComparisonRow {
    pub fn speedup(&self) -> f64 {
        self.multistrided_gib / self.reference_gib
    }
}

/// Run a reference implementation model on a kernel.
pub fn run_reference(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    reference: Reference,
) -> Option<f64> {
    run_reference_on(
        &ResultStore::ephemeral(),
        &mut EngineCache::new(),
        machine,
        kernel,
        budget,
        reference,
    )
}

/// [`run_reference`] through a result store. A reference's schedule is
/// an ordinary [`StridingConfig`], so its point dedups against sweep
/// points that happen to share it. References run with the machine's own
/// prefetch setting (the pre-store protocol: `EngineConfig::new` leaves
/// `machine.prefetch` untouched), passed explicitly so the point key
/// says what actually ran.
pub fn run_reference_on(
    store: &ResultStore,
    cache: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    reference: Reference,
) -> Option<f64> {
    let pk = kernel_by_name(kernel, budget)?;
    let cfg = reference.schedule();
    let t = transform(&pk.spec, cfg).ok()?;
    let footprint = t.spec.footprint();
    let point = SimPoint::kernel_from_spec(
        machine,
        kernel,
        budget,
        cfg,
        machine.prefetch.enabled,
        &pk.spec,
    );
    let result = store.get_or_run(cache, &point).expect("validated reference point simulates");
    let mut gib = machine.gib_per_s(footprint, result.counters.cycles);
    // References that fail to vectorize (the paper verified Polly/CLang
    // emitted no AVX2 for these kernels) stream 4-byte elements through a
    // serial accumulate chain: ~one element per cycle is the practical
    // ceiling, so their data throughput is core-bound, not DRAM-bound.
    if reference.scalar_on(kernel) {
        // One 4-byte element every ~2 cycles: the serial FMA accumulate
        // chain (4-5 cycle latency, partially hidden by the OoO core).
        let scalar_bound = machine.gib_per_s(2, 1);
        gib = gib.min(scalar_bound);
    }
    Some(gib)
}

/// Figure 7: compare the tuned multi-strided kernel against every
/// applicable reference on one machine.
pub fn figure7(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
) -> Vec<ComparisonRow> {
    figure7_on(&ResultStore::ephemeral(), machine, kernel, budget, max_total)
}

/// [`figure7`] against a caller-owned result store (the sweep half is
/// shared with [`figure6_on`] / [`summarize_kernel_on`] verbatim).
pub fn figure7_on(
    store: &ResultStore,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
) -> Vec<ComparisonRow> {
    let summary = summarize_kernel_on(store, machine, kernel, budget, max_total);
    let refs = Reference::for_kernel(kernel);
    let mut engines = EngineCache::new();
    let mut rows = Vec::new();
    for r in refs {
        let reference_gib = match r {
            Reference::BestSingleStrided => summary.best_single.throughput_gib,
            Reference::NoUnroll => summary.no_unroll.throughput_gib,
            _ => match run_reference_on(store, &mut engines, machine, kernel, budget, r) {
                Some(g) => g,
                None => continue,
            },
        };
        rows.push(ComparisonRow {
            kernel: kernel.to_string(),
            reference: r,
            reference_gib,
            multistrided_gib: summary.best_multi.throughput_gib,
        });
    }
    rows
}

/// All kernels the Figure 6 experiments sweep, derived from the registry
/// (the add-a-kernel recipe reaches the sweeps without touching this
/// file): the paper's Figure 6 panel set **plus the extended universe**.
/// Only gemver's mxv-shaped sub-kernels are excluded, as duplicate shapes
/// of `mxv` (the paper's own panel choice). The name source is
/// [`crate::runtime::universe_names`] — the same projection
/// `runtime::kernel_universe` and [`tune_universe`] use — so the three
/// kernel lists cannot drift.
pub fn figure6_kernels() -> Vec<String> {
    const EXCLUDE: [&str; 2] = ["gemvermxv1", "gemvermxv2"];
    // Specs are metadata-only (no data arrays), so enumerating the
    // registry at the smallest scale just to harvest names is cheap.
    const NAME_BUDGET: u64 = 1 << 20;
    crate::runtime::universe_names(NAME_BUDGET)
        .into_iter()
        .filter(|n| !EXCLUDE.contains(&n.as_str()))
        .collect()
}

/// All kernels compared in Figure 7: the Figure 6 set restricted to
/// kernels with vendor reference models beyond the four compiler
/// baselines (see [`Reference::for_kernel`]). `gemversum` is excluded
/// explicitly: it has BLAS reference models but the paper's Figure 7 does
/// not show a panel for it, and the pre-registry hand list matched the
/// paper.
pub fn figure7_kernels() -> Vec<String> {
    let has_vendor_model =
        |k: &str| Reference::for_kernel(k).iter().any(|r| r.is_vendor_model());
    figure6_kernels().into_iter().filter(|k| k != "gemversum" && has_vendor_model(k)).collect()
}

/// The simulate-or-skip classification every kernel sweep applies,
/// reduced to its point: `None` when the kernel cannot host the config
/// or the variant is infeasible (those rows never reach an engine).
fn kernel_sim_point(
    machine: MachineConfig,
    name: &str,
    budget: u64,
    cfg: StridingConfig,
    prefetch: bool,
) -> Option<SimPoint> {
    let pk = kernel_by_name(name, budget)?;
    let t = transform(&pk.spec, cfg).ok()?;
    if !is_feasible(&t, machine.simd_registers) {
        return None;
    }
    Some(SimPoint::kernel_from_spec(machine, name, budget, cfg, prefetch, &pk.spec))
}

/// The full `repro all` simulation plan as one flat, key-deduplicated
/// point batch — the partitionable face of the reproduction: the micro
/// grids (figure2/3/4 at the machine's array size, figure5's pow2 grid
/// across every preset), every Figure 6 sweep point (figure7's sweep
/// half is a subset), the registry-wide universe variant family, and
/// the Figure 7 reference schedules. `repro grid --shard k/n` hands
/// this plan to [`crate::exec::grid::run_shard`]; a store populated by
/// all shards then serves `repro all` without engine work. Tuner probe
/// points are excluded by design: probes run at tuner-chosen reduced
/// budgets, and the search's full-budget rung reads these points.
pub fn repro_all_points(
    machine: MachineConfig,
    scale: ScaleConfig,
    max_total: u32,
    prefetch: bool,
) -> Vec<SimPoint> {
    let mut points: Vec<SimPoint> = Vec::new();
    let mut micro_grid = |m: MachineConfig, bytes: u64| {
        for pf in [true, false] {
            for op in MicroOp::all() {
                for &s in &MICRO_STRIDES {
                    points.push(SimPoint::micro(m, op, s, bytes, pf, false));
                    if op == MicroOp::StoreNt {
                        points.push(SimPoint::micro(m, op, s, bytes, pf, true));
                    }
                }
            }
        }
    };
    micro_grid(machine, scale.micro_bytes);
    for preset in crate::config::MachinePreset::all() {
        micro_grid(preset.config(), scale.micro_pow2_bytes);
    }
    let budget = scale.kernel_bytes;
    let cfgs = figure6_configs(max_total);
    for name in figure6_kernels() {
        for &cfg in &cfgs {
            points.extend(kernel_sim_point(machine, &name, budget, cfg, prefetch));
        }
    }
    for name in crate::runtime::universe_names(budget) {
        for cfg in variant_configs(2) {
            points.extend(kernel_sim_point(machine, &name, budget, cfg, prefetch));
        }
    }
    // References run at the machine's own prefetch setting (see
    // [`run_reference_on`]); the sweep-derived baselines need no points.
    for name in figure7_kernels() {
        for r in Reference::for_kernel(&name) {
            if matches!(r, Reference::BestSingleStrided | Reference::NoUnroll) {
                continue;
            }
            let pf = machine.prefetch.enabled;
            points.extend(kernel_sim_point(machine, &name, budget, r.schedule(), pf));
        }
    }
    let mut seen = std::collections::HashSet::new();
    points.retain(|p| seen.insert(p.key()));
    points
}

/// Tune one kernel against the plan cache (cold-search on miss/stale,
/// persist the winner). One-shot convenience over [`crate::tune::Tuner`];
/// batch callers should prefer [`tune_universe`] / [`tune_kernels`],
/// which reuse warm engines across kernels.
pub fn tune_kernel(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    prefetch: bool,
    cache: &crate::tune::PlanCache,
    force: bool,
) -> crate::Result<crate::tune::TuneOutcome> {
    tune_kernel_on(&ResultStore::ephemeral(), machine, kernel, budget, prefetch, cache, force)
}

/// [`tune_kernel`] with the search's cost-model reads flowing through a
/// result store (a tune after a sweep at the same budget is nearly free).
pub fn tune_kernel_on(
    store: &ResultStore,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    prefetch: bool,
    cache: &crate::tune::PlanCache,
    force: bool,
) -> crate::Result<crate::tune::TuneOutcome> {
    let tuner = crate::tune::Tuner { machine, budget, prefetch, params: Default::default() };
    tuner.tune_on(store, &mut EngineCache::new(), cache, kernel, force)
}

/// Tune the whole registry universe in parallel: one job per kernel, one
/// warm engine per worker, each winner persisted to `cache`. Results come
/// back in registry order; per-kernel failures are reported per slot, not
/// by poisoning the batch.
pub fn tune_universe(
    machine: MachineConfig,
    budget: u64,
    prefetch: bool,
    cache: &crate::tune::PlanCache,
    force: bool,
) -> Vec<crate::Result<crate::tune::TuneOutcome>> {
    tune_universe_on(&ResultStore::ephemeral(), machine, budget, prefetch, cache, force)
}

/// [`tune_universe`] against a caller-owned result store.
pub fn tune_universe_on(
    store: &ResultStore,
    machine: MachineConfig,
    budget: u64,
    prefetch: bool,
    cache: &crate::tune::PlanCache,
    force: bool,
) -> Vec<crate::Result<crate::tune::TuneOutcome>> {
    let names = crate::runtime::universe_names(budget);
    tune_kernels_on(store, machine, budget, prefetch, cache, force, &names)
}

/// [`tune_universe`] restricted to an explicit kernel-name list.
pub fn tune_kernels(
    machine: MachineConfig,
    budget: u64,
    prefetch: bool,
    cache: &crate::tune::PlanCache,
    force: bool,
    kernels: &[String],
) -> Vec<crate::Result<crate::tune::TuneOutcome>> {
    tune_kernels_on(&ResultStore::ephemeral(), machine, budget, prefetch, cache, force, kernels)
}

/// [`tune_kernels`] against a caller-owned result store.
#[allow(clippy::too_many_arguments)]
pub fn tune_kernels_on(
    store: &ResultStore,
    machine: MachineConfig,
    budget: u64,
    prefetch: bool,
    cache: &crate::tune::PlanCache,
    force: bool,
    kernels: &[String],
) -> Vec<crate::Result<crate::tune::TuneOutcome>> {
    let tuner = crate::tune::Tuner { machine, budget, prefetch, params: Default::default() };
    let jobs: Vec<String> = kernels.to_vec();
    parallel_map_with(jobs, default_workers(), EngineCache::new, |engines, name| {
        tuner.tune_on(store, engines, cache, name, force)
    })
}

/// Sanity: the whole kernel universe (Table 1 subset included) transforms
/// under the paper's default configuration.
pub fn selfcheck(budget: u64) -> crate::Result<()> {
    for pk in all_kernels(budget) {
        transform(&pk.spec, StridingConfig::new(2, 2))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;

    const MIB: u64 = 1 << 20;

    #[test]
    fn micro_point_reports_throughput() {
        let p = run_micro(coffee_lake(), MicroOp::LoadAligned, 4, 4 * MIB, true, false);
        assert!(p.throughput_gib > 1.0, "got {}", p.throughput_gib);
    }

    #[test]
    fn kernel_point_runs() {
        let p = run_kernel(coffee_lake(), "mxv", 8 * MIB, StridingConfig::new(4, 1), true).unwrap();
        assert!(p.feasible);
        assert!(p.throughput_gib > 1.0);
    }

    #[test]
    fn infeasible_configs_flagged_not_run() {
        // 16×4 = 64 accumulators cannot fit 16 ymm registers.
        let p =
            run_kernel(coffee_lake(), "mxv", 8 * MIB, StridingConfig::new(16, 4), true).unwrap();
        assert!(!p.feasible);
        assert_eq!(p.throughput_gib, 0.0);
    }

    #[test]
    fn repro_all_plan_is_deduped_and_covers_the_sweeps() {
        let scale = ScaleConfig::smoke();
        let m = coffee_lake();
        let points = repro_all_points(m, scale, 6, true);
        let mut keys: Vec<u64> = points.iter().map(|p| p.key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), points.len(), "plan must be key-deduplicated");
        // Every figure2/3/4 micro point is in the plan…
        for pf in [true, false] {
            for &s in &MICRO_STRIDES {
                let p = SimPoint::micro(m, MicroOp::LoadAligned, s, scale.micro_bytes, pf, false);
                assert!(points.iter().any(|q| q.key() == p.key()), "missing micro s={s}");
            }
        }
        // …as is figure5's pow2 grid on every preset…
        for preset in crate::config::MachinePreset::all() {
            let mc = preset.config();
            let pow2 = scale.micro_pow2_bytes;
            let p = SimPoint::micro(mc, MicroOp::LoadAligned, 1, pow2, true, false);
            assert!(points.iter().any(|q| q.key() == p.key()), "missing pow2 on {}", mc.name);
        }
        // …and the kernel sweeps contribute points too.
        use crate::exec::point::Workload;
        assert!(points.iter().any(|p| matches!(p.workload, Workload::Kernel { .. })));
    }

    #[test]
    fn figure6_totals_structure() {
        let ts = figure6_totals(50);
        assert!(ts.contains(&1) && ts.contains(&50));
        let ts = figure6_totals(8);
        assert!(ts.iter().all(|&t| t <= 8));
    }

    #[test]
    fn summarize_finds_multi_advantage_mxv() {
        let s = summarize_kernel(coffee_lake(), "mxv", 8 * MIB, 8);
        assert!(
            s.multi_over_single() > 1.0,
            "multi-striding must beat single-striding on mxv: {:.3}",
            s.multi_over_single()
        );
        assert!(s.best_single.throughput_gib >= s.no_unroll.throughput_gib * 0.9);
    }

    #[test]
    fn figure7_rows_cover_references() {
        let rows = figure7(coffee_lake(), "mxv", 8 * MIB, 6);
        let labels: Vec<&str> = rows.iter().map(|r| r.reference.label()).collect();
        assert!(labels.contains(&"MKL (model)"));
        assert!(labels.contains(&"CLang"));
        for r in &rows {
            assert!(r.reference_gib > 0.0 && r.multistrided_gib > 0.0);
        }
    }

    #[test]
    fn selfcheck_passes() {
        selfcheck(4 * MIB).unwrap();
    }

    #[test]
    fn kernel_lists_derive_from_the_registry_universe() {
        // figure6 = universe minus exactly the two mxv-shaped gemver
        // parts; figure7 ⊆ figure6. All three lists share the
        // runtime::universe_names projection, so they cannot drift.
        let names = crate::runtime::universe_names(1 << 20);
        let f6 = figure6_kernels();
        assert!(f6.iter().all(|k| names.contains(k)));
        assert_eq!(f6.len() + 2, names.len());
        assert!(!f6.contains(&"gemvermxv1".to_string()));
        assert!(!f6.contains(&"gemvermxv2".to_string()));
        let f7 = figure7_kernels();
        assert!(f7.iter().all(|k| f6.contains(k)));
    }

    #[test]
    fn tune_kernels_batch_reports_per_slot() {
        let dir = std::env::temp_dir()
            .join(format!("multistride_tune_batch_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cache = crate::tune::PlanCache::new(&dir);
        let names: Vec<String> = ["mxv", "init"].map(String::from).to_vec();
        let cold = tune_kernels(coffee_lake(), MIB, true, &cache, false, &names);
        assert_eq!(cold.len(), 2);
        for (name, out) in names.iter().zip(&cold) {
            let o = out.as_ref().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!o.cache_hit);
            assert_eq!(&o.plan.kernel, name);
        }
        // One plan per kernel persisted; a second batch is all hits.
        assert_eq!(cache.list().len(), 2);
        let warm = tune_kernels(coffee_lake(), MIB, true, &cache, false, &names);
        for out in &warm {
            assert!(out.as_ref().unwrap().cache_hit);
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn extended_kernel_point_runs() {
        let p =
            run_kernel(coffee_lake(), "3mm", 4 * MIB, StridingConfig::new(8, 1), true).unwrap();
        assert!(p.feasible, "rank-8 panel GEMM fits 16 ymm at S=8");
        assert!(p.throughput_gib > 0.0);
        let p = run_kernel(coffee_lake(), "triad", 4 * MIB, StridingConfig::new(4, 1), true)
            .unwrap();
        assert!(p.feasible);
        assert!(p.throughput_gib > 0.0);
    }

    #[test]
    fn variant_sweep_mechanics_on_cheap_subset() {
        // End-to-end sweep mechanics on cheap kernels only (a 1-D blocked
        // micro, a square paper kernel, a 3-deep extended kernel); the
        // full-universe "every kernel derives its whole family with no
        // drops" invariant is pinned transform-side in
        // transform::variants::tests without simulation cost.
        let budget = MIB;
        let kernels: Vec<String> = ["init", "mxv", "3mm"].map(String::from).to_vec();
        let pts = variant_sweep_for(coffee_lake(), budget, 1, true, &kernels);
        let fam_len = 1 + crate::transform::STRIDE_FAMILY.len();
        assert_eq!(pts.len(), kernels.len() * fam_len, "no config dropped");
        for name in &kernels {
            let fam: Vec<&KernelPoint> = pts.iter().filter(|p| &p.kernel == name).collect();
            assert_eq!(fam.len(), fam_len, "{name}");
            assert!(fam.iter().any(|p| p.config.stride_unroll == 1), "{name} baseline");
            for s in crate::transform::STRIDE_FAMILY {
                assert!(
                    fam.iter().any(|p| p.config.stride_unroll == s),
                    "{name} missing S={s}"
                );
            }
            for p in fam {
                assert!(
                    !p.feasible || p.throughput_gib > 0.0,
                    "{name} S={}",
                    p.config.stride_unroll
                );
            }
        }
        // The registry-driven entry point enumerates the whole universe.
        let universe = crate::kernels::library::all_kernels(budget);
        assert!(universe.len() * fam_len > kernels.len() * fam_len);
    }

    #[test]
    fn warm_store_serves_sweeps_without_engine_work_bit_identically() {
        // The acceptance shape at unit scale: a sweep against a warm
        // store performs zero fresh simulations and formats results
        // bit-identical to the cold pass.
        let store = ResultStore::ephemeral();
        let m = coffee_lake();
        let kernels: Vec<String> = ["mxv"].map(String::from).to_vec();
        let cold = variant_sweep_for_on(&store, m, MIB, 1, true, &kernels);
        let cold_runs = store.stats().engine_runs;
        assert!(cold_runs > 0, "cold sweep simulates");
        let warm = variant_sweep_for_on(&store, m, MIB, 1, true, &kernels);
        assert_eq!(
            store.stats().engine_runs,
            cold_runs,
            "warm sweep performs no engine runs"
        );
        assert!(store.stats().hits() >= cold_runs);
        assert_eq!(cold.len(), warm.len());
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.throughput_gib.to_bits(), b.throughput_gib.to_bits(), "{}", a.kernel);
            assert_eq!(a.feasible, b.feasible);
        }
    }

    #[test]
    fn figure3_4_is_served_from_figure2s_grid() {
        // figure3_4's jobs ⊂ figure2's at the same scale: with a shared
        // store the whole figure formats from stored results.
        let store = ResultStore::ephemeral();
        let m = coffee_lake();
        let scale = ScaleConfig {
            micro_bytes: MIB,
            micro_pow2_bytes: MIB,
            kernel_bytes: MIB,
            repetitions: 1,
        };
        let _grid = figure2_on(&store, m, scale, false);
        let runs = store.stats().engine_runs;
        let series = figure3_4_on(&store, m, scale);
        assert_eq!(store.stats().engine_runs, runs, "no new simulations");
        assert_eq!(series.len(), 2 * MICRO_STRIDES.len());
    }

    #[test]
    #[should_panic(expected = "unknown kernel")]
    fn sweep_panics_loudly_on_unknown_kernel() {
        // A typo'd kernel name must not produce an empty sweep.
        let jobs = vec![("nope".to_string(), StridingConfig::new(1, 1))];
        kernel_points_on(
            &ResultStore::ephemeral(),
            coffee_lake(),
            "test",
            MIB,
            true,
            &jobs,
        );
    }
}
