//! One driver per paper figure/table. Each returns structured data; the
//! [`crate::report`] layer renders it in the paper's format.

use crate::config::{MachineConfig, ScaleConfig};
use crate::kernels::library::{kernel_by_name, paper_kernels};
use crate::kernels::micro::{MicroBench, MicroOp};
use crate::kernels::reference::Reference;
use crate::sim::{Engine, EngineConfig, RunResult};
use crate::trace::KernelTrace;
use crate::transform::{enumerate_configs, is_feasible, transform, StridingConfig};

use super::pool::{default_workers, parallel_map_with};

/// The stride counts the micro-benchmarks sweep (divisors of 32).
pub const MICRO_STRIDES: [u32; 6] = [1, 2, 4, 8, 16, 32];

/// Per-worker engine reuse for config sweeps: one warm [`Engine`] whose
/// cache/TLB/DRAM allocations persist across sweep points. Each point is
/// applied with [`Engine::prepare`], which resets to cold state
/// bit-identically with a fresh construction, so results are unchanged —
/// only the per-point construction cost (hierarchy allocation and zeroing)
/// is gone.
#[derive(Default)]
pub struct EngineCache {
    engine: Option<Engine>,
}

impl EngineCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// A cold engine for `cfg`, reusing the cached allocation when the
    /// machine matches.
    pub fn engine_for(&mut self, cfg: EngineConfig) -> &mut Engine {
        match &mut self.engine {
            Some(e) => e.prepare(cfg),
            None => self.engine = Some(Engine::new(cfg)),
        }
        self.engine.as_mut().expect("engine present")
    }
}

/// One measured micro-benchmark point.
#[derive(Debug, Clone)]
pub struct MicroPoint {
    pub op: MicroOp,
    pub strides: u32,
    pub interleaved: bool,
    pub prefetch: bool,
    pub throughput_gib: f64,
    pub result: RunResult,
}

/// Run one micro-benchmark configuration (§4 protocol: huge pages on).
pub fn run_micro(
    machine: MachineConfig,
    op: MicroOp,
    strides: u32,
    bytes: u64,
    prefetch: bool,
    interleaved: bool,
) -> MicroPoint {
    run_micro_with(&mut EngineCache::new(), machine, op, strides, bytes, prefetch, interleaved)
}

/// [`run_micro`] against a reusable per-worker engine.
pub fn run_micro_with(
    cache: &mut EngineCache,
    machine: MachineConfig,
    op: MicroOp,
    strides: u32,
    bytes: u64,
    prefetch: bool,
    interleaved: bool,
) -> MicroPoint {
    let mut bench = MicroBench::new(op, strides, bytes);
    if interleaved {
        bench = bench.interleaved();
    }
    let engine = cache
        .engine_for(EngineConfig::new(machine).with_prefetch(prefetch).with_huge_pages(true));
    let result = engine.run(bench.trace());
    MicroPoint {
        op,
        strides,
        interleaved,
        prefetch,
        throughput_gib: result.throughput_gib(),
        result,
    }
}

/// Figure 2 / Figure 5: the micro-benchmark throughput grid for one array
/// size. `pow2 = true` reproduces Figure 5's 2-GiB-analog collision setup.
pub fn figure2(machine: MachineConfig, scale: ScaleConfig, pow2: bool) -> Vec<MicroPoint> {
    let bytes = if pow2 { scale.micro_pow2_bytes } else { scale.micro_bytes };
    let mut jobs = Vec::new();
    for prefetch in [true, false] {
        for op in MicroOp::all() {
            for &s in &MICRO_STRIDES {
                jobs.push((op, s, prefetch, false));
                // The §4.4 interleaved-NT-store variant.
                if op == MicroOp::StoreNt {
                    jobs.push((op, s, prefetch, true));
                }
            }
        }
    }
    parallel_map_with(jobs, default_workers(), EngineCache::new, |cache, &(op, s, pf, inter)| {
        run_micro_with(cache, machine, op, s, bytes, pf, inter)
    })
}

/// Figure 3 + Figure 4 series: stall cycles and hit ratios for the aligned
/// read micro-benchmark across stride counts, prefetch on/off.
pub fn figure3_4(machine: MachineConfig, scale: ScaleConfig) -> Vec<MicroPoint> {
    let mut jobs = Vec::new();
    for prefetch in [true, false] {
        for &s in &MICRO_STRIDES {
            jobs.push((MicroOp::LoadAligned, s, prefetch, false));
        }
    }
    parallel_map_with(jobs, default_workers(), EngineCache::new, |cache, &(op, s, pf, inter)| {
        run_micro_with(cache, machine, op, s, scale.micro_bytes, pf, inter)
    })
}

/// One point of the Figure 6 kernel sweep.
#[derive(Debug, Clone)]
pub struct KernelPoint {
    pub kernel: String,
    pub config: StridingConfig,
    pub prefetch: bool,
    pub feasible: bool,
    pub throughput_gib: f64,
}

/// Run one kernel configuration through the simulator (§6 protocol:
/// default 4 KiB pages, aligned+interleaved loop bodies kept as generated).
pub fn run_kernel(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    run_kernel_with(&mut EngineCache::new(), machine, kernel, budget, config, prefetch)
}

/// [`run_kernel`] against a reusable per-worker engine. The kernel trace
/// streams straight from [`KernelTrace::iter`] into [`Engine::run`] — no
/// `Vec<Access>` is ever materialized, so multi-GiB footprints stay cheap.
pub fn run_kernel_with(
    cache: &mut EngineCache,
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    config: StridingConfig,
    prefetch: bool,
) -> Option<KernelPoint> {
    let pk = kernel_by_name(kernel, budget)?;
    let t = transform(&pk.spec, config).ok()?;
    let feasible = is_feasible(&t, machine.simd_registers);
    if !feasible {
        return Some(KernelPoint {
            kernel: kernel.to_string(),
            config,
            prefetch,
            feasible,
            throughput_gib: 0.0,
        });
    }
    let trace = KernelTrace::new(t);
    // The paper reports kernel throughput as *data size / time* (§6.3
    // compares kernels across data sizes "we report throughput rather than
    // time"), i.e. each array counts once — not per-access traffic, which
    // would reward cache-hit reloads.
    let footprint = trace.transformed().spec.footprint();
    let engine = cache
        .engine_for(EngineConfig::new(machine).with_prefetch(prefetch).with_huge_pages(false));
    let result = engine.run(trace.iter());
    Some(KernelPoint {
        kernel: kernel.to_string(),
        config,
        prefetch,
        feasible,
        throughput_gib: machine.gib_per_s(footprint, result.counters.cycles),
    })
}

/// The Figure 6 unroll totals swept (the paper sweeps 1..=50; the default
/// driver covers the same range more sparsely past 12 where divisor pairs
/// explode — override with `max_total` for the full grid).
pub fn figure6_totals(max_total: u32) -> Vec<u32> {
    (1..=max_total.min(12)).chain([16, 18, 20, 24, 30, 32, 36, 40, 48, 50]).filter(|&t| t <= max_total).collect()
}

/// Figure 6: sweep the striding optimization space of one isolated kernel.
pub fn figure6(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    max_total: u32,
    prefetch: bool,
) -> Vec<KernelPoint> {
    let mut cfgs: Vec<StridingConfig> = Vec::new();
    for t in figure6_totals(max_total) {
        for c in enumerate_configs(t) {
            if c.total_unrolls() == t {
                cfgs.push(c);
            }
        }
    }
    cfgs.dedup_by_key(|c| (c.stride_unroll, c.portion_unroll));
    let kernel = kernel.to_string();
    parallel_map_with(cfgs, default_workers(), EngineCache::new, |cache, &cfg| {
        run_kernel_with(cache, machine, &kernel, budget, cfg, prefetch).expect("library kernel")
    })
}

/// Pick the best feasible configuration out of a sweep.
pub fn best_point(points: &[KernelPoint]) -> Option<&KernelPoint> {
    points
        .iter()
        .filter(|p| p.feasible)
        .max_by(|a, b| a.throughput_gib.partial_cmp(&b.throughput_gib).expect("no NaN"))
}

/// Best multi-strided vs best single-strided vs no-unroll summary
/// (the green/red lines of Figure 6).
#[derive(Debug, Clone)]
pub struct KernelSummary {
    pub kernel: String,
    pub best_multi: KernelPoint,
    pub best_single: KernelPoint,
    pub no_unroll: KernelPoint,
}

impl KernelSummary {
    /// The §6.3 headline: multi-strided speedup over the best
    /// single-strided configuration.
    pub fn multi_over_single(&self) -> f64 {
        self.best_multi.throughput_gib / self.best_single.throughput_gib
    }
}

/// Summarize a kernel's sweep into the Figure 6 reference lines.
pub fn summarize_kernel(machine: MachineConfig, kernel: &str, budget: u64, max_total: u32) -> KernelSummary {
    let points = figure6(machine, kernel, budget, max_total, true);
    let best_multi = best_point(&points).expect("at least one feasible config").clone();
    let best_single = points
        .iter()
        .filter(|p| p.feasible && p.config.stride_unroll == 1)
        .max_by(|a, b| a.throughput_gib.partial_cmp(&b.throughput_gib).expect("no NaN"))
        .expect("single-strided configs always feasible")
        .clone();
    let no_unroll = points
        .iter()
        .find(|p| p.config.stride_unroll == 1 && p.config.portion_unroll == 1)
        .expect("no-unroll config present")
        .clone();
    KernelSummary { kernel: kernel.to_string(), best_multi, best_single, no_unroll }
}

/// One Figure 7 comparison row: the best multi-strided kernel against one
/// reference implementation model.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub kernel: String,
    pub reference: Reference,
    pub reference_gib: f64,
    pub multistrided_gib: f64,
}

impl ComparisonRow {
    pub fn speedup(&self) -> f64 {
        self.multistrided_gib / self.reference_gib
    }
}

/// Run a reference implementation model on a kernel.
pub fn run_reference(
    machine: MachineConfig,
    kernel: &str,
    budget: u64,
    reference: Reference,
) -> Option<f64> {
    let pk = kernel_by_name(kernel, budget)?;
    let cfg = reference.schedule();
    let t = transform(&pk.spec, cfg).ok()?;
    let trace = KernelTrace::new(t);
    let footprint = trace.transformed().spec.footprint();
    let mut engine = Engine::new(EngineConfig::new(machine).with_huge_pages(false));
    let result = engine.run(trace.iter());
    let mut gib = machine.gib_per_s(footprint, result.counters.cycles);
    // References that fail to vectorize (the paper verified Polly/CLang
    // emitted no AVX2 for these kernels) stream 4-byte elements through a
    // serial accumulate chain: ~one element per cycle is the practical
    // ceiling, so their data throughput is core-bound, not DRAM-bound.
    if reference.scalar_on(kernel) {
        // One 4-byte element every ~2 cycles: the serial FMA accumulate
        // chain (4-5 cycle latency, partially hidden by the OoO core).
        let scalar_bound = machine.gib_per_s(2, 1);
        gib = gib.min(scalar_bound);
    }
    Some(gib)
}

/// Figure 7: compare the tuned multi-strided kernel against every
/// applicable reference on one machine.
pub fn figure7(machine: MachineConfig, kernel: &str, budget: u64, max_total: u32) -> Vec<ComparisonRow> {
    let summary = summarize_kernel(machine, kernel, budget, max_total);
    let refs = Reference::for_kernel(kernel);
    let mut rows = Vec::new();
    for r in refs {
        let reference_gib = match r {
            Reference::BestSingleStrided => summary.best_single.throughput_gib,
            Reference::NoUnroll => summary.no_unroll.throughput_gib,
            _ => match run_reference(machine, kernel, budget, r) {
                Some(g) => g,
                None => continue,
            },
        };
        rows.push(ComparisonRow {
            kernel: kernel.to_string(),
            reference: r,
            reference_gib,
            multistrided_gib: summary.best_multi.throughput_gib,
        });
    }
    rows
}

/// All kernels the Figure 6/7 experiments sweep.
pub fn figure6_kernels() -> Vec<&'static str> {
    vec![
        "bicg",
        "conv",
        "doitgen",
        "gemverouter",
        "gemversum",
        "jacobi2d",
        "mxv",
        "init",
        "writeback",
    ]
}

/// All kernels compared in Figure 7.
pub fn figure7_kernels() -> Vec<&'static str> {
    vec!["bicg", "conv", "doitgen", "gemverouter", "jacobi2d", "mxv"]
}

/// Sanity: the whole kernel library transforms under the paper's default
/// configuration on every machine preset.
pub fn selfcheck(budget: u64) -> crate::Result<()> {
    for pk in paper_kernels(budget) {
        transform(&pk.spec, StridingConfig::new(2, 2))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;

    const MIB: u64 = 1 << 20;

    #[test]
    fn micro_point_reports_throughput() {
        let p = run_micro(coffee_lake(), MicroOp::LoadAligned, 4, 4 * MIB, true, false);
        assert!(p.throughput_gib > 1.0, "got {}", p.throughput_gib);
    }

    #[test]
    fn kernel_point_runs() {
        let p = run_kernel(coffee_lake(), "mxv", 8 * MIB, StridingConfig::new(4, 1), true).unwrap();
        assert!(p.feasible);
        assert!(p.throughput_gib > 1.0);
    }

    #[test]
    fn infeasible_configs_flagged_not_run() {
        // 16×4 = 64 accumulators cannot fit 16 ymm registers.
        let p =
            run_kernel(coffee_lake(), "mxv", 8 * MIB, StridingConfig::new(16, 4), true).unwrap();
        assert!(!p.feasible);
        assert_eq!(p.throughput_gib, 0.0);
    }

    #[test]
    fn figure6_totals_structure() {
        let ts = figure6_totals(50);
        assert!(ts.contains(&1) && ts.contains(&50));
        let ts = figure6_totals(8);
        assert!(ts.iter().all(|&t| t <= 8));
    }

    #[test]
    fn summarize_finds_multi_advantage_mxv() {
        let s = summarize_kernel(coffee_lake(), "mxv", 8 * MIB, 8);
        assert!(
            s.multi_over_single() > 1.0,
            "multi-striding must beat single-striding on mxv: {:.3}",
            s.multi_over_single()
        );
        assert!(s.best_single.throughput_gib >= s.no_unroll.throughput_gib * 0.9);
    }

    #[test]
    fn figure7_rows_cover_references() {
        let rows = figure7(coffee_lake(), "mxv", 8 * MIB, 6);
        let labels: Vec<&str> = rows.iter().map(|r| r.reference.label()).collect();
        assert!(labels.contains(&"MKL (model)"));
        assert!(labels.contains(&"CLang"));
        for r in &rows {
            assert!(r.reference_gib > 0.0 && r.multistrided_gib > 0.0);
        }
    }

    #[test]
    fn selfcheck_passes() {
        selfcheck(4 * MIB).unwrap();
    }
}
