//! The L2 streamer — the prefetch engine multi-striding exploits.
//!
//! Modeled after Intel's documented behaviour (Optimization Reference
//! Manual §E.2.5.4 and the primer the paper cites [13]):
//!
//! * Streams are tracked per **4 KiB page region**; a tracker table holds up
//!   to `table_size` concurrent streams (32 on recent big cores).
//! * A stream *trains* after `train_threshold` accesses in a consistent
//!   direction within the page, then issues prefetches ahead of the demand
//!   position.
//! * The lookahead **distance ramps up** with confirmations, up to
//!   `max_distance` lines, and never crosses the 4 KiB page boundary.
//! * Each stream keeps at most `per_stream_outstanding` prefetches in
//!   flight (enforced by the engine's caller via the `stream` slot id).
//!
//! The paper's entire effect lives in the interplay of these limits: one
//! stride = one trained stream = one stream's worth of in-flight lines;
//! n strides = n streams = n× the in-flight lines, until DRAM bandwidth or
//! the tracker table saturates.

use std::collections::HashMap;

use super::{Observation, PrefetchContext, PrefetchEngine, PrefetchLevel, PrefetchReq};
use crate::mem::addr;

/// Streamer tuning knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamerConfig {
    /// Stream tracker table entries (concurrent 4 KiB page streams).
    pub table_size: u32,
    /// Consistent accesses within a page before prefetching starts.
    pub train_threshold: u32,
    /// Initial lookahead distance (lines) once trained.
    pub init_distance: u32,
    /// Lookahead growth per confirmation (lines).
    pub ramp: u32,
    /// Maximum lookahead distance (lines).
    pub max_distance: u32,
    /// Maximum prefetches one stream may have in flight.
    pub per_stream_outstanding: u32,
    /// Carry a trained stream's state into the next sequential page
    /// (next-page prefetch of recent cores): the stream re-arms in the new
    /// page without paying the full training threshold again.
    pub next_page_carry: bool,
}

impl Default for StreamerConfig {
    fn default() -> Self {
        Self {
            // 32 architectural streams plus headroom for next-page carry
            // transients (a tracker at exactly 32 thrashes when all 32
            // streams cross page boundaries while carries pre-arm).
            table_size: 48,
            train_threshold: 2,
            init_distance: 4,
            ramp: 2,
            max_distance: 24,
            per_stream_outstanding: 16,
            next_page_carry: true,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dir {
    Unknown,
    Fwd,
    Bwd,
}

#[derive(Debug, Clone, Copy)]
struct StreamEntry {
    /// 4 KiB page this stream lives in.
    page: u64,
    valid: bool,
    dir: Dir,
    /// Last demand line observed (absolute line address).
    last_line: u64,
    /// Number of consistent observations (training + confirmations).
    confirmations: u32,
    /// Next line to prefetch (absolute line address).
    next_prefetch: u64,
    /// LRU stamp for table replacement.
    stamp: u64,
    /// Stream was carried over from the previous page fully trained.
    carried: bool,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamerStats {
    pub observations: u64,
    pub streams_allocated: u64,
    pub streams_evicted: u64,
    /// Streams evicted before they ever trained — tracker thrashing.
    pub streams_evicted_untrained: u64,
    pub prefetches_issued: u64,
    pub page_carries: u64,
}

/// The streamer engine.
pub struct Streamer {
    cfg: StreamerConfig,
    table: Vec<StreamEntry>,
    /// page -> table slot (§Perf: replaces a linear table scan on every
    /// L2 observation).
    index: HashMap<u64, usize>,
    clock: u64,
    pub stats: StreamerStats,
}

impl Streamer {
    pub fn new(cfg: StreamerConfig) -> Self {
        Self {
            cfg,
            table: Vec::with_capacity(cfg.table_size as usize),
            index: HashMap::with_capacity(cfg.table_size as usize * 2),
            clock: 0,
            stats: StreamerStats::default(),
        }
    }

    pub fn config(&self) -> StreamerConfig {
        self.cfg
    }

    /// Observe a demand access arriving at L2; push generated prefetch
    /// requests into `out`. `inflight(slot)` reports how many prefetches the
    /// given stream slot currently has outstanding, so the engine can hold
    /// back requests beyond the per-stream budget.
    pub fn observe(
        &mut self,
        obs: Observation,
        inflight: impl Fn(u32) -> u32,
        out: &mut Vec<PrefetchReq>,
    ) {
        self.clock += 1;
        self.stats.observations += 1;
        let line = obs.line;
        let page = addr::page_of_line(line);

        // Find or allocate the stream for this page.
        let slot = match self.index.get(&page) {
            Some(&i) => i,
            None => self.allocate(page, line),
        };
        let clock = self.clock;
        let cfg = self.cfg;
        let e = &mut self.table[slot];
        e.stamp = clock;

        if e.confirmations == 0 && !e.carried {
            // First observation in this page: record position, direction unknown.
            e.last_line = line;
            e.confirmations = 1;
            return;
        }

        // Establish / confirm direction.
        let dir = if line > e.last_line {
            Dir::Fwd
        } else if line < e.last_line {
            Dir::Bwd
        } else {
            // Same line (e.g. second half of an unaligned pair): neutral.
            e.last_line = line;
            return;
        };
        if e.dir == Dir::Unknown {
            e.dir = dir;
        } else if e.dir != dir {
            // Direction flip: retrain in the new direction.
            e.dir = dir;
            e.confirmations = 1;
            e.last_line = line;
            e.next_prefetch = line;
            return;
        }
        e.last_line = line;
        e.confirmations = e.confirmations.saturating_add(1);

        if e.confirmations < cfg.train_threshold {
            return;
        }

        // Trained: compute the lookahead window and emit requests.
        let ramped = cfg.init_distance + cfg.ramp * (e.confirmations - cfg.train_threshold);
        let distance = ramped.min(cfg.max_distance) as u64;
        let budget = cfg.per_stream_outstanding.saturating_sub(inflight(slot as u32));
        if budget == 0 {
            return;
        }

        let mut issued = 0u32;
        match e.dir {
            Dir::Fwd => {
                let page_end = addr::page_last_line(line);
                let target_end = (line + distance).min(page_end);
                let mut next = e.next_prefetch.max(line + 1);
                while next <= target_end && issued < budget {
                    out.push(PrefetchReq { line: next, stream: slot as u32, to_l1: false });
                    next += 1;
                    issued += 1;
                }
                e.next_prefetch = next;
            }
            Dir::Bwd => {
                let page_start = addr::page_first_line(line);
                let target_end = line.saturating_sub(distance).max(page_start);
                let mut next = if e.next_prefetch == 0 || e.next_prefetch >= line {
                    line.saturating_sub(1)
                } else {
                    e.next_prefetch
                };
                while next >= target_end && next < line && issued < budget {
                    out.push(PrefetchReq { line: next, stream: slot as u32, to_l1: false });
                    if next == 0 {
                        break;
                    }
                    next -= 1;
                    issued += 1;
                }
                e.next_prefetch = next;
            }
            Dir::Unknown => unreachable!(),
        }
        self.stats.prefetches_issued += issued as u64;

        // Next-page carry: once the stream's prefetch cursor parks at the
        // page boundary and demand is close behind, pre-arm the next page.
        if cfg.next_page_carry && e.dir == Dir::Fwd {
            let page_end = addr::page_last_line(line);
            if e.next_prefetch > page_end && line + 4 >= page_end {
                let next_page = page + 1;
                let confirmed = e.confirmations;
                if !self.index.contains_key(&next_page) {
                    let ns = self.allocate(next_page, addr::page_first_line(line) + 64);
                    let t = &mut self.table[ns];
                    t.carried = true;
                    t.dir = Dir::Fwd;
                    t.confirmations = confirmed.min(cfg.train_threshold + 2);
                    t.last_line =
                        (next_page << (addr::PAGE_SHIFT - addr::LINE_SHIFT)).wrapping_sub(1);
                    t.next_prefetch = next_page << (addr::PAGE_SHIFT - addr::LINE_SHIFT);
                    self.stats.page_carries += 1;
                }
            }
        }
    }

    fn allocate(&mut self, page: u64, line: u64) -> usize {
        self.stats.streams_allocated += 1;
        let fresh = StreamEntry {
            page,
            valid: true,
            dir: Dir::Unknown,
            last_line: line,
            confirmations: 0,
            next_prefetch: line,
            stamp: self.clock,
            carried: false,
        };
        if self.table.len() < self.cfg.table_size as usize {
            self.table.push(fresh);
            let idx = self.table.len() - 1;
            self.index.insert(page, idx);
            return idx;
        }
        // Evict LRU tracker — with more concurrent page streams than table
        // entries, streams get evicted before they finish training, and the
        // engine degrades (the >32-stride regime).
        let (idx, _) = self
            .table
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| if e.valid { e.stamp } else { 0 })
            .expect("table non-empty");
        self.stats.streams_evicted += 1;
        if self.table[idx].valid
            && self.table[idx].confirmations < self.cfg.train_threshold
            && !self.table[idx].carried
        {
            self.stats.streams_evicted_untrained += 1;
        }
        if self.table[idx].valid {
            self.index.remove(&self.table[idx].page);
        }
        self.table[idx] = fresh;
        self.index.insert(page, idx);
        idx
    }

    /// Number of currently trained streams (debug/test aid).
    pub fn trained_streams(&self) -> usize {
        self.table
            .iter()
            .filter(|e| e.valid && (e.confirmations >= self.cfg.train_threshold || e.carried))
            .count()
    }

    pub fn reset(&mut self) {
        self.table.clear();
        self.index.clear();
        self.clock = 0;
        self.stats = StreamerStats::default();
    }
}

impl PrefetchEngine for Streamer {
    fn name(&self) -> &'static str {
        "l2-streamer"
    }

    fn level(&self) -> PrefetchLevel {
        PrefetchLevel::L2
    }

    fn observe(
        &mut self,
        obs: Observation,
        ctx: &PrefetchContext<'_>,
        out: &mut Vec<PrefetchReq>,
    ) {
        Streamer::observe(self, obs, |slot| (ctx.outstanding)(slot), out);
    }

    fn reset(&mut self) {
        Streamer::reset(self);
    }

    fn clear_stats(&mut self) {
        self.stats = StreamerStats::default();
    }

    fn streamer_stats(&self) -> Option<StreamerStats> {
        Some(self.stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(line: u64) -> Observation {
        Observation { line, ip: 0, miss: true, store: false }
    }

    fn run_seq(s: &mut Streamer, lines: impl IntoIterator<Item = u64>) -> Vec<PrefetchReq> {
        let mut out = Vec::new();
        for l in lines {
            s.observe(obs(l), |_| 0, &mut out);
        }
        out
    }

    #[test]
    fn trains_after_threshold_and_prefetches_ahead() {
        let mut s = Streamer::new(StreamerConfig::default());
        let reqs = run_seq(&mut s, [0, 1]);
        assert!(!reqs.is_empty(), "trained after 2 consistent accesses");
        assert!(reqs.iter().all(|r| r.line > 1), "prefetches are ahead of demand");
        assert!(reqs.iter().all(|r| !r.to_l1), "streamer fills L2/L3");
    }

    #[test]
    fn lookahead_ramps_with_confirmations() {
        let cfg = StreamerConfig::default();
        let mut s = Streamer::new(cfg);
        let mut out = Vec::new();
        for l in 0..12u64 {
            out.clear();
            s.observe(obs(l), |_| 0, &mut out);
        }
        // After many confirmations the cursor must be >= max_distance ahead.
        let reqs = run_seq(&mut s, [12]);
        if let Some(r) = reqs.last() {
            assert!(r.line >= 12 + cfg.init_distance as u64);
        }
        // Cursor never exceeds max_distance beyond demand:
        assert!(reqs.iter().all(|r| r.line <= 12 + cfg.max_distance as u64));
    }

    #[test]
    fn never_crosses_page_boundary() {
        let mut s = Streamer::new(StreamerConfig { next_page_carry: false, ..Default::default() });
        // Train near the end of page 0 (lines 0..63).
        let reqs = run_seq(&mut s, [58, 59, 60, 61, 62]);
        assert!(reqs.iter().all(|r| r.line <= 63), "prefetch stays in page: {reqs:?}");
    }

    #[test]
    fn backward_streams_train_too() {
        let mut s = Streamer::new(StreamerConfig::default());
        let reqs = run_seq(&mut s, [40, 39, 38]);
        assert!(!reqs.is_empty());
        // Every prefetch runs ahead of the demand that triggered it.
        assert!(reqs.iter().all(|r| r.line < 39), "{reqs:?}");
    }

    #[test]
    fn per_stream_outstanding_budget_respected() {
        let cfg = StreamerConfig { per_stream_outstanding: 3, ..Default::default() };
        let mut s = Streamer::new(cfg);
        let mut out = Vec::new();
        s.observe(obs(0), |_| 0, &mut out);
        s.observe(obs(1), |_| 0, &mut out);
        assert!(out.len() <= 3, "issued {} > budget", out.len());
        // With the budget reported as exhausted, nothing is issued.
        out.clear();
        s.observe(obs(2), |_| 3, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn n_strides_train_n_streams() {
        let mut s = Streamer::new(StreamerConfig::default());
        let stride = 1 << 14; // lines; well beyond a page
        let mut out = Vec::new();
        for step in 0..4u64 {
            for k in 0..8u64 {
                s.observe(obs(k * stride + step), |_| 0, &mut out);
            }
        }
        assert_eq!(s.trained_streams(), 8, "one trained stream per stride");
    }

    #[test]
    fn table_thrashing_beyond_capacity() {
        let cfg = StreamerConfig { table_size: 4, next_page_carry: false, ..Default::default() };
        let mut s = Streamer::new(cfg);
        let stride = 1 << 14;
        let mut out = Vec::new();
        // 8 interleaved streams with only 4 trackers: each stream's entry is
        // evicted before its second access arrives -> no stream ever trains.
        for step in 0..8u64 {
            for k in 0..8u64 {
                s.observe(obs(k * stride + step), |_| 0, &mut out);
            }
        }
        assert_eq!(out.len(), 0, "no prefetches under tracker thrash");
        assert!(s.stats.streams_evicted_untrained > 0);
    }

    #[test]
    fn direction_flip_retrains() {
        let mut s = Streamer::new(StreamerConfig::default());
        let mut out = Vec::new();
        s.observe(obs(10), |_| 0, &mut out);
        s.observe(obs(11), |_| 0, &mut out);
        out.clear();
        s.observe(obs(9), |_| 0, &mut out); // flip
        assert!(out.is_empty(), "flip must retrain, not prefetch");
    }

    #[test]
    fn next_page_carry_rearms() {
        let cfg = StreamerConfig::default();
        let mut s = Streamer::new(cfg);
        let mut out = Vec::new();
        for l in 0..64u64 {
            s.observe(obs(l), |_| 0, &mut out);
        }
        assert!(s.stats.page_carries >= 1, "stream carried into page 1");
        // First access in page 1 resumes prefetching without retraining.
        out.clear();
        s.observe(obs(64), |_| 0, &mut out);
        assert!(!out.is_empty(), "carried stream prefetches immediately");
    }

    #[test]
    fn same_line_observation_is_neutral() {
        let mut s = Streamer::new(StreamerConfig::default());
        let mut out = Vec::new();
        s.observe(obs(5), |_| 0, &mut out);
        s.observe(obs(5), |_| 0, &mut out);
        s.observe(obs(6), |_| 0, &mut out);
        s.observe(obs(7), |_| 0, &mut out);
        assert!(!out.is_empty(), "duplicate lines do not reset training");
    }
}
