//! Hardware prefetch engines behind a pluggable trait.
//!
//! Coffee Lake exposes four prefetchers via MSR 0x1A4 (the knob the paper
//! toggles): the **L2 streamer**, the **L2 adjacent-line** prefetcher, the
//! **DCU next-line** prefetcher and the **DCU IP-stride** prefetcher. The
//! load-bearing engine for the paper's effect is the streamer: it tracks one
//! *stream* per 4 KiB page region and issues prefetches ahead of each
//! detected stream, with a per-stream lookahead budget. One single-strided
//! loop trains exactly one stream at a time and is therefore limited to one
//! stream's lookahead; a multi-strided loop trains `n` streams whose
//! lookaheads aggregate — that is the paper's mechanism.
//!
//! Every model implements [`PrefetchEngine`]; the simulation engine
//! decides timing, budget and installation level. New prefetcher models
//! (an AMD-style region prefetcher, a next-page engine, …) implement the
//! trait and register via [`crate::sim::Engine::register_prefetcher`] — no
//! engine changes needed. The four built-in hardware models additionally
//! get **static dispatch** on the engine's hot path through the
//! [`BuiltinEngine`] enum ([`PrefetchConfig::build_builtins`]); trait
//! objects remain the plugin extension point, observing right after the
//! built-ins ([`PrefetchConfig::build_engines`] still hands out boxed
//! built-ins for code that wants uniform trait objects).

pub mod adjacent;
pub mod builtin;
pub mod dcu;
pub mod ipstride;
pub mod streamer;

pub use adjacent::AdjacentLine;
pub use builtin::{partition_builtins_by_level, BuiltinEngine};
pub use dcu::{DcuNextLine, DcuNextLineConfig};
pub use ipstride::{IpStride, IpStrideConfig};
pub use streamer::{Streamer, StreamerConfig};

/// A prefetch request produced by an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchReq {
    /// Line address to fetch.
    pub line: u64,
    /// Stream slot that generated the request (for per-stream in-flight
    /// accounting); `u32::MAX` for engines without stream state.
    pub stream: u32,
    /// Install into L1 (DCU engines) rather than L2/L3 (streamer).
    pub to_l1: bool,
}

/// Demand-access context handed to engines on every observation.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    /// Line address of the demand access.
    pub line: u64,
    /// Synthetic instruction pointer (unroll-slot id) of the access; drives
    /// the IP-stride engine.
    pub ip: u32,
    /// The demand access missed the observing cache level.
    pub miss: bool,
    /// Access was a store (streamer trains on RFO traffic too).
    pub store: bool,
}

/// Cache level an engine observes traffic at (and installs toward).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrefetchLevel {
    /// Observes L1 demand traffic; fills install into L1 (+L2).
    L1,
    /// Observes requests arriving at L2; fills install into L2 + L3.
    L2,
}

/// Simulator-side context available to an engine at observation time.
pub struct PrefetchContext<'a> {
    /// The demand access hit the observing cache level (gates engines that
    /// trigger on misses only, like adjacent-line).
    pub level_hit: bool,
    /// Live outstanding prefetches for a stream slot, so engines can hold
    /// back requests beyond their per-stream budget.
    pub outstanding: &'a dyn Fn(u32) -> u32,
}

/// A hardware prefetch engine model.
///
/// Contract (see `ARCHITECTURE.md` for the full write-up):
///
/// * [`observe`](Self::observe) is called for every demand access reaching
///   the engine's [`level`](Self::level) — hits and misses, loads and RFOs
///   — in trace order. The engine pushes any [`PrefetchReq`]s it wants
///   issued into `out`; the simulator decides redundancy, timing and
///   installation, and issues requests in the order pushed.
/// * Engines must be deterministic: identical observation sequences must
///   produce identical request sequences.
/// * [`reset`](Self::reset) must restore the exact post-construction state
///   (the engine-reuse path depends on it being bit-identical).
pub trait PrefetchEngine: Send {
    /// Stable identifier for diagnostics.
    fn name(&self) -> &'static str;

    /// Which cache level this engine observes.
    fn level(&self) -> PrefetchLevel;

    /// Observe one demand access; push generated requests into `out`.
    fn observe(&mut self, obs: Observation, ctx: &PrefetchContext<'_>, out: &mut Vec<PrefetchReq>);

    /// Restore the post-construction state.
    fn reset(&mut self);

    /// Zero statistics while keeping trained state (warmup protocol).
    fn clear_stats(&mut self) {}

    /// Streamer statistics, when this engine is the L2 streamer (reported
    /// in [`crate::sim::RunResult`]).
    fn streamer_stats(&self) -> Option<streamer::StreamerStats> {
        None
    }
}

/// The MSR-0x1A4-style master switch plus per-engine enables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefetchConfig {
    /// Master enable: when false, no engine observes or issues anything —
    /// equivalent to the paper's "hardware prefetching disabled" MSR state.
    pub enabled: bool,
    pub streamer: StreamerConfig,
    pub streamer_enabled: bool,
    /// L2 adjacent-line prefetch: pull the 128-byte pair line of every L2
    /// demand miss.
    pub adjacent_enabled: bool,
    pub dcu: DcuNextLineConfig,
    pub dcu_enabled: bool,
    pub ipstride: IpStrideConfig,
    pub ipstride_enabled: bool,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            streamer: StreamerConfig::default(),
            streamer_enabled: true,
            adjacent_enabled: true,
            dcu: DcuNextLineConfig::default(),
            // The DCU engines are present in hardware but contribute nothing
            // to the streaming patterns studied here (the measured L1 hit
            // ratio in Figure 4 is pinned at 0.5, i.e. the DCU prefetches
            // never arrive ahead of the demand for these access rates).
            // They are modeled and unit-tested, but the calibrated machine
            // presets keep them disabled; enable to explore.
            dcu_enabled: false,
            ipstride: IpStrideConfig::default(),
            ipstride_enabled: false,
        }
    }
}

impl PrefetchConfig {
    /// Instantiate the enabled built-in hardware models, in observation
    /// order (L1: DCU next-line, then IP-stride; L2: streamer, then
    /// adjacent-line). The master `enabled` switch is enforced by the
    /// simulation engine at observation time, matching the MSR semantics
    /// of a present-but-disabled prefetcher.
    pub fn build_engines(&self) -> Vec<Box<dyn PrefetchEngine>> {
        // Derived from build_builtins (the single registry): the enum is
        // itself a PrefetchEngine that delegates to the wrapped model.
        self.build_builtins()
            .into_iter()
            .map(|e| Box::new(e) as Box<dyn PrefetchEngine>)
            .collect()
    }

    /// The single registry of built-in hardware models, wrapped in the
    /// statically dispatched [`BuiltinEngine`] the simulation engine
    /// drives on its hot path ([`PrefetchConfig::build_engines`] boxes
    /// the same values for code that wants trait objects).
    pub fn build_builtins(&self) -> Vec<BuiltinEngine> {
        let mut v = Vec::new();
        if self.dcu_enabled {
            v.push(BuiltinEngine::DcuNextLine(DcuNextLine::new(self.dcu)));
        }
        if self.ipstride_enabled {
            v.push(BuiltinEngine::IpStride(IpStride::new(self.ipstride)));
        }
        if self.streamer_enabled {
            v.push(BuiltinEngine::Streamer(Streamer::new(self.streamer)));
        }
        if self.adjacent_enabled {
            v.push(BuiltinEngine::AdjacentLine(AdjacentLine));
        }
        v
    }
}

/// Partition engines by observation level, preserving order within each.
pub fn partition_by_level(
    engines: Vec<Box<dyn PrefetchEngine>>,
) -> (Vec<Box<dyn PrefetchEngine>>, Vec<Box<dyn PrefetchEngine>>) {
    let mut l1 = Vec::new();
    let mut l2 = Vec::new();
    for e in engines {
        match e.level() {
            PrefetchLevel::L1 => l1.push(e),
            PrefetchLevel::L2 => l2.push(e),
        }
    }
    (l1, l2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_respects_enable_flags() {
        let cfg = PrefetchConfig::default();
        let names: Vec<&str> = cfg.build_engines().iter().map(|e| e.name()).collect();
        assert_eq!(names, vec!["l2-streamer", "l2-adjacent-line"]);

        let all = PrefetchConfig { dcu_enabled: true, ipstride_enabled: true, ..cfg };
        let names: Vec<&str> = all.build_engines().iter().map(|e| e.name()).collect();
        assert_eq!(
            names,
            vec!["dcu-next-line", "dcu-ip-stride", "l2-streamer", "l2-adjacent-line"]
        );
    }

    #[test]
    fn levels_partition_l1_and_l2() {
        let cfg = PrefetchConfig {
            dcu_enabled: true,
            ipstride_enabled: true,
            ..PrefetchConfig::default()
        };
        for e in cfg.build_engines() {
            let expect =
                if e.name().starts_with("dcu") { PrefetchLevel::L1 } else { PrefetchLevel::L2 };
            assert_eq!(e.level(), expect, "{}", e.name());
        }
    }
}
