//! Hardware prefetch engines.
//!
//! Coffee Lake exposes four prefetchers via MSR 0x1A4 (the knob the paper
//! toggles): the **L2 streamer**, the **L2 adjacent-line** prefetcher, the
//! **DCU next-line** prefetcher and the **DCU IP-stride** prefetcher. The
//! load-bearing engine for the paper's effect is the streamer: it tracks one
//! *stream* per 4 KiB page region and issues prefetches ahead of each
//! detected stream, with a per-stream lookahead budget. One single-strided
//! loop trains exactly one stream at a time and is therefore limited to one
//! stream's lookahead; a multi-strided loop trains `n` streams whose
//! lookaheads aggregate — that is the paper's mechanism.
//!
//! Engines produce [`PrefetchReq`]s; the simulation engine decides timing,
//! budget and installation level.

pub mod dcu;
pub mod ipstride;
pub mod streamer;

pub use dcu::{DcuNextLine, DcuNextLineConfig};
pub use ipstride::{IpStride, IpStrideConfig};
pub use streamer::{Streamer, StreamerConfig};

/// A prefetch request produced by an engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefetchReq {
    /// Line address to fetch.
    pub line: u64,
    /// Stream slot that generated the request (for per-stream in-flight
    /// accounting); `u32::MAX` for engines without stream state.
    pub stream: u32,
    /// Install into L1 (DCU engines) rather than L2/L3 (streamer).
    pub to_l1: bool,
}

/// Demand-access context handed to engines on every observation.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    /// Line address of the demand access.
    pub line: u64,
    /// Synthetic instruction pointer (unroll-slot id) of the access; drives
    /// the IP-stride engine.
    pub ip: u32,
    /// The demand access missed the observing cache level.
    pub miss: bool,
    /// Access was a store (streamer trains on RFO traffic too).
    pub store: bool,
}

/// The MSR-0x1A4-style master switch plus per-engine enables.
#[derive(Debug, Clone, Copy)]
pub struct PrefetchConfig {
    /// Master enable: when false, no engine observes or issues anything —
    /// equivalent to the paper's "hardware prefetching disabled" MSR state.
    pub enabled: bool,
    pub streamer: StreamerConfig,
    pub streamer_enabled: bool,
    /// L2 adjacent-line prefetch: pull the 128-byte pair line of every L2
    /// demand miss.
    pub adjacent_enabled: bool,
    pub dcu: DcuNextLineConfig,
    pub dcu_enabled: bool,
    pub ipstride: IpStrideConfig,
    pub ipstride_enabled: bool,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            streamer: StreamerConfig::default(),
            streamer_enabled: true,
            adjacent_enabled: true,
            dcu: DcuNextLineConfig::default(),
            // The DCU engines are present in hardware but contribute nothing
            // to the streaming patterns studied here (the measured L1 hit
            // ratio in Figure 4 is pinned at 0.5, i.e. the DCU prefetches
            // never arrive ahead of the demand for these access rates).
            // They are modeled and unit-tested, but the calibrated machine
            // presets keep them disabled; enable to explore.
            dcu_enabled: false,
            ipstride: IpStrideConfig::default(),
            ipstride_enabled: false,
        }
    }
}
