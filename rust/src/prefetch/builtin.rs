//! Static dispatch for the four built-in hardware prefetchers.
//!
//! The simulation engine calls `observe` once (L1 engines) or twice (L2
//! engines are consulted on every request arriving at L2) per simulated
//! access — hot enough that the indirect call through `Box<dyn
//! PrefetchEngine>` plus the `&dyn Fn` budget callback show up in profiles.
//! [`BuiltinEngine`] wraps the four built-ins in an enum so the hot path
//! dispatches with a match (inlinable, no vtable) and passes the budget
//! query as a monomorphized closure.
//!
//! `Box<dyn PrefetchEngine>` remains the extension point for user models:
//! [`crate::sim::Engine::register_prefetcher`] is unchanged and registered
//! plugins observe right after the built-ins, in registration order.
//! [`super::PrefetchConfig::build_engines`] still exists for code that
//! wants trait objects for the built-ins too.

use super::{
    AdjacentLine, DcuNextLine, IpStride, Observation, PrefetchContext, PrefetchEngine,
    PrefetchLevel, PrefetchReq, Streamer,
};
use crate::prefetch::streamer::StreamerStats;

/// One of the four MSR-0x1A4 hardware prefetchers, statically dispatched.
pub enum BuiltinEngine {
    DcuNextLine(DcuNextLine),
    IpStride(IpStride),
    Streamer(Streamer),
    AdjacentLine(AdjacentLine),
}

impl BuiltinEngine {
    /// Stable identifier, delegated to the wrapped model's trait impl.
    pub fn name(&self) -> &'static str {
        match self {
            Self::DcuNextLine(e) => PrefetchEngine::name(e),
            Self::IpStride(e) => PrefetchEngine::name(e),
            Self::Streamer(e) => PrefetchEngine::name(e),
            Self::AdjacentLine(e) => PrefetchEngine::name(e),
        }
    }

    /// Which cache level this engine observes (trait-impl delegated).
    pub fn level(&self) -> PrefetchLevel {
        match self {
            Self::DcuNextLine(e) => PrefetchEngine::level(e),
            Self::IpStride(e) => PrefetchEngine::level(e),
            Self::Streamer(e) => PrefetchEngine::level(e),
            Self::AdjacentLine(e) => PrefetchEngine::level(e),
        }
    }

    /// Observe one demand access; push generated requests into `out`.
    /// Semantically identical to `PrefetchEngine::observe` with a context
    /// of `{ level_hit, outstanding }`, but the budget query is a
    /// monomorphized closure instead of a `&dyn Fn`.
    #[inline]
    pub fn observe(
        &mut self,
        obs: Observation,
        level_hit: bool,
        outstanding: impl Fn(u32) -> u32,
        out: &mut Vec<PrefetchReq>,
    ) {
        match self {
            Self::DcuNextLine(e) => e.observe(obs, out),
            Self::IpStride(e) => e.observe(obs, out),
            Self::Streamer(e) => e.observe(obs, outstanding, out),
            Self::AdjacentLine(e) => e.observe(obs, level_hit, out),
        }
    }

    /// Restore the post-construction state.
    pub fn reset(&mut self) {
        match self {
            Self::DcuNextLine(e) => e.reset(),
            Self::IpStride(e) => e.reset(),
            Self::Streamer(e) => e.reset(),
            Self::AdjacentLine(_) => {}
        }
    }

    /// Zero statistics while keeping trained state (warmup protocol).
    pub fn clear_stats(&mut self) {
        match self {
            Self::DcuNextLine(e) => e.stats = Default::default(),
            Self::IpStride(e) => e.stats = Default::default(),
            Self::Streamer(e) => e.stats = Default::default(),
            Self::AdjacentLine(_) => {}
        }
    }

    /// Streamer statistics, when this is the L2 streamer.
    pub fn streamer_stats(&self) -> Option<StreamerStats> {
        match self {
            Self::Streamer(e) => Some(e.stats),
            _ => None,
        }
    }
}

/// The enum is itself a [`PrefetchEngine`], delegating to the wrapped
/// model — this is how [`super::PrefetchConfig::build_engines`] derives
/// its boxed registry from [`super::PrefetchConfig::build_builtins`], so
/// there is exactly one place that lists the built-ins.
impl PrefetchEngine for BuiltinEngine {
    fn name(&self) -> &'static str {
        BuiltinEngine::name(self)
    }

    fn level(&self) -> PrefetchLevel {
        BuiltinEngine::level(self)
    }

    fn observe(
        &mut self,
        obs: Observation,
        ctx: &PrefetchContext<'_>,
        out: &mut Vec<PrefetchReq>,
    ) {
        BuiltinEngine::observe(self, obs, ctx.level_hit, |slot| (ctx.outstanding)(slot), out);
    }

    fn reset(&mut self) {
        BuiltinEngine::reset(self);
    }

    fn clear_stats(&mut self) {
        BuiltinEngine::clear_stats(self);
    }

    fn streamer_stats(&self) -> Option<StreamerStats> {
        BuiltinEngine::streamer_stats(self)
    }
}

/// Partition builtin engines by observation level, preserving order within
/// each (the devirtualized analogue of [`super::partition_by_level`]).
pub fn partition_builtins_by_level(
    engines: Vec<BuiltinEngine>,
) -> (Vec<BuiltinEngine>, Vec<BuiltinEngine>) {
    let mut l1 = Vec::new();
    let mut l2 = Vec::new();
    for e in engines {
        match e.level() {
            PrefetchLevel::L1 => l1.push(e),
            PrefetchLevel::L2 => l2.push(e),
        }
    }
    (l1, l2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prefetch::{PrefetchConfig, PrefetchContext, PrefetchEngine};

    fn obs(line: u64, ip: u32, miss: bool) -> Observation {
        Observation { line, ip, miss, store: false }
    }

    /// Every builtin must behave identically through the enum and through
    /// the trait object — same names, levels and request streams.
    #[test]
    fn enum_dispatch_matches_trait_dispatch() {
        let cfg = PrefetchConfig {
            dcu_enabled: true,
            ipstride_enabled: true,
            ..PrefetchConfig::default()
        };
        let mut builtins = cfg.build_builtins();
        let mut dyns = cfg.build_engines();
        assert_eq!(builtins.len(), dyns.len());
        let none = |_: u32| 0u32;
        for (b, d) in builtins.iter_mut().zip(dyns.iter_mut()) {
            assert_eq!(b.name(), d.name());
            assert_eq!(b.level(), d.level());
            // A miss-y ascending sequence exercises all four models.
            for (i, line) in [10u64, 11, 12, 13, 14].iter().enumerate() {
                let mut out_b = Vec::new();
                let mut out_d = Vec::new();
                b.observe(obs(*line, i as u32 % 2, true), false, none, &mut out_b);
                let ctx = PrefetchContext { level_hit: false, outstanding: &none };
                d.observe(obs(*line, i as u32 % 2, true), &ctx, &mut out_d);
                assert_eq!(out_b, out_d, "{} diverged at line {line}", b.name());
            }
        }
    }

    #[test]
    fn adjacent_enum_respects_level_hit() {
        let mut e = BuiltinEngine::AdjacentLine(AdjacentLine);
        let mut out = Vec::new();
        e.observe(obs(10, 0, false), true, |_| 0, &mut out);
        assert!(out.is_empty(), "silent on hits");
        e.observe(obs(10, 0, true), false, |_| 0, &mut out);
        assert_eq!(out, vec![PrefetchReq { line: 11, stream: u32::MAX, to_l1: false }]);
    }

    #[test]
    fn builtin_partition_matches_levels() {
        let cfg = PrefetchConfig {
            dcu_enabled: true,
            ipstride_enabled: true,
            ..PrefetchConfig::default()
        };
        let (l1, l2) = partition_builtins_by_level(cfg.build_builtins());
        assert_eq!(
            l1.iter().map(|e| e.name()).collect::<Vec<_>>(),
            vec!["dcu-next-line", "dcu-ip-stride"]
        );
        assert_eq!(
            l2.iter().map(|e| e.name()).collect::<Vec<_>>(),
            vec!["l2-streamer", "l2-adjacent-line"]
        );
    }
}
