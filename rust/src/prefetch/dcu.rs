//! DCU next-line prefetcher (L1).
//!
//! Fetches line N+1 into L1 on an ascending access to line N. Present on
//! all three surveyed micro-architectures; for the streaming access rates
//! of the paper's kernels its fills arrive too late to lift the L1 hit
//! ratio above the 0.5 floor Figure 4 shows, so the calibrated presets
//! disable it (see [`super::PrefetchConfig`]). It is still modeled fully so
//! ablations can enable it.

use super::{Observation, PrefetchContext, PrefetchEngine, PrefetchLevel, PrefetchReq};

/// DCU next-line knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DcuNextLineConfig {
    /// Only trigger on ascending accesses (hardware behaviour).
    pub ascending_only: bool,
    /// Trigger on hits as well as misses.
    pub on_hits: bool,
}

impl Default for DcuNextLineConfig {
    fn default() -> Self {
        Self { ascending_only: true, on_hits: true }
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct DcuStats {
    pub observations: u64,
    pub prefetches_issued: u64,
}

/// The DCU next-line engine: one previous-line register.
pub struct DcuNextLine {
    cfg: DcuNextLineConfig,
    last_line: u64,
    has_last: bool,
    pub stats: DcuStats,
}

impl DcuNextLine {
    pub fn new(cfg: DcuNextLineConfig) -> Self {
        Self { cfg, last_line: 0, has_last: false, stats: DcuStats::default() }
    }

    /// Observe an L1 demand access; maybe emit a next-line request.
    pub fn observe(&mut self, obs: Observation, out: &mut Vec<PrefetchReq>) {
        self.stats.observations += 1;
        if !obs.miss && !self.cfg.on_hits {
            self.note(obs.line);
            return;
        }
        let ascending = !self.has_last || obs.line >= self.last_line;
        if self.cfg.ascending_only && !ascending {
            self.note(obs.line);
            return;
        }
        out.push(PrefetchReq { line: obs.line + 1, stream: u32::MAX, to_l1: true });
        self.stats.prefetches_issued += 1;
        self.note(obs.line);
    }

    fn note(&mut self, line: u64) {
        self.last_line = line;
        self.has_last = true;
    }

    pub fn reset(&mut self) {
        self.has_last = false;
        self.stats = DcuStats::default();
    }
}

impl PrefetchEngine for DcuNextLine {
    fn name(&self) -> &'static str {
        "dcu-next-line"
    }

    fn level(&self) -> PrefetchLevel {
        PrefetchLevel::L1
    }

    fn observe(
        &mut self,
        obs: Observation,
        _ctx: &PrefetchContext<'_>,
        out: &mut Vec<PrefetchReq>,
    ) {
        DcuNextLine::observe(self, obs, out);
    }

    fn reset(&mut self) {
        DcuNextLine::reset(self);
    }

    fn clear_stats(&mut self) {
        self.stats = DcuStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(line: u64, miss: bool) -> Observation {
        Observation { line, ip: 0, miss, store: false }
    }

    #[test]
    fn emits_next_line_on_ascending() {
        let mut d = DcuNextLine::new(DcuNextLineConfig::default());
        let mut out = Vec::new();
        d.observe(obs(10, true), &mut out);
        assert_eq!(out, vec![PrefetchReq { line: 11, stream: u32::MAX, to_l1: true }]);
    }

    #[test]
    fn suppressed_on_descending() {
        let mut d = DcuNextLine::new(DcuNextLineConfig::default());
        let mut out = Vec::new();
        d.observe(obs(10, true), &mut out);
        out.clear();
        d.observe(obs(9, true), &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn miss_only_mode() {
        let mut d = DcuNextLine::new(DcuNextLineConfig { on_hits: false, ..Default::default() });
        let mut out = Vec::new();
        d.observe(obs(10, false), &mut out);
        assert!(out.is_empty());
        d.observe(obs(11, true), &mut out);
        assert_eq!(out.len(), 1);
    }
}
