//! DCU IP-stride prefetcher (L1).
//!
//! Tracks, per instruction pointer, the stride between successive accesses
//! made by that instruction; once a stable stride is seen it prefetches
//! `degree` strides ahead into L1. Our traces carry a synthetic IP per
//! unroll slot, so this engine sees exactly what hardware would: each unroll
//! slot advances by the loop step every iteration.
//!
//! Like the next-line engine this is disabled in the calibrated presets
//! (Figure 4's hard 0.5 L1 hit ratio shows its fills are not timely for
//! these kernels) but is fully modeled for ablation studies.

use super::{Observation, PrefetchContext, PrefetchEngine, PrefetchLevel, PrefetchReq};

/// IP-stride knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IpStrideConfig {
    /// Tracker table entries (indexed by IP hash).
    pub table_size: u32,
    /// Matching strides required before issuing.
    pub train_threshold: u32,
    /// How many strides ahead to prefetch.
    pub degree: u32,
    /// Maximum absolute stride in lines that the tracker accepts.
    pub max_stride_lines: i64,
}

impl Default for IpStrideConfig {
    fn default() -> Self {
        Self { table_size: 64, train_threshold: 2, degree: 1, max_stride_lines: 512 }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct IpEntry {
    ip: u32,
    valid: bool,
    last_line: u64,
    stride: i64,
    confidence: u32,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct IpStrideStats {
    pub observations: u64,
    pub prefetches_issued: u64,
}

/// The IP-stride engine.
pub struct IpStride {
    cfg: IpStrideConfig,
    table: Vec<IpEntry>,
    pub stats: IpStrideStats,
}

impl IpStride {
    pub fn new(cfg: IpStrideConfig) -> Self {
        Self {
            cfg,
            table: vec![IpEntry::default(); cfg.table_size as usize],
            stats: IpStrideStats::default(),
        }
    }

    /// Observe an L1 access from instruction `obs.ip`.
    pub fn observe(&mut self, obs: Observation, out: &mut Vec<PrefetchReq>) {
        self.stats.observations += 1;
        let idx = (obs.ip as usize) % self.table.len();
        let e = &mut self.table[idx];
        if !e.valid || e.ip != obs.ip {
            *e = IpEntry { ip: obs.ip, valid: true, last_line: obs.line, stride: 0, confidence: 0 };
            return;
        }
        let stride = obs.line as i64 - e.last_line as i64;
        e.last_line = obs.line;
        if stride == 0 {
            return;
        }
        if stride.abs() > self.cfg.max_stride_lines {
            e.confidence = 0;
            e.stride = 0;
            return;
        }
        if stride == e.stride {
            e.confidence = e.confidence.saturating_add(1);
        } else {
            e.stride = stride;
            e.confidence = 1;
        }
        if e.confidence >= self.cfg.train_threshold {
            for k in 1..=self.cfg.degree as i64 {
                let target = obs.line as i64 + e.stride * k;
                if target >= 0 {
                    out.push(PrefetchReq { line: target as u64, stream: u32::MAX, to_l1: true });
                    self.stats.prefetches_issued += 1;
                }
            }
        }
    }

    pub fn reset(&mut self) {
        self.table.fill(IpEntry::default());
        self.stats = IpStrideStats::default();
    }
}

impl PrefetchEngine for IpStride {
    fn name(&self) -> &'static str {
        "dcu-ip-stride"
    }

    fn level(&self) -> PrefetchLevel {
        PrefetchLevel::L1
    }

    fn observe(
        &mut self,
        obs: Observation,
        _ctx: &PrefetchContext<'_>,
        out: &mut Vec<PrefetchReq>,
    ) {
        IpStride::observe(self, obs, out);
    }

    fn reset(&mut self) {
        IpStride::reset(self);
    }

    fn clear_stats(&mut self) {
        self.stats = IpStrideStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(ip: u32, line: u64) -> Observation {
        Observation { line, ip, miss: true, store: false }
    }

    #[test]
    fn learns_constant_stride_per_ip() {
        let mut p = IpStride::new(IpStrideConfig::default());
        let mut out = Vec::new();
        // IP 7 strides by 16 lines each iteration.
        p.observe(obs(7, 0), &mut out);
        p.observe(obs(7, 16), &mut out);
        p.observe(obs(7, 32), &mut out); // confidence reaches threshold
        assert_eq!(out, vec![PrefetchReq { line: 48, stream: u32::MAX, to_l1: true }]);
    }

    #[test]
    fn distinct_ips_do_not_interfere() {
        let mut p = IpStride::new(IpStrideConfig::default());
        let mut out = Vec::new();
        for i in 0..4 {
            p.observe(obs(1, i * 10), &mut out);
            p.observe(obs(2, 1000 + i * 20), &mut out);
        }
        assert!(out.contains(&PrefetchReq { line: 40, stream: u32::MAX, to_l1: true }));
        assert!(out.contains(&PrefetchReq { line: 1080, stream: u32::MAX, to_l1: true }));
    }

    #[test]
    fn oversized_strides_rejected() {
        let mut p = IpStride::new(IpStrideConfig { max_stride_lines: 8, ..Default::default() });
        let mut out = Vec::new();
        p.observe(obs(3, 0), &mut out);
        p.observe(obs(3, 1000), &mut out);
        p.observe(obs(3, 2000), &mut out);
        p.observe(obs(3, 3000), &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn negative_stride_supported() {
        let mut p = IpStride::new(IpStrideConfig::default());
        let mut out = Vec::new();
        p.observe(obs(9, 100), &mut out);
        p.observe(obs(9, 90), &mut out);
        p.observe(obs(9, 80), &mut out);
        assert_eq!(out, vec![PrefetchReq { line: 70, stream: u32::MAX, to_l1: true }]);
    }
}
