//! L2 adjacent-line prefetcher.
//!
//! On every L2 demand *miss*, pull the other half of the 128-byte aligned
//! line pair (line address XOR 1). Stateless — the simplest of the four
//! MSR-0x1A4 engines, and the reference example of the
//! [`PrefetchEngine`](super::PrefetchEngine) contract.

use super::{Observation, PrefetchContext, PrefetchEngine, PrefetchLevel, PrefetchReq};

/// The adjacent-line engine: completes the 128-byte aligned pair on misses.
pub struct AdjacentLine;

impl AdjacentLine {
    /// Observe a request arriving at L2; `level_hit` mirrors
    /// `PrefetchContext::level_hit` (misses trigger, hits stay silent).
    #[inline]
    pub fn observe(&mut self, obs: Observation, level_hit: bool, out: &mut Vec<PrefetchReq>) {
        if !level_hit {
            out.push(PrefetchReq { line: obs.line ^ 1, stream: u32::MAX, to_l1: false });
        }
    }
}

impl PrefetchEngine for AdjacentLine {
    fn name(&self) -> &'static str {
        "l2-adjacent-line"
    }

    fn level(&self) -> PrefetchLevel {
        PrefetchLevel::L2
    }

    fn observe(
        &mut self,
        obs: Observation,
        ctx: &PrefetchContext<'_>,
        out: &mut Vec<PrefetchReq>,
    ) {
        AdjacentLine::observe(self, obs, ctx.level_hit, out);
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(line: u64) -> Observation {
        Observation { line, ip: 0, miss: true, store: false }
    }

    #[test]
    fn completes_the_pair_on_miss() {
        let none = |_: u32| 0u32;
        let ctx = PrefetchContext { level_hit: false, outstanding: &none };
        let mut a = AdjacentLine;
        let mut out = Vec::new();
        a.observe(obs(10), &ctx, &mut out);
        assert_eq!(out, vec![PrefetchReq { line: 11, stream: u32::MAX, to_l1: false }]);
        out.clear();
        a.observe(obs(11), &ctx, &mut out);
        assert_eq!(out[0].line, 10, "pairing is XOR, not +1");
    }

    #[test]
    fn silent_on_hits() {
        let none = |_: u32| 0u32;
        let ctx = PrefetchContext { level_hit: true, outstanding: &none };
        let mut a = AdjacentLine;
        let mut out = Vec::new();
        a.observe(obs(10), &ctx, &mut out);
        assert!(out.is_empty());
    }
}
