//! Deterministic PRNG (xoshiro256** core) — the simulator and tests need
//! reproducible randomness and no external crates are available.

/// A small, fast, deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so any u64 gives a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e3779b97f4a7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // test workloads.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(2);
        let mut seen = [false; 3];
        for _ in 0..1000 {
            seen[(r.range(5, 7) - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
