//! Minimal property-testing harness (proptest is unavailable offline).
//!
//! [`check`] runs a property against `cases` randomly generated inputs and,
//! on failure, performs a simple halving shrink over the generator's size
//! parameter before panicking with the seed so the case can be replayed.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 128, seed: 0xDEFA17 }
    }
}

/// Run `prop` against `cases` inputs drawn by `gen`. `gen` receives the RNG
/// and a *size* hint that grows over the run (small cases first).
///
/// On failure the harness retries the failing size at smaller sizes to
/// report a smaller counterexample when the generator respects the hint.
pub fn check<T: std::fmt::Debug, G, P>(cfg: Config, mut generate: G, mut prop: P)
where
    G: FnMut(&mut Rng, u32) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        // Size ramps from 1 to 100.
        let size = 1 + (case * 100) / cfg.cases.max(1);
        let input = generate(&mut rng, size);
        if !prop(&input) {
            // Shrink: try progressively smaller sizes with fresh draws.
            let mut smallest = input;
            let mut s = size;
            while s > 1 {
                s /= 2;
                let mut shrink_rng = Rng::new(cfg.seed ^ (s as u64) << 32);
                for _ in 0..16 {
                    let candidate = generate(&mut shrink_rng, s);
                    if !prop(&candidate) {
                        smallest = candidate;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={:#x}, case={case}, size={size}).\ncounterexample: {:?}",
                cfg.seed, smallest
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(Config::default(), |r, size| r.below(size as u64 + 1), |&x| x <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check(
            Config { cases: 64, seed: 7 },
            |r, size| r.below(size as u64 + 1),
            |&x| x < 20, // fails for larger sizes
        );
    }
}
