//! Small self-contained utilities (no third-party crates are available
//! offline): a PCG-style PRNG, summary statistics, a wall-clock timer and a
//! tiny property-testing harness used by the test suite.

pub mod proptest;
pub mod rng;
pub mod stats;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use timer::Timer;
