//! Wall-clock timing for the native probes and the bench harnesses
//! (criterion is not available offline; the bench binaries use this).

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Seconds elapsed.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the previous elapsed seconds.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Measure a closure `reps` times, returning per-rep seconds.
pub fn measure<F: FnMut()>(reps: u32, mut f: F) -> Vec<f64> {
    let mut out = Vec::with_capacity(reps as usize);
    for _ in 0..reps {
        let t = Timer::start();
        f();
        out.push(t.secs());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.secs();
        let b = t.secs();
        assert!(b >= a);
    }

    #[test]
    fn measure_counts_reps() {
        let xs = measure(5, || {});
        assert_eq!(xs.len(), 5);
        assert!(xs.iter().all(|&x| x >= 0.0));
    }
}
