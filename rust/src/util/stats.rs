//! Summary statistics for measurement series (native mode uses median-of-5
//! like the paper; the report layer prints means/medians).

/// Summary of a sample of f64 measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; returns `None` for an empty sample.
    pub fn of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mut sorted: Vec<f64> = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("no NaNs in measurements"));
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        };
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary { n, min, max, mean, median, stddev: var.sqrt() })
    }
}

/// Median of a sample (the paper's reported statistic). Panics on empty.
pub fn median(samples: &[f64]) -> f64 {
    Summary::of(samples).expect("non-empty sample").median
}

/// Geometric mean (used for cross-kernel speedup aggregation).
pub fn geomean(samples: &[f64]) -> f64 {
    assert!(!samples.is_empty());
    let log_sum: f64 = samples.iter().map(|x| x.ln()).sum();
    (log_sum / samples.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[3.0, 1.0, 2.0]).unwrap();
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
        assert!((s.mean - 2.0).abs() < 1e-12);
    }

    #[test]
    fn even_median_averages() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn empty_is_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }
}
