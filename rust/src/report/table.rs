//! ASCII table rendering.

/// A simple column-aligned ASCII table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width must match header");
        self.rows.push(cells);
        self
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a throughput in GiB/s with 2 decimals.
pub fn gib(v: f64) -> String {
    format!("{v:.2}")
}

/// Format a ratio as `1.23x`.
pub fn speedup(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(&["kernel", "GiB/s"]).with_title("demo");
        t.row(vec!["mxv".into(), "12.34".into()]);
        t.row(vec!["jacobi2d".into(), "9.9".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert_eq!(s.lines().count(), 5, "title + header + separator + 2 rows");
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[2].len(), lines[3].len(), "rows equally wide");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Table::new(&["a", "b"]).row(vec!["x".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(gib(1.234), "1.23");
        assert_eq!(speedup(2.5), "2.50x");
    }
}
