//! Rendering experiment results in the paper's formats: ASCII tables that
//! mirror Tables 1–2, series dumps that mirror the figure axes, and CSV
//! export for external plotting.

pub mod csv;
pub mod figures;
pub mod table;

pub use csv::write_csv;
pub use figures::*;
pub use table::Table;
