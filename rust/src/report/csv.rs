//! CSV export of experiment series (for external plotting of the figures).

use std::io::Write;
use std::path::Path;

/// Write rows of f64/string cells as CSV. Creates parent directories.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> crate::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","))?;
    }
    Ok(())
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("multistride_csv_test");
        let path = dir.join("out.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "x,y".into()], vec!["2".into(), "q\"z".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"q\"\"z\""));
        std::fs::remove_dir_all(&dir).ok();
    }
}
