//! Renderers that print each figure's series in the paper's shape.

use crate::coordinator::experiments::{ComparisonRow, KernelPoint, MicroPoint, MICRO_STRIDES};
use crate::kernels::micro::MicroOp;

use super::table::{gib, speedup, Table};

/// Figure 2/5: throughput per op type across stride counts.
pub fn render_micro_grid(points: &[MicroPoint], title: &str) -> String {
    let mut out = String::new();
    for prefetch in [true, false] {
        let mut t = Table::new(
            &std::iter::once("operation")
                .chain(MICRO_STRIDES.iter().map(|s| match s {
                    1 => "1 stride",
                    2 => "2",
                    4 => "4",
                    8 => "8",
                    16 => "16",
                    32 => "32",
                    _ => "?",
                }))
                .collect::<Vec<_>>(),
        )
        .with_title(&format!(
            "{title} — hardware prefetching {} (GiB/s)",
            if prefetch { "ENABLED" } else { "DISABLED" }
        ));
        for op in MicroOp::all() {
            for interleaved in [false, true] {
                let series: Vec<&MicroPoint> = points
                    .iter()
                    .filter(|p| {
                        p.op == op && p.prefetch == prefetch && p.interleaved == interleaved
                    })
                    .collect();
                if series.is_empty() {
                    continue;
                }
                let mut cells = vec![format!(
                    "{}{}",
                    op.label(),
                    if interleaved { " [interleaved]" } else { "" }
                )];
                for &s in &MICRO_STRIDES {
                    let v = series
                        .iter()
                        .find(|p| p.strides == s)
                        .map(|p| gib(p.throughput_gib))
                        .unwrap_or_else(|| "-".into());
                    cells.push(v);
                }
                t.row(cells);
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Figure 3: stall-cycle series for the read micro-benchmark.
pub fn render_stalls(points: &[MicroPoint]) -> String {
    let mut t = Table::new(&[
        "strides",
        "prefetch",
        "cycles (M)",
        "stalls total (M)",
        "w/ L1D miss (M)",
        "w/ L2 miss (M)",
        "w/ L3 miss (M)",
        "L2-miss frac",
        "L3-miss frac",
    ])
    .with_title("Figure 3 — execution stalls with outstanding loads (aligned reads)");
    let m = 1e6;
    for p in points {
        let c = &p.result.counters;
        t.row(vec![
            p.strides.to_string(),
            if p.prefetch { "on" } else { "off" }.into(),
            format!("{:.1}", c.cycles as f64 / m),
            format!("{:.1}", c.stalls_total as f64 / m),
            format!("{:.1}", c.stalls_l1d_miss as f64 / m),
            format!("{:.1}", c.stalls_l2_miss as f64 / m),
            format!("{:.1}", c.stalls_l3_miss as f64 / m),
            format!("{:.2}", c.l2_stall_fraction()),
            format!("{:.2}", c.l3_stall_fraction()),
        ]);
    }
    t.render()
}

/// Figure 4: hit ratios per cache level.
pub fn render_hit_ratios(points: &[MicroPoint]) -> String {
    let mut t = Table::new(&["strides", "prefetch", "L1 hit", "L2 hit", "L3 hit"])
        .with_title("Figure 4 — cache hit ratio per level (aligned reads)");
    for p in points {
        t.row(vec![
            p.strides.to_string(),
            if p.prefetch { "on" } else { "off" }.into(),
            format!("{:.3}", p.result.l1.hit_ratio()),
            format!("{:.3}", p.result.l2.hit_ratio()),
            format!("{:.3}", p.result.l3.hit_ratio()),
        ]);
    }
    t.render()
}

/// Figure 6: a kernel's striding-sweep, one row per (stride, portion).
pub fn render_kernel_sweep(kernel: &str, points: &[KernelPoint]) -> String {
    let mut t = Table::new(&["strides", "portion", "total", "feasible", "GiB/s"])
        .with_title(&format!("Figure 6 — {kernel}: striding optimization space"));
    let mut sorted: Vec<&KernelPoint> = points.iter().collect();
    sorted.sort_by_key(|p| (p.config.stride_unroll, p.config.portion_unroll));
    for p in sorted {
        t.row(vec![
            p.config.stride_unroll.to_string(),
            p.config.portion_unroll.to_string(),
            p.config.total_unrolls().to_string(),
            if p.feasible { "y" } else { "REG" }.into(),
            if p.feasible { gib(p.throughput_gib) } else { "-".into() },
        ]);
    }
    t.render()
}

/// Kernel-universe variant trajectory: one row per kernel, one column per
/// derived family member (S = 1 baseline, then S ∈ {2, 4, 8}), plus the
/// best multi-over-single ratio. Input is [`variant_sweep`]'s point list
/// (`crate::coordinator::experiments::variant_sweep`).
pub fn render_variant_trajectory(points: &[KernelPoint]) -> String {
    // Columns derive from the family definition — a new STRIDE_FAMILY
    // member shows up here without touching this renderer.
    let family: Vec<u32> = std::iter::once(1).chain(crate::transform::STRIDE_FAMILY).collect();
    let header: Vec<String> = std::iter::once("kernel".to_string())
        .chain(family.iter().map(|s| format!("S={s}")))
        .chain(std::iter::once("best multi/single".to_string()))
        .collect();
    let mut t = Table::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>())
        .with_title("Kernel universe — derived variant family throughput (GiB/s)");
    let mut kernels: Vec<&str> = Vec::new();
    for p in points {
        if !kernels.contains(&p.kernel.as_str()) {
            kernels.push(p.kernel.as_str());
        }
    }
    for k in kernels {
        let fam: Vec<&KernelPoint> = points.iter().filter(|p| p.kernel == k).collect();
        let cell = |s: u32| -> String {
            match fam.iter().find(|p| p.config.stride_unroll == s) {
                Some(p) if p.feasible => gib(p.throughput_gib),
                Some(_) => "REG".into(),
                None => "-".into(),
            }
        };
        let single = fam
            .iter()
            .find(|p| p.config.stride_unroll == 1)
            .filter(|p| p.feasible)
            .map(|p| p.throughput_gib);
        let best_multi = fam
            .iter()
            .filter(|p| p.config.stride_unroll > 1 && p.feasible)
            .map(|p| p.throughput_gib)
            .fold(None::<f64>, |acc, v| Some(acc.map_or(v, |a| a.max(v))));
        let ratio = match (single, best_multi) {
            (Some(s), Some(m)) if s > 0.0 => speedup(m / s),
            _ => "-".into(),
        };
        let mut row = vec![k.to_string()];
        row.extend(family.iter().map(|&s| cell(s)));
        row.push(ratio);
        t.row(row);
    }
    t.render()
}

/// Tuner results: one row per tuned kernel — the chosen (S, P), whether
/// the plan came from the cache or a cold search, the predicted
/// throughput, the probe-rung speedup over the single-stride baseline,
/// and the search cost in simulated accesses. The cost columns report
/// *this request's* cost — all zero on cache hits (the persisted plan
/// keeps the original search's provenance); `tune.csv` follows the same
/// convention.
pub fn render_tuning_table(machine: &str, rows: &[crate::tune::TuneOutcome]) -> String {
    let mut t = Table::new(&[
        "kernel",
        "S",
        "P",
        "source",
        "GiB/s",
        "vs single",
        "probe sims",
        "full sims",
        "search cost (Macc)",
    ])
    .with_title(&format!("Tuner — chosen variant per kernel ({machine})"));
    for o in rows {
        let p = &o.plan;
        t.row(vec![
            p.kernel.clone(),
            p.config.stride_unroll.to_string(),
            p.config.portion_unroll.to_string(),
            if o.cache_hit { "cache" } else { "search" }.into(),
            gib(p.predicted_gib),
            p.speedup_over_single().map(speedup).unwrap_or_else(|| "-".into()),
            if o.cache_hit { "0".into() } else { p.probe_runs.to_string() },
            if o.cache_hit { "0".into() } else { p.full_runs.to_string() },
            if o.cache_hit {
                "0.00".into()
            } else {
                format!("{:.2}", p.search_sim_accesses as f64 / 1e6)
            },
        ]);
    }
    t.render()
}

/// A cold search's audit trace: every candidate visited, at which rung
/// and budget, its score, and why it was kept or pruned.
pub fn render_search_trace(kernel: &str, steps: &[crate::tune::SearchStep]) -> String {
    use crate::tune::Verdict;
    let mut t = Table::new(&["rung", "budget (MiB)", "S", "P", "GiB/s", "verdict"])
        .with_title(&format!("Tuner search trace — {kernel}"));
    for s in steps {
        t.row(vec![
            s.rung.to_string(),
            if s.budget == 0 {
                "-".into()
            } else {
                format!("{:.1}", s.budget as f64 / 1048576.0)
            },
            s.config.stride_unroll.to_string(),
            s.config.portion_unroll.to_string(),
            s.score_gib.map(gib).unwrap_or_else(|| "-".into()),
            match s.verdict {
                Verdict::Infeasible => "infeasible (register file)".into(),
                Verdict::Pruned { cutoff_gib } => {
                    format!("pruned (cutoff {cutoff_gib:.2} GiB/s)")
                }
                Verdict::Advanced => "advanced".into(),
                Verdict::Winner => "WINNER".into(),
            },
        ]);
    }
    t.render()
}

/// Figure 7: speedups of the best multi-strided configuration over each
/// reference.
pub fn render_comparison(machine: &str, rows: &[ComparisonRow]) -> String {
    let mut t = Table::new(&["kernel", "reference", "ref GiB/s", "multi-strided GiB/s", "speedup"])
        .with_title(&format!("Figure 7 — comparison with the state of the art ({machine})"));
    for r in rows {
        t.row(vec![
            r.kernel.clone(),
            r.reference.label().into(),
            gib(r.reference_gib),
            gib(r.multistrided_gib),
            speedup(r.speedup()),
        ]);
    }
    t.render()
}

/// One-line execution-layer economy summary (the `[exec]` line `repro`
/// prints after every store-backed command; CI's store-smoke job greps
/// the `store hits:` and `engine runs:` figures out of it, so keep those
/// labels stable).
pub fn render_exec_summary(
    stats: &crate::exec::ExecStats,
    dir: Option<&std::path::Path>,
) -> String {
    let snap = crate::obs::fold_exec_stats(crate::obs::global(), stats);
    render_exec_summary_from(&snap, dir)
}

/// Render the `[exec]` line from a registry snapshot — the single
/// formatter both the summary println and `repro obs report` share, so
/// the greppable line can never drift from the scraped metrics.
pub fn render_exec_summary_from(
    snap: &crate::obs::Snapshot,
    dir: Option<&std::path::Path>,
) -> String {
    let c = |name: &str| snap.counter(name);
    let mut s = format!(
        "[exec] sim points: {} requests, engine runs: {}, store hits: {} (mem {} / disk {}), deduped: {}, written: {}",
        c("exec_requests_total"),
        c("exec_engine_runs_total"),
        c("exec_mem_hits_total") + c("exec_disk_hits_total"),
        c("exec_mem_hits_total"),
        c("exec_disk_hits_total"),
        c("exec_deduped_total"),
        c("exec_disk_writes_total"),
    );
    if c("exec_legacy_hits_total") > 0 {
        s.push_str(&format!(
            ", legacy-shard hits: {} (pack with `repro store compact`)",
            c("exec_legacy_hits_total")
        ));
    }
    if c("exec_corrupt_discards_total") > 0 {
        s.push_str(&format!(", corrupt discards: {}", c("exec_corrupt_discards_total")));
    }
    if c("exec_disk_errors_total") > 0 {
        s.push_str(&format!(", disk errors: {}", c("exec_disk_errors_total")));
    }
    if c("exec_dropped_unsimulatable_total") > 0 {
        s.push_str(&format!(
            ", unsimulatable hits dropped: {}",
            c("exec_dropped_unsimulatable_total")
        ));
    }
    if snap.gauge("store_degraded") != 0 {
        s.push_str(", PERSISTENT TIER DISABLED (memory-only)");
    }
    if c("exec_verified_hits_total") > 0 {
        s.push_str(&format!(", debug-verified hits: {}", c("exec_verified_hits_total")));
    }
    if c("pool_jobs_claimed_total") > 0 {
        s.push_str(&format!(
            ", pool: {} job(s) claimed / {} steal(s)",
            c("pool_jobs_claimed_total"),
            c("pool_steals_total"),
        ));
    }
    if c("grid_fleet_drains_total") > 0 {
        s.push_str(&format!(
            ", fleet: {} result(s) from {} worker(s), {} re-lease(s)",
            c("grid_results_received_total"),
            c("grid_workers_total"),
            c("grid_lease_reassignments_total"),
        ));
    }
    match dir {
        Some(d) => s.push_str(&format!("; results dir: {}", d.display())),
        None => s.push_str("; results dir: (none — cold/ephemeral store)"),
    }
    s.push('\n');
    s
}

/// One-line serving-layer summary (the `[serve]` line the daemon prints
/// on shutdown and serves live at `GET /stats`; CI's serve-smoke job
/// greps the `pool hits:` and `tunes:` figures out of it, so keep those
/// labels stable).
pub fn render_serve_summary(stats: &crate::serve::ServeStats) -> String {
    let snap = crate::obs::fold_serve_stats(crate::obs::global(), stats);
    render_serve_summary_from(&snap, stats.policy.cli_name(), stats.on_miss.cli_name())
}

/// Render the `[serve]` line from a registry snapshot (the numeric
/// half; policy names ride along as strings — they are configuration,
/// not metrics).
pub fn render_serve_summary_from(
    snap: &crate::obs::Snapshot,
    policy: &str,
    on_miss: &str,
) -> String {
    let c = |name: &str| snap.counter(name);
    let requests = c("serve_pool_requests_total");
    let hits = c("serve_pool_hits_total");
    let hit_pct = if requests == 0 { 0.0 } else { 100.0 * hits as f64 / requests as f64 };
    let mut s = format!(
        "[serve] requests: {}, pool hits: {} ({:.1}%), misses: {}, disk plans: {}, \
         tunes: {}, 404s: {}, 400s: {}, evictions: {}, pool: {}/{} B in {} entry(ies), \
         policy: {}, on-miss: {}",
        requests,
        hits,
        hit_pct,
        c("serve_pool_misses_total"),
        c("serve_disk_plans_total"),
        c("serve_tunes_total"),
        c("serve_not_found_total"),
        c("serve_bad_requests_total"),
        c("serve_pool_evictions_total"),
        snap.gauge("serve_pool_bytes"),
        snap.gauge("serve_pool_capacity_bytes"),
        snap.gauge("serve_pool_entries"),
        policy,
        on_miss,
    );
    if c("serve_tune_failures_total") > 0 {
        s.push_str(&format!(", tune failures: {}", c("serve_tune_failures_total")));
    }
    if c("serve_single_flight_waits_total") > 0 {
        s.push_str(&format!(", single-flight waits: {}", c("serve_single_flight_waits_total")));
    }
    if c("serve_pool_oversize_rejects_total") > 0 {
        s.push_str(&format!(", oversize rejects: {}", c("serve_pool_oversize_rejects_total")));
    }
    s.push('\n');
    s
}

/// Counter + gauge table for `repro obs report` — the deterministic
/// half of the registry, in snapshot (lexicographic) order.
pub fn render_obs_counters(entries: &[(String, u64)]) -> String {
    let mut t = Table::new(&["metric", "value"]).with_title("Counters");
    for (name, v) in entries {
        t.row(vec![name.clone(), v.to_string()]);
    }
    t.render()
}

/// Top-spans table for `repro obs report`: one row per span name,
/// sorted by total time (the aggregation [`crate::obs::span::aggregate`]
/// already did).
pub fn render_span_report(aggs: &[crate::obs::SpanAgg]) -> String {
    let mut t =
        Table::new(&["span", "count", "total ms", "mean us", "max us"]).with_title("Top spans");
    for a in aggs {
        t.row(vec![
            a.name.clone(),
            a.count.to_string(),
            format!("{:.3}", a.total_us as f64 / 1000.0),
            a.mean_us().to_string(),
            a.max_us.to_string(),
        ]);
    }
    t.render()
}

/// CSV rows for a micro grid (external plotting).
pub fn micro_csv_rows(points: &[MicroPoint]) -> Vec<Vec<String>> {
    points
        .iter()
        .map(|p| {
            vec![
                p.op.label().to_string(),
                p.strides.to_string(),
                p.interleaved.to_string(),
                p.prefetch.to_string(),
                format!("{:.4}", p.throughput_gib),
                format!("{:.4}", p.result.l1.hit_ratio()),
                format!("{:.4}", p.result.l2.hit_ratio()),
                format!("{:.4}", p.result.l3.hit_ratio()),
                p.result.counters.stalls_total.to_string(),
            ]
        })
        .collect()
}

pub const MICRO_CSV_HEADER: [&str; 9] = [
    "op", "strides", "interleaved", "prefetch", "gib_s", "l1_hit", "l2_hit", "l3_hit",
    "stalls_total",
];

/// figure5.csv: the micro columns prefixed with the machine (the grid is
/// swept over every preset in one invocation) and suffixed with the
/// paper's §4.5 set-collision diagnostics — how many distinct cache sets
/// the stride heads land in per level, and the per-level eviction counts
/// those collisions drive.
pub const FIG5_CSV_HEADER: [&str; 16] = [
    "machine", "op", "strides", "interleaved", "prefetch", "gib_s", "l1_hit", "l2_hit", "l3_hit",
    "stalls_total", "l1_stride_sets", "l2_stride_sets", "l3_stride_sets", "l1_evictions",
    "l2_evictions", "l3_evictions",
];

/// CSV rows for one machine's power-of-two grid ([`FIG5_CSV_HEADER`]).
pub fn figure5_csv_rows(
    machine: &crate::config::MachineConfig,
    bytes: u64,
    points: &[MicroPoint],
) -> Vec<Vec<String>> {
    points
        .iter()
        .zip(micro_csv_rows(points))
        .map(|(p, micro)| {
            let mut row = vec![machine.name.to_string()];
            row.extend(micro);
            for cache in [&machine.l1, &machine.l2, &machine.l3] {
                row.push(cache.stride_head_sets(p.strides, bytes).to_string());
            }
            for level in [&p.result.l1, &p.result.l2, &p.result.l3] {
                row.push(level.evictions.to_string());
            }
            row
        })
        .collect()
}

/// `repro store stats` rendering. The `[store]` labels are grepped by
/// CI's store-smoke job — keep them stable.
pub fn render_store_stats(dir: &std::path::Path, s: &crate::exec::lifecycle::DirStats) -> String {
    let mib = |b: u64| format!("{:.1} MiB", b as f64 / 1048576.0);
    let mut out = format!("[store] dir: {}\n", dir.display());
    out.push_str(&format!(
        "[store] segments: {} ({}, {} sealed)\n",
        s.segments,
        mib(s.segment_bytes),
        s.sealed_segments
    ));
    out.push_str(&format!("[store] live records: {} ({})\n", s.live_records, mib(s.live_bytes)));
    out.push_str(&format!(
        "[store] dead bytes: {} (reclaim with `repro store compact`)\n",
        mib(s.dead_bytes)
    ));
    out.push_str(&format!(
        "[store] legacy shards: {} ({} — fold in with `repro store compact`)\n",
        s.legacy_files,
        mib(s.legacy_bytes)
    ));
    out.push_str(&format!(
        "[store] index: {}\n",
        if s.index_loaded { "loaded" } else { "rebuilt from segment scan" }
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::coordinator::experiments::run_micro;

    #[test]
    fn variant_trajectory_renders_universe() {
        use crate::coordinator::experiments::run_kernel;
        use crate::transform::StridingConfig;
        let m = coffee_lake();
        let mut pts = Vec::new();
        for name in ["mxv", "triad"] {
            for s in [1u32, 2] {
                pts.push(run_kernel(m, name, 1 << 20, StridingConfig::new(s, 1), true).unwrap());
            }
        }
        let out = render_variant_trajectory(&pts);
        assert!(out.contains("mxv") && out.contains("triad"));
        assert!(out.contains("S=8"), "family columns present even when unswept");
    }

    #[test]
    fn tuning_table_and_trace_render() {
        use crate::coordinator::experiments::EngineCache;
        use crate::tune::{search, SearchParams, TuneOutcome};
        let out = search(
            &mut EngineCache::new(),
            coffee_lake(),
            "mxv",
            1 << 21,
            true,
            &SearchParams::default(),
        )
        .unwrap();
        let outcome = TuneOutcome { plan: out.plan, cache_hit: false, steps: out.steps };
        let s = render_tuning_table("Coffee Lake", std::slice::from_ref(&outcome));
        assert!(s.contains("mxv") && s.contains("search"));
        let tr = render_search_trace("mxv", &outcome.steps);
        assert!(tr.contains("WINNER"));
    }

    #[test]
    fn micro_grid_renders() {
        let pts = vec![
            run_micro(coffee_lake(), MicroOp::LoadAligned, 1, 1 << 22, true, false),
            run_micro(coffee_lake(), MicroOp::LoadAligned, 4, 1 << 22, true, false),
        ];
        let s = render_micro_grid(&pts, "Figure 2");
        assert!(s.contains("aligned loads"));
        assert!(s.contains("ENABLED"));
        let s3 = render_stalls(&pts);
        assert!(s3.contains("Figure 3"));
        let s4 = render_hit_ratios(&pts);
        assert!(s4.contains("L2 hit"));
        let rows = micro_csv_rows(&pts);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].len(), MICRO_CSV_HEADER.len());
    }
}
