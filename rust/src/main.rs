//! `repro` — the command-line driver that regenerates every table and
//! figure of *Multi-Strided Access Patterns to Boost Hardware Prefetching*.
//!
//! ```text
//! repro table1                  # Table 1: kernel overview + stride formulas
//! repro table2                  # Table 2: machine presets
//! repro figure2 [--machine M]   # micro-benchmark throughput grid
//! repro figure3 / figure4       # stall cycles / hit ratios
//! repro figure5                 # power-of-two cache-collision grid
//! repro figure6 [--kernel K]    # striding-space sweep per kernel
//! repro figure7 [--kernel K]    # comparison with state-of-the-art models
//! repro sweep --kernel K        # detailed sweep of one kernel
//! repro universe                # kernel registry + derived variant family
//! repro tune [--kernel K]       # auto-tune variants, persist plans (--force re-tunes)
//! repro native                  # real host-memory multi-striding probe
//! repro validate                # load + execute the PJRT artifacts
//! repro all                     # everything (writes results/*.csv too)
//! repro grid --shard k/n        # simulate one shard of the full plan
//! repro store merge A B --into C  # union result stores by content key
//! repro serve --on-miss tune    # plan-serving HTTP daemon (bounded pool)
//! ```

use std::path::PathBuf;

use multistride::config::{MachinePreset, ScaleConfig};
use multistride::coordinator::experiments as exp;
use multistride::exec::ResultStore;
use multistride::kernels::library::{ensure_known_kernel, paper_kernels};
use multistride::kernels::micro::UNROLL_SLOTS;
use multistride::report::{self, figures, table::Table};
use multistride::runtime::{oracle, ArtifactRegistry, Runtime};
use multistride::transform::{stride_profile, transform, StridingConfig};
use multistride::util::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].as_str();
    // `repro store <sub>` owns its own grammar (`--max-bytes`, …), so it
    // is parsed before — and never constructs — the shared result store:
    // lifecycle operations work on the directory itself.
    if cmd == "store" {
        std::process::exit(store_command(&args[1..]));
    }
    // Same shape for the daemon: `repro serve` owns its flags (`--port`,
    // `--policy`, …) and hands the generic ones to Opts::parse.
    if cmd == "serve" {
        std::process::exit(serve_command(&args[1..]));
    }
    // And the observability report: `repro obs report --trace FILE`
    // renders tables from a prior `--trace` run's artifacts.
    if cmd == "obs" {
        std::process::exit(obs_command(&args[1..]));
    }
    // The fleet roles own their flags (`--port`, `--connect`, …);
    // `repro grid --shard k/n` (no role word) stays on the generic path.
    if cmd == "grid"
        && matches!(args.get(1).map(String::as_str), Some("coordinator") | Some("worker"))
    {
        std::process::exit(grid_fleet_command(&args[1..]));
    }
    let opts = Opts::parse(&args[1..]);
    // One result store per invocation: the memory tier spans every
    // command `repro all` chains, so overlapping sweeps dedup in-process
    // and the persistent tier carries results across invocations.
    let store = opts.result_store();
    let result = match cmd {
        "table1" => table1(&opts),
        "table2" => table2(),
        "figure2" => figure2(&opts, &store),
        "figure3" | "figure4" => figure3_4(&opts, &store),
        "figure5" => figure5(&opts, &store),
        "figure6" | "sweep" => figure6(&opts, &store),
        "figure7" => figure7(&opts, &store),
        "universe" => universe(&opts, &store),
        "tune" => tune(&opts, &store),
        "native" => native(&opts),
        "validate" => validate(&opts),
        "run" => run_config(&opts, &store),
        "all" => all(&opts, &store),
        "grid" => grid_cmd(&opts, &store),
        "help" | "--help" | "-h" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command: {other}");
            usage();
            std::process::exit(2);
        }
    };
    // The hit/dedup economy summary: how much engine work this
    // invocation actually performed vs served from the store.
    let stats = store.stats();
    if result.is_ok() && stats.requests > 0 {
        print!("{}", figures::render_exec_summary(&stats, store.dir()));
    }
    if result.is_ok() {
        write_trace_if_requested(&opts);
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Write the `--trace` artifacts after a successful command. Telemetry
/// is never part of the result contract: a failed write warns on
/// stderr and leaves the exit code alone.
fn write_trace_if_requested(opts: &Opts) {
    let Some(path) = &opts.trace else { return };
    match multistride::obs::write_trace_artifacts(path) {
        Ok(a) => println!(
            "[obs] trace: {} ({} span(s)); counters: {}",
            a.trace.display(),
            a.spans,
            a.counters.display(),
        ),
        Err(e) => eprintln!("[obs] trace export failed: {e:#} — results are unaffected"),
    }
}

fn usage() {
    eprintln!(
        "usage: repro <command> [--machine coffee-lake|cascade-lake|zen2] \
         [--kernel NAME] [--smoke] [--max-total N] [--csv DIR] [--artifacts DIR] \
         [--plans DIR] [--results DIR] [--cold] [--force] [--no-prefetch] \
         [--config FILE] [--trace FILE]\n\
         commands: table1 table2 figure2 figure3 figure4 figure5 figure6 figure7 \
         sweep universe tune native validate run all grid store serve obs\n\
         grid:     repro grid --shard k/n [--results DIR]   (one shard of the full plan)\n\
         \u{20}         repro grid coordinator [--port N] [--lease-ms N] [--batch N] [--results DIR]\n\
         \u{20}         repro grid worker --connect HOST:PORT [--batch N] [--results DIR|--cold]\n\
         store:    repro store stats|gc|verify|compact|merge [--results DIR]\n\
         \u{20}         repro store gc --max-bytes N and/or --max-age-days N\n\
         \u{20}         repro store merge SRC... --into DST   (union stores by content key)\n\
         serve:    repro serve [--port N] [--pool-bytes N] [--policy lru|clock|sieve]\n\
         \u{20}         [--on-miss 404|tune] [--max-requests N] [--plans DIR] [--results DIR]\n\
         obs:      repro obs report --trace FILE   (top spans + counters from a --trace run)\n\
         \u{20}         --trace FILE on any command writes Chrome trace events (Perfetto/\n\
         \u{20}         about:tracing) plus a deterministic FILE sibling .counters.json"
    );
}

/// `repro store {stats,gc,verify,compact,merge}`: lifecycle tooling for
/// a persistent results directory. Returns the process exit code:
/// verify exits nonzero when it finds corruption or a semantic
/// mismatch, merge when same-key/different-bytes conflicts were
/// quarantined — so CI and scripts can gate on both.
fn store_command(args: &[String]) -> i32 {
    use multistride::exec::lifecycle::{self, StoreCommand};
    let (cmd, rest) = match lifecycle::parse_store_cli(args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
            return 2;
        }
    };
    let opts = Opts::parse(&rest);
    if opts.cold {
        eprintln!("error: repro store needs a persistent results directory (drop --cold)");
        return 2;
    }
    let dir = opts.results.clone().unwrap_or_else(|| opts.artifacts.join("results"));
    let result: multistride::Result<i32> = match cmd {
        StoreCommand::Stats => {
            print!("{}", figures::render_store_stats(&dir, &lifecycle::dir_stats(&dir)));
            Ok(0)
        }
        StoreCommand::Gc { max_bytes, max_age_days } => {
            lifecycle::gc(&dir, max_bytes, max_age_days).map(|r| {
                println!(
                    "[store] gc: evicted {} record(s), deleted {} legacy shard(s); \
                     {} live record(s) ({}) remain; {} reclaimable via `repro store compact`",
                    r.evicted_records,
                    r.deleted_legacy,
                    r.live_records,
                    bytes_h(r.live_bytes),
                    bytes_h(r.reclaimable_bytes),
                );
                0
            })
        }
        StoreCommand::Verify => {
            lifecycle::verify(&dir, opts.machine.config(), opts.scale()).map(|r| {
                println!(
                    "[store] verify: {} record(s) ok, {} corrupt; {} legacy shard(s) ok, \
                     {} corrupt; canonical plan: {} point(s), {} verified bit-exact, \
                     {} mismatched (healed), {} absent",
                    r.records_ok,
                    r.records_corrupt,
                    r.legacy_ok,
                    r.legacy_corrupt,
                    r.resimulated,
                    r.verified,
                    r.mismatched,
                    r.absent,
                );
                if r.is_clean() {
                    println!("[store] verify: OK");
                    0
                } else {
                    eprintln!("[store] verify: FAILED (store contents diverged)");
                    1
                }
            })
        }
        StoreCommand::Compact => {
            lifecycle::compact(&dir).map(|r| {
                println!(
                    "[store] compact: {} record(s) rewritten, {} dropped, {} legacy shard(s) \
                     migrated ({} deleted); reclaimed {}; now {} segment(s) ({})",
                    r.rewritten,
                    r.dropped,
                    r.migrated_legacy,
                    r.deleted_legacy,
                    bytes_h(r.reclaimed_bytes),
                    r.segments,
                    bytes_h(r.segment_bytes),
                );
                0
            })
        }
        StoreCommand::Merge { sources, into } => {
            multistride::exec::grid::merge(&sources, &into).map(|r| {
                println!(
                    "[store] merge: {} source(s): {} record(s) merged ({} from legacy \
                     shards), {} already present, {} corrupt skipped, {} conflict(s) \
                     quarantined; {} manifest(s) validated, {} corrupt",
                    r.sources,
                    r.merged,
                    r.legacy_folded,
                    r.already_present,
                    r.corrupt_skipped,
                    r.conflicts,
                    r.manifests_seen,
                    r.manifests_corrupt,
                );
                if r.is_clean() {
                    0
                } else {
                    eprintln!(
                        "[store] merge: CONFLICTS — {} record(s) quarantined under {} \
                         (same key, different bytes; never silently chosen)",
                        r.conflicts,
                        into.join(multistride::exec::grid::QUARANTINE_DIR).display(),
                    );
                    1
                }
            })
        }
    };
    match result {
        Ok(code) => code,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// `repro serve`: the plan-serving HTTP daemon. Serves tuned plans and
/// predicted counters out of the plan cache (`--plans DIR`, default
/// `<artifacts>/plans`) through a bounded buffer pool; `--on-miss tune`
/// additionally tunes unknown keys on demand against the result store.
/// Returns the process exit code: 0 after a clean (budgeted) shutdown,
/// 1 on runtime trouble, 2 for a malformed invocation.
fn serve_command(args: &[String]) -> i32 {
    use multistride::serve;
    let (serve_opts, rest) = match serve::parse_serve_cli(args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
            return 2;
        }
    };
    let opts = Opts::parse(&rest);
    let plans = match &opts.plans {
        Some(dir) => multistride::tune::PlanCache::new(dir),
        None => multistride::tune::PlanCache::default_under(&opts.artifacts),
    };
    let store = opts.result_store();
    match serve::run_serve(serve_opts, plans, store) {
        Ok(stats) => {
            print!("{}", figures::render_serve_summary(&stats));
            write_trace_if_requested(&opts);
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// `repro obs report --trace FILE`: render the top-spans table and the
/// counter table from a prior `--trace` run's artifacts. Exit codes
/// follow the CLI contract: 2 for a malformed invocation, 1 when the
/// files cannot be read or parsed.
fn obs_command(args: &[String]) -> i32 {
    match args.first().map(|s| s.as_str()) {
        Some("report") => {}
        Some(other) => {
            eprintln!("error: unknown obs subcommand {other:?} (expected: report)");
            usage();
            return 2;
        }
        None => {
            eprintln!("error: repro obs needs a subcommand: report");
            usage();
            return 2;
        }
    }
    let opts = Opts::parse(&args[1..]);
    let Some(path) = &opts.trace else {
        eprintln!("error: obs report requires --trace FILE (a file written by a --trace run)");
        usage();
        return 2;
    };
    match obs_report(path) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn obs_report(path: &std::path::Path) -> multistride::Result<()> {
    use multistride::obs;
    let text = std::fs::read_to_string(path)
        .map_err(|e| multistride::format_err!("reading trace file {}: {e}", path.display()))?;
    let events = obs::trace::parse_chrome_trace(&text)?;
    let aggs = obs::span::aggregate(events.iter().map(|e| (e.name.as_str(), e.dur_us)));
    println!("{}", figures::render_span_report(&aggs));

    // The sibling counter snapshot rides along when present; a trace
    // file alone still yields the span report.
    let counters = obs::counters_path_for(path);
    match std::fs::read_to_string(&counters) {
        Ok(body) => {
            let entries = obs::export::parse_json_snapshot(&body)?;
            println!("{}", figures::render_obs_counters(&entries));
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            println!("(no counter snapshot at {} — spans only)", counters.display());
        }
        Err(e) => {
            return Err(multistride::format_err!(
                "reading counter snapshot {}: {e}",
                counters.display()
            ))
        }
    }
    Ok(())
}

/// Parsed command-line options.
struct Opts {
    machine: MachinePreset,
    kernel: Option<String>,
    smoke: bool,
    max_total: u32,
    csv_dir: Option<PathBuf>,
    artifacts: PathBuf,
    config: Option<PathBuf>,
    /// MSR-style prefetcher switch for the kernel sweeps (the Figure 6
    /// bicg top-right panel runs with it off).
    prefetch: bool,
    /// Plan-cache directory for `repro tune` (default: `<artifacts>/plans`).
    plans: Option<PathBuf>,
    /// `repro tune --force`: bypass the plan cache and re-search.
    force: bool,
    /// Result-store directory (default: `<artifacts>/results`).
    results: Option<PathBuf>,
    /// `--cold`: run against an ephemeral store — no persistent tier is
    /// read or written, so nothing from previous invocations is served
    /// (in-process dedup across this invocation's commands still applies).
    cold: bool,
    /// `repro grid --shard k/n`: which key-range shard this host owns.
    shard: Option<String>,
    /// `--trace FILE`: write Chrome trace events (plus the sibling
    /// `.counters.json` deterministic snapshot) after a clean run.
    trace: Option<PathBuf>,
}

impl Opts {
    /// The flag's value, or the contract's clean exit: a missing value
    /// is a malformed invocation — report it, print usage, exit 2. (A
    /// `.expect()` here would panic with exit 101 and a backtrace,
    /// which `tests/cli_boundary.rs` pins against.)
    fn require_value<'a>(it: &mut std::slice::Iter<'a, String>, flag: &str) -> &'a String {
        match it.next() {
            Some(v) => v,
            None => {
                eprintln!("error: {flag} needs a value");
                usage();
                std::process::exit(2);
            }
        }
    }

    fn parse(args: &[String]) -> Self {
        let mut o = Opts {
            machine: MachinePreset::CoffeeLake,
            kernel: None,
            smoke: false,
            max_total: 24,
            csv_dir: None,
            artifacts: ArtifactRegistry::default_dir(),
            config: None,
            prefetch: true,
            plans: None,
            force: false,
            results: None,
            cold: false,
            shard: None,
            trace: None,
        };
        let mut it = args.iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--machine" => {
                    let v = Self::require_value(&mut it, "--machine");
                    o.machine = match MachinePreset::from_name_or_listing(v) {
                        Ok(p) => p,
                        Err(e) => {
                            eprintln!("error: {e}");
                            std::process::exit(2);
                        }
                    };
                }
                "--kernel" => o.kernel = Some(Self::require_value(&mut it, "--kernel").clone()),
                "--smoke" => o.smoke = true,
                "--max-total" => {
                    let v = Self::require_value(&mut it, "--max-total");
                    o.max_total = match v.parse() {
                        Ok(n) => n,
                        Err(_) => {
                            eprintln!("error: --max-total needs a number, got {v:?}");
                            usage();
                            std::process::exit(2);
                        }
                    };
                }
                "--csv" => {
                    o.csv_dir = Some(PathBuf::from(Self::require_value(&mut it, "--csv")))
                }
                "--artifacts" => {
                    o.artifacts = PathBuf::from(Self::require_value(&mut it, "--artifacts"))
                }
                "--config" => {
                    o.config = Some(PathBuf::from(Self::require_value(&mut it, "--config")))
                }
                "--plans" => {
                    o.plans = Some(PathBuf::from(Self::require_value(&mut it, "--plans")))
                }
                "--results" => {
                    o.results = Some(PathBuf::from(Self::require_value(&mut it, "--results")))
                }
                "--cold" => o.cold = true,
                "--shard" => o.shard = Some(Self::require_value(&mut it, "--shard").clone()),
                "--trace" => {
                    o.trace = Some(PathBuf::from(Self::require_value(&mut it, "--trace")))
                }
                "--force" => o.force = true,
                "--no-prefetch" => o.prefetch = false,
                other => {
                    eprintln!("unknown option {other}");
                    usage();
                    std::process::exit(2);
                }
            }
        }
        // `--cold` means "no persistent tier at all"; silently ignoring
        // an explicit `--results DIR` alongside it would leave the named
        // directory untouched with no hint why.
        if o.cold && o.results.is_some() {
            eprintln!(
                "error: --cold and --results are mutually exclusive \
                 (--cold runs with no persistent result store; to force a \
                 fresh populate of a store, delete its directory instead)"
            );
            std::process::exit(2);
        }
        o
    }

    fn scale(&self) -> ScaleConfig {
        if self.smoke {
            ScaleConfig::smoke()
        } else {
            ScaleConfig::default()
        }
    }

    /// The invocation's result store: persistent under `--results DIR`
    /// (default `<artifacts>/results`), or memory-only under `--cold`.
    fn result_store(&self) -> ResultStore {
        if self.cold {
            return ResultStore::ephemeral();
        }
        match &self.results {
            Some(dir) => ResultStore::persistent(dir),
            None => ResultStore::default_under(&self.artifacts),
        }
    }
}

/// Table 1: the kernel overview with our computed stride profiles at n=4.
fn table1(opts: &Opts) -> multistride::Result<()> {
    let mut t = Table::new(&[
        "name", "description", "AT", "L", "S", "L/S", "IN", "WB", "LE", "LI", "LB",
        "data (iso/cmp GiB)",
    ])
    .with_title("Table 1 — surveyed compute kernels (stride columns at n=4 via stride_profile)");
    let n = 4u32;
    for pk in paper_kernels(opts.scale().kernel_bytes) {
        let prof =
            transform(&pk.spec, StridingConfig::new(n, 2)).map(|tr| stride_profile(&tr)).ok();
        let (l, s, ls) = prof.map_or((0, 0, 0), |p| (p.loads, p.stores, p.loadstores));
        let yn = |b: bool| if b { "Y" } else { "" }.to_string();
        t.row(vec![
            pk.name.clone(),
            pk.description.into(),
            if pk.aligned { "A" } else { "U" }.into(),
            l.to_string(),
            s.to_string(),
            ls.to_string(),
            yn(pk.has_init),
            yn(pk.has_writeback),
            if pk.loop_embedment > 0 { pk.loop_embedment.to_string() } else { String::new() },
            yn(pk.loop_interchange),
            yn(pk.loop_blocking),
            format!("{}/{}", pk.data_gib.0, pk.data_gib.1),
        ]);
    }
    t.print();
    Ok(())
}

/// Table 2: machine presets.
fn table2() -> multistride::Result<()> {
    let ms: Vec<_> = MachinePreset::all().iter().map(|p| p.config()).collect();
    let mut t = Table::new(&["", "Coffee Lake", "Cascade Lake", "Zen 2"])
        .with_title("Table 2 — simulated micro-architectures");
    let row = |label: &str, f: &dyn Fn(&multistride::config::MachineConfig) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(ms.iter().map(f));
        cells
    };
    t.row(row("Vendor", &|m| m.vendor.into()));
    t.row(row("Model", &|m| m.model.into()));
    t.row(row("Base freq (GHz)", &|m| format!("{:.1}", m.freq_ghz)));
    t.row(row("Bandwidth (GiB/s, paper)", &|m| format!("{:.2}", m.bandwidth_gib)));
    t.row(row("Bandwidth (GiB/s, model roofline)", &|m| format!("{:.2}", m.model_peak_gib())));
    t.row(row("Memory channels", &|m| m.mem_channels.to_string()));
    t.row(row("L1D size/assoc", &|m| {
        format!("{} KiB / {}-way", m.l1.size_bytes / 1024, m.l1.ways)
    }));
    t.row(row("L2 size/assoc", &|m| format!("{} KiB / {}-way", m.l2.size_bytes / 1024, m.l2.ways)));
    t.row(row("L3 size/assoc", &|m| {
        format!("{:.1} MiB / {}-way", m.l3.size_bytes as f64 / 1048576.0, m.l3.ways)
    }));
    t.row(row("RAM (GiB)", &|m| m.ram_gib.to_string()));
    t.row(row("Max FMA (GFLOP/s)", &|m| format!("{:.1}", m.max_fma_gflops)));
    t.print();
    Ok(())
}

fn figure2(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    let m = opts.machine.config();
    let scale = opts.scale();
    let title = format!(
        "Figure 2 — micro-benchmark throughput ({}, {})",
        bytes_h(scale.micro_bytes),
        m.name
    );
    println!(
        "[{} unroll slots over n strides; huge pages; array size is NOT a power of two]",
        UNROLL_SLOTS
    );
    let points = exp::figure2_on(store, m, scale, false);
    print!("{}", figures::render_micro_grid(&points, &title));
    if let Some(dir) = &opts.csv_dir {
        report::write_csv(
            &dir.join("figure2.csv"),
            &figures::MICRO_CSV_HEADER,
            &figures::micro_csv_rows(&points),
        )?;
    }
    Ok(())
}

/// Figure 5: the power-of-two collision grid, swept over ALL machine
/// presets in one invocation — the paper's §4.5 point is that the
/// collision pattern follows the cache geometry, so the three machines
/// belong side by side (`--machine` is ignored here by design). The CSV
/// carries the §4.5 set-collision diagnostics next to the throughput
/// columns.
fn figure5(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    let scale = opts.scale();
    println!(
        "[{} unroll slots over n strides; huge pages; array size IS a power of two; \
         sweeping all machine presets]",
        UNROLL_SLOTS
    );
    let mut rows = Vec::new();
    for preset in MachinePreset::all() {
        let m = preset.config();
        let title = format!(
            "Figure 5 — {} of power-of-two data, {}",
            bytes_h(scale.micro_pow2_bytes),
            m.name
        );
        let points = exp::figure2_on(store, m, scale, true);
        print!("{}", figures::render_micro_grid(&points, &title));
        rows.extend(figures::figure5_csv_rows(&m, scale.micro_pow2_bytes, &points));
    }
    if let Some(dir) = &opts.csv_dir {
        report::write_csv(&dir.join("figure5.csv"), &figures::FIG5_CSV_HEADER, &rows)?;
    }
    Ok(())
}

fn figure3_4(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    let m = opts.machine.config();
    let points = exp::figure3_4_on(store, m, opts.scale());
    print!("{}", figures::render_stalls(&points));
    println!();
    print!("{}", figures::render_hit_ratios(&points));
    if let Some(dir) = &opts.csv_dir {
        report::write_csv(
            &dir.join("figure3_4.csv"),
            &figures::MICRO_CSV_HEADER,
            &figures::micro_csv_rows(&points),
        )?;
    }
    Ok(())
}

fn figure6(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    let m = opts.machine.config();
    let budget = opts.scale().kernel_bytes;
    ensure_known_kernel(opts.kernel.as_deref(), budget)?;
    let kernels: Vec<String> = match &opts.kernel {
        Some(k) => vec![k.clone()],
        None => exp::figure6_kernels(),
    };
    if !opts.prefetch {
        println!("[hardware prefetching DISABLED for this sweep]");
    }
    for k in kernels {
        let points = exp::figure6_on(store, m, &k, budget, opts.max_total, opts.prefetch);
        print!("{}", figures::render_kernel_sweep(&k, &points));
        if let Some(best) = exp::best_point(&points) {
            let single = points
                .iter()
                .filter(|p| p.feasible && p.config.stride_unroll == 1)
                .max_by(|a, b| a.throughput_gib.partial_cmp(&b.throughput_gib).unwrap());
            if let Some(sgl) = single {
                println!(
                    "best multi-strided: s={} p={} -> {:.2} GiB/s ({:.2}x over best single-strided {:.2})\n",
                    best.config.stride_unroll,
                    best.config.portion_unroll,
                    best.throughput_gib,
                    best.throughput_gib / sgl.throughput_gib,
                    sgl.throughput_gib,
                );
            }
        }
        if let Some(dir) = &opts.csv_dir {
            report::write_csv(
                &dir.join(format!("figure6_{k}.csv")),
                &KERNEL_POINT_CSV_HEADER,
                &kernel_point_csv_rows(&points),
            )?;
        }
    }
    Ok(())
}

fn figure7(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    let m = opts.machine.config();
    let budget = opts.scale().kernel_bytes;
    ensure_known_kernel(opts.kernel.as_deref(), budget)?;
    let kernels: Vec<String> = match &opts.kernel {
        Some(k) => vec![k.clone()],
        None => exp::figure7_kernels(),
    };
    let mut all_rows = Vec::new();
    for k in kernels {
        let rows = exp::figure7_on(store, m, &k, budget, opts.max_total);
        print!("{}", figures::render_comparison(m.name, &rows));
        println!();
        all_rows.extend(rows);
    }
    if let Some(dir) = &opts.csv_dir {
        let rows: Vec<Vec<String>> = all_rows
            .iter()
            .map(|r| {
                vec![
                    r.kernel.clone(),
                    r.reference.label().to_string(),
                    format!("{:.4}", r.reference_gib),
                    format!("{:.4}", r.multistrided_gib),
                    format!("{:.4}", r.speedup()),
                ]
            })
            .collect();
        report::write_csv(
            &dir.join("figure7.csv"),
            &["kernel", "reference", "ref_gib_s", "multi_gib_s", "speedup"],
            &rows,
        )?;
    }
    Ok(())
}

/// `repro universe`: the registered kernel universe (family, nest depth,
/// artifact availability) plus each kernel's derived variant-family
/// throughput trajectory. `--kernel NAME` restricts both views.
fn universe(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    let m = opts.machine.config();
    let budget = opts.scale().kernel_bytes;
    let reg = ArtifactRegistry::new(&opts.artifacts);
    ensure_known_kernel(opts.kernel.as_deref(), budget)?;
    let keep = |name: &str| opts.kernel.as_deref().map_or(true, |k| k == name);
    let mut t =
        Table::new(&["kernel", "family", "loops", "footprint (MiB)", "artifact", "description"])
            .with_title("Kernel universe — registry");
    for k in multistride::runtime::kernel_universe(&reg, budget) {
        if !keep(&k.name) {
            continue;
        }
        t.row(vec![
            k.name.clone(),
            match k.family {
                multistride::runtime::KernelFamily::Paper => "paper".into(),
                multistride::runtime::KernelFamily::Extended => "extended".into(),
            },
            k.loop_depth.to_string(),
            format!("{:.1}", k.footprint as f64 / 1048576.0),
            if k.has_artifact { "Y" } else { "" }.into(),
            k.description.into(),
        ]);
    }
    t.print();
    println!();
    // With --kernel, simulate only that kernel's family (not the whole
    // universe followed by a filter).
    let points: Vec<exp::KernelPoint> = match opts.kernel.as_deref() {
        Some(k) => {
            exp::variant_sweep_for_on(store, m, budget, 2, opts.prefetch, &[k.to_string()])
        }
        None => exp::variant_sweep_on(store, m, budget, 2, opts.prefetch),
    };
    print!("{}", figures::render_variant_trajectory(&points));
    if let Some(dir) = &opts.csv_dir {
        report::write_csv(
            &dir.join("universe.csv"),
            &KERNEL_POINT_CSV_HEADER,
            &kernel_point_csv_rows(&points),
        )?;
    }
    Ok(())
}

/// `repro tune`: auto-tune the variant space of one kernel (`--kernel`)
/// or the whole registry, with the simulator as cost model. Winning plans
/// persist to the plan cache (`--plans DIR`, default `<artifacts>/plans`)
/// keyed by (spec hash, machine fingerprint, budget class); repeated
/// invocations are served from the cache unless `--force`.
fn tune(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    use multistride::tune::PlanCache;
    let m = opts.machine.config();
    let budget = opts.scale().kernel_bytes;
    ensure_known_kernel(opts.kernel.as_deref(), budget)?;
    let cache = match &opts.plans {
        Some(dir) => PlanCache::new(dir),
        None => PlanCache::default_under(&opts.artifacts),
    };
    let plans_dir = cache.dir().to_path_buf();
    let kernels: Vec<String> = match &opts.kernel {
        Some(k) => vec![k.clone()],
        None => multistride::runtime::universe_names(budget),
    };
    if !opts.prefetch {
        println!("[hardware prefetching DISABLED for this tuning run]");
    }
    let outcomes =
        exp::tune_kernels_on(store, m, budget, opts.prefetch, &cache, opts.force, &kernels);
    let mut rows = Vec::new();
    let mut failures = 0u32;
    for (name, out) in kernels.iter().zip(outcomes) {
        match out {
            Ok(o) => rows.push(o),
            Err(e) => {
                failures += 1;
                eprintln!("[tune] {name}: FAILED: {e}");
            }
        }
    }
    print!("{}", figures::render_tuning_table(m.name, &rows));
    // With a single kernel requested, show the full search audit trace.
    if opts.kernel.is_some() {
        for o in &rows {
            if o.cache_hit {
                println!(
                    "({}: served from the plan cache — use --force to re-search)",
                    o.plan.kernel
                );
            } else {
                print!("{}", figures::render_search_trace(&o.plan.kernel, &o.steps));
            }
        }
    }
    println!("plans dir: {}", plans_dir.display());
    if let Some(dir) = &opts.csv_dir {
        report::write_csv(&dir.join("tune.csv"), &TUNE_CSV_HEADER, &tune_csv_rows(&rows))?;
    }
    multistride::ensure!(failures == 0, "{failures} kernel(s) failed to tune");
    Ok(())
}

const TUNE_CSV_HEADER: [&str; 10] = [
    "kernel",
    "machine",
    "strides",
    "portion",
    "cache_hit",
    "predicted_gib",
    "speedup_vs_single",
    "probe_runs",
    "full_runs",
    "search_accesses",
];

fn tune_csv_rows(rows: &[multistride::tune::TuneOutcome]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|o| {
            let p = &o.plan;
            vec![
                p.kernel.clone(),
                p.machine.clone(),
                p.config.stride_unroll.to_string(),
                p.config.portion_unroll.to_string(),
                o.cache_hit.to_string(),
                format!("{:.4}", p.predicted_gib),
                p.speedup_over_single()
                    .map(|s| format!("{s:.4}"))
                    .unwrap_or_else(|| "-".into()),
                // Cost columns report THIS request's cost (zero on a
                // hit), matching the rendered table; the plan file keeps
                // the original search's provenance.
                if o.cache_hit { "0".into() } else { p.probe_runs.to_string() },
                if o.cache_hit { "0".into() } else { p.full_runs.to_string() },
                if o.cache_hit { "0".into() } else { p.search_sim_accesses.to_string() },
            ]
        })
        .collect()
}

fn native(opts: &Opts) -> multistride::Result<()> {
    use multistride::native::NativeProbe;
    let probe = if opts.smoke {
        NativeProbe { bytes: 64 * 1024 * 1024, reps: 3 }
    } else {
        NativeProbe::default()
    };
    println!(
        "native probe on this host: {} buffer, median of {} reps",
        bytes_h(probe.bytes as u64),
        probe.reps
    );
    let pts = probe.run(&[1, 2, 4, 8, 16, 32]);
    let mut t = Table::new(&["strides", "read GiB/s", "write GiB/s", "copy GiB/s"])
        .with_title("host multi-striding probe (real hardware, prefetcher state unknown)");
    for p in &pts {
        t.row(vec![
            p.strides.to_string(),
            format!("{:.2}", p.read_gib_s),
            format!("{:.2}", p.write_gib_s),
            format!("{:.2}", p.copy_gib_s),
        ]);
    }
    t.print();
    Ok(())
}

/// Load every artifact, execute it on random inputs, check against the
/// Rust oracles.
fn validate(opts: &Opts) -> multistride::Result<()> {
    let reg = ArtifactRegistry::new(&opts.artifacts);
    let names = reg.list();
    if names.is_empty() {
        multistride::bail!("no artifacts in {:?} — run `make artifacts` first", reg.dir());
    }
    let mut rt = Runtime::new()?;
    println!("PJRT: {}", rt.platform());
    for n in &names {
        rt.load(n, &reg.path_for(n))?;
        println!("loaded {n}");
    }
    let mut rng = Rng::new(0xA07);
    let mut rand_vec = |len: usize| -> Vec<f32> {
        (0..len).map(|_| rng.f64() as f32 - 0.5).collect()
    };

    // Shapes here must match python/compile/aot.py's AOT example shapes.
    let (m, n) = (64usize, 128usize);
    if names.iter().any(|s| s == "mxv") {
        let a = rand_vec(m * n);
        let x = rand_vec(n);
        let got = &rt.execute_f32("mxv", &[(&a, &[m as i64, n as i64]), (&x, &[n as i64])])?[0];
        let want = oracle::mxv(&a, &x, m, n);
        let err = oracle::max_rel_err(got, &want);
        println!("mxv: max rel err {err:.2e}");
        multistride::ensure!(err < 1e-3, "mxv mismatch");
    }
    if names.iter().any(|s| s == "bicg") {
        let a = rand_vec(m * n);
        let r = rand_vec(m);
        let p = rand_vec(n);
        let out = rt.execute_f32(
            "bicg",
            &[(&a, &[m as i64, n as i64]), (&r, &[m as i64]), (&p, &[n as i64])],
        )?;
        let (s_want, q_want) = oracle::bicg(&a, &r, &p, m, n);
        let es = oracle::max_rel_err(&out[0], &s_want);
        let eq = oracle::max_rel_err(&out[1], &q_want);
        println!("bicg: max rel err s={es:.2e} q={eq:.2e}");
        multistride::ensure!(es < 1e-3 && eq < 1e-3, "bicg mismatch");
    }
    if names.iter().any(|s| s == "conv") {
        let (h, w) = (34usize, 66usize);
        let img = rand_vec(h * w);
        let wts = rand_vec(9);
        let got = &rt.execute_f32("conv", &[(&img, &[h as i64, w as i64]), (&wts, &[3, 3])])?[0];
        let mut w9 = [0f32; 9];
        w9.copy_from_slice(&wts);
        let want = oracle::conv3x3(&img, &w9, h, w);
        let err = oracle::max_rel_err(got, &want);
        println!("conv: max rel err {err:.2e}");
        multistride::ensure!(err < 1e-3, "conv mismatch");
    }
    if names.iter().any(|s| s == "jacobi2d") {
        let (h, w) = (32usize, 64usize);
        let a = rand_vec(h * w);
        let got = &rt.execute_f32("jacobi2d", &[(&a, &[h as i64, w as i64])])?[0];
        let want = oracle::jacobi2d(&a, h, w);
        let err = oracle::max_rel_err(got, &want);
        println!("jacobi2d: max rel err {err:.2e}");
        multistride::ensure!(err < 1e-3, "jacobi2d mismatch");
    }
    println!("validate OK ({} artifacts)", names.len());
    Ok(())
}

fn all(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    table1(opts)?;
    println!();
    table2()?;
    println!();
    figure2(opts, store)?;
    // figure3_4's points are a subset of figure2's grid: pure store hits.
    figure3_4(opts, store)?;
    println!();
    figure5(opts, store)?;
    figure6(opts, store)?;
    // figure7 re-summarizes figure6's sweeps and universe re-visits the
    // family configs figure6 covered; with the shared store both format
    // from stored results instead of re-simulating the overlap.
    figure7(opts, store)?;
    universe(opts, store)?;
    // Consume (or, on first run, populate) the persistent plan cache: a
    // re-run of `repro all` serves every kernel's tuned variant from
    // disk, and the search's full-budget rung reads universe's stored
    // measurements through the result store.
    tune(opts, store)?;
    if ArtifactRegistry::new(&opts.artifacts).list().is_empty() {
        println!("(skipping validate: no artifacts built)");
    } else {
        validate(opts)?;
    }
    Ok(())
}

/// `repro grid --shard k/n`: simulate this host's key-range slice of
/// the full `repro all` plan into the persistent store and write its
/// checksummed ownership manifest. Stores populated by disjoint shards
/// union with `repro store merge`, after which `repro all` against the
/// merged directory formats everything without engine work.
fn grid_cmd(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    use multistride::exec::grid;
    let spec = opts.shard.as_deref().ok_or_else(|| {
        multistride::format_err!("grid requires --shard k/n (e.g. --shard 1/2)")
    })?;
    let shard = grid::ShardSpec::parse(spec)?;
    let m = opts.machine.config();
    let points = exp::repro_all_points(m, opts.scale(), opts.max_total, opts.prefetch);
    let report = grid::run_shard(store, shard, &points)?;
    println!(
        "[grid] shard {}: {} of {} plan point(s) owned; manifest {}",
        report.shard.label(),
        report.owned,
        report.plan_points,
        report.manifest.display(),
    );
    Ok(())
}

/// `repro grid coordinator|worker`: the dynamic fleet roles. Parsed
/// before `Opts::parse` (like store/serve/obs) so the roles own their
/// flags; returns the process exit code — 2 for malformed invocations
/// (including a bad `--connect`), 1 for runtime trouble (including an
/// unreachable coordinator).
fn grid_fleet_command(args: &[String]) -> i32 {
    use multistride::grid::{self, FleetRole};
    let (role, rest) = match grid::parse_fleet_cli(args) {
        Ok(v) => v,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
            return 2;
        }
    };
    let opts = Opts::parse(&rest);
    let store = opts.result_store();
    let m = opts.machine.config();
    let points = exp::repro_all_points(m, opts.scale(), opts.max_total, opts.prefetch);
    let result: multistride::Result<()> = match role {
        FleetRole::Coordinator { port, cfg } => (|| {
            if store.dir().is_none() {
                return Err(multistride::format_err!(
                    "grid coordinator appends through a persistent store (drop --cold)"
                ));
            }
            let coord = grid::Coordinator::bind(port)?;
            println!(
                "[grid] coordinator: listening on 127.0.0.1:{} — {} plan point(s), \
                 batch {}, lease {} ms",
                coord.port(),
                points.len(),
                cfg.batch,
                cfg.lease_ms,
            );
            let r = coord.run(&store, &points, &cfg)?;
            println!(
                "[grid] coordinator: drained {} point(s) ({} already present), \
                 {} result(s) from {} worker(s) in {} batch(es), \
                 {} lease(s) reassigned, {} duplicate(s) discarded",
                r.plan_points,
                r.already_present,
                r.results,
                r.workers,
                r.batches,
                r.reassigned,
                r.duplicates,
            );
            Ok(())
        })(),
        FleetRole::Worker { host, port, cfg } => (|| {
            let r = grid::run_worker(&host, port, &store, &points, &cfg)?;
            println!(
                "[grid] worker {}: {} point(s) over {} batch(es){}",
                r.worker_id,
                r.points,
                r.batches,
                if r.abandoned { " — ABANDONED (scripted crash)" } else { "" },
            );
            Ok(())
        })(),
    };
    let stats = store.stats();
    if result.is_ok() && stats.requests > 0 {
        print!("{}", figures::render_exec_summary(&stats, store.dir()));
    }
    if result.is_ok() {
        write_trace_if_requested(&opts);
    }
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// `repro run --config FILE`: a TOML-driven kernel sweep.
fn run_config(opts: &Opts, store: &ResultStore) -> multistride::Result<()> {
    use multistride::config::ExperimentFile;
    let path = opts
        .config
        .clone()
        .ok_or_else(|| multistride::format_err!("run requires --config FILE (see configs/)"))?;
    let file = ExperimentFile::load(&path)?;
    let get_str = |k: &str| file.get("experiment", k).and_then(|v| v.as_str().map(String::from));
    let machine = get_str("machine")
        .and_then(|n| MachinePreset::from_name(&n))
        .unwrap_or(opts.machine)
        .config();
    let kernel = get_str("kernel").unwrap_or_else(|| "mxv".into());
    let max_total = file
        .get("experiment", "max_total")
        .and_then(|v| v.as_int())
        .unwrap_or(opts.max_total as i64) as u32;
    let prefetch =
        file.get("experiment", "prefetch").and_then(|v| v.as_bool()).unwrap_or(true);
    let budget = file
        .get("experiment", "kernel_mib")
        .and_then(|v| v.as_int())
        .map(|m| m as u64 * 1024 * 1024)
        .unwrap_or(opts.scale().kernel_bytes);

    ensure_known_kernel(Some(&kernel), budget)?;
    println!(
        "config {path:?}: kernel={kernel} machine={} max_total={max_total} prefetch={prefetch} budget={}",
        machine.name,
        bytes_h(budget)
    );
    let points = exp::figure6_on(store, machine, &kernel, budget, max_total, prefetch);
    print!("{}", figures::render_kernel_sweep(&kernel, &points));
    if let Some(best) = exp::best_point(&points) {
        println!(
            "best: s={} p={} -> {:.2} GiB/s",
            best.config.stride_unroll, best.config.portion_unroll, best.throughput_gib
        );
    }
    let csv = file.get("report", "csv").and_then(|v| v.as_str().map(String::from));
    if let Some(dir) = csv.filter(|s| !s.is_empty()) {
        report::write_csv(
            &PathBuf::from(dir).join(format!("sweep_{kernel}.csv")),
            &KERNEL_POINT_CSV_HEADER,
            &kernel_point_csv_rows(&points),
        )?;
    }
    Ok(())
}

/// Shared CSV shape for kernel sweep points (figure6 / universe / run).
const KERNEL_POINT_CSV_HEADER: [&str; 5] = ["kernel", "strides", "portion", "feasible", "gib_s"];

fn kernel_point_csv_rows(points: &[exp::KernelPoint]) -> Vec<Vec<String>> {
    points
        .iter()
        .map(|p| {
            vec![
                p.kernel.clone(),
                p.config.stride_unroll.to_string(),
                p.config.portion_unroll.to_string(),
                p.feasible.to_string(),
                format!("{:.4}", p.throughput_gib),
            ]
        })
        .collect()
}

fn bytes_h(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1} GiB", b as f64 / (1u64 << 30) as f64)
    } else {
        format!("{} MiB", b >> 20)
    }
}
