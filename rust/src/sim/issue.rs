//! Issue stage: the core front end of the pipeline.
//!
//! * An **issue cursor** advances by the issue cost per vector access.
//! * Access *i* may not issue before access *i − W* has retired
//!   (out-of-order window of `window_accesses`) — the window gate.
//! * Retirement is in-order: `retire(i) = max(retire(i−1), data_ready(i))`.
//!   The gap between consecutive retirements beyond the issue cost is the
//!   raw material of stall attribution ([`super::stalls`]).

use std::collections::VecDeque;

use super::TICKS;

/// Issue cursor + out-of-order window + in-order retirement.
pub struct IssueUnit {
    /// Out-of-order window in accesses.
    window: usize,
    /// Ticks consumed per access by the issue ports.
    issue_cost: u64,
    /// Issue cursor in ticks.
    cursor: u64,
    /// Last in-order retirement time (ticks).
    last_retire: u64,
    /// Retirement times (ticks) of the last `window` accesses.
    retire_ring: VecDeque<u64>,
}

impl IssueUnit {
    pub fn new(window_accesses: u32, issue_per_cycle: u32) -> Self {
        Self {
            window: window_accesses as usize,
            issue_cost: TICKS / issue_per_cycle as u64,
            cursor: 0,
            last_retire: 0,
            retire_ring: VecDeque::with_capacity(window_accesses as usize + 1),
        }
    }

    /// Ticks one access occupies the issue ports.
    pub fn issue_cost(&self) -> u64 {
        self.issue_cost
    }

    /// Last in-order retirement time (ticks).
    pub fn last_retire(&self) -> u64 {
        self.last_retire
    }

    /// Current issue-cursor position (ticks), ungated.
    pub fn cursor(&self) -> u64 {
        self.cursor
    }

    /// Issue time of the next access: the cursor, gated by the out-of-order
    /// window (the access `window` positions back must have retired).
    pub fn next_issue(&self) -> u64 {
        let mut t = self.cursor;
        if self.retire_ring.len() >= self.window {
            let gate = self.retire_ring[self.retire_ring.len() - self.window];
            if gate > t {
                t = gate;
            }
        }
        t
    }

    /// Retire an access issued at `t_issue` whose data is ready at
    /// `data_ready`. Returns the stall ticks its retirement gap left beyond
    /// the issue cost (0 when retirement kept pace with issue).
    pub fn retire(&mut self, t_issue: u64, data_ready: u64) -> u64 {
        let retire = data_ready.max(self.last_retire);
        let gap = retire - self.last_retire;
        let stall_ticks = gap.saturating_sub(self.issue_cost);
        self.last_retire = retire;
        self.retire_ring.push_back(retire);
        if self.retire_ring.len() > self.window {
            self.retire_ring.pop_front();
        }
        self.cursor = t_issue + self.issue_cost;
        stall_ticks
    }

    /// Force the retirement cursor forward (a fence waiting on outstanding
    /// work). Does not touch the window ring or the issue cursor.
    pub fn force_retire(&mut self, t: u64) {
        self.last_retire = t;
    }

    /// Rebase all internal timestamps so the current cursor becomes t = 0
    /// (the warmup-then-measure protocol). Returns the subtracted offset.
    pub fn rebase(&mut self) -> u64 {
        let t0 = self.cursor;
        self.cursor = 0;
        self.last_retire = self.last_retire.saturating_sub(t0);
        for r in &mut self.retire_ring {
            *r = r.saturating_sub(t0);
        }
        t0
    }

    /// Cold state, keeping the configuration.
    pub fn reset(&mut self) {
        self.cursor = 0;
        self.last_retire = 0;
        self.retire_ring.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit(window: u32) -> IssueUnit {
        IssueUnit::new(window, 2) // issue cost = 2 ticks
    }

    #[test]
    fn cursor_advances_by_issue_cost() {
        let mut u = unit(4);
        assert_eq!(u.next_issue(), 0);
        u.retire(0, 0);
        assert_eq!(u.next_issue(), 2);
        u.retire(2, 2);
        assert_eq!(u.next_issue(), 4);
    }

    #[test]
    fn window_gates_issue_on_oldest_unretired() {
        let mut u = unit(2);
        // Two slow accesses retire far in the future.
        u.retire(0, 100);
        u.retire(2, 200);
        // The next access may not issue before access (i-2) retired at 100.
        assert_eq!(u.next_issue(), 100.max(u.cursor()));
    }

    #[test]
    fn retirement_is_in_order() {
        let mut u = unit(8);
        u.retire(0, 50);
        // Data ready earlier than the previous retirement still retires
        // after it (in-order).
        u.retire(2, 10);
        assert_eq!(u.last_retire(), 50);
    }

    #[test]
    fn stall_ticks_exclude_issue_cost() {
        let mut u = unit(8);
        // Gap of 10 ticks, issue cost 2: 8 stall ticks.
        assert_eq!(u.retire(0, 10), 8);
        // Back-to-back retirement at the issue rate: no stall.
        assert_eq!(u.retire(2, 10), 0);
        assert_eq!(u.retire(4, 12), 0);
    }

    #[test]
    fn rebase_shifts_everything() {
        let mut u = unit(4);
        u.retire(0, 40);
        let t0 = u.rebase();
        assert_eq!(t0, 2);
        assert_eq!(u.cursor(), 0);
        assert_eq!(u.last_retire(), 38);
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut u = unit(4);
        u.retire(0, 100);
        u.reset();
        assert_eq!(u.next_issue(), 0);
        assert_eq!(u.last_retire(), 0);
    }
}
