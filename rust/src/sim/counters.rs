//! `perf`-style event counters.
//!
//! The paper's Figures 3 and 4 are built from Intel performance events:
//!
//! * `CYCLE_ACTIVITY.STALLS_TOTAL` — stall cycles;
//! * `CYCLE_ACTIVITY.STALLS_MEM_ANY` — stalls with ≥1 outstanding load;
//! * `CYCLE_ACTIVITY.STALLS_L1D_MISS` / `STALLS_L2_MISS` / `STALLS_L3_MISS`
//!   — stalls with an outstanding load that missed L1/L2/L3;
//! * per-level hit ratios from the `MEM_LOAD_RETIRED.*` family.
//!
//! The simulator attributes each retirement-gap to the deepest level the
//! blocking access had to reach, mirroring the subset semantics of those
//! events (`STALLS_L3_MISS ⊆ STALLS_L2_MISS ⊆ STALLS_L1D_MISS ⊆ MEM_ANY ⊆
//! TOTAL`).

/// Aggregated event counts over one simulated run. All cycle values are in
/// core cycles of the simulated machine.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Counters {
    /// Total cycles of the run (fence-to-fence).
    pub cycles: u64,
    /// Stall cycles (no retirement progress).
    pub stalls_total: u64,
    /// Stall cycles with at least one outstanding memory load.
    pub stalls_mem_any: u64,
    /// Stall cycles while an outstanding load had missed L1D.
    pub stalls_l1d_miss: u64,
    /// … had missed L2.
    pub stalls_l2_miss: u64,
    /// … had missed L3.
    pub stalls_l3_miss: u64,

    /// Retired vector memory accesses.
    pub accesses: u64,
    /// Bytes moved by loads.
    pub bytes_read: u64,
    /// Bytes moved by stores.
    pub bytes_written: u64,

    /// Demand reads satisfied from DRAM (after any prefetch merge).
    pub dram_demand_lines: u64,
    /// Lines brought by prefetch engines.
    pub prefetch_lines: u64,
    /// Demand accesses that merged with an in-flight prefetch.
    pub prefetch_merges: u64,
    /// Added cycles spent in TLB misses/walks.
    pub tlb_cycles: u64,
}

impl Counters {
    /// Fraction of stall cycles attributable to outstanding L2 misses —
    /// one of the Figure 3 series.
    pub fn l2_stall_fraction(&self) -> f64 {
        if self.stalls_total == 0 {
            0.0
        } else {
            self.stalls_l2_miss as f64 / self.stalls_total as f64
        }
    }

    /// Fraction of stall cycles attributable to outstanding L3 misses.
    pub fn l3_stall_fraction(&self) -> f64 {
        if self.stalls_total == 0 {
            0.0
        } else {
            self.stalls_l3_miss as f64 / self.stalls_total as f64
        }
    }

    /// Total bytes moved.
    pub fn bytes(&self) -> u64 {
        self.bytes_read + self.bytes_written
    }

    /// Check the event-subset invariant the hardware events obey.
    pub fn subset_invariant_holds(&self) -> bool {
        self.stalls_l3_miss <= self.stalls_l2_miss
            && self.stalls_l2_miss <= self.stalls_l1d_miss
            && self.stalls_l1d_miss <= self.stalls_mem_any
            && self.stalls_mem_any <= self.stalls_total
            && self.stalls_total <= self.cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_of_zero_are_zero() {
        let c = Counters::default();
        assert_eq!(c.l2_stall_fraction(), 0.0);
        assert_eq!(c.l3_stall_fraction(), 0.0);
        assert!(c.subset_invariant_holds());
    }

    #[test]
    fn subset_invariant_detects_violation() {
        let c = Counters { cycles: 10, stalls_total: 5, stalls_mem_any: 6, ..Default::default() };
        assert!(!c.subset_invariant_holds());
    }
}
