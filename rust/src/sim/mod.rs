//! The timestamp-driven memory-subsystem simulator, structured as a
//! layered pipeline.
//!
//! [`engine::Engine`] consumes an [`crate::trace::Access`] stream and plays
//! it against the modeled hierarchy ([`crate::mem`]) and prefetch engines
//! ([`crate::prefetch`]), producing throughput and `perf`-style counters
//! ([`counters::Counters`]).
//!
//! The simulator is *trace driven* and *timestamp based* rather than
//! cycle-stepped: each access resolves to a completion timestamp by walking
//! the hierarchy, memory-level parallelism is bounded by the line-fill
//! buffers and the out-of-order window, and DRAM serializes transfers
//! through a bandwidth-limited service cursor. This keeps full-footprint
//! runs (millions of vector accesses per configuration) in the tens of
//! milliseconds while preserving the structural effects the paper measures.
//!
//! ## Pipeline stages
//!
//! Each access flows **issue → fill → stall**, one module per stage:
//!
//! * [`issue`] — the core front end: the issue cursor, the out-of-order
//!   window gate, and in-order retirement. Produces, per access, its issue
//!   time and the retirement gap left over after the issue cost.
//! * [`fills`] — everything outstanding: the in-flight fill map keyed by
//!   line address, line-fill-buffer occupancy for demand misses, per-stream
//!   prefetch budgets, and the bounded lazy harvest of landed fills.
//! * [`stalls`] — stall attribution: retirement gaps are charged to the
//!   deepest level the blocking access reached, emulating the
//!   `CYCLE_ACTIVITY.STALLS_*` event family ([`counters`]).
//! * [`engine`] — the orchestrator: owns the cache/TLB/DRAM models and the
//!   [`crate::prefetch::PrefetchEngine`] set, and walks each access through
//!   the stages above.
//!
//! Traces stay fully streaming end to end: [`Engine::run`] takes any
//! `IntoIterator<Item = Access>` ([`crate::trace::TraceCursor`],
//! [`crate::kernels::micro::MicroBench::trace`], …) and never materializes
//! a `Vec<Access>`.
//!
//! Engines are reusable across runs: [`Engine::reset`] restores cold state
//! bit-identically to a fresh construction, and [`Engine::prepare`]
//! additionally applies a new [`EngineConfig`] while keeping the existing
//! cache/TLB/DRAM allocations — the [`crate::coordinator`] sweeps lean on
//! this to avoid rebuilding the hierarchy for every sweep point.

pub mod counters;
pub mod engine;
pub mod fills;
pub mod hierarchy;
pub mod issue;
pub mod stalls;

// Only the orchestration surface is re-exported; the pipeline-stage types
// stay behind their modules (`sim::fills`, `sim::issue`, …) so external
// code does not couple to the decomposition's internals.
pub use counters::Counters;
pub use engine::Engine;

use crate::config::MachineConfig;
use crate::prefetch::PrefetchConfig;

/// Ticks per core cycle (issue-slot resolution): time advances in
/// *ticks* = 1/4 core cycle so a 2-accesses-per-cycle issue rate is
/// expressible exactly.
pub const TICKS: u64 = 4;

/// Engine construction parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EngineConfig {
    /// The simulated machine (caches, DRAM, prefetchers, core limits).
    pub machine: MachineConfig,
    /// Prefetch configuration — override of `machine.prefetch`, so the
    /// MSR-style enable bit can be flipped per run.
    pub prefetch: PrefetchConfig,
    /// Use huge pages for address translation (the paper's §4 setting).
    pub huge_pages: bool,
}

impl EngineConfig {
    pub fn new(machine: MachineConfig) -> Self {
        Self { machine, prefetch: machine.prefetch, huge_pages: false }
    }

    pub fn with_prefetch(mut self, enabled: bool) -> Self {
        self.prefetch.enabled = enabled;
        self
    }

    pub fn with_huge_pages(mut self, huge: bool) -> Self {
        self.huge_pages = huge;
        self
    }
}

/// Result of one simulated run.
///
/// This is also the observability boundary: the engine aggregates its
/// counters here with plain `u64`s, and `crate::obs::fold_run_result`
/// folds the finished struct into the metrics registry once per run —
/// the per-access hot path never sees an atomic or a lock.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub counters: Counters,
    pub l1: crate::mem::cache::CacheStats,
    pub l2: crate::mem::cache::CacheStats,
    pub l3: crate::mem::cache::CacheStats,
    pub dram: crate::mem::dram::DramStats,
    pub wc: crate::mem::writebuffer::WcStats,
    pub tlb: crate::mem::tlb::TlbStats,
    pub streamer: crate::prefetch::streamer::StreamerStats,
    /// Locked frequency the cycle counts convert with.
    pub freq_ghz: f64,
}

impl RunResult {
    /// Achieved throughput over the run in GiB/s (the paper's unit:
    /// gigibytes of *program data* moved per second).
    pub fn throughput_gib(&self) -> f64 {
        if self.counters.cycles == 0 {
            return 0.0;
        }
        let secs = self.counters.cycles as f64 / (self.freq_ghz * 1e9);
        self.counters.bytes() as f64 / (1u64 << 30) as f64 / secs
    }
}
