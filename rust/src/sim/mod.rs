//! The timestamp-driven memory-subsystem simulator.
//!
//! [`engine::Engine`] consumes an [`crate::trace::Access`] stream and plays
//! it against the modeled hierarchy ([`crate::mem`]) and prefetch engines
//! ([`crate::prefetch`]), producing throughput and `perf`-style counters
//! ([`counters::Counters`]).
//!
//! The simulator is *trace driven* and *timestamp based* rather than
//! cycle-stepped: each access resolves to a completion timestamp by walking
//! the hierarchy, memory-level parallelism is bounded by the line-fill
//! buffers and the out-of-order window, and DRAM serializes transfers
//! through a bandwidth-limited service cursor. This keeps full-footprint
//! runs (millions of vector accesses per configuration) in the tens of
//! milliseconds while preserving the structural effects the paper measures.

pub mod counters;
pub mod engine;

pub use counters::Counters;
pub use engine::{Engine, EngineConfig, RunResult};
