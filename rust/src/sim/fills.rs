//! Fill stage: everything outstanding between the core and DRAM.
//!
//! Demand misses and prefetches enter an **in-flight map** keyed by line
//! address. A later demand to an in-flight line **merges**: it completes
//! when the fill lands. Demand misses additionally occupy a **line-fill
//! buffer**; with all `lfb_entries` occupied a new miss waits for the
//! earliest outstanding fill. Completed fills are *harvested lazily* —
//! handed back to the engine the next time the line is touched, plus
//! periodic bounded sweeps — which is exact for a single-core trace.
//!
//! The tracker also carries the per-stream outstanding-prefetch budgets the
//! L2 streamer consults (cleaned amortized, every 32 observations).

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-shift hasher for line-address keys (§Perf: the inflight map is
/// on the hot path; SipHash costs ~3× more than the whole lookup).
#[derive(Default)]
pub struct LineHasher(u64);

impl Hasher for LineHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x9e3779b97f4a7c15);
        }
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        let h = v.wrapping_mul(0x9e3779b97f4a7c15);
        self.0 = h ^ (h >> 29);
    }
}

/// Hot-path map from line address to value.
pub type LineMap<V> = HashMap<u64, V, BuildHasherDefault<LineHasher>>;

/// Where a fill is headed once it lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FillDest {
    /// Demand fill: installs L1 + L2 + L3.
    Demand,
    /// Streamer prefetch: installs L2 + L3.
    PrefetchL2,
    /// DCU prefetch: installs L1 (+L2).
    PrefetchL1,
}

/// One outstanding line transfer. (The originating stream slot is not
/// recorded here: per-stream budget accounting lives in the tracker's
/// `stream_outstanding` table, charged at insert time.)
#[derive(Debug, Clone, Copy)]
pub struct Fill {
    /// Completion time in ticks.
    pub complete_ticks: u64,
    pub dest: FillDest,
    /// Store intent (RFO): install dirty.
    pub dirty: bool,
    /// A demand access already merged with this fill. Subsequent demands to
    /// the same line are *fill-buffer hits* and count as L1 hits — the
    /// mechanism behind Figure 4's 0.5 L1 ratio (first half of each line
    /// misses, second half hits the LFB).
    pub demanded: bool,
}

/// Outcome of merging a demand access with an in-flight fill.
#[derive(Debug, Clone, Copy)]
pub struct Merge {
    pub complete_ticks: u64,
    pub dest: FillDest,
    /// The fill had already absorbed a demand before this one.
    pub already_demanded: bool,
}

/// In-flight map + LFB occupancy + per-stream budgets + lazy harvest.
pub struct FillTracker {
    /// In-flight fills keyed by line address.
    inflight: LineMap<Fill>,
    /// Outstanding *demand* fill completion times (ticks).
    lfb: Vec<u64>,
    lfb_entries: usize,
    /// Outstanding prefetch completion ticks per streamer slot.
    stream_outstanding: Vec<Vec<u64>>,
    /// Accesses since the last completed-fill sweep.
    sweep_counter: u32,
    /// Observations since the last outstanding-prefetch cleanup.
    clean_counter: u32,
}

/// Bounded lazy sweep period in accesses.
const SWEEP_PERIOD: u32 = 512;
/// Outstanding-prefetch cleanup period in L2 observations.
const CLEAN_PERIOD: u32 = 32;

impl FillTracker {
    pub fn new(lfb_entries: u32, stream_slots: u32) -> Self {
        Self {
            inflight: LineMap::with_capacity_and_hasher(1024, Default::default()),
            lfb: Vec::with_capacity(lfb_entries as usize + 1),
            lfb_entries: lfb_entries as usize,
            stream_outstanding: vec![Vec::new(); stream_slots as usize],
            sweep_counter: 0,
            clean_counter: 0,
        }
    }

    /// Is any transfer outstanding for `line`?
    pub fn is_inflight(&self, line: u64) -> bool {
        self.inflight.contains_key(&line)
    }

    /// Harvest the fill for `line` if it has completed by `t`.
    pub fn take_completed(&mut self, line: u64, t: u64) -> Option<Fill> {
        let f = self.inflight.get(&line).copied()?;
        if f.complete_ticks <= t {
            self.inflight.remove(&line);
            Some(f)
        } else {
            None
        }
    }

    /// Merge a demand access into the in-flight fill for `line`, if any:
    /// the fill absorbs store intent and records that a demand touched it.
    pub fn merge_demand(&mut self, line: u64, is_store: bool) -> Option<Merge> {
        let f = self.inflight.get_mut(&line)?;
        let m = Merge {
            complete_ticks: f.complete_ticks,
            dest: f.dest,
            already_demanded: f.demanded,
        };
        f.dirty |= is_store;
        f.demanded = true;
        Some(m)
    }

    /// Acquire a line-fill buffer for a demand miss wanting to start at
    /// `t`: with all entries occupied, the miss waits for the earliest
    /// outstanding fill. Returns the effective start time.
    pub fn lfb_acquire(&mut self, t: u64) -> u64 {
        if self.lfb.len() < self.lfb_entries {
            return t;
        }
        let (idx, &earliest) =
            self.lfb.iter().enumerate().min_by_key(|(_, &c)| c).expect("lfb non-empty");
        self.lfb.swap_remove(idx);
        earliest.max(t)
    }

    /// Record a demand fill completing at `complete` ticks.
    pub fn insert_demand(&mut self, line: u64, complete: u64, dirty: bool) {
        self.lfb.push(complete);
        self.inflight.insert(
            line,
            Fill { complete_ticks: complete, dest: FillDest::Demand, dirty, demanded: true },
        );
    }

    /// Record an L1 (DCU) prefetch completing at `complete` ticks.
    pub fn insert_prefetch_l1(&mut self, line: u64, complete: u64) {
        self.inflight.insert(
            line,
            Fill {
                complete_ticks: complete,
                dest: FillDest::PrefetchL1,
                dirty: false,
                demanded: false,
            },
        );
    }

    /// Record an L2 (streamer/adjacent) prefetch completing at `complete`
    /// ticks, charged against the stream slot's outstanding budget.
    pub fn insert_prefetch_l2(&mut self, line: u64, complete: u64, stream: u32) {
        if let Some(slot) = self.stream_outstanding.get_mut(stream as usize) {
            slot.push(complete);
        }
        self.inflight.insert(
            line,
            Fill {
                complete_ticks: complete,
                dest: FillDest::PrefetchL2,
                dirty: false,
                demanded: false,
            },
        );
    }

    /// Live outstanding prefetches for a stream slot at time `t`.
    pub fn outstanding(&self, slot: u32, t: u64) -> u32 {
        self.stream_outstanding
            .get(slot as usize)
            .map_or(0, |v| v.iter().filter(|&&c| c > t).count() as u32)
    }

    /// Amortized cleanup of completed outstanding entries so budgets free
    /// up — §Perf: every [`CLEAN_PERIOD`] observations instead of per-
    /// observation; [`FillTracker::outstanding`] counts live entries
    /// exactly regardless.
    pub fn maybe_clean_outstanding(&mut self, t: u64) {
        self.clean_counter += 1;
        if self.clean_counter >= CLEAN_PERIOD {
            self.clean_counter = 0;
            for s in &mut self.stream_outstanding {
                s.retain(|&c| c > t);
            }
        }
    }

    /// Advance the lazy-sweep counter; `true` once per [`SWEEP_PERIOD`]
    /// accesses, telling the engine to run [`FillTracker::collect_completed`].
    pub fn tick_sweep(&mut self) -> bool {
        self.sweep_counter += 1;
        if self.sweep_counter >= SWEEP_PERIOD {
            self.sweep_counter = 0;
            true
        } else {
            false
        }
    }

    /// Remove every fill completed by `t`, appending them to `landed` for
    /// the engine to install.
    pub fn collect_completed(&mut self, t: u64, landed: &mut Vec<(u64, Fill)>) {
        self.inflight.retain(|&line, f| {
            if f.complete_ticks <= t {
                landed.push((line, *f));
                false
            } else {
                true
            }
        });
    }

    /// Nothing in flight (post-fence invariant).
    pub fn is_empty(&self) -> bool {
        self.inflight.is_empty()
    }

    /// Shift all timestamps down by `t0` (warmup-then-measure rebase).
    pub fn rebase(&mut self, t0: u64) {
        for f in self.inflight.values_mut() {
            f.complete_ticks = f.complete_ticks.saturating_sub(t0);
        }
        for l in &mut self.lfb {
            *l = l.saturating_sub(t0);
        }
        for s in &mut self.stream_outstanding {
            for t in s.iter_mut() {
                *t = t.saturating_sub(t0);
            }
        }
    }

    /// Cold state; optionally resize the stream-slot table (engine reuse
    /// under a different streamer configuration).
    pub fn reset(&mut self, stream_slots: u32) {
        self.inflight.clear();
        self.lfb.clear();
        if self.stream_outstanding.len() != stream_slots as usize {
            self.stream_outstanding.resize(stream_slots as usize, Vec::new());
        }
        for s in &mut self.stream_outstanding {
            s.clear();
        }
        self.sweep_counter = 0;
        self.clean_counter = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfb_gate_waits_for_earliest_when_full() {
        let mut f = FillTracker::new(2, 4);
        f.insert_demand(1, 100, false);
        f.insert_demand(2, 60, false);
        // Pool full: the next miss at t=10 waits for the earliest (60).
        assert_eq!(f.lfb_acquire(10), 60);
        // One slot was freed by the acquire.
        assert_eq!(f.lfb_acquire(10), 10);
    }

    #[test]
    fn lfb_gate_passes_through_when_free() {
        let mut f = FillTracker::new(2, 4);
        assert_eq!(f.lfb_acquire(42), 42);
    }

    #[test]
    fn merge_accumulates_store_intent_and_demand() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(7, 500, 0);
        let m1 = f.merge_demand(7, false).unwrap();
        assert_eq!(m1.dest, FillDest::PrefetchL2);
        assert!(!m1.already_demanded);
        let m2 = f.merge_demand(7, true).unwrap();
        assert!(m2.already_demanded, "second demand sees the first");
        let fill = f.take_completed(7, 500).unwrap();
        assert!(fill.dirty, "RFO merge marked the fill dirty");
        assert!(fill.demanded);
    }

    #[test]
    fn take_completed_respects_time() {
        let mut f = FillTracker::new(8, 4);
        f.insert_demand(3, 100, false);
        assert!(f.take_completed(3, 99).is_none());
        assert!(f.is_inflight(3));
        assert!(f.take_completed(3, 100).is_some());
        assert!(!f.is_inflight(3));
    }

    #[test]
    fn outstanding_counts_only_live_entries() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(1, 50, 2);
        f.insert_prefetch_l2(2, 150, 2);
        assert_eq!(f.outstanding(2, 100), 1);
        assert_eq!(f.outstanding(2, 10), 2);
        assert_eq!(f.outstanding(2, 200), 0);
        // Out-of-range slot is an empty budget.
        assert_eq!(f.outstanding(99, 0), 0);
    }

    #[test]
    fn collect_completed_drains_landed_fills() {
        let mut f = FillTracker::new(8, 4);
        f.insert_demand(1, 10, false);
        f.insert_demand(2, 99, false);
        let mut landed = Vec::new();
        f.collect_completed(50, &mut landed);
        assert_eq!(landed.len(), 1);
        assert_eq!(landed[0].0, 1);
        assert!(f.is_inflight(2));
        landed.clear();
        f.collect_completed(u64::MAX, &mut landed);
        assert!(f.is_empty());
    }

    #[test]
    fn sweep_ticks_once_per_period() {
        let mut f = FillTracker::new(8, 4);
        let fired = (0..2 * SWEEP_PERIOD).filter(|_| f.tick_sweep()).count();
        assert_eq!(fired, 2);
    }

    #[test]
    fn reset_resizes_stream_table() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(1, 50, 2);
        f.reset(6);
        assert_eq!(f.outstanding(2, 0), 0);
        assert_eq!(f.outstanding(5, 0), 0);
    }
}
