//! Fill stage: everything outstanding between the core and DRAM.
//!
//! Demand misses and prefetches enter an **in-flight map** keyed by line
//! address. A later demand to an in-flight line **merges**: it completes
//! when the fill lands. Demand misses additionally occupy a **line-fill
//! buffer**; with all `lfb_entries` occupied a new miss waits for the
//! earliest outstanding fill. Completed fills are *harvested lazily* —
//! handed back to the engine the next time the line is touched, plus
//! periodic bounded sweeps — which is exact for a single-core trace.
//!
//! The tracker also carries the per-stream outstanding-prefetch budgets the
//! L2 streamer consults (cleaned amortized, every 32 observations).
//!
//! §Perf: two hot-path accelerators live here (see ARCHITECTURE.md §Perf):
//!
//! * [`FillTracker::maybe_completed`] — a monotone lower bound on the
//!   earliest in-flight completion time. While `t` is below it (or nothing
//!   is in flight), [`FillTracker::take_completed`] can only return `None`,
//!   so the engine skips the per-access HashMap probe entirely.
//! * Per-stream budgets are **sorted completion rings** ([`VecDeque`]s in
//!   ascending completion order). [`FillTracker::outstanding`] answers the
//!   streamer's budget query from the ring ends in O(1) in the common cases
//!   (nothing expired / everything expired) and O(log budget) otherwise,
//!   replacing the old per-query O(n) filter-count scan of an unsorted Vec.

use std::collections::{HashMap, VecDeque};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-shift hasher for line-address keys (§Perf: the inflight map is
/// on the hot path; SipHash costs ~3× more than the whole lookup).
#[derive(Default)]
pub struct LineHasher(u64);

impl Hasher for LineHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x9e3779b97f4a7c15);
        }
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        let h = v.wrapping_mul(0x9e3779b97f4a7c15);
        self.0 = h ^ (h >> 29);
    }
}

/// Hot-path map from line address to value.
pub type LineMap<V> = HashMap<u64, V, BuildHasherDefault<LineHasher>>;

/// Where a fill is headed once it lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FillDest {
    /// Demand fill: installs L1 + L2 + L3.
    Demand,
    /// Streamer prefetch: installs L2 + L3.
    PrefetchL2,
    /// DCU prefetch: installs L1 (+L2).
    PrefetchL1,
}

/// One outstanding line transfer. (The originating stream slot is not
/// recorded here: per-stream budget accounting lives in the tracker's
/// `stream_outstanding` table, charged at insert time.)
#[derive(Debug, Clone, Copy)]
pub struct Fill {
    /// Completion time in ticks.
    pub complete_ticks: u64,
    pub dest: FillDest,
    /// Store intent (RFO): install dirty.
    pub dirty: bool,
    /// A demand access already merged with this fill. Subsequent demands to
    /// the same line are *fill-buffer hits* and count as L1 hits — the
    /// mechanism behind Figure 4's 0.5 L1 ratio (first half of each line
    /// misses, second half hits the LFB).
    pub demanded: bool,
}

/// Outcome of merging a demand access with an in-flight fill.
#[derive(Debug, Clone, Copy)]
pub struct Merge {
    pub complete_ticks: u64,
    pub dest: FillDest,
    /// The fill had already absorbed a demand before this one.
    pub already_demanded: bool,
}

/// In-flight map + LFB occupancy + per-stream budgets + lazy harvest.
pub struct FillTracker {
    /// In-flight fills keyed by line address.
    inflight: LineMap<Fill>,
    /// Lower bound on the earliest `complete_ticks` among `inflight`
    /// entries; `u64::MAX` when the map is empty. May be stale-low after
    /// removals (the true minimum only grows), so it is always safe to
    /// probe when `t >= inflight_min_complete` — and always correct to
    /// *skip* the probe when `t` is below it.
    inflight_min_complete: u64,
    /// Outstanding *demand* fill completion times (ticks).
    lfb: Vec<u64>,
    lfb_entries: usize,
    /// Per-slot sorted completion rings (ascending `complete_ticks`) of
    /// outstanding prefetches; the ring length is the slot's live count.
    stream_outstanding: Vec<VecDeque<u64>>,
    /// Accesses since the last completed-fill sweep.
    sweep_counter: u32,
    /// Observations since the last outstanding-prefetch cleanup.
    clean_counter: u32,
}

/// Bounded lazy sweep period in accesses.
const SWEEP_PERIOD: u32 = 512;
/// Outstanding-prefetch cleanup period in L2 observations.
const CLEAN_PERIOD: u32 = 32;

impl FillTracker {
    pub fn new(lfb_entries: u32, stream_slots: u32) -> Self {
        Self {
            inflight: LineMap::with_capacity_and_hasher(1024, Default::default()),
            inflight_min_complete: u64::MAX,
            lfb: Vec::with_capacity(lfb_entries as usize + 1),
            lfb_entries: lfb_entries as usize,
            stream_outstanding: vec![VecDeque::new(); stream_slots as usize],
            sweep_counter: 0,
            clean_counter: 0,
        }
    }

    /// Is any transfer outstanding for `line`?
    pub fn is_inflight(&self, line: u64) -> bool {
        self.inflight.contains_key(&line)
    }

    /// Could any in-flight fill have completed by `t`? `false` is a
    /// guarantee that [`FillTracker::take_completed`] returns `None` for
    /// every line — the engine's per-access fast-path gate that skips the
    /// HashMap probe while nothing is in flight (or everything in flight
    /// still has time to run).
    #[inline(always)]
    pub fn maybe_completed(&self, t: u64) -> bool {
        t >= self.inflight_min_complete
    }

    /// Tighten the completion bound after an insert.
    #[inline(always)]
    fn note_inflight(&mut self, complete: u64) {
        if complete < self.inflight_min_complete {
            self.inflight_min_complete = complete;
        }
    }

    /// Relax the (now possibly stale) bound once the map drains.
    #[inline(always)]
    fn note_removed(&mut self) {
        if self.inflight.is_empty() {
            self.inflight_min_complete = u64::MAX;
        }
    }

    /// Harvest the fill for `line` if it has completed by `t`.
    pub fn take_completed(&mut self, line: u64, t: u64) -> Option<Fill> {
        let f = self.inflight.get(&line).copied()?;
        if f.complete_ticks <= t {
            self.inflight.remove(&line);
            self.note_removed();
            Some(f)
        } else {
            None
        }
    }

    /// Merge a demand access into the in-flight fill for `line`, if any:
    /// the fill absorbs store intent and records that a demand touched it.
    pub fn merge_demand(&mut self, line: u64, is_store: bool) -> Option<Merge> {
        let f = self.inflight.get_mut(&line)?;
        let m = Merge {
            complete_ticks: f.complete_ticks,
            dest: f.dest,
            already_demanded: f.demanded,
        };
        f.dirty |= is_store;
        f.demanded = true;
        Some(m)
    }

    /// Acquire a line-fill buffer for a demand miss wanting to start at
    /// `t`: with all entries occupied, the miss waits for the earliest
    /// outstanding fill. Returns the effective start time.
    pub fn lfb_acquire(&mut self, t: u64) -> u64 {
        if self.lfb.len() < self.lfb_entries {
            return t;
        }
        let (idx, &earliest) =
            self.lfb.iter().enumerate().min_by_key(|(_, &c)| c).expect("lfb non-empty");
        self.lfb.swap_remove(idx);
        earliest.max(t)
    }

    /// Record a demand fill completing at `complete` ticks.
    pub fn insert_demand(&mut self, line: u64, complete: u64, dirty: bool) {
        self.lfb.push(complete);
        self.inflight.insert(
            line,
            Fill { complete_ticks: complete, dest: FillDest::Demand, dirty, demanded: true },
        );
        self.note_inflight(complete);
    }

    /// Record an L1 (DCU) prefetch completing at `complete` ticks.
    pub fn insert_prefetch_l1(&mut self, line: u64, complete: u64) {
        self.inflight.insert(
            line,
            Fill {
                complete_ticks: complete,
                dest: FillDest::PrefetchL1,
                dirty: false,
                demanded: false,
            },
        );
        self.note_inflight(complete);
    }

    /// Record an L2 (streamer/adjacent) prefetch completing at `complete`
    /// ticks, charged against the stream slot's outstanding budget.
    pub fn insert_prefetch_l2(&mut self, line: u64, complete: u64, stream: u32) {
        if let Some(ring) = self.stream_outstanding.get_mut(stream as usize) {
            // Completion times are near-monotone (DRAM service starts are
            // monotone; only the row hit/miss latency delta reorders), so
            // this is a push_back in the overwhelmingly common case.
            match ring.back() {
                Some(&b) if b > complete => {
                    let pos = ring.partition_point(|&c| c <= complete);
                    ring.insert(pos, complete);
                }
                _ => ring.push_back(complete),
            }
        }
        self.inflight.insert(
            line,
            Fill {
                complete_ticks: complete,
                dest: FillDest::PrefetchL2,
                dirty: false,
                demanded: false,
            },
        );
        self.note_inflight(complete);
    }

    /// Live outstanding prefetches for a stream slot at time `t`: ring
    /// entries with `complete > t`. O(1) when nothing or everything in the
    /// ring has expired (the common cases), O(log len) otherwise.
    pub fn outstanding(&self, slot: u32, t: u64) -> u32 {
        let Some(ring) = self.stream_outstanding.get(slot as usize) else { return 0 };
        match (ring.front(), ring.back()) {
            (None, _) => 0,
            (Some(&first), _) if first > t => ring.len() as u32,
            (_, Some(&last)) if last <= t => 0,
            _ => (ring.len() - ring.partition_point(|&c| c <= t)) as u32,
        }
    }

    /// Amortized cleanup of completed outstanding entries so budgets free
    /// up — every [`CLEAN_PERIOD`] observations. The cadence is **pinned
    /// semantics**, not a perf knob: observation times are not strictly
    /// monotone (TLB-penalty jitter), so queries count `c > t` among the
    /// entries *kept since the last cleanup* — cleaning eagerly would drop
    /// entries a later lower-`t` query still counts and break the golden
    /// oracle. Rings are sorted, so expiry pops a prefix.
    pub fn maybe_clean_outstanding(&mut self, t: u64) {
        self.clean_counter += 1;
        if self.clean_counter >= CLEAN_PERIOD {
            self.clean_counter = 0;
            for ring in &mut self.stream_outstanding {
                while ring.front().is_some_and(|&c| c <= t) {
                    ring.pop_front();
                }
            }
        }
    }

    /// Advance the lazy-sweep counter; `true` once per [`SWEEP_PERIOD`]
    /// accesses, telling the engine to run [`FillTracker::collect_completed`].
    pub fn tick_sweep(&mut self) -> bool {
        self.sweep_counter += 1;
        if self.sweep_counter >= SWEEP_PERIOD {
            self.sweep_counter = 0;
            true
        } else {
            false
        }
    }

    /// Remove every fill completed by `t`, appending them to `landed` for
    /// the engine to install.
    pub fn collect_completed(&mut self, t: u64, landed: &mut Vec<(u64, Fill)>) {
        self.inflight.retain(|&line, f| {
            if f.complete_ticks <= t {
                landed.push((line, *f));
                false
            } else {
                true
            }
        });
        self.note_removed();
    }

    /// Nothing in flight (post-fence invariant).
    pub fn is_empty(&self) -> bool {
        self.inflight.is_empty()
    }

    /// Shift all timestamps down by `t0` (warmup-then-measure rebase).
    pub fn rebase(&mut self, t0: u64) {
        for f in self.inflight.values_mut() {
            f.complete_ticks = f.complete_ticks.saturating_sub(t0);
        }
        if self.inflight_min_complete != u64::MAX {
            self.inflight_min_complete = self.inflight_min_complete.saturating_sub(t0);
        }
        for l in &mut self.lfb {
            *l = l.saturating_sub(t0);
        }
        for ring in &mut self.stream_outstanding {
            // Saturating subtraction is monotone: the rings stay sorted.
            for t in ring.iter_mut() {
                *t = t.saturating_sub(t0);
            }
        }
    }

    /// Cold state; optionally resize the stream-slot table (engine reuse
    /// under a different streamer configuration).
    pub fn reset(&mut self, stream_slots: u32) {
        self.inflight.clear();
        self.inflight_min_complete = u64::MAX;
        self.lfb.clear();
        if self.stream_outstanding.len() != stream_slots as usize {
            self.stream_outstanding.resize(stream_slots as usize, VecDeque::new());
        }
        for ring in &mut self.stream_outstanding {
            ring.clear();
        }
        self.sweep_counter = 0;
        self.clean_counter = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfb_gate_waits_for_earliest_when_full() {
        let mut f = FillTracker::new(2, 4);
        f.insert_demand(1, 100, false);
        f.insert_demand(2, 60, false);
        // Pool full: the next miss at t=10 waits for the earliest (60).
        assert_eq!(f.lfb_acquire(10), 60);
        // One slot was freed by the acquire.
        assert_eq!(f.lfb_acquire(10), 10);
    }

    #[test]
    fn lfb_gate_passes_through_when_free() {
        let mut f = FillTracker::new(2, 4);
        assert_eq!(f.lfb_acquire(42), 42);
    }

    #[test]
    fn merge_accumulates_store_intent_and_demand() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(7, 500, 0);
        let m1 = f.merge_demand(7, false).unwrap();
        assert_eq!(m1.dest, FillDest::PrefetchL2);
        assert!(!m1.already_demanded);
        let m2 = f.merge_demand(7, true).unwrap();
        assert!(m2.already_demanded, "second demand sees the first");
        let fill = f.take_completed(7, 500).unwrap();
        assert!(fill.dirty, "RFO merge marked the fill dirty");
        assert!(fill.demanded);
    }

    #[test]
    fn take_completed_respects_time() {
        let mut f = FillTracker::new(8, 4);
        f.insert_demand(3, 100, false);
        assert!(f.take_completed(3, 99).is_none());
        assert!(f.is_inflight(3));
        assert!(f.take_completed(3, 100).is_some());
        assert!(!f.is_inflight(3));
    }

    #[test]
    fn maybe_completed_bounds_the_probe() {
        let mut f = FillTracker::new(8, 4);
        assert!(!f.maybe_completed(u64::MAX - 1), "empty tracker: never probe");
        f.insert_demand(1, 100, false);
        f.insert_prefetch_l1(2, 70);
        assert!(!f.maybe_completed(69), "everything still in flight");
        assert!(f.maybe_completed(70), "earliest fill may have landed");
        // Drain everything: the bound relaxes back to never-probe.
        assert!(f.take_completed(2, 80).is_some());
        assert!(f.maybe_completed(80), "stale-low bound stays probe-safe");
        assert!(f.take_completed(1, 100).is_some());
        assert!(!f.maybe_completed(u64::MAX - 1));
    }

    #[test]
    fn maybe_completed_never_skips_a_harvestable_fill() {
        // The gate contract: maybe_completed(t) == false must imply
        // take_completed(line, t) == None for every line.
        let mut f = FillTracker::new(8, 4);
        f.insert_demand(1, 50, false);
        f.insert_prefetch_l2(2, 90, 0);
        for t in [0, 49, 50, 89, 90, 200] {
            if !f.maybe_completed(t) {
                assert!(f.take_completed(1, t).is_none());
                assert!(f.take_completed(2, t).is_none());
            }
        }
    }

    #[test]
    fn outstanding_counts_only_live_entries() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(1, 50, 2);
        f.insert_prefetch_l2(2, 150, 2);
        assert_eq!(f.outstanding(2, 100), 1);
        assert_eq!(f.outstanding(2, 10), 2);
        assert_eq!(f.outstanding(2, 200), 0);
        // Out-of-range slot is an empty budget.
        assert_eq!(f.outstanding(99, 0), 0);
    }

    #[test]
    fn outstanding_ring_accepts_out_of_order_completions() {
        // Row-miss/row-hit latency deltas can complete a later-issued
        // prefetch earlier; the sorted ring must keep counts exact.
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(1, 300, 1);
        f.insert_prefetch_l2(2, 210, 1); // issued later, completes earlier
        f.insert_prefetch_l2(3, 250, 1);
        assert_eq!(f.outstanding(1, 200), 3);
        assert_eq!(f.outstanding(1, 210), 2);
        assert_eq!(f.outstanding(1, 250), 1);
        assert_eq!(f.outstanding(1, 300), 0);
    }

    #[test]
    fn clean_preserves_counts_for_later_times() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(1, 50, 0);
        f.insert_prefetch_l2(2, 150, 0);
        for _ in 0..CLEAN_PERIOD {
            f.maybe_clean_outstanding(100);
        }
        assert_eq!(f.outstanding(0, 100), 1, "expired entry cleaned, live one kept");
        assert_eq!(f.outstanding(0, 150), 0);
    }

    #[test]
    fn collect_completed_drains_landed_fills() {
        let mut f = FillTracker::new(8, 4);
        f.insert_demand(1, 10, false);
        f.insert_demand(2, 99, false);
        let mut landed = Vec::new();
        f.collect_completed(50, &mut landed);
        assert_eq!(landed.len(), 1);
        assert_eq!(landed[0].0, 1);
        assert!(f.is_inflight(2));
        landed.clear();
        f.collect_completed(u64::MAX, &mut landed);
        assert!(f.is_empty());
        assert!(!f.maybe_completed(u64::MAX - 1), "drained tracker never probes");
    }

    #[test]
    fn sweep_ticks_once_per_period() {
        let mut f = FillTracker::new(8, 4);
        let fired = (0..2 * SWEEP_PERIOD).filter(|_| f.tick_sweep()).count();
        assert_eq!(fired, 2);
    }

    #[test]
    fn rebase_shifts_bound_and_rings() {
        let mut f = FillTracker::new(8, 4);
        f.insert_demand(1, 100, false);
        f.insert_prefetch_l2(2, 140, 0);
        f.rebase(40);
        assert!(!f.maybe_completed(59));
        assert!(f.maybe_completed(60));
        assert_eq!(f.outstanding(0, 99), 1);
        assert_eq!(f.outstanding(0, 100), 0);
    }

    #[test]
    fn reset_resizes_stream_table() {
        let mut f = FillTracker::new(8, 4);
        f.insert_prefetch_l2(1, 50, 2);
        f.reset(6);
        assert_eq!(f.outstanding(2, 0), 0);
        assert_eq!(f.outstanding(5, 0), 0);
        assert!(!f.maybe_completed(u64::MAX - 1));
    }
}
