//! The modeled cache/DRAM hierarchy and its fill-installation rules.
//!
//! Owns the three cache levels and the DRAM device, and implements the
//! install paths shared by demand fills, prefetch fills and the lazy
//! sweep: inclusive-LLC back-invalidation, dirty write-back chaining
//! (L1 → L2 → L3 → DRAM), and the eager-install rule for streamer
//! prefetches (handled by the engine; see [`super::engine`]).

use crate::config::MachineConfig;
use crate::mem::dram::DramOp;
use crate::mem::{Cache, Dram};

use super::fills::{Fill, FillDest};
use super::TICKS;

/// L1 + L2 + L3 + DRAM with the install/write-back rules between them.
pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    pub l3: Cache,
    pub dram: Dram,
}

impl Hierarchy {
    pub fn new(m: &MachineConfig) -> Self {
        Self {
            l1: Cache::new(m.l1),
            l2: Cache::new(m.l2),
            l3: Cache::new(m.l3),
            dram: Dram::new(m.dram),
        }
    }

    /// Install a landed fill into the hierarchy. `wb_ticks` is the current
    /// retirement time, used to schedule victim write-backs.
    pub fn install(&mut self, line: u64, f: Fill, wb_ticks: u64) {
        match f.dest {
            FillDest::Demand => {
                self.fill_l3(line, wb_ticks);
                self.fill_l2(line, false, false);
                self.fill_l1(line, f.dirty);
            }
            FillDest::PrefetchL2 => {
                // `dirty` set when an RFO merged with this prefetch.
                self.fill_l3_prefetch(line, wb_ticks);
                self.fill_l2(line, true, f.dirty);
            }
            FillDest::PrefetchL1 => {
                self.fill_l2(line, true, false);
                self.fill_l1(line, f.dirty);
            }
        }
    }

    pub fn fill_l1(&mut self, line: u64, dirty: bool) {
        if let Some(ev) = self.l1.insert(line, false, dirty) {
            if ev.dirty {
                // Write-back to L2 (present under inclusion; mark dirty).
                self.l2.mark_dirty(ev.line);
            }
        }
    }

    pub fn fill_l2(&mut self, line: u64, prefetch: bool, dirty: bool) {
        if let Some(ev) = self.l2.insert(line, prefetch, dirty) {
            if ev.dirty {
                self.l3.mark_dirty(ev.line);
            }
        }
    }

    pub fn fill_l3(&mut self, line: u64, wb_ticks: u64) {
        self.fill_l3_inner(line, false, wb_ticks);
    }

    pub fn fill_l3_prefetch(&mut self, line: u64, wb_ticks: u64) {
        self.fill_l3_inner(line, true, wb_ticks);
    }

    fn fill_l3_inner(&mut self, line: u64, prefetch: bool, wb_ticks: u64) {
        if let Some(ev) = self.l3.insert(line, prefetch, false) {
            // Inclusive LLC: back-invalidate inner levels.
            let mut dirty = ev.dirty;
            dirty |= self.l1.invalidate(ev.line);
            dirty |= self.l2.invalidate(ev.line);
            if dirty {
                // Victim write-back consumes a DRAM service slot.
                self.dram.access(wb_ticks / TICKS, ev.line, DramOp::WriteLine);
            }
        }
    }

    /// Cold state, keeping all allocations.
    pub fn reset(&mut self) {
        self.l1.reset();
        self.l2.reset();
        self.l3.reset();
        self.dram.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;

    #[test]
    fn demand_install_lands_in_all_levels() {
        let mut h = Hierarchy::new(&coffee_lake());
        let f = Fill { complete_ticks: 0, dest: FillDest::Demand, dirty: false, demanded: true };
        h.install(7, f, 0);
        assert!(h.l1.contains(7) && h.l2.contains(7) && h.l3.contains(7));
    }

    #[test]
    fn l2_prefetch_install_skips_l1() {
        let mut h = Hierarchy::new(&coffee_lake());
        let f =
            Fill { complete_ticks: 0, dest: FillDest::PrefetchL2, dirty: false, demanded: false };
        h.install(7, f, 0);
        assert!(!h.l1.contains(7) && h.l2.contains(7) && h.l3.contains(7));
    }
}
