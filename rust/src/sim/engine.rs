//! The simulation engine: orchestrates the issue → fill → stall pipeline
//! (see [`super`] for the stage overview). Per access it asks the
//! [`IssueUnit`] for the issue time, pays address translation, walks the
//! line(s) through L1 → L2 → L3 → DRAM — merging with in-flight fills and
//! acquiring line-fill buffers via [`FillTracker`] — then retires in order
//! and hands the retirement gap to [`StallModel`].
//!
//! Prefetch engines observe traffic at their level: L1 engines see every
//! L1 demand access, L2 engines see every request arriving at L2 (hit or
//! miss, loads and RFOs). Requests respect the per-stream in-flight
//! budget; streamer fills install into L2 + L3 *eagerly* at issue time —
//! they occupy their cache set from the start, so aliasing streams evict
//! each other's prefetched lines exactly as §4.5 of the paper describes —
//! while demand and DCU fills install on harvest.
//!
//! §Perf (see ARCHITECTURE.md §Perf for the invariants): the four built-in
//! prefetchers are held as [`BuiltinEngine`] values and dispatched
//! statically on the hot path; `Box<dyn PrefetchEngine>` is kept only for
//! models added through [`Engine::register_prefetcher`], which observe
//! right after the built-ins. The per-access completed-fill probe is
//! gated by [`FillTracker::maybe_completed`], so an L1 hit with nothing
//! harvestable costs one tag scan and zero HashMap traffic.

use crate::mem::addr;
use crate::mem::dram::DramOp;
use crate::mem::{Tlb, WriteCombineBuffer};
use crate::prefetch::{
    partition_builtins_by_level, BuiltinEngine, Observation, PrefetchContext, PrefetchEngine,
    PrefetchLevel, PrefetchReq,
};
use crate::trace::{Access, Op};

use super::fills::{Fill, FillDest, FillTracker};
use super::hierarchy::Hierarchy;
use super::issue::IssueUnit;
use super::stalls::{Depth, StallModel};
use super::{EngineConfig, RunResult, TICKS};

/// The engine. Construct once; [`Engine::run`] consumes a trace. Reuse
/// across configurations via [`Engine::prepare`] / [`Engine::reset`].
pub struct Engine {
    cfg: EngineConfig,
    mem: Hierarchy,
    tlb: Tlb,
    wc: WriteCombineBuffer,
    /// Built-in engines observing L1 demand traffic (DCU next-line,
    /// IP-stride), statically dispatched.
    l1_builtin: Vec<BuiltinEngine>,
    /// Built-in engines observing requests arriving at L2 (streamer,
    /// adjacent-line), statically dispatched.
    l2_builtin: Vec<BuiltinEngine>,
    /// User-registered L1 engines; observe after the L1 built-ins.
    l1_plugins: Vec<Box<dyn PrefetchEngine>>,
    /// User-registered L2 engines; observe after the L2 built-ins.
    l2_plugins: Vec<Box<dyn PrefetchEngine>>,
    fills: FillTracker,
    issue: IssueUnit,
    stalls: StallModel,
    /// Scratch buffer for prefetch requests.
    pf_scratch: Vec<PrefetchReq>,
    /// Scratch buffer for harvested fills.
    landed_scratch: Vec<(u64, Fill)>,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let m = &cfg.machine;
        let mut tlb_cfg = m.tlb;
        tlb_cfg.huge_pages = cfg.huge_pages;
        let (l1_builtin, l2_builtin) = partition_builtins_by_level(cfg.prefetch.build_builtins());
        Self {
            mem: Hierarchy::new(m),
            tlb: Tlb::new(tlb_cfg),
            wc: WriteCombineBuffer::new(m.wc),
            l1_builtin,
            l2_builtin,
            l1_plugins: Vec::new(),
            l2_plugins: Vec::new(),
            fills: FillTracker::new(m.lfb_entries, cfg.prefetch.streamer.table_size),
            issue: IssueUnit::new(m.window_accesses, m.issue_per_cycle),
            stalls: StallModel::new(),
            pf_scratch: Vec::with_capacity(64),
            landed_scratch: Vec::with_capacity(64),
            cfg,
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Any engine observing L1 traffic (fast-path gate: skip observation
    /// setup entirely when the lists are empty).
    #[inline(always)]
    fn l1_engines_active(&self) -> bool {
        !self.l1_builtin.is_empty() || !self.l1_plugins.is_empty()
    }

    /// Any engine observing L2 traffic.
    #[inline(always)]
    fn l2_engines_active(&self) -> bool {
        !self.l2_builtin.is_empty() || !self.l2_plugins.is_empty()
    }

    /// Register an extra prefetch engine at its level, after the
    /// built-ins; the master prefetch enable still gates it. Registered
    /// engines survive [`Engine::reset`], but every [`Engine::prepare`]
    /// rebuilds the engine set from the config and drops them (prepare is
    /// bit-identical with a fresh construction) — re-register afterwards.
    pub fn register_prefetcher(&mut self, engine: Box<dyn PrefetchEngine>) {
        match engine.level() {
            PrefetchLevel::L1 => self.l1_plugins.push(engine),
            PrefetchLevel::L2 => self.l2_plugins.push(engine),
        }
    }

    /// Full reset: cold caches, cleared counters. Bit-identical to a
    /// freshly constructed engine with the same configuration, unless
    /// extra prefetchers were registered — those are reset in place but
    /// kept (use [`Engine::prepare`] to drop them).
    pub fn reset(&mut self) {
        self.mem.reset();
        self.tlb.reset();
        self.wc.reset();
        for e in self.l1_builtin.iter_mut().chain(self.l2_builtin.iter_mut()) {
            e.reset();
        }
        for e in self.l1_plugins.iter_mut().chain(self.l2_plugins.iter_mut()) {
            e.reset();
        }
        self.fills.reset(self.cfg.prefetch.streamer.table_size);
        self.issue.reset();
        self.stalls.reset();
    }

    /// Reset to cold state under a (possibly different) configuration,
    /// reusing allocations where the machine matches. The sweep-reuse
    /// entry point: bit-identical with `*self = Engine::new(cfg)`.
    pub fn prepare(&mut self, cfg: EngineConfig) {
        if self.cfg.machine != cfg.machine {
            *self = Engine::new(cfg);
            return;
        }
        if self.cfg.huge_pages != cfg.huge_pages {
            let mut tlb_cfg = cfg.machine.tlb;
            tlb_cfg.huge_pages = cfg.huge_pages;
            self.tlb = Tlb::new(tlb_cfg);
        }
        // Always rebuild the engine set from the config: a reused engine
        // must match `Engine::new(cfg)` exactly, including dropping any
        // extra engines added via `register_prefetcher`.
        let (l1b, l2b) = partition_builtins_by_level(cfg.prefetch.build_builtins());
        self.l1_builtin = l1b;
        self.l2_builtin = l2b;
        self.l1_plugins.clear();
        self.l2_plugins.clear();
        self.cfg = cfg;
        self.reset();
    }

    /// Run a full trace to the closing memory fence; returns the metrics.
    /// The engine retains warm state; call [`Engine::reset`] or
    /// [`Engine::prepare`] between measurements.
    pub fn run(&mut self, trace: impl IntoIterator<Item = Access>) -> RunResult {
        for acc in trace {
            self.step(acc);
        }
        self.fence();
        self.result()
    }

    /// Warm the hierarchy with a trace, then reset only the statistics —
    /// the paper's warmup-then-measure protocol.
    pub fn warmup(&mut self, trace: impl IntoIterator<Item = Access>) {
        for acc in trace {
            self.step(acc);
        }
        self.fence();
        // Keep cache/TLB/stream state; zero the measurement.
        self.mem.l1.stats = Default::default();
        self.mem.l2.stats = Default::default();
        self.mem.l3.stats = Default::default();
        self.wc.stats = Default::default();
        self.tlb.stats = Default::default();
        for e in self.l1_builtin.iter_mut().chain(self.l2_builtin.iter_mut()) {
            e.clear_stats();
        }
        for e in self.l1_plugins.iter_mut().chain(self.l2_plugins.iter_mut()) {
            e.clear_stats();
        }
        self.stalls.reset();
        let t0 = self.issue.rebase();
        self.fills.rebase(t0);
        // DRAM service cursor rebuilt idle at t = 0: the first accesses
        // re-open rows, like a measurement starting at a row boundary.
        // In-place rebuild — identical to a fresh `Dram::new` with the
        // same config, without churning the open-row allocation per
        // warmup (§Perf).
        self.mem.dram.reset();
    }

    /// Process a single vector access.
    #[inline]
    pub fn step(&mut self, acc: Access) {
        let t_issue = self.issue.next_issue();

        // ---- address translation ---------------------------------------
        let tlb_pen = self.tlb.translate(acc.addr);
        self.stalls.record_tlb(tlb_pen);
        let t_ready_base = t_issue + tlb_pen * TICKS;

        // ---- the access -------------------------------------------------
        let (data_ready, depth) = if acc.op == Op::StoreNt {
            self.step_nt_store(acc, t_ready_base)
        } else {
            self.step_cached(acc, t_ready_base)
        };

        // ---- retire + stall accounting ----------------------------------
        self.stalls.record_access(acc.op.is_store(), acc.size);
        let stall_ticks = self.issue.retire(t_issue, data_ready);
        self.stalls.attribute(depth, stall_ticks);

        // Bounded lazy sweep of completed fills (see sweep_completed).
        if self.fills.tick_sweep() {
            self.sweep_completed(self.issue.last_retire());
        }
    }

    /// Cached load/store path. Returns (data-ready ticks, depth reached).
    fn step_cached(&mut self, acc: Access, t: u64) -> (u64, Depth) {
        let m = self.cfg.machine;
        let (first, last) = addr::lines_touched(acc.addr, acc.size);
        let is_store = acc.op.is_store();
        let mut ready = t + m.l1_lat * TICKS;
        let mut depth = Depth::L1Hit;

        let mut line = first;
        loop {
            let (r, d) = self.touch_line(line, acc.ip, is_store, t);
            if r > ready {
                ready = r;
            }
            if d > depth {
                depth = d;
            }
            if line == last {
                break;
            }
            line += 1;
        }
        (ready, depth)
    }

    /// Resolve one line of a demand access.
    fn touch_line(&mut self, line: u64, ip: u32, is_store: bool, t: u64) -> (u64, Depth) {
        let m = self.cfg.machine;
        let pf_enabled = self.cfg.prefetch.enabled;
        // The L1 observation gate, hoisted so the streaming-hit fast path
        // pays two `len == 0` checks instead of an observation setup.
        let l1_observes = pf_enabled && self.l1_engines_active();

        // Harvest a completed in-flight fill for this line first. L2
        // prefetches installed eagerly at issue time — harvesting them just
        // drops the transit record; demand and DCU fills install here.
        // `maybe_completed` bounds the probe: when nothing in flight can
        // have landed by `t`, `take_completed` could only return `None`,
        // so the HashMap probe is skipped outright (the dominant case on
        // L1-hit-heavy traces).
        if self.fills.maybe_completed(t) {
            if let Some(f) = self.fills.take_completed(line, t) {
                if f.dest != FillDest::PrefetchL2 {
                    self.mem.install(line, f, self.issue.last_retire());
                }
            }
        }

        // ---- L1 ----------------------------------------------------------
        if self.mem.l1.demand_lookup(line) {
            if is_store {
                self.mem.l1.mark_dirty(line);
            }
            // L1 engines observe L1 traffic (hits included).
            if l1_observes {
                self.observe_l1(line, ip, false, is_store, t);
            }
            return (t + m.l1_lat * TICKS, Depth::L1Hit);
        }
        if l1_observes {
            self.observe_l1(line, ip, true, is_store, t);
        }

        // ---- merge with in-flight fill ----------------------------------
        if let Some(merge) = self.fills.merge_demand(line, is_store) {
            self.stalls.counters_mut().prefetch_merges += 1;
            // Repeat demand to a line whose fill is outstanding: a
            // fill-buffer hit — architecturally an L1 hit (Figure 4's 0.5
            // ratio: first half of every line misses, second half FB-hits).
            if merge.already_demanded {
                self.mem.l1.stats.demand_hits += 1;
                self.mem.l1.stats.demand_misses -= 1; // undo the lookup's miss
                return (merge.complete_ticks.max(t + m.l1_lat * TICKS), Depth::L1Hit);
            }
            // First demand touching this fill: account by fill origin.
            return match merge.dest {
                FillDest::Demand | FillDest::PrefetchL1 => {
                    self.mem.l1.stats.demand_hits += 1;
                    self.mem.l1.stats.demand_misses -= 1;
                    (merge.complete_ticks.max(t + m.l1_lat * TICKS), Depth::L1Hit)
                }
                FillDest::PrefetchL2 => {
                    // Merged with a streamer prefetch: data still in flight
                    // from DRAM — counts as L2+L3 miss, but the wait is the
                    // remaining fill time, not a full DRAM round trip. The
                    // line is already resident (eager install); record the
                    // demand touch + RFO dirtiness there.
                    self.mem.l2.stats.demand_misses += 1;
                    self.mem.l3.stats.demand_misses += 1;
                    if is_store {
                        self.mem.l2.mark_dirty(line);
                    }
                    self.observe_l2(line, is_store, false, t);
                    (merge.complete_ticks.max(t + m.l2_lat * TICKS), Depth::Dram)
                }
            };
        }

        // ---- L2 ----------------------------------------------------------
        // The L2 engines see every request arriving there.
        if self.mem.l2.demand_lookup(line) {
            self.observe_l2(line, is_store, true, t);
            self.mem.fill_l1(line, is_store);
            return (t + m.l2_lat * TICKS, Depth::L2Hit);
        }
        self.observe_l2(line, is_store, false, t);

        // ---- L3 ----------------------------------------------------------
        if self.mem.l3.demand_lookup(line) {
            self.mem.fill_l2(line, false, false);
            self.mem.fill_l1(line, is_store);
            return (t + m.l3_lat * TICKS, Depth::L3Hit);
        }

        // ---- DRAM (demand), behind the line-fill buffer gate -------------
        let t_eff = self.fills.lfb_acquire(t);
        let complete_cycles = self.mem.dram.access(t_eff / TICKS, line, DramOp::Read);
        let complete = complete_cycles * TICKS + m.l3_lat * TICKS / 2;
        self.fills.insert_demand(line, complete, is_store);
        self.stalls.counters_mut().dram_demand_lines += 1;
        (complete, Depth::Dram)
    }

    /// L1-level engine observation + request issue. Callers gate on
    /// prefetch-enabled + [`Engine::l1_engines_active`].
    fn observe_l1(&mut self, line: u64, ip: u32, miss: bool, store: bool, t: u64) {
        let obs = Observation { line, ip, miss, store };
        self.pf_scratch.clear();
        // L1 engines consult no per-stream budget.
        for e in &mut self.l1_builtin {
            e.observe(obs, !miss, |_| 0, &mut self.pf_scratch);
        }
        if !self.l1_plugins.is_empty() {
            let none = |_: u32| 0u32;
            let ctx = PrefetchContext { level_hit: !miss, outstanding: &none };
            for e in &mut self.l1_plugins {
                e.observe(obs, &ctx, &mut self.pf_scratch);
            }
        }
        self.issue_scratch(t);
    }

    /// L2-level engine observation + request issue. `l2_hit` gates the
    /// engines that trigger on misses (adjacent-line).
    fn observe_l2(&mut self, line: u64, store: bool, l2_hit: bool, t: u64) {
        if !self.cfg.prefetch.enabled || !self.l2_engines_active() {
            return;
        }
        // Free up completed per-stream budget entries (amortized).
        self.fills.maybe_clean_outstanding(t);
        self.pf_scratch.clear();
        // L2 observations carry no instruction pointer (the request lost it
        // on the way down); `miss` mirrors `ctx.level_hit` truthfully.
        let obs = Observation { line, ip: 0, miss: !l2_hit, store };
        let fills = &self.fills;
        for e in &mut self.l2_builtin {
            e.observe(obs, l2_hit, |slot| fills.outstanding(slot, t), &mut self.pf_scratch);
        }
        if !self.l2_plugins.is_empty() {
            let outstanding = move |slot: u32| fills.outstanding(slot, t);
            let ctx = PrefetchContext { level_hit: l2_hit, outstanding: &outstanding };
            for e in &mut self.l2_plugins {
                e.observe(obs, &ctx, &mut self.pf_scratch);
            }
        }
        self.issue_scratch(t);
    }

    /// Issue every request accumulated in the scratch buffer.
    fn issue_scratch(&mut self, t: u64) {
        let reqs = std::mem::take(&mut self.pf_scratch);
        for r in &reqs {
            self.issue_prefetch(*r, t);
        }
        self.pf_scratch = reqs;
    }

    /// Issue one prefetch request if it is not redundant.
    fn issue_prefetch(&mut self, req: PrefetchReq, t: u64) {
        let m = self.cfg.machine;
        let line = req.line;
        if self.fills.is_inflight(line) {
            return;
        }
        if req.to_l1 {
            if self.mem.l1.contains(line) {
                return;
            }
            // DCU prefetch: source from L2/L3/DRAM.
            let complete = if self.mem.l2.contains(line) {
                t + m.l2_lat * TICKS
            } else if self.mem.l3.contains(line) {
                t + m.l3_lat * TICKS
            } else {
                self.mem.dram.access(t / TICKS, line, DramOp::Read) * TICKS
            };
            self.stalls.counters_mut().prefetch_lines += 1;
            self.fills.insert_prefetch_l1(line, complete);
            return;
        }
        // Streamer/adjacent: target L2.
        if self.mem.l2.contains(line) {
            return;
        }
        if self.mem.l3.contains(line) {
            // LLC→L2 move: cheap, model as immediate install.
            self.mem.fill_l2(line, true, false);
            return;
        }
        let complete = self.mem.dram.access(t / TICKS, line, DramOp::Read) * TICKS;
        self.stalls.counters_mut().prefetch_lines += 1;
        // Eager install: the prefetched line occupies its L2/L3 set from
        // issue (the Figure 5 conflicts); timing stays in the fill tracker.
        self.mem.fill_l3_prefetch(line, self.issue.last_retire());
        self.mem.fill_l2(line, true, false);
        self.fills.insert_prefetch_l2(line, complete, req.stream);
    }

    /// Install every completed in-flight fill (bounded lazy sweep): demand
    /// fills must eventually land so dirty lines write back and warm state
    /// persists, even for lines the trace never touches again.
    fn sweep_completed(&mut self, t: u64) {
        let mut landed = std::mem::take(&mut self.landed_scratch);
        self.fills.collect_completed(t, &mut landed);
        for (line, f) in landed.drain(..) {
            if f.dest != FillDest::PrefetchL2 {
                self.mem.install(line, f, self.issue.last_retire());
            }
        }
        self.landed_scratch = landed;
    }

    /// Non-temporal store path: write-combining buffers, no allocation.
    fn step_nt_store(&mut self, acc: Access, t: u64) -> (u64, Depth) {
        let m = self.cfg.machine;
        // Coherence: NT stores to cached lines must evict them first
        // (invalidate is a no-op on absent lines).
        let line = addr::line_of(acc.addr);
        self.mem.l1.invalidate(line);
        self.mem.l2.invalidate(line);
        self.mem.l3.invalidate(line);
        if let Some(flush) = self.wc.store(t / TICKS, acc.addr, acc.size) {
            let op = if flush.full { DramOp::WriteLine } else { DramOp::WritePartial };
            self.mem.dram.access(flush.at, flush.line, op);
        }
        // The store itself retires quickly; backpressure appears when the
        // DRAM write queue runs far ahead of the core — model by gating on
        // the channel's next-free time once it exceeds a window.
        let backlog_ticks = (self.mem.dram.next_free() * TICKS).saturating_sub(t);
        let allowed = 64 * TICKS * m.wc.entries as u64;
        let ready = if backlog_ticks > allowed { t + (backlog_ticks - allowed) } else { t } + TICKS;
        (ready, if backlog_ticks > allowed { Depth::Dram } else { Depth::L1Hit })
    }

    /// Closing `mfence`: drain write-combining buffers and wait for every
    /// outstanding operation.
    pub fn fence(&mut self) {
        let t = self.issue.last_retire().max(self.issue.cursor());
        let mut done = t;
        // Land everything outstanding so warm state persists across runs.
        self.sweep_completed(u64::MAX);
        debug_assert!(self.fills.is_empty(), "fence left fills outstanding");
        for flush in self.wc.drain(t / TICKS) {
            let op = if flush.full { DramOp::WriteLine } else { DramOp::WritePartial };
            let c = self.mem.dram.access(flush.at, flush.line, op) * TICKS;
            done = done.max(c);
        }
        done = done.max(self.mem.dram.next_free() * TICKS);
        self.stalls.record_fence_wait(self.issue.last_retire(), done);
        self.issue.force_retire(done);
    }

    /// Snapshot the metrics.
    pub fn result(&self) -> RunResult {
        let streamer = self
            .l2_builtin
            .iter()
            .find_map(|e| e.streamer_stats())
            .or_else(|| self.l2_plugins.iter().find_map(|e| e.streamer_stats()))
            .unwrap_or_default();
        RunResult {
            counters: self.stalls.snapshot(self.issue.last_retire()),
            l1: self.mem.l1.stats,
            l2: self.mem.l2.stats,
            l3: self.mem.l3.stats,
            dram: self.mem.dram.stats,
            wc: self.wc.stats,
            tlb: self.tlb.stats,
            streamer,
            freq_ghz: self.cfg.machine.freq_ghz,
        }
    }
}
