//! The simulation engine: plays an access trace against the modeled memory
//! subsystem and produces throughput + counters.
//!
//! ## Timing model
//!
//! Time advances through **timestamps**, not stepped cycles. Internally the
//! engine counts in *ticks* = 1/4 core cycle so that a 2-accesses-per-cycle
//! issue rate is expressible exactly.
//!
//! * An **issue cursor** advances by `issue_ticks` per vector access.
//! * Access *i* may not issue before access *i − W* has retired
//!   (out-of-order window of `window_accesses`).
//! * A demand L3 miss needs a **line-fill buffer**; with all `lfb_entries`
//!   occupied the access waits for the earliest outstanding fill.
//! * Retirement is in-order: `retire(i) = max(retire(i−1), data_ready(i))`.
//!   Gaps between consecutive retirements beyond the issue cost are **stall
//!   cycles**, attributed to the deepest level the blocking access reached
//!   (the `CYCLE_ACTIVITY.STALLS_*` emulation of [`super::counters`]).
//!
//! ## Fill tracking
//!
//! Demand misses and prefetches enter an `inflight` map keyed by line
//! address. A later demand to an in-flight line **merges**: it completes
//! when the fill lands. Completed fills are *harvested lazily* — installed
//! into the caches the next time the line is touched (plus periodic sweeps
//! bounded by the prefetch budget), which is exact for a single-core trace.
//!
//! ## Prefetch plumbing
//!
//! The L2 streamer observes every access arriving at L2 (hit or miss, loads
//! and RFOs). Its requests respect a per-stream in-flight budget; fills
//! install into L2 + L3. DCU engines (next-line, IP-stride) observe L1
//! traffic and install into L1; they are modeled but disabled in the
//! calibrated presets (see [`crate::prefetch::PrefetchConfig`]).

use std::collections::{HashMap, VecDeque};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-shift hasher for line-address keys (§Perf: the inflight map is
/// on the hot path; SipHash costs ~3× more than the whole lookup).
#[derive(Default)]
pub struct LineHasher(u64);

impl Hasher for LineHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x9e3779b97f4a7c15);
        }
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        let h = v.wrapping_mul(0x9e3779b97f4a7c15);
        self.0 = h ^ (h >> 29);
    }
}

type LineMap<V> = HashMap<u64, V, BuildHasherDefault<LineHasher>>;

use crate::config::MachineConfig;
use crate::mem::addr;
use crate::mem::{Cache, Dram, Tlb, WriteCombineBuffer};
use crate::mem::dram::DramOp;
use crate::prefetch::{DcuNextLine, IpStride, Observation, PrefetchConfig, PrefetchReq, Streamer};
use crate::trace::{Access, Op};

use super::Counters;

/// Ticks per core cycle (issue-slot resolution).
const TICKS: u64 = 4;

/// Engine construction parameters.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// The simulated machine (caches, DRAM, prefetchers, core limits).
    pub machine: MachineConfig,
    /// Prefetch configuration — override of `machine.prefetch`, so the
    /// MSR-style enable bit can be flipped per run.
    pub prefetch: PrefetchConfig,
    /// Use huge pages for address translation (the paper's §4 setting).
    pub huge_pages: bool,
}

impl EngineConfig {
    pub fn new(machine: MachineConfig) -> Self {
        Self { machine, prefetch: machine.prefetch, huge_pages: false }
    }

    pub fn with_prefetch(mut self, enabled: bool) -> Self {
        self.prefetch.enabled = enabled;
        self
    }

    pub fn with_huge_pages(mut self, huge: bool) -> Self {
        self.huge_pages = huge;
        self
    }
}

/// Where a fill is headed once it lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FillDest {
    /// Demand fill: installs L1 + L2 + L3.
    Demand,
    /// Streamer prefetch: installs L2 + L3.
    PrefetchL2,
    /// DCU prefetch: installs L1 (+L2).
    PrefetchL1,
}

#[derive(Debug, Clone, Copy)]
struct Fill {
    /// Completion time in ticks.
    complete_ticks: u64,
    dest: FillDest,
    /// Streamer slot for outstanding accounting (`u32::MAX` if none).
    #[allow(dead_code)]
    stream: u32,
    /// Store intent (RFO): install dirty.
    dirty: bool,
    /// A demand access already merged with this fill. Subsequent demands to
    /// the same line are *fill-buffer hits* and count as L1 hits — the
    /// mechanism behind Figure 4's 0.5 L1 ratio (first half of each line
    /// misses, second half hits the LFB).
    demanded: bool,
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub counters: Counters,
    pub l1: crate::mem::cache::CacheStats,
    pub l2: crate::mem::cache::CacheStats,
    pub l3: crate::mem::cache::CacheStats,
    pub dram: crate::mem::dram::DramStats,
    pub wc: crate::mem::writebuffer::WcStats,
    pub tlb: crate::mem::tlb::TlbStats,
    pub streamer: crate::prefetch::streamer::StreamerStats,
    /// Locked frequency the cycle counts convert with.
    pub freq_ghz: f64,
}

impl RunResult {
    /// Achieved throughput over the run in GiB/s (the paper's unit:
    /// gigibytes of *program data* moved per second).
    pub fn throughput_gib(&self) -> f64 {
        if self.counters.cycles == 0 {
            return 0.0;
        }
        let secs = self.counters.cycles as f64 / (self.freq_ghz * 1e9);
        self.counters.bytes() as f64 / (1u64 << 30) as f64 / secs
    }
}

/// The engine. Construct once per configuration; `run` consumes a trace.
pub struct Engine {
    cfg: EngineConfig,
    l1: Cache,
    l2: Cache,
    l3: Cache,
    tlb: Tlb,
    dram: Dram,
    wc: WriteCombineBuffer,
    streamer: Streamer,
    dcu: DcuNextLine,
    ipstride: IpStride,

    /// In-flight fills keyed by line address.
    inflight: LineMap<Fill>,
    /// Outstanding *demand* fill completion times (ticks), min-heap via sort.
    lfb: Vec<u64>,
    /// Outstanding prefetch completion ticks per streamer slot.
    stream_outstanding: Vec<Vec<u64>>,
    /// Retirement times (ticks) of the last `window_accesses` accesses.
    retire_ring: VecDeque<u64>,
    /// Issue cursor in ticks.
    issue_ticks_cursor: u64,
    /// Ticks consumed per access by the issue ports.
    issue_cost: u64,
    /// Last in-order retirement time (ticks).
    last_retire: u64,

    counters: Counters,
    /// Scratch buffer for prefetch requests.
    pf_scratch: Vec<PrefetchReq>,
    /// Accesses since the last completed-fill sweep.
    sweep_counter: u32,
    /// Observations since the last outstanding-prefetch cleanup.
    outstanding_clean_counter: u32,
}

/// Deepest level a demand access had to reach (for stall attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Depth {
    L1Hit,
    L2Hit,
    L3Hit,
    Dram,
}

impl Engine {
    pub fn new(cfg: EngineConfig) -> Self {
        let m = &cfg.machine;
        let mut tlb_cfg = m.tlb;
        tlb_cfg.huge_pages = cfg.huge_pages;
        let table = cfg.prefetch.streamer.table_size as usize;
        Self {
            l1: Cache::new(m.l1),
            l2: Cache::new(m.l2),
            l3: Cache::new(m.l3),
            tlb: Tlb::new(tlb_cfg),
            dram: Dram::new(m.dram),
            wc: WriteCombineBuffer::new(m.wc),
            streamer: Streamer::new(cfg.prefetch.streamer),
            dcu: DcuNextLine::new(cfg.prefetch.dcu),
            ipstride: IpStride::new(cfg.prefetch.ipstride),
            inflight: LineMap::with_capacity_and_hasher(1024, Default::default()),
            lfb: Vec::with_capacity(m.lfb_entries as usize + 1),
            stream_outstanding: vec![Vec::new(); table],
            retire_ring: VecDeque::with_capacity(m.window_accesses as usize + 1),
            issue_ticks_cursor: 0,
            issue_cost: TICKS / m.issue_per_cycle as u64,
            last_retire: 0,
            counters: Counters::default(),
            pf_scratch: Vec::with_capacity(64),
            sweep_counter: 0,
            outstanding_clean_counter: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Run a full trace to the closing memory fence; returns the metrics.
    /// The engine retains warm state; call [`Engine::reset`] between
    /// measurements, or rebuild.
    pub fn run(&mut self, trace: impl IntoIterator<Item = Access>) -> RunResult {
        for acc in trace {
            self.step(acc);
        }
        self.fence();
        self.result()
    }

    /// Warm the hierarchy with a trace, then reset only the statistics —
    /// the paper's warmup-then-measure protocol.
    pub fn warmup(&mut self, trace: impl IntoIterator<Item = Access>) {
        for acc in trace {
            self.step(acc);
        }
        self.fence();
        // Keep cache/TLB/stream state; zero the measurement.
        self.l1.stats = Default::default();
        self.l2.stats = Default::default();
        self.l3.stats = Default::default();
        self.dram.stats = Default::default();
        self.wc.stats = Default::default();
        self.tlb.stats = Default::default();
        self.streamer.stats = Default::default();
        self.counters = Counters::default();
        let t0 = self.issue_ticks_cursor;
        self.issue_ticks_cursor = 0;
        self.last_retire = self.last_retire.saturating_sub(t0);
        for r in &mut self.retire_ring {
            *r = r.saturating_sub(t0);
        }
        for f in self.inflight.values_mut() {
            f.complete_ticks = f.complete_ticks.saturating_sub(t0);
        }
        for l in &mut self.lfb {
            *l = l.saturating_sub(t0);
        }
        for s in &mut self.stream_outstanding {
            for t in s.iter_mut() {
                *t = t.saturating_sub(t0);
            }
        }
        // NOTE: Dram's internal service cursor is reset; its open-row state
        // persists via reset-less stats clearing above.
        self.rebase_dram(t0);
    }

    fn rebase_dram(&mut self, _t0: u64) {
        // The DRAM service cursor is in cycles; after a warmup rebase the
        // conservative choice is "channel idle at t=0".
        let open_rows_kept = true;
        let _ = open_rows_kept;
        // Recreate with same config but preserve open-row locality by
        // replaying nothing: the first accesses will re-open rows, which
        // matches a measurement that starts at a row boundary.
        self.dram = Dram::new(self.cfg.machine.dram);
    }

    /// Process a single vector access.
    #[inline]
    pub fn step(&mut self, acc: Access) {
        // ---- issue time -------------------------------------------------
        let window = self.cfg.machine.window_accesses as usize;
        let mut t_issue = self.issue_ticks_cursor;
        if self.retire_ring.len() >= window {
            let gate = self.retire_ring[self.retire_ring.len() - window];
            if gate > t_issue {
                t_issue = gate;
            }
        }

        // ---- address translation ---------------------------------------
        let tlb_pen = self.tlb.translate(acc.addr);
        self.counters.tlb_cycles += tlb_pen;
        let t_ready_base = t_issue + tlb_pen * TICKS;

        // ---- the access -------------------------------------------------
        let (data_ready, depth) = if acc.op == Op::StoreNt {
            self.step_nt_store(acc, t_ready_base)
        } else {
            self.step_cached(acc, t_ready_base)
        };

        // ---- retire + stall accounting ----------------------------------
        self.counters.accesses += 1;
        if acc.op.is_store() {
            self.counters.bytes_written += acc.size as u64;
        } else {
            self.counters.bytes_read += acc.size as u64;
        }

        let retire = data_ready.max(self.last_retire);
        let gap = retire.saturating_sub(self.last_retire);
        let busy = self.issue_cost;
        if gap > busy {
            let stall = (gap - busy) / TICKS;
            self.counters.stalls_total += stall;
            self.counters.stalls_mem_any += stall;
            match depth {
                Depth::L1Hit => {}
                Depth::L2Hit => self.counters.stalls_l1d_miss += stall,
                Depth::L3Hit => {
                    self.counters.stalls_l1d_miss += stall;
                    self.counters.stalls_l2_miss += stall;
                }
                Depth::Dram => {
                    self.counters.stalls_l1d_miss += stall;
                    self.counters.stalls_l2_miss += stall;
                    self.counters.stalls_l3_miss += stall;
                }
            }
        }
        self.last_retire = retire;
        self.retire_ring.push_back(retire);
        if self.retire_ring.len() > window {
            self.retire_ring.pop_front();
        }
        self.issue_ticks_cursor = t_issue + self.issue_cost;

        // Bounded lazy sweep: land completed fills so caches stay coherent
        // with time even when lines are never touched again.
        self.sweep_counter += 1;
        if self.sweep_counter >= 512 {
            self.sweep_counter = 0;
            self.sweep_completed(self.last_retire);
        }
    }

    /// Cached load/store path. Returns (data-ready ticks, depth reached).
    fn step_cached(&mut self, acc: Access, t: u64) -> (u64, Depth) {
        let m = self.cfg.machine;
        let (first, last) = addr::lines_touched(acc.addr, acc.size);
        let is_store = acc.op.is_store();
        let mut ready = t + m.l1_lat * TICKS;
        let mut depth = Depth::L1Hit;

        let mut line = first;
        loop {
            let (r, d) = self.touch_line(line, acc.ip, is_store, t);
            if r > ready {
                ready = r;
            }
            if d > depth {
                depth = d;
            }
            if line == last {
                break;
            }
            line += 1;
        }
        (ready, depth)
    }

    /// Resolve one line of a demand access.
    fn touch_line(&mut self, line: u64, ip: u32, is_store: bool, t: u64) -> (u64, Depth) {
        let m = self.cfg.machine;
        let pf = self.cfg.prefetch;

        // Harvest a completed in-flight fill for this line first.
        // Streamer (L2) prefetches were installed *eagerly* at issue time —
        // they occupy their cache set from the start, so aliasing streams
        // evict each other's prefetched lines exactly as §4.5 describes;
        // harvesting them is just dropping the transit record. Demand and
        // DCU fills install on harvest.
        if let Some(f) = self.inflight.get(&line).copied() {
            if f.complete_ticks <= t {
                self.inflight.remove(&line);
                if f.dest != FillDest::PrefetchL2 {
                    self.install_fill(line, f);
                }
            }
        }

        // ---- L1 ----------------------------------------------------------
        if self.l1.demand_lookup(line) {
            if is_store {
                self.l1.mark_dirty(line);
            }
            // DCU engines observe L1 traffic (hits included).
            if pf.enabled {
                self.observe_l1(line, ip, false, is_store, t);
            }
            return (t + m.l1_lat * TICKS, Depth::L1Hit);
        }
        if pf.enabled {
            self.observe_l1(line, ip, true, is_store, t);
        }

        // ---- merge with in-flight fill ----------------------------------
        if let Some(f) = self.inflight.get_mut(&line) {
            let complete = f.complete_ticks;
            let dest = f.dest;
            let already_demanded = f.demanded;
            f.dirty |= is_store;
            f.demanded = true;
            self.counters.prefetch_merges += 1;
            // Repeat demand to a line whose fill is outstanding: a
            // fill-buffer hit — architecturally an L1 hit (Figure 4's 0.5
            // ratio: first half of every line misses, second half FB-hits).
            if already_demanded {
                self.l1.stats.demand_hits += 1;
                self.l1.stats.demand_misses -= 1; // undo the lookup's miss
                return (complete.max(t + m.l1_lat * TICKS), Depth::L1Hit);
            }
            // First demand touching this fill: account by fill origin.
            return match dest {
                FillDest::Demand | FillDest::PrefetchL1 => {
                    self.l1.stats.demand_hits += 1;
                    self.l1.stats.demand_misses -= 1;
                    (complete.max(t + m.l1_lat * TICKS), Depth::L1Hit)
                }
                FillDest::PrefetchL2 => {
                    // Merged with a streamer prefetch: data still in flight
                    // from DRAM — counts as L2+L3 miss, but the wait is the
                    // remaining fill time, not a full DRAM round trip. The
                    // line is already resident (eager install); record the
                    // demand touch + RFO dirtiness there.
                    self.l2.stats.demand_misses += 1;
                    self.l3.stats.demand_misses += 1;
                    if is_store {
                        self.l2.mark_dirty(line);
                    }
                    self.observe_l2(line, is_store, false, t);
                    (complete.max(t + m.l2_lat * TICKS), Depth::Dram)
                }
            };
        }

        // ---- L2 ----------------------------------------------------------
        // The streamer sits at L2 and sees every request arriving there.
        if self.l2.demand_lookup(line) {
            self.observe_l2(line, is_store, true, t);
            self.fill_l1(line, is_store);
            return (t + m.l2_lat * TICKS, Depth::L2Hit);
        }
        self.observe_l2(line, is_store, false, t);

        // ---- L3 ----------------------------------------------------------
        if self.l3.demand_lookup(line) {
            self.fill_l2(line, false, false);
            self.fill_l1(line, is_store);
            return (t + m.l3_lat * TICKS, Depth::L3Hit);
        }

        // ---- DRAM (demand) ----------------------------------------------
        // Line-fill buffer gate.
        let mut t_eff = t;
        if self.lfb.len() >= m.lfb_entries as usize {
            // Wait for the earliest outstanding demand fill.
            let (idx, &earliest) =
                self.lfb.iter().enumerate().min_by_key(|(_, &c)| c).expect("lfb non-empty");
            self.lfb.swap_remove(idx);
            if earliest > t_eff {
                t_eff = earliest;
            }
        }
        let complete_cycles = self.dram.access(t_eff / TICKS, line, DramOp::Read);
        let complete = complete_cycles * TICKS + m.l3_lat * TICKS / 2;
        self.lfb.push(complete);
        self.counters.dram_demand_lines += 1;
        self.inflight.insert(
            line,
            Fill {
                complete_ticks: complete,
                dest: FillDest::Demand,
                stream: u32::MAX,
                dirty: is_store,
                demanded: true,
            },
        );
        (complete, Depth::Dram)
    }

    /// DCU-level (L1) prefetcher observation + request issue.
    fn observe_l1(&mut self, line: u64, ip: u32, miss: bool, store: bool, t: u64) {
        let pf = self.cfg.prefetch;
        if !pf.dcu_enabled && !pf.ipstride_enabled {
            return;
        }
        let obs = Observation { line, ip, miss, store };
        self.pf_scratch.clear();
        if pf.dcu_enabled {
            self.dcu.observe(obs, &mut self.pf_scratch);
        }
        if pf.ipstride_enabled {
            self.ipstride.observe(obs, &mut self.pf_scratch);
        }
        let reqs = std::mem::take(&mut self.pf_scratch);
        for r in &reqs {
            self.issue_prefetch(*r, t);
        }
        self.pf_scratch = reqs;
    }

    /// L2-level (streamer + adjacent) observation + request issue.
    /// `l2_hit` gates the adjacent-line engine (it triggers on misses).
    fn observe_l2(&mut self, line: u64, store: bool, l2_hit: bool, t: u64) {
        let pf = self.cfg.prefetch;
        if !pf.enabled {
            return;
        }
        self.pf_scratch.clear();
        if pf.streamer_enabled {
            // Clean completed outstanding entries so budgets free up —
            // §Perf: amortized (every 32 observations) instead of per-
            // observation; the budget closure counts live entries exactly.
            self.outstanding_clean_counter += 1;
            if self.outstanding_clean_counter >= 32 {
                self.outstanding_clean_counter = 0;
                for s in &mut self.stream_outstanding {
                    s.retain(|&c| c > t);
                }
            }
            let outstanding = &self.stream_outstanding;
            let obs = Observation { line, ip: 0, miss: true, store };
            self.streamer.observe(
                obs,
                |slot| {
                    outstanding
                        .get(slot as usize)
                        .map_or(0, |v| v.iter().filter(|&&c| c > t).count() as u32)
                },
                &mut self.pf_scratch,
            );
        }
        if pf.adjacent_enabled && !l2_hit {
            // Adjacent-line: complete the 128-byte aligned pair on misses.
            let pair = line ^ 1;
            self.pf_scratch.push(PrefetchReq { line: pair, stream: u32::MAX, to_l1: false });
        }
        let reqs = std::mem::take(&mut self.pf_scratch);
        for r in &reqs {
            self.issue_prefetch(*r, t);
        }
        self.pf_scratch = reqs;
    }

    /// Issue one prefetch request if it is not redundant.
    fn issue_prefetch(&mut self, req: PrefetchReq, t: u64) {
        let m = self.cfg.machine;
        let line = req.line;
        if self.inflight.contains_key(&line) {
            return;
        }
        if req.to_l1 {
            if self.l1.contains(line) {
                return;
            }
            // DCU prefetch: source from L2/L3/DRAM.
            let complete = if self.l2.contains(line) {
                t + m.l2_lat * TICKS
            } else if self.l3.contains(line) {
                t + m.l3_lat * TICKS
            } else {
                self.dram.access(t / TICKS, line, DramOp::Read) * TICKS
            };
            self.counters.prefetch_lines += 1;
            self.inflight.insert(
                line,
                Fill {
                    complete_ticks: complete,
                    dest: FillDest::PrefetchL1,
                    stream: req.stream,
                    dirty: false,
                    demanded: false,
                },
            );
            return;
        }
        // Streamer/adjacent: target L2.
        if self.l2.contains(line) {
            return;
        }
        if self.l3.contains(line) {
            // LLC→L2 move: cheap, model as immediate install.
            self.fill_l2(line, true, false);
            return;
        }
        let complete = self.dram.access(t / TICKS, line, DramOp::Read) * TICKS;
        self.counters.prefetch_lines += 1;
        if let Some(slot) = self.stream_outstanding.get_mut(req.stream as usize) {
            slot.push(complete);
        }
        // Eager install: the prefetched line occupies its L2/L3 set from
        // issue, so competing streams' prefetches conflict realistically
        // (Figure 5). Timing stays in `inflight` until completion.
        self.fill_l3_prefetch(line);
        self.fill_l2(line, true, false);
        self.inflight.insert(
            line,
            Fill {
                complete_ticks: complete,
                dest: FillDest::PrefetchL2,
                stream: req.stream,
                dirty: false,
                demanded: false,
            },
        );
    }

    /// Install every completed in-flight fill (bounded lazy sweep): demand
    /// fills must eventually land so dirty lines write back and warm state
    /// persists, even for lines the trace never touches again.
    fn sweep_completed(&mut self, t: u64) {
        let mut landed: Vec<(u64, Fill)> = Vec::new();
        self.inflight.retain(|&line, f| {
            if f.complete_ticks <= t {
                landed.push((line, *f));
                false
            } else {
                true
            }
        });
        for (line, f) in landed {
            if f.dest != FillDest::PrefetchL2 {
                self.install_fill(line, f);
            }
        }
    }

    /// Install a landed fill into the hierarchy.
    fn install_fill(&mut self, line: u64, f: Fill) {
        match f.dest {
            FillDest::Demand => {
                self.fill_l3(line);
                self.fill_l2(line, false, false);
                self.fill_l1(line, f.dirty);
            }
            FillDest::PrefetchL2 => {
                // `dirty` set when an RFO merged with this prefetch.
                self.fill_l3_prefetch(line);
                self.fill_l2(line, true, f.dirty);
            }
            FillDest::PrefetchL1 => {
                self.fill_l2(line, true, false);
                self.fill_l1(line, f.dirty);
            }
        }
    }

    fn fill_l1(&mut self, line: u64, dirty: bool) {
        if let Some(ev) = self.l1.insert(line, false, dirty) {
            if ev.dirty {
                // Write-back to L2 (present under inclusion; mark dirty).
                self.l2.mark_dirty(ev.line);
            }
        }
    }

    fn fill_l2(&mut self, line: u64, prefetch: bool, dirty: bool) {
        if let Some(ev) = self.l2.insert(line, prefetch, dirty) {
            if ev.dirty {
                self.l3.mark_dirty(ev.line);
            }
        }
    }

    fn fill_l3(&mut self, line: u64) {
        self.fill_l3_inner(line, false);
    }

    fn fill_l3_prefetch(&mut self, line: u64) {
        self.fill_l3_inner(line, true);
    }

    fn fill_l3_inner(&mut self, line: u64, prefetch: bool) {
        if let Some(ev) = self.l3.insert(line, prefetch, false) {
            // Inclusive LLC: back-invalidate inner levels.
            let mut dirty = ev.dirty;
            dirty |= self.l1.invalidate(ev.line);
            dirty |= self.l2.invalidate(ev.line);
            if dirty {
                // Victim write-back consumes a DRAM service slot.
                self.dram.access(self.last_retire / TICKS, ev.line, DramOp::WriteLine);
            }
        }
    }

    /// Non-temporal store path: write-combining buffers, no allocation.
    fn step_nt_store(&mut self, acc: Access, t: u64) -> (u64, Depth) {
        let m = self.cfg.machine;
        // Coherence: NT stores to cached lines must evict them first.
        let line = addr::line_of(acc.addr);
        if self.l1.contains(line) {
            self.l1.invalidate(line);
        }
        if self.l2.contains(line) {
            self.l2.invalidate(line);
        }
        if self.l3.contains(line) {
            self.l3.invalidate(line);
        }
        if let Some(flush) = self.wc.store(t / TICKS, acc.addr, acc.size) {
            let op = if flush.full { DramOp::WriteLine } else { DramOp::WritePartial };
            self.dram.access(flush.at, flush.line, op);
        }
        // The store itself retires quickly; backpressure appears when the
        // DRAM write queue runs far ahead of the core — model by gating on
        // the channel's next-free time once it exceeds a window.
        let backlog_ticks = (self.dram.next_free() * TICKS).saturating_sub(t);
        let allowed = 64 * TICKS * m.wc.entries as u64;
        let ready = if backlog_ticks > allowed { t + (backlog_ticks - allowed) } else { t } + TICKS;
        (ready, if backlog_ticks > allowed { Depth::Dram } else { Depth::L1Hit })
    }

    /// Closing `mfence`: drain write-combining buffers and wait for every
    /// outstanding operation.
    pub fn fence(&mut self) {
        let t = self.last_retire.max(self.issue_ticks_cursor);
        let mut done = t;
        // Land everything outstanding so warm state persists across runs.
        self.sweep_completed(u64::MAX);
        for flush in self.wc.drain(t / TICKS) {
            let op = if flush.full { DramOp::WriteLine } else { DramOp::WritePartial };
            let c = self.dram.access(flush.at, flush.line, op) * TICKS;
            done = done.max(c);
        }
        for f in self.inflight.values() {
            if f.dest == FillDest::Demand {
                done = done.max(f.complete_ticks);
            }
        }
        done = done.max(self.dram.next_free() * TICKS);
        if done > self.last_retire {
            let stall = (done - self.last_retire) / TICKS;
            self.counters.stalls_total += stall;
            self.counters.stalls_mem_any += stall;
        }
        self.last_retire = done;
    }

    /// Snapshot the metrics.
    pub fn result(&self) -> RunResult {
        let mut c = self.counters;
        c.cycles = self.last_retire / TICKS;
        RunResult {
            counters: c,
            l1: self.l1.stats,
            l2: self.l2.stats,
            l3: self.l3.stats,
            dram: self.dram.stats,
            wc: self.wc.stats,
            tlb: self.tlb.stats,
            streamer: self.streamer.stats,
            freq_ghz: self.cfg.machine.freq_ghz,
        }
    }

    /// Full reset: cold caches, cleared counters.
    pub fn reset(&mut self) {
        self.l1.reset();
        self.l2.reset();
        self.l3.reset();
        self.tlb.reset();
        self.dram.reset();
        self.wc.reset();
        self.streamer.reset();
        self.dcu.reset();
        self.ipstride.reset();
        self.inflight.clear();
        self.lfb.clear();
        for s in &mut self.stream_outstanding {
            s.clear();
        }
        self.retire_ring.clear();
        self.issue_ticks_cursor = 0;
        self.last_retire = 0;
        self.counters = Counters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::trace::{Access, Op};

    fn engine(prefetch: bool) -> Engine {
        Engine::new(EngineConfig::new(coffee_lake()).with_prefetch(prefetch).with_huge_pages(true))
    }

    /// Sequential aligned 32 B loads over `bytes` of memory.
    fn seq_loads(bytes: u64) -> impl Iterator<Item = Access> {
        (0..bytes / 32).map(|i| Access::new(i * 32, Op::Load, 32, (i % 32) as u32))
    }

    /// `n` concurrent strides covering `bytes` total, grouped arrangement,
    /// 32 unroll slots. Stride spans use an odd line count so concurrent
    /// streams spread over cache sets (the non-power-of-two §4 setup).
    fn strided_loads(bytes: u64, n: u64) -> Vec<Access> {
        let stride_bytes = ((bytes / n / 64) | 1) * 64;
        let per = stride_bytes / 32; // vectors per stride
        let unrolls_per_stride = 32 / n.min(32);
        let mut out = Vec::new();
        let mut pos = 0u64;
        while pos < per {
            for s in 0..n {
                for u in 0..unrolls_per_stride {
                    if pos + u < per {
                        let ip = (s * unrolls_per_stride + u) as u32;
                        out.push(Access::new(s * stride_bytes + (pos + u) * 32, Op::Load, 32, ip));
                    }
                }
            }
            pos += unrolls_per_stride;
        }
        out
    }

    const MIB: u64 = 1 << 20;

    #[test]
    fn sequential_read_beats_prefetch_off() {
        let bytes = 8 * MIB;
        let mut on = engine(true);
        let r_on = on.run(seq_loads(bytes));
        let mut off = engine(false);
        let r_off = off.run(seq_loads(bytes));
        assert!(
            r_on.throughput_gib() > r_off.throughput_gib() * 1.2,
            "prefetch on {:.2} GiB/s must beat off {:.2} GiB/s",
            r_on.throughput_gib(),
            r_off.throughput_gib()
        );
    }

    #[test]
    fn multi_stride_beats_single_stride_with_prefetch() {
        let bytes = 16 * MIB;
        let mut e1 = engine(true);
        let r1 = e1.run(strided_loads(bytes, 1));
        let mut e8 = engine(true);
        let r8 = e8.run(strided_loads(bytes, 8));
        assert!(
            r8.throughput_gib() > r1.throughput_gib() * 1.1,
            "8 strides {:.2} must beat 1 stride {:.2}",
            r8.throughput_gib(),
            r1.throughput_gib()
        );
    }

    #[test]
    fn multi_stride_does_not_help_without_prefetch() {
        let bytes = 16 * MIB;
        let mut e1 = engine(false);
        let r1 = e1.run(strided_loads(bytes, 1));
        let mut e8 = engine(false);
        let r8 = e8.run(strided_loads(bytes, 8));
        assert!(
            r8.throughput_gib() <= r1.throughput_gib() * 1.05,
            "without prefetch 8 strides {:.2} must not beat 1 stride {:.2}",
            r8.throughput_gib(),
            r1.throughput_gib()
        );
    }

    #[test]
    fn l1_hit_ratio_is_half_for_streaming_reads() {
        let mut e = engine(true);
        let r = e.run(seq_loads(8 * MIB));
        let ratio = r.l1.hit_ratio();
        assert!(
            (ratio - 0.5).abs() < 0.02,
            "Figure 4: L1 hit ratio pinned at 0.5, got {ratio:.3}"
        );
    }

    #[test]
    fn l2_hit_ratio_rises_with_strides() {
        let bytes = 16 * MIB;
        let mut e1 = engine(true);
        let r1 = e1.run(strided_loads(bytes, 1));
        let mut e16 = engine(true);
        let r16 = e16.run(strided_loads(bytes, 16));
        assert!(
            r16.l2.hit_ratio() > r1.l2.hit_ratio() + 0.1,
            "L2 hit ratio must rise: 1-stride {:.3} vs 16-stride {:.3}",
            r1.l2.hit_ratio(),
            r16.l2.hit_ratio()
        );
    }

    #[test]
    fn prefetch_off_zeroes_l2_l3_hit_ratio() {
        let mut e = engine(false);
        let r = e.run(seq_loads(8 * MIB));
        assert!(r.l2.hit_ratio() < 0.05, "no reuse, no prefetch => no L2 hits");
        assert!(r.l3.hit_ratio() < 0.05);
    }

    #[test]
    fn counters_satisfy_subset_invariant() {
        for pf in [false, true] {
            for n in [1, 4, 16] {
                let mut e = engine(pf);
                let r = e.run(strided_loads(8 * MIB, n));
                assert!(r.counters.subset_invariant_holds(), "pf={pf} n={n}: {:?}", r.counters);
            }
        }
    }

    #[test]
    fn stores_consume_write_bandwidth() {
        // Footprint must dwarf the 12 MiB L3 so most dirty lines actually
        // write back (at 60 MiB, ~80% of lines are evicted dirty).
        let bytes = 60 * MIB;
        let mut e = engine(true);
        let loads = e.run(seq_loads(bytes)).throughput_gib();
        let mut e2 = engine(true);
        let stores = e2
            .run((0..bytes / 32).map(|i| Access::new(i * 32, Op::Store, 32, (i % 32) as u32)))
            .throughput_gib();
        assert!(
            stores < loads * 0.85,
            "RFO+writeback store stream {stores:.2} must trail read stream {loads:.2}"
        );
    }

    #[test]
    fn nt_store_grouped_beats_interleaved_many_strides() {
        let bytes = 8 * MIB;
        let n = 16u64;
        let per = bytes / n; // bytes per stride
        // Grouped: finish each line before next stride touches anything.
        let mut grouped = Vec::new();
        let mut interleaved = Vec::new();
        let vectors_per_stride = per / 32;
        for v in 0..vectors_per_stride {
            for s in 0..n {
                interleaved.push(Access::new(s * per + v * 32, Op::StoreNt, 32, s as u32));
            }
        }
        for chunk in 0..vectors_per_stride / 2 {
            for s in 0..n {
                for half in 0..2u64 {
                    grouped.push(Access::new(
                        s * per + chunk * 64 + half * 32,
                        Op::StoreNt,
                        32,
                        s as u32,
                    ));
                }
            }
        }
        let mut eg = engine(true);
        let tg = eg.run(grouped).throughput_gib();
        let mut ei = engine(true);
        let ti = ei.run(interleaved).throughput_gib();
        assert!(
            tg > ti * 2.0,
            "grouped NT {tg:.2} GiB/s must dwarf interleaved NT {ti:.2} GiB/s (write-combining)"
        );
    }

    #[test]
    fn unaligned_loads_slightly_slower() {
        let bytes = 8 * MIB;
        let mut ea = engine(true);
        let ta = ea.run(seq_loads(bytes)).throughput_gib();
        let mut eu = engine(true);
        let tu = eu
            .run((0..bytes / 32 - 1).map(|i| Access::new(i * 32 + 4, Op::LoadU, 32, (i % 32) as u32)))
            .throughput_gib();
        assert!(tu < ta, "unaligned {tu:.2} must trail aligned {ta:.2}");
        assert!(tu > ta * 0.7, "but not by much");
    }

    #[test]
    fn throughput_below_model_roofline() {
        let m = coffee_lake();
        let mut e = engine(true);
        let r = e.run(strided_loads(16 * MIB, 16));
        assert!(r.throughput_gib() <= m.model_peak_gib() * 1.001);
    }

    #[test]
    fn warmup_then_measure_keeps_cache_state() {
        let mut e = engine(true);
        // Warm with the first 4 MiB...
        e.warmup(seq_loads(4 * MIB));
        // ...measure re-reading the same 4 MiB minus what L3 can hold: the
        // first 12 MiB fit nowhere fully, but re-reading 4 MiB after warmup
        // finds a good chunk in L3 (12 MiB L3, nothing else touched).
        let r = e.run(seq_loads(4 * MIB));
        assert!(
            r.l3.hit_ratio() > 0.5,
            "warm L3 must serve re-read, ratio {:.3}",
            r.l3.hit_ratio()
        );
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut e = engine(true);
        e.run(seq_loads(MIB));
        e.reset();
        let r = e.run(seq_loads(MIB));
        assert_eq!(r.l3.hit_ratio(), 0.0, "cold again after reset");
    }
}
