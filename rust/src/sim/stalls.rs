//! Stall stage: attribution of retirement gaps to the memory hierarchy.
//!
//! Each retirement gap beyond the issue cost (from [`super::issue`]) is
//! charged to the deepest level the blocking access had to reach,
//! mirroring the subset semantics of the `CYCLE_ACTIVITY.STALLS_*` events
//! (`STALLS_L3_MISS ⊆ STALLS_L2_MISS ⊆ STALLS_L1D_MISS ⊆ MEM_ANY ⊆
//! TOTAL`) — see [`super::counters`].

use super::counters::Counters;
use super::TICKS;

/// Deepest level a demand access had to reach (for stall attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Depth {
    L1Hit,
    L2Hit,
    L3Hit,
    Dram,
}

/// Stall attribution and `perf`-style counter emulation. Owns the run's
/// [`Counters`]; the engine funnels every event through here.
#[derive(Debug, Default)]
pub struct StallModel {
    counters: Counters,
}

impl StallModel {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Mutable access for events recorded outside this stage (prefetch
    /// issue counts, DRAM demand lines, merges).
    pub fn counters_mut(&mut self) -> &mut Counters {
        &mut self.counters
    }

    /// Account one retired access and its data movement.
    pub fn record_access(&mut self, is_store: bool, size: u32) {
        self.counters.accesses += 1;
        if is_store {
            self.counters.bytes_written += size as u64;
        } else {
            self.counters.bytes_read += size as u64;
        }
    }

    /// Account added TLB translation cycles.
    pub fn record_tlb(&mut self, cycles: u64) {
        self.counters.tlb_cycles += cycles;
    }

    /// Attribute a retirement gap (`stall_ticks`, already net of the issue
    /// cost) to the deepest level the blocking access reached.
    pub fn attribute(&mut self, depth: Depth, stall_ticks: u64) {
        if stall_ticks == 0 {
            return;
        }
        let stall = stall_ticks / TICKS;
        self.counters.stalls_total += stall;
        self.counters.stalls_mem_any += stall;
        match depth {
            Depth::L1Hit => {}
            Depth::L2Hit => self.counters.stalls_l1d_miss += stall,
            Depth::L3Hit => {
                self.counters.stalls_l1d_miss += stall;
                self.counters.stalls_l2_miss += stall;
            }
            Depth::Dram => {
                self.counters.stalls_l1d_miss += stall;
                self.counters.stalls_l2_miss += stall;
                self.counters.stalls_l3_miss += stall;
            }
        }
    }

    /// Account the closing-fence wait (`done` − `last_retire`) as memory
    /// stall without a level attribution.
    pub fn record_fence_wait(&mut self, last_retire: u64, done: u64) {
        if done > last_retire {
            let stall = (done - last_retire) / TICKS;
            self.counters.stalls_total += stall;
            self.counters.stalls_mem_any += stall;
        }
    }

    /// Snapshot the counters with the final cycle count filled in.
    pub fn snapshot(&self, last_retire_ticks: u64) -> Counters {
        let mut c = self.counters;
        c.cycles = last_retire_ticks / TICKS;
        c
    }

    pub fn reset(&mut self) {
        self.counters = Counters::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subset_invariant_by_construction() {
        let mut s = StallModel::new();
        s.attribute(Depth::Dram, 40);
        s.attribute(Depth::L2Hit, 12);
        s.attribute(Depth::L1Hit, 8);
        let c = s.snapshot(1000 * TICKS);
        assert!(c.subset_invariant_holds(), "{c:?}");
        assert_eq!(c.stalls_total, 15);
        assert_eq!(c.stalls_l1d_miss, 13);
        assert_eq!(c.stalls_l2_miss, 10);
        assert_eq!(c.stalls_l3_miss, 10);
    }

    #[test]
    fn sub_cycle_gaps_do_not_count() {
        let mut s = StallModel::new();
        s.attribute(Depth::Dram, TICKS - 1);
        assert_eq!(s.counters().stalls_total, 0);
    }

    #[test]
    fn fence_wait_counts_as_mem_any() {
        let mut s = StallModel::new();
        s.record_fence_wait(100, 100 + 8 * TICKS);
        assert_eq!(s.counters().stalls_total, 8);
        assert_eq!(s.counters().stalls_mem_any, 8);
        assert_eq!(s.counters().stalls_l1d_miss, 0);
    }
}
