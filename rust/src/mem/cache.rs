//! Set-associative cache model with pluggable replacement.
//!
//! The cache is indexed by *line address* (byte address >> 6). Set selection
//! uses the low bits of the line address — exactly the power-of-two indexing
//! that makes equally-spaced strides collide in §4.5 of the paper ("Blocks
//! spaced equally at a specific power of two are assigned to the same cache
//! set").
//!
//! The model tracks, per line, whether it was installed by a prefetch and
//! whether it has been referenced by a demand access since. This lets the
//! simulator report the *useless prefetch* (prefetched-but-evicted-unused)
//! statistic that explains the Figure-5 collapse.
//!
//! §Perf: storage is struct-of-arrays (see ARCHITECTURE.md §Perf). Way
//! lookup is a sentinel-tag scan over a contiguous `u64` slice — validity is
//! folded into the tag, so the hot compare is a single branch-light equality
//! pass with no per-way flag loads. Metadata bits and recency stamps live in
//! separate parallel arrays and are only touched on the matched way.

/// Replacement policy for a set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replacement {
    /// True least-recently-used via a monotone stamp.
    Lru,
    /// Tree-PLRU approximation (what real L2/L3s implement).
    TreePlru,
    /// Pseudo-random replacement (xorshift), a lower bound on policy quality.
    Random,
}

/// Static geometry + policy of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Total capacity in bytes. Must be `ways * n_sets * 64`.
    pub size_bytes: u64,
    /// Associativity.
    pub ways: u32,
    /// Replacement policy.
    pub replacement: Replacement,
}

impl CacheConfig {
    pub const fn new(size_bytes: u64, ways: u32, replacement: Replacement) -> Self {
        Self { size_bytes, ways, replacement }
    }

    /// Number of sets implied by the geometry.
    pub fn n_sets(&self) -> u64 {
        self.size_bytes / super::addr::LINE_BYTES / self.ways as u64
    }

    /// §4.5 collision diagnostic: how many *distinct* sets the head
    /// lines of an `strides`-way decomposition of a `bytes` array index
    /// into. Stream k starts at byte `k * (bytes / strides)`; when the
    /// span is a power of two that spacing is a multiple of the set
    /// period, every head aliases to one set, and the streams fight over
    /// its `ways` lines. Mirrors [`Cache::set_index`]'s mask-plus-slice
    /// math exactly, so figure5.csv reports what the simulated cache
    /// actually does (including sliced non-power-of-two LLCs).
    pub fn stride_head_sets(&self, strides: u32, bytes: u64) -> u64 {
        let n_sets = self.n_sets();
        let sets_per_slice = n_sets & n_sets.wrapping_neg();
        let n_slices = n_sets / sets_per_slice;
        let set_mask = sets_per_slice - 1;
        let shift = sets_per_slice.trailing_zeros();
        let strides = strides.max(1) as u64;
        let span = bytes / strides;
        let mut sets = std::collections::HashSet::new();
        for k in 0..strides {
            let line = (k * span) / super::addr::LINE_BYTES;
            let within = line & set_mask;
            let set = if n_slices == 1 {
                within
            } else {
                let slice = ((line >> shift) & 3) % n_slices;
                slice * (set_mask + 1) + within
            };
            sets.insert(set);
        }
        sets.len() as u64
    }
}

/// A line evicted by [`Cache::insert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Eviction {
    /// Line address of the victim.
    pub line: u64,
    /// Victim was dirty (needs write-back).
    pub dirty: bool,
    /// Victim was installed by a prefetch and never referenced by a demand
    /// access — a wasted prefetch (the Figure-5 failure mode).
    pub unused_prefetch: bool,
}

/// Tag value marking an empty way. Line addresses are byte addresses
/// shifted right by 6, so no reachable line can collide with it.
const INVALID_TAG: u64 = u64::MAX;

/// Per-way metadata bits (packed into one byte per way).
const META_DIRTY: u8 = 1 << 0;
/// Installed by a prefetch engine.
const META_PREFETCHED: u8 = 1 << 1;
/// Referenced by a demand access since installation.
const META_REFERENCED: u8 = 1 << 2;

/// Aggregate statistics for one cache level.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub demand_hits: u64,
    pub demand_misses: u64,
    /// Demand hits on lines a prefetcher installed.
    pub prefetch_hits: u64,
    pub evictions: u64,
    pub dirty_evictions: u64,
    /// Evicted lines that a prefetcher installed and no demand ever touched.
    pub unused_prefetch_evictions: u64,
    pub prefetch_installs: u64,
}

impl CacheStats {
    /// Demand hit ratio: hits / (hits + misses); the quantity Figure 4 plots.
    pub fn hit_ratio(&self) -> f64 {
        let total = self.demand_hits + self.demand_misses;
        if total == 0 {
            0.0
        } else {
            self.demand_hits as f64 / total as f64
        }
    }
}

/// One level of set-associative cache, stored struct-of-arrays: the three
/// parallel vectors below are indexed `set * ways + way`.
pub struct Cache {
    cfg: CacheConfig,
    n_sets: u64,
    /// `sets_per_slice - 1`. Power-of-two caches are one "slice".
    set_mask: u64,
    /// Non-power-of-two LLCs (Coffee Lake: 12 MiB = 3×4 MiB worth of sets)
    /// are built from `n_slices` power-of-two slices; the slice is chosen
    /// by an address hash, the set *within* the slice by the low index
    /// bits. Power-of-two stride spacings therefore alias to the same
    /// within-slice set — the §4.5 collision mechanism survives slicing,
    /// exactly as on the real part.
    n_slices: u64,
    shift: u32,
    /// Line tag per way; [`INVALID_TAG`] = empty way (validity folded in).
    tags: Vec<u64>,
    /// Packed `META_*` bits per way.
    meta: Vec<u8>,
    /// LRU stamp (monotone counter) per way — also reused as PLRU hint.
    stamps: Vec<u64>,
    clock: u64,
    rng: u64,
    pub stats: CacheStats,
}

/// Index of `line` within one set's tag slice, if resident. Invalid ways
/// hold [`INVALID_TAG`] and can never match a real line, so this is a pure
/// equality scan — the shared way-scan helper of every lookup-shaped path.
#[inline(always)]
fn way_of(tags: &[u64], line: u64) -> Option<usize> {
    tags.iter().position(|&t| t == line)
}

impl Cache {
    /// Build a cache. Power-of-two set counts use mask indexing; others are
    /// decomposed into `odd × pow2` slices (see struct docs).
    pub fn new(cfg: CacheConfig) -> Self {
        let n_sets = cfg.n_sets();
        assert!(n_sets >= 1, "cache must have at least one set");
        assert!(cfg.ways >= 1);
        // Largest power-of-two divisor = sets per slice.
        let sets_per_slice = n_sets & n_sets.wrapping_neg();
        let n_slices = n_sets / sets_per_slice;
        let n_ways = (n_sets * cfg.ways as u64) as usize;
        Self {
            cfg,
            n_sets,
            set_mask: sets_per_slice - 1,
            n_slices,
            shift: sets_per_slice.trailing_zeros(),
            tags: vec![INVALID_TAG; n_ways],
            meta: vec![0; n_ways],
            stamps: vec![0; n_ways],
            clock: 0,
            rng: 0x9e3779b97f4a7c15,
            stats: CacheStats::default(),
        }
    }

    /// Geometry this cache was built with.
    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    #[inline(always)]
    fn set_index(&self, line: u64) -> u64 {
        let within = line & self.set_mask;
        if self.n_slices == 1 {
            return within;
        }
        // Slice selection from a narrow window of bits just above the
        // within-slice index: sequential data rotates through the slices
        // every `sets_per_slice` lines (capacity distributes), while
        // streams spaced at large powers of two land in the *same* slice
        // and the *same* within-slice set — the §4.5 aliasing the paper
        // measures on the real sliced LLC (its hash folds to the same
        // slice for the 2 GiB / n spacings of the experiment).
        let slice = ((line >> self.shift) & 3) % self.n_slices;
        slice * (self.set_mask + 1) + within
    }

    /// First way index (into the parallel arrays) of the set holding `line`.
    #[inline(always)]
    fn set_base(&self, line: u64) -> usize {
        self.set_index(line) as usize * self.cfg.ways as usize
    }

    /// Demand lookup. Updates recency and statistics. Returns `true` on hit.
    pub fn demand_lookup(&mut self, line: u64) -> bool {
        self.clock += 1;
        let base = self.set_base(line);
        let ways = self.cfg.ways as usize;
        match way_of(&self.tags[base..base + ways], line) {
            Some(w) => {
                let i = base + w;
                self.stamps[i] = self.clock;
                let m = self.meta[i];
                if m & (META_PREFETCHED | META_REFERENCED) == META_PREFETCHED {
                    self.stats.prefetch_hits += 1;
                }
                self.meta[i] = m | META_REFERENCED;
                self.stats.demand_hits += 1;
                true
            }
            None => {
                self.stats.demand_misses += 1;
                false
            }
        }
    }

    /// Non-destructive probe: no recency update, no statistics.
    pub fn contains(&self, line: u64) -> bool {
        let base = self.set_base(line);
        way_of(&self.tags[base..base + self.cfg.ways as usize], line).is_some()
    }

    /// Mark a resident line dirty (store hit). No-op when absent.
    pub fn mark_dirty(&mut self, line: u64) {
        let base = self.set_base(line);
        if let Some(w) = way_of(&self.tags[base..base + self.cfg.ways as usize], line) {
            self.meta[base + w] |= META_DIRTY;
        }
    }

    /// Install a line (demand fill when `prefetch == false`). Returns the
    /// victim if a valid line had to be evicted. Installing a line that is
    /// already resident refreshes it in place and returns `None`.
    pub fn insert(&mut self, line: u64, prefetch: bool, dirty: bool) -> Option<Eviction> {
        debug_assert_ne!(line, INVALID_TAG, "line address collides with the empty-way sentinel");
        self.clock += 1;
        let clock = self.clock;
        if prefetch {
            self.stats.prefetch_installs += 1;
        }
        let base = self.set_base(line);
        let ways = self.cfg.ways as usize;
        let set_tags = &self.tags[base..base + ways];
        let install_meta = (dirty as u8 * META_DIRTY)
            | (prefetch as u8 * META_PREFETCHED)
            | (!prefetch as u8 * META_REFERENCED);

        // Already resident: refresh.
        if let Some(w) = way_of(set_tags, line) {
            let i = base + w;
            self.stamps[i] = clock;
            self.meta[i] |= (dirty as u8 * META_DIRTY) | (!prefetch as u8 * META_REFERENCED);
            return None;
        }

        // Invalid way available (first empty way in way order, as the AoS
        // layout's scan picked it).
        if let Some(w) = way_of(set_tags, INVALID_TAG) {
            let i = base + w;
            self.tags[i] = line;
            self.meta[i] = install_meta;
            self.stamps[i] = clock;
            return None;
        }

        // Choose a victim (every way valid from here on).
        let set_stamps = &self.stamps[base..base + ways];
        let victim_off = match self.cfg.replacement {
            Replacement::Lru => {
                let mut best = 0usize;
                let mut best_stamp = u64::MAX;
                for (i, &s) in set_stamps.iter().enumerate() {
                    if s < best_stamp {
                        best_stamp = s;
                        best = i;
                    }
                }
                best
            }
            Replacement::TreePlru => {
                // Approximate tree-PLRU: descend away from the recently
                // used half at every level (halves compared by max stamp,
                // ties to the left) until a single way remains. The total
                // work is the geometric series ways + ways/2 + … = O(ways)
                // plain u64 maxes over the contiguous stamp slice.
                let (mut lo, mut hi) = (0usize, ways);
                while hi - lo > 1 {
                    let mid = (lo + hi) / 2;
                    let mut left_max = 0u64;
                    for &s in &set_stamps[lo..mid] {
                        if s > left_max {
                            left_max = s;
                        }
                    }
                    let mut right_max = 0u64;
                    for &s in &set_stamps[mid..hi] {
                        if s > right_max {
                            right_max = s;
                        }
                    }
                    if left_max <= right_max {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                lo
            }
            Replacement::Random => {
                // xorshift64*
                self.rng ^= self.rng << 13;
                self.rng ^= self.rng >> 7;
                self.rng ^= self.rng << 17;
                (self.rng % self.cfg.ways as u64) as usize
            }
        };

        let idx = base + victim_off;
        let victim_meta = self.meta[idx];
        let victim_line = self.tags[idx];
        self.stats.evictions += 1;
        let victim_dirty = victim_meta & META_DIRTY != 0;
        if victim_dirty {
            self.stats.dirty_evictions += 1;
        }
        let unused_prefetch =
            victim_meta & (META_PREFETCHED | META_REFERENCED) == META_PREFETCHED;
        if unused_prefetch {
            self.stats.unused_prefetch_evictions += 1;
        }
        self.tags[idx] = line;
        self.meta[idx] = install_meta;
        self.stamps[idx] = clock;
        Some(Eviction { line: victim_line, dirty: victim_dirty, unused_prefetch })
    }

    /// Invalidate a line (inclusive-hierarchy back-invalidation). Returns
    /// whether the line was present and dirty.
    pub fn invalidate(&mut self, line: u64) -> bool {
        let base = self.set_base(line);
        if let Some(w) = way_of(&self.tags[base..base + self.cfg.ways as usize], line) {
            let i = base + w;
            let dirty = self.meta[i] & META_DIRTY != 0;
            self.tags[i] = INVALID_TAG;
            return dirty;
        }
        false
    }

    /// Drop all contents and statistics (between experiment repetitions).
    /// Restores the exact post-construction state — including the
    /// replacement RNG, so `Replacement::Random` runs reproduce too.
    pub fn reset(&mut self) {
        self.tags.fill(INVALID_TAG);
        self.meta.fill(0);
        self.stamps.fill(0);
        self.clock = 0;
        self.rng = 0x9e3779b97f4a7c15;
        self.stats = CacheStats::default();
    }

    /// Number of valid lines currently resident (test / debug helper).
    pub fn resident_lines(&self) -> usize {
        self.tags.iter().filter(|&&t| t != INVALID_TAG).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64B = 512 B.
        Cache::new(CacheConfig::new(512, 2, Replacement::Lru))
    }

    #[test]
    fn geometry() {
        let c = tiny();
        assert_eq!(c.config().n_sets(), 4);
    }

    #[test]
    fn stride_head_sets_collapse_on_pow2_spans() {
        // 32 KiB / 8-way = 64 sets (an L1-shaped geometry).
        let cfg = CacheConfig::new(32 * 1024, 8, Replacement::Lru);
        // Power-of-two span: every head offset is a multiple of 2 MiB,
        // so all 32 streams alias to one set — total collapse.
        assert_eq!(cfg.stride_head_sets(32, 64 * 1024 * 1024), 1);
        // The paper's odd-span arrays (32 × 30517 lines) spread the
        // heads: 30517 ≡ 53 (mod 64) and gcd(53, 64) = 1, so all 32
        // heads land in distinct sets.
        assert_eq!(cfg.stride_head_sets(32, 32 * 30517 * 64), 32);
        // One stream trivially touches one set.
        assert_eq!(cfg.stride_head_sets(1, 64 * 1024 * 1024), 1);
    }

    #[test]
    fn hit_after_insert() {
        let mut c = tiny();
        assert!(!c.demand_lookup(10));
        c.insert(10, false, false);
        assert!(c.demand_lookup(10));
        assert_eq!(c.stats.demand_hits, 1);
        assert_eq!(c.stats.demand_misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = tiny();
        // Lines 0, 4, 8 all map to set 0 (4 sets). 2 ways.
        c.insert(0, false, false);
        c.insert(4, false, false);
        c.demand_lookup(0); // 0 is now MRU
        let ev = c.insert(8, false, false).expect("must evict");
        assert_eq!(ev.line, 4, "LRU victim is line 4");
        assert!(c.contains(0));
        assert!(c.contains(8));
        assert!(!c.contains(4));
    }

    #[test]
    fn same_set_aliasing_at_power_of_two_spacing() {
        // The §4.5 mechanism: line addresses spaced by n_sets alias.
        let mut c = tiny();
        for i in 0..3 {
            c.insert(i * 4, false, false); // all set 0
        }
        assert_eq!(c.resident_lines(), 2, "third aliasing line evicted one");
    }

    #[test]
    fn dirty_eviction_reported() {
        let mut c = tiny();
        c.insert(0, false, true);
        c.insert(4, false, false);
        let ev = c.insert(8, false, false).unwrap();
        assert!(ev.dirty, "victim 0 was dirty");
        assert_eq!(c.stats.dirty_evictions, 1);
    }

    #[test]
    fn unused_prefetch_eviction_reported() {
        let mut c = tiny();
        c.insert(0, true, false); // prefetch install, never referenced
        c.insert(4, false, false);
        let ev = c.insert(8, false, false).unwrap();
        assert!(ev.unused_prefetch);
        assert_eq!(c.stats.unused_prefetch_evictions, 1);
    }

    #[test]
    fn prefetch_then_demand_counts_prefetch_hit() {
        let mut c = tiny();
        c.insert(0, true, false);
        assert!(c.demand_lookup(0));
        assert_eq!(c.stats.prefetch_hits, 1);
        // Second demand is a plain hit, not another prefetch hit.
        assert!(c.demand_lookup(0));
        assert_eq!(c.stats.prefetch_hits, 1);
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut c = tiny();
        c.insert(0, false, false);
        assert!(c.insert(0, false, true).is_none());
        c.insert(4, false, false);
        // 0 was refreshed after 4? No: 0 refreshed before 4 inserted; LRU is 0.
        let ev = c.insert(8, false, false).unwrap();
        assert_eq!(ev.line, 0);
        assert!(ev.dirty, "refresh carried dirty bit");
    }

    #[test]
    fn invalidate_removes() {
        let mut c = tiny();
        c.insert(0, false, true);
        assert!(c.invalidate(0), "was dirty");
        assert!(!c.contains(0));
        assert!(!c.invalidate(0));
    }

    #[test]
    fn invalidated_way_is_refilled_first() {
        let mut c = tiny();
        c.insert(0, false, false);
        c.insert(4, false, false);
        c.invalidate(0);
        // The freed way absorbs the next insert: no eviction.
        assert!(c.insert(8, false, false).is_none());
        assert!(c.contains(4) && c.contains(8));
    }

    #[test]
    fn mark_dirty_then_evict() {
        let mut c = tiny();
        c.insert(0, false, false);
        c.mark_dirty(0);
        c.insert(4, false, false);
        c.insert(8, false, false);
        assert_eq!(c.stats.dirty_evictions, 1);
    }

    #[test]
    fn random_replacement_stays_in_set() {
        let mut c = Cache::new(CacheConfig::new(512, 2, Replacement::Random));
        for i in 0..16 {
            c.insert(i * 4, false, false);
        }
        // Only set-0 lines inserted; residency never exceeds the 2 ways.
        assert!(c.resident_lines() <= 2);
    }

    #[test]
    fn plru_replacement_evicts_old() {
        let mut c = Cache::new(CacheConfig::new(2048, 8, Replacement::TreePlru));
        // Fill set 0 (4 sets): lines 0,4,...,28.
        for i in 0..8 {
            c.insert(i * 4, false, false);
        }
        // Touch everything but line 0.
        for i in 1..8 {
            c.demand_lookup(i * 4);
        }
        let ev = c.insert(8 * 4, false, false).unwrap();
        assert_eq!(ev.line, 0, "PLRU approximation must victimize the stale line");
    }

    #[test]
    fn hit_ratio_computation() {
        let mut c = tiny();
        c.insert(0, false, false);
        c.demand_lookup(0);
        c.demand_lookup(4);
        assert!((c.stats.hit_ratio() - 0.5).abs() < 1e-12);
    }
}
