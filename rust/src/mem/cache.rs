//! Set-associative cache model with pluggable replacement.
//!
//! The cache is indexed by *line address* (byte address >> 6). Set selection
//! uses the low bits of the line address — exactly the power-of-two indexing
//! that makes equally-spaced strides collide in §4.5 of the paper ("Blocks
//! spaced equally at a specific power of two are assigned to the same cache
//! set").
//!
//! The model tracks, per line, whether it was installed by a prefetch and
//! whether it has been referenced by a demand access since. This lets the
//! simulator report the *useless prefetch* (prefetched-but-evicted-unused)
//! statistic that explains the Figure-5 collapse.

/// Replacement policy for a set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Replacement {
    /// True least-recently-used via a monotone stamp.
    Lru,
    /// Tree-PLRU approximation (what real L2/L3s implement).
    TreePlru,
    /// Pseudo-random replacement (xorshift), a lower bound on policy quality.
    Random,
}

/// Static geometry + policy of one cache level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheConfig {
    /// Total capacity in bytes. Must be `ways * n_sets * 64`.
    pub size_bytes: u64,
    /// Associativity.
    pub ways: u32,
    /// Replacement policy.
    pub replacement: Replacement,
}

impl CacheConfig {
    pub const fn new(size_bytes: u64, ways: u32, replacement: Replacement) -> Self {
        Self { size_bytes, ways, replacement }
    }

    /// Number of sets implied by the geometry.
    pub fn n_sets(&self) -> u64 {
        self.size_bytes / super::addr::LINE_BYTES / self.ways as u64
    }
}

/// A line evicted by [`Cache::insert`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Eviction {
    /// Line address of the victim.
    pub line: u64,
    /// Victim was dirty (needs write-back).
    pub dirty: bool,
    /// Victim was installed by a prefetch and never referenced by a demand
    /// access — a wasted prefetch (the Figure-5 failure mode).
    pub unused_prefetch: bool,
}

#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    /// Line address; `valid` gates interpretation.
    tag: u64,
    valid: bool,
    dirty: bool,
    /// Installed by a prefetch engine.
    prefetched: bool,
    /// Referenced by a demand access since installation.
    referenced: bool,
    /// LRU stamp (monotone counter) — also reused as PLRU hint.
    stamp: u64,
}

/// Aggregate statistics for one cache level.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub demand_hits: u64,
    pub demand_misses: u64,
    /// Demand hits on lines a prefetcher installed.
    pub prefetch_hits: u64,
    pub evictions: u64,
    pub dirty_evictions: u64,
    /// Evicted lines that a prefetcher installed and no demand ever touched.
    pub unused_prefetch_evictions: u64,
    pub prefetch_installs: u64,
}

impl CacheStats {
    /// Demand hit ratio: hits / (hits + misses); the quantity Figure 4 plots.
    pub fn hit_ratio(&self) -> f64 {
        let total = self.demand_hits + self.demand_misses;
        if total == 0 {
            0.0
        } else {
            self.demand_hits as f64 / total as f64
        }
    }
}

/// One level of set-associative cache.
pub struct Cache {
    cfg: CacheConfig,
    n_sets: u64,
    /// `sets_per_slice - 1`. Power-of-two caches are one "slice".
    set_mask: u64,
    /// Non-power-of-two LLCs (Coffee Lake: 12 MiB = 3×4 MiB worth of sets)
    /// are built from `n_slices` power-of-two slices; the slice is chosen
    /// by an address hash, the set *within* the slice by the low index
    /// bits. Power-of-two stride spacings therefore alias to the same
    /// within-slice set — the §4.5 collision mechanism survives slicing,
    /// exactly as on the real part.
    n_slices: u64,
    shift: u32,
    entries: Vec<Entry>,
    clock: u64,
    rng: u64,
    pub stats: CacheStats,
}

impl Cache {
    /// Build a cache. Power-of-two set counts use mask indexing; others are
    /// decomposed into `odd × pow2` slices (see struct docs).
    pub fn new(cfg: CacheConfig) -> Self {
        let n_sets = cfg.n_sets();
        assert!(n_sets >= 1, "cache must have at least one set");
        assert!(cfg.ways >= 1);
        // Largest power-of-two divisor = sets per slice.
        let sets_per_slice = n_sets & n_sets.wrapping_neg();
        let n_slices = n_sets / sets_per_slice;
        Self {
            cfg,
            n_sets,
            set_mask: sets_per_slice - 1,
            n_slices,
            shift: sets_per_slice.trailing_zeros(),
            entries: vec![Entry::default(); (n_sets * cfg.ways as u64) as usize],
            clock: 0,
            rng: 0x9e3779b97f4a7c15,
            stats: CacheStats::default(),
        }
    }

    /// Geometry this cache was built with.
    pub fn config(&self) -> CacheConfig {
        self.cfg
    }

    #[inline(always)]
    fn set_index(&self, line: u64) -> u64 {
        let within = line & self.set_mask;
        if self.n_slices == 1 {
            return within;
        }
        // Slice selection from a narrow window of bits just above the
        // within-slice index: sequential data rotates through the slices
        // every `sets_per_slice` lines (capacity distributes), while
        // streams spaced at large powers of two land in the *same* slice
        // and the *same* within-slice set — the §4.5 aliasing the paper
        // measures on the real sliced LLC (its hash folds to the same
        // slice for the 2 GiB / n spacings of the experiment).
        let slice = ((line >> self.shift) & 3) % self.n_slices;
        slice * (self.set_mask + 1) + within
    }

    #[inline(always)]
    fn set_range(&self, line: u64) -> std::ops::Range<usize> {
        let set = self.set_index(line) as usize * self.cfg.ways as usize;
        set..set + self.cfg.ways as usize
    }

    /// Demand lookup. Updates recency and statistics. Returns `true` on hit.
    pub fn demand_lookup(&mut self, line: u64) -> bool {
        self.clock += 1;
        let clock = self.clock;
        let range = self.set_range(line);
        for e in &mut self.entries[range] {
            if e.valid && e.tag == line {
                e.stamp = clock;
                if e.prefetched && !e.referenced {
                    self.stats.prefetch_hits += 1;
                }
                e.referenced = true;
                self.stats.demand_hits += 1;
                return true;
            }
        }
        self.stats.demand_misses += 1;
        false
    }

    /// Non-destructive probe: no recency update, no statistics.
    pub fn contains(&self, line: u64) -> bool {
        let set = self.set_index(line) as usize * self.cfg.ways as usize;
        self.entries[set..set + self.cfg.ways as usize]
            .iter()
            .any(|e| e.valid && e.tag == line)
    }

    /// Mark a resident line dirty (store hit). No-op when absent.
    pub fn mark_dirty(&mut self, line: u64) {
        let range = self.set_range(line);
        for e in &mut self.entries[range] {
            if e.valid && e.tag == line {
                e.dirty = true;
                return;
            }
        }
    }

    /// Install a line (demand fill when `prefetch == false`). Returns the
    /// victim if a valid line had to be evicted. Installing a line that is
    /// already resident refreshes it in place and returns `None`.
    pub fn insert(&mut self, line: u64, prefetch: bool, dirty: bool) -> Option<Eviction> {
        self.clock += 1;
        let clock = self.clock;
        if prefetch {
            self.stats.prefetch_installs += 1;
        }
        let range = self.set_range(line);

        // Already resident: refresh.
        for e in &mut self.entries[range.clone()] {
            if e.valid && e.tag == line {
                e.stamp = clock;
                e.dirty |= dirty;
                if !prefetch {
                    e.referenced = true;
                }
                return None;
            }
        }

        // Invalid way available.
        for e in &mut self.entries[range.clone()] {
            if !e.valid {
                *e = Entry {
                    tag: line,
                    valid: true,
                    dirty,
                    prefetched: prefetch,
                    referenced: !prefetch,
                    stamp: clock,
                };
                return None;
            }
        }

        // Choose a victim.
        let victim_off = match self.cfg.replacement {
            Replacement::Lru => {
                let mut best = 0usize;
                let mut best_stamp = u64::MAX;
                for (i, e) in self.entries[range.clone()].iter().enumerate() {
                    if e.stamp < best_stamp {
                        best_stamp = e.stamp;
                        best = i;
                    }
                }
                best
            }
            Replacement::TreePlru => {
                // Approximate tree-PLRU: victimize the way whose stamp is
                // older than the set median — cheap and close enough to the
                // hardware policy for the aggregate statistics we report.
                let ways = self.cfg.ways as usize;
                let mut best = 0usize;
                let mut best_stamp = u64::MAX;
                // Walk a tree-like halving: compare halves by max stamp.
                let slice = &self.entries[range.clone()];
                let (mut lo, mut hi) = (0usize, ways);
                while hi - lo > 1 {
                    let mid = (lo + hi) / 2;
                    let left_max = slice[lo..mid].iter().map(|e| e.stamp).max().unwrap();
                    let right_max = slice[mid..hi].iter().map(|e| e.stamp).max().unwrap();
                    if left_max <= right_max {
                        hi = mid;
                    } else {
                        lo = mid;
                    }
                }
                // Within the chosen leaf pair, take the older one.
                for (i, e) in slice.iter().enumerate().take(hi).skip(lo) {
                    if e.stamp < best_stamp {
                        best_stamp = e.stamp;
                        best = i;
                    }
                }
                best
            }
            Replacement::Random => {
                // xorshift64*
                self.rng ^= self.rng << 13;
                self.rng ^= self.rng >> 7;
                self.rng ^= self.rng << 17;
                (self.rng % self.cfg.ways as u64) as usize
            }
        };

        let idx = range.start + victim_off;
        let victim = self.entries[idx];
        self.stats.evictions += 1;
        if victim.dirty {
            self.stats.dirty_evictions += 1;
        }
        let unused_prefetch = victim.prefetched && !victim.referenced;
        if unused_prefetch {
            self.stats.unused_prefetch_evictions += 1;
        }
        self.entries[idx] = Entry {
            tag: line,
            valid: true,
            dirty,
            prefetched: prefetch,
            referenced: !prefetch,
            stamp: clock,
        };
        Some(Eviction { line: victim.tag, dirty: victim.dirty, unused_prefetch })
    }

    /// Invalidate a line (inclusive-hierarchy back-invalidation). Returns
    /// whether the line was present and dirty.
    pub fn invalidate(&mut self, line: u64) -> bool {
        let range = self.set_range(line);
        for e in &mut self.entries[range] {
            if e.valid && e.tag == line {
                let dirty = e.dirty;
                e.valid = false;
                return dirty;
            }
        }
        false
    }

    /// Drop all contents and statistics (between experiment repetitions).
    /// Restores the exact post-construction state — including the
    /// replacement RNG, so `Replacement::Random` runs reproduce too.
    pub fn reset(&mut self) {
        self.entries.fill(Entry::default());
        self.clock = 0;
        self.rng = 0x9e3779b97f4a7c15;
        self.stats = CacheStats::default();
    }

    /// Number of valid lines currently resident (test / debug helper).
    pub fn resident_lines(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets x 2 ways x 64B = 512 B.
        Cache::new(CacheConfig::new(512, 2, Replacement::Lru))
    }

    #[test]
    fn geometry() {
        let c = tiny();
        assert_eq!(c.config().n_sets(), 4);
    }

    #[test]
    fn hit_after_insert() {
        let mut c = tiny();
        assert!(!c.demand_lookup(10));
        c.insert(10, false, false);
        assert!(c.demand_lookup(10));
        assert_eq!(c.stats.demand_hits, 1);
        assert_eq!(c.stats.demand_misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = tiny();
        // Lines 0, 4, 8 all map to set 0 (4 sets). 2 ways.
        c.insert(0, false, false);
        c.insert(4, false, false);
        c.demand_lookup(0); // 0 is now MRU
        let ev = c.insert(8, false, false).expect("must evict");
        assert_eq!(ev.line, 4, "LRU victim is line 4");
        assert!(c.contains(0));
        assert!(c.contains(8));
        assert!(!c.contains(4));
    }

    #[test]
    fn same_set_aliasing_at_power_of_two_spacing() {
        // The §4.5 mechanism: line addresses spaced by n_sets alias.
        let mut c = tiny();
        for i in 0..3 {
            c.insert(i * 4, false, false); // all set 0
        }
        assert_eq!(c.resident_lines(), 2, "third aliasing line evicted one");
    }

    #[test]
    fn dirty_eviction_reported() {
        let mut c = tiny();
        c.insert(0, false, true);
        c.insert(4, false, false);
        let ev = c.insert(8, false, false).unwrap();
        assert!(ev.dirty, "victim 0 was dirty");
        assert_eq!(c.stats.dirty_evictions, 1);
    }

    #[test]
    fn unused_prefetch_eviction_reported() {
        let mut c = tiny();
        c.insert(0, true, false); // prefetch install, never referenced
        c.insert(4, false, false);
        let ev = c.insert(8, false, false).unwrap();
        assert!(ev.unused_prefetch);
        assert_eq!(c.stats.unused_prefetch_evictions, 1);
    }

    #[test]
    fn prefetch_then_demand_counts_prefetch_hit() {
        let mut c = tiny();
        c.insert(0, true, false);
        assert!(c.demand_lookup(0));
        assert_eq!(c.stats.prefetch_hits, 1);
        // Second demand is a plain hit, not another prefetch hit.
        assert!(c.demand_lookup(0));
        assert_eq!(c.stats.prefetch_hits, 1);
    }

    #[test]
    fn reinsert_refreshes_in_place() {
        let mut c = tiny();
        c.insert(0, false, false);
        assert!(c.insert(0, false, true).is_none());
        c.insert(4, false, false);
        // 0 was refreshed after 4? No: 0 refreshed before 4 inserted; LRU is 0.
        let ev = c.insert(8, false, false).unwrap();
        assert_eq!(ev.line, 0);
        assert!(ev.dirty, "refresh carried dirty bit");
    }

    #[test]
    fn invalidate_removes() {
        let mut c = tiny();
        c.insert(0, false, true);
        assert!(c.invalidate(0), "was dirty");
        assert!(!c.contains(0));
        assert!(!c.invalidate(0));
    }

    #[test]
    fn mark_dirty_then_evict() {
        let mut c = tiny();
        c.insert(0, false, false);
        c.mark_dirty(0);
        c.insert(4, false, false);
        c.insert(8, false, false);
        assert_eq!(c.stats.dirty_evictions, 1);
    }

    #[test]
    fn random_replacement_stays_in_set() {
        let mut c = Cache::new(CacheConfig::new(512, 2, Replacement::Random));
        for i in 0..16 {
            c.insert(i * 4, false, false);
        }
        // Only set-0 lines inserted; residency never exceeds the 2 ways.
        assert!(c.resident_lines() <= 2);
    }

    #[test]
    fn plru_replacement_evicts_old() {
        let mut c = Cache::new(CacheConfig::new(2048, 8, Replacement::TreePlru));
        // Fill set 0 (4 sets): lines 0,4,...,28.
        for i in 0..8 {
            c.insert(i * 4, false, false);
        }
        // Touch everything but line 0.
        for i in 1..8 {
            c.demand_lookup(i * 4);
        }
        let ev = c.insert(8 * 4, false, false).unwrap();
        assert_eq!(ev.line, 0, "PLRU approximation must victimize the stale line");
    }

    #[test]
    fn hit_ratio_computation() {
        let mut c = tiny();
        c.insert(0, false, false);
        c.demand_lookup(0);
        c.demand_lookup(4);
        assert!((c.stats.hit_ratio() - 0.5).abs() < 1e-12);
    }
}
