//! Address arithmetic shared by every memory-model component.
//!
//! All components speak *byte addresses* (`Addr`) at their interfaces and
//! convert internally to cache-line or page granules. The line size is fixed
//! at 64 bytes — true of all three micro-architectures surveyed in Table 2
//! of the paper ("All caches have a cache line size of 64 bytes").

/// Byte address in the simulated (virtual = physical) address space.
pub type Addr = u64;

/// Simulation timestamp in core clock cycles. Sub-cycle issue slots are
/// handled by the engine's issue cursor, which counts in fixed-point
/// quarter-cycles internally.
pub type Cycle = u64;

/// log2 of the cache-line size in bytes.
pub const LINE_SHIFT: u32 = 6;
/// Cache-line size in bytes (64 B on Coffee Lake / Cascade Lake / Zen 2).
pub const LINE_BYTES: u64 = 1 << LINE_SHIFT;

/// log2 of the small-page size (4 KiB, the default page size used for the
/// kernel experiments in §6.2 of the paper).
pub const PAGE_SHIFT: u32 = 12;
/// Small-page size in bytes.
pub const PAGE_BYTES: u64 = 1 << PAGE_SHIFT;

/// log2 of a huge page (2 MiB; the micro-benchmarks of §4 enabled these).
pub const HUGE_PAGE_SHIFT: u32 = 21;

/// Cache-line index of a byte address.
#[inline(always)]
pub fn line_of(addr: Addr) -> u64 {
    addr >> LINE_SHIFT
}

/// Byte address of the start of a line index.
#[inline(always)]
pub fn line_base(line: u64) -> Addr {
    line << LINE_SHIFT
}

/// 4 KiB page index of a byte address.
#[inline(always)]
pub fn page_of(addr: Addr) -> u64 {
    addr >> PAGE_SHIFT
}

/// 4 KiB page index of a *line* index.
#[inline(always)]
pub fn page_of_line(line: u64) -> u64 {
    line >> (PAGE_SHIFT - LINE_SHIFT)
}

/// Line index of the last line in the 4 KiB page containing `line`.
#[inline(always)]
pub fn page_last_line(line: u64) -> u64 {
    (page_of_line(line) << (PAGE_SHIFT - LINE_SHIFT)) + ((PAGE_BYTES >> LINE_SHIFT) - 1)
}

/// Line index of the first line in the 4 KiB page containing `line`.
#[inline(always)]
pub fn page_first_line(line: u64) -> u64 {
    page_of_line(line) << (PAGE_SHIFT - LINE_SHIFT)
}

/// Inclusive range of line indices touched by a `[addr, addr+size)` access.
/// A 32-byte AVX2 access touches one line when aligned, and two lines when
/// it straddles a 64-byte boundary (the "unaligned" case in §3).
#[inline(always)]
pub fn lines_touched(addr: Addr, size: u32) -> (u64, u64) {
    debug_assert!(size > 0);
    (line_of(addr), line_of(addr + size as u64 - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_math() {
        assert_eq!(line_of(0), 0);
        assert_eq!(line_of(63), 0);
        assert_eq!(line_of(64), 1);
        assert_eq!(line_base(3), 192);
    }

    #[test]
    fn page_math() {
        assert_eq!(page_of(4095), 0);
        assert_eq!(page_of(4096), 1);
        assert_eq!(page_of_line(line_of(4096)), 1);
        assert_eq!(page_first_line(65), 64);
        assert_eq!(page_last_line(65), 127);
    }

    #[test]
    fn aligned_vector_touches_one_line() {
        // A 32 B access at a 32 B-aligned offset never splits across lines
        // when offset % 64 ∈ {0, 32}.
        assert_eq!(lines_touched(0, 32), (0, 0));
        assert_eq!(lines_touched(32, 32), (0, 0));
        assert_eq!(lines_touched(64, 32), (1, 1));
    }

    #[test]
    fn unaligned_vector_may_split() {
        // The paper's unaligned benchmarks offset by 4 bytes: half of the
        // 32 B accesses then straddle a 64 B line boundary.
        assert_eq!(lines_touched(4, 32), (0, 0)); // [4,36) inside line 0
        assert_eq!(lines_touched(36, 32), (0, 1)); // [36,68) splits
    }
}
