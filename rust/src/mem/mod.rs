//! Memory-subsystem models: address arithmetic, set-associative caches,
//! TLBs, DRAM (banks + row buffers + bandwidth-limited service queue) and
//! the write-combining buffer used by non-temporal stores.
//!
//! These are the substrates the paper's measurements run on: the paper used
//! a real Coffee Lake i7-8700; we build the machine (see DESIGN.md §2).

pub mod addr;
pub mod cache;
pub mod dram;
pub mod tlb;
pub mod writebuffer;

pub use addr::{Addr, Cycle, LINE_BYTES, LINE_SHIFT, PAGE_BYTES, PAGE_SHIFT};
pub use cache::{Cache, CacheConfig, Eviction, Replacement};
pub use dram::{Dram, DramConfig};
pub use tlb::{Tlb, TlbConfig};
pub use writebuffer::{WcFlush, WriteCombineBuffer, WriteCombineConfig};
