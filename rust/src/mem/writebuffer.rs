//! Write-combining (WC) buffer model for non-temporal stores.
//!
//! Coffee Lake's non-temporal stores are no-write-allocate: they bypass the
//! cache hierarchy and land in a small pool of write-combining buffers
//! (shared with the line-fill buffers, ~10-12 entries). A buffer collects
//! stores to one 64-byte line; when the line is *fully* written it drains to
//! memory as a single efficient burst. If the pool is under pressure and a
//! buffer is evicted *partially filled*, the drain needs masked partial
//! writes, which occupy the memory channel far longer.
//!
//! §4.4 of the paper shows exactly this failure: interleaved multi-strided
//! NT stores touch many lines concurrently, evicting partial buffers and
//! capping throughput around 1.74 GiB/s, while grouped NT stores (complete
//! one line before the next) stay efficient. This module reproduces that
//! mechanism; the paper's Fritts [14] citation describes the same
//! write-buffer contention point.

use super::addr::{Cycle, LINE_BYTES};

/// Configuration of the WC buffer pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WriteCombineConfig {
    /// Number of concurrent WC buffers (≈ line-fill buffers on Intel).
    pub entries: u32,
}

impl Default for WriteCombineConfig {
    fn default() -> Self {
        Self { entries: 10 }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct WcEntry {
    line: u64,
    valid: bool,
    /// Bitmask of written 4-byte chunks (16 chunks per 64 B line).
    filled: u16,
    stamp: u64,
}

/// A buffer flush that must be sent to DRAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WcFlush {
    /// Line address being drained.
    pub line: u64,
    /// All 64 bytes were written: drain as one full-line burst.
    pub full: bool,
    /// Time the triggering store was issued (drain is ordered after it).
    pub at: Cycle,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WcStats {
    pub stores: u64,
    pub full_flushes: u64,
    pub partial_flushes: u64,
}

/// The WC buffer pool.
pub struct WriteCombineBuffer {
    #[allow(dead_code)]
    cfg: WriteCombineConfig,
    entries: Vec<WcEntry>,
    clock: u64,
    pub stats: WcStats,
}

impl WriteCombineBuffer {
    pub fn new(cfg: WriteCombineConfig) -> Self {
        Self {
            cfg,
            entries: vec![WcEntry::default(); cfg.entries as usize],
            clock: 0,
            stats: WcStats::default(),
        }
    }

    /// Record a non-temporal store of `size` bytes at `addr`, time `now`.
    /// Returns any flush (at most one) the store forces: either the target
    /// line completing, or an LRU victim evicted to make room.
    pub fn store(&mut self, now: Cycle, addr: u64, size: u32) -> Option<WcFlush> {
        self.clock += 1;
        self.stats.stores += 1;
        let line = addr >> 6;
        let offset = (addr & (LINE_BYTES - 1)) as u32;
        debug_assert!(offset + size <= 64, "NT store must not split a line");
        let first_chunk = offset / 4;
        let chunks = size.div_ceil(4);
        let mask: u16 = (((1u32 << chunks) - 1) << first_chunk) as u16;

        // Hit an open buffer?
        if let Some(e) = self.entries.iter_mut().find(|e| e.valid && e.line == line) {
            e.filled |= mask;
            e.stamp = self.clock;
            if e.filled == u16::MAX {
                e.valid = false;
                self.stats.full_flushes += 1;
                return Some(WcFlush { line, full: true, at: now });
            }
            return None;
        }

        // Allocate: free entry or evict LRU (partial flush).
        let mut victim_flush = None;
        let idx = if let Some(i) = self.entries.iter().position(|e| !e.valid) {
            i
        } else {
            let (i, _) = self
                .entries
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| e.stamp)
                .expect("pool is non-empty");
            let v = self.entries[i];
            self.stats.partial_flushes += 1;
            victim_flush = Some(WcFlush { line: v.line, full: false, at: now });
            i
        };

        // Newly allocated buffer; if this single store fills the line
        // (64-byte store), it drains immediately.
        if mask == u16::MAX {
            self.stats.full_flushes += 1;
            debug_assert!(victim_flush.is_none() || self.entries[idx].valid);
            // The line never occupies the buffer; victim (if any) still flushed.
            return victim_flush.or(Some(WcFlush { line, full: true, at: now }));
        }
        self.entries[idx] = WcEntry { line, valid: true, filled: mask, stamp: self.clock };
        victim_flush
    }

    /// Drain every open buffer (the trailing `sfence`/`mfence` of a kernel).
    pub fn drain(&mut self, now: Cycle) -> Vec<WcFlush> {
        let mut out = Vec::new();
        for e in &mut self.entries {
            if e.valid {
                e.valid = false;
                let full = e.filled == u16::MAX;
                if full {
                    self.stats.full_flushes += 1;
                } else {
                    self.stats.partial_flushes += 1;
                }
                out.push(WcFlush { line: e.line, full, at: now });
            }
        }
        out
    }

    /// Number of currently open (partially filled) buffers.
    pub fn open_buffers(&self) -> usize {
        self.entries.iter().filter(|e| e.valid).count()
    }

    pub fn reset(&mut self) {
        self.entries.fill(WcEntry::default());
        self.clock = 0;
        self.stats = WcStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wc(n: u32) -> WriteCombineBuffer {
        WriteCombineBuffer::new(WriteCombineConfig { entries: n })
    }

    #[test]
    fn two_halves_complete_a_line() {
        let mut w = wc(4);
        assert!(w.store(0, 0, 32).is_none());
        let f = w.store(1, 32, 32).expect("line complete");
        assert!(f.full);
        assert_eq!(f.line, 0);
        assert_eq!(w.stats.full_flushes, 1);
        assert_eq!(w.open_buffers(), 0);
    }

    #[test]
    fn grouped_stores_never_flush_partial() {
        let mut w = wc(4);
        // Grouped arrangement: finish each line before moving on.
        for line in 0..100u64 {
            assert!(w.store(0, line * 64, 32).is_none());
            assert!(w.store(0, line * 64 + 32, 32).unwrap().full);
        }
        assert_eq!(w.stats.partial_flushes, 0);
        assert_eq!(w.stats.full_flushes, 100);
    }

    #[test]
    fn interleaved_streams_beyond_pool_flush_partial() {
        // 16 streams, 10 buffers: visiting each stream once per offset (the
        // paper's "interleaved" arrangement) evicts partial buffers nonstop.
        let mut w = wc(10);
        let stride = 1 << 20;
        for off in 0..32u64 {
            for s in 0..16u64 {
                w.store(0, s * stride + off * 32, 32);
            }
        }
        assert!(
            w.stats.partial_flushes > 100,
            "partial flushes dominate: {:?}",
            w.stats
        );
        assert_eq!(w.stats.full_flushes, 0, "no line ever completes before eviction");
    }

    #[test]
    fn interleaved_streams_within_pool_are_fine() {
        // 4 streams fit in 10 buffers: each line's second half arrives
        // before any eviction.
        let mut w = wc(10);
        let stride = 1 << 20;
        for off in 0..32u64 {
            for s in 0..4u64 {
                w.store(0, s * stride + off * 32, 32);
            }
        }
        assert_eq!(w.stats.partial_flushes, 0);
        assert_eq!(w.stats.full_flushes, 4 * 16);
    }

    #[test]
    fn drain_reports_leftovers() {
        let mut w = wc(4);
        w.store(0, 0, 32);
        w.store(0, 64, 64); // full-line store drains immediately
        let fl = w.drain(10);
        assert_eq!(fl.len(), 1);
        assert!(!fl[0].full);
        assert_eq!(fl[0].line, 0);
    }

    #[test]
    fn full_line_store_bypasses_buffer() {
        let mut w = wc(1);
        let f = w.store(0, 0, 64).unwrap();
        assert!(f.full);
        assert_eq!(w.open_buffers(), 0);
    }
}
