//! DRAM model: banks with open-row buffers behind a bandwidth-limited
//! service queue.
//!
//! Two properties of real DRAM drive the paper's results and are modeled
//! here explicitly:
//!
//! 1. **Bandwidth is a shared service rate.** Every line transfer occupies
//!    the channel for `service_cycles`; completions are serialized through a
//!    single service cursor. A single demand/prefetch stream cannot keep the
//!    cursor busy (latency-bound); many concurrent streams can (bandwidth-
//!    bound). This is precisely the gap multi-striding closes.
//! 2. **Row buffers reward locality.** An access to the currently open row
//!    of a bank costs `row_hit_cycles`; switching rows costs
//!    `row_miss_cycles`. Sequential streams enjoy row hits; many interleaved
//!    streams that alias to the same bank ping-pong rows — the slight
//!    *decline* of multi-strided throughput with the prefetcher disabled
//!    (Figure 2, bottom row) falls out of this.
//!
//! Address mapping: line address → row-sized frames, frames interleaved
//! round-robin over banks (`bank = frame % n_banks`). Spacings that are a
//! multiple of `n_banks * row_bytes` therefore land in the *same* bank —
//! another power-of-two hazard, alongside the cache-set aliasing of §4.5.

use super::addr::{Cycle, LINE_SHIFT};

/// DRAM timing + geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DramConfig {
    /// Cycles the channel is occupied per 64-byte line *read* transfer.
    /// Sets the read-bandwidth roofline: `64 B / (service_cycles / f)`.
    pub service_cycles: u64,
    /// Cycles the channel is occupied per 64-byte line *write* transfer.
    /// Writes pay bus turnaround + write recovery, so their effective
    /// bandwidth is lower — the paper's NT-store plateau (~55% of the read
    /// roofline on Coffee Lake) reflects this.
    pub write_service_cycles: u64,
    /// Total latency (core cycles) of a row-buffer hit, excluding queueing.
    pub row_hit_cycles: u64,
    /// Total latency of a row-buffer miss (precharge + activate + CAS).
    pub row_miss_cycles: u64,
    /// Number of banks (across all channels/ranks, flattened).
    pub banks: u32,
    /// Row-buffer size in bytes.
    pub row_bytes: u64,
    /// Extra service occupancy multiplier for partial (masked) writes from
    /// the write-combining buffer — a partially-filled WC flush cannot use a
    /// full-line burst. Expressed in multiples of `service_cycles`.
    pub partial_write_penalty: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        Self {
            // Tuned for the Coffee Lake preset in config::machines (see
            // DESIGN.md §2 and EXPERIMENTS.md for the calibration log).
            service_cycles: 10,
            write_service_cycles: 18,
            row_hit_cycles: 200,
            row_miss_cycles: 300,
            banks: 16,
            row_bytes: 8192,
            partial_write_penalty: 4,
        }
    }
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    /// Cycles the channel spent transferring data (occupancy).
    pub busy_cycles: u64,
}

/// The DRAM device: per-bank open rows + a single service cursor.
pub struct Dram {
    cfg: DramConfig,
    lines_per_row: u64,
    /// Open row per bank (`u64::MAX` = closed).
    open_rows: Vec<u64>,
    /// Time at which the channel becomes free.
    next_free: Cycle,
    pub stats: DramStats,
}

/// What kind of transfer is requested.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DramOp {
    Read,
    /// Full-line write (write-back or fully-combined NT store).
    WriteLine,
    /// Partial-line write (under-filled WC buffer flush).
    WritePartial,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Self {
        assert!(cfg.row_bytes >= 64 && cfg.row_bytes.is_power_of_two());
        Self {
            lines_per_row: cfg.row_bytes >> LINE_SHIFT,
            open_rows: vec![u64::MAX; cfg.banks as usize],
            next_free: 0,
            cfg,
            stats: DramStats::default(),
        }
    }

    pub fn config(&self) -> DramConfig {
        self.cfg
    }

    #[inline]
    fn frame_of(&self, line: u64) -> u64 {
        line / self.lines_per_row
    }

    /// Issue a transfer for `line` at time `now`; returns the completion
    /// time of the data (for reads: when the line arrives at the LLC edge).
    pub fn access(&mut self, now: Cycle, line: u64, op: DramOp) -> Cycle {
        let frame = self.frame_of(line);
        let bank = (frame % self.cfg.banks as u64) as usize;
        let row = frame / self.cfg.banks as u64;

        let row_hit = self.open_rows[bank] == row;
        if row_hit {
            self.stats.row_hits += 1;
        } else {
            self.stats.row_misses += 1;
            self.open_rows[bank] = row;
        }

        let latency = if row_hit { self.cfg.row_hit_cycles } else { self.cfg.row_miss_cycles };
        let occupancy = match op {
            DramOp::Read => self.cfg.service_cycles,
            DramOp::WriteLine => self.cfg.write_service_cycles,
            DramOp::WritePartial => {
                self.cfg.write_service_cycles * self.cfg.partial_write_penalty
            }
        };
        match op {
            DramOp::Read => self.stats.reads += 1,
            _ => self.stats.writes += 1,
        }

        // Single-server queue: the transfer starts when the channel frees.
        let start = self.next_free.max(now);
        self.next_free = start + occupancy;
        self.stats.busy_cycles += occupancy;
        start + latency
    }

    /// Earliest time a new transfer could start (queue visibility for the
    /// engine's stall attribution).
    pub fn next_free(&self) -> Cycle {
        self.next_free
    }

    /// Achieved read+write bandwidth in bytes/cycle over `total_cycles`.
    pub fn achieved_bytes_per_cycle(&self, total_cycles: Cycle) -> f64 {
        if total_cycles == 0 {
            return 0.0;
        }
        ((self.stats.reads + self.stats.writes) * 64) as f64 / total_cycles as f64
    }

    pub fn reset(&mut self) {
        self.open_rows.fill(u64::MAX);
        self.next_free = 0;
        self.stats = DramStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dram() -> Dram {
        Dram::new(DramConfig::default())
    }

    #[test]
    fn sequential_stream_gets_row_hits() {
        let mut d = dram();
        let lines_per_row = DramConfig::default().row_bytes / 64;
        for l in 0..lines_per_row * 4 {
            d.access(0, l, DramOp::Read);
        }
        // One row miss per row opened; the rest are hits.
        assert_eq!(d.stats.row_misses, 4);
        assert_eq!(d.stats.row_hits, lines_per_row * 4 - 4);
    }

    #[test]
    fn same_bank_interleaving_ping_pongs_rows() {
        let cfg = DramConfig::default();
        let mut d = Dram::new(cfg);
        let lines_per_row = cfg.row_bytes / 64;
        // Two streams spaced banks*row_bytes apart -> same bank, different rows.
        let s2 = cfg.banks as u64 * lines_per_row;
        for i in 0..100 {
            d.access(0, i, DramOp::Read);
            d.access(0, s2 + i, DramOp::Read);
        }
        assert!(
            d.stats.row_misses as f64 / (d.stats.row_hits + d.stats.row_misses) as f64 > 0.9,
            "aliased interleave must be row-miss dominated"
        );
    }

    #[test]
    fn different_bank_interleaving_keeps_hits() {
        let cfg = DramConfig::default();
        let mut d = Dram::new(cfg);
        let lines_per_row = cfg.row_bytes / 64;
        // Two streams offset by one frame -> adjacent banks.
        let s2 = lines_per_row;
        // Skip the first-touch misses by warming both rows.
        d.access(0, 0, DramOp::Read);
        d.access(0, s2, DramOp::Read);
        let misses0 = d.stats.row_misses;
        for i in 1..lines_per_row {
            d.access(0, i, DramOp::Read);
            d.access(0, s2 + i, DramOp::Read);
        }
        assert_eq!(d.stats.row_misses, misses0, "no extra misses within rows");
    }

    #[test]
    fn service_rate_caps_bandwidth() {
        let mut d = dram();
        // Saturate: issue 100 reads at time 0; completion of the last is
        // bounded below by 100 * service_cycles.
        let mut last = 0;
        for l in 0..100 {
            last = d.access(0, l * 1000, DramOp::Read); // all row misses
        }
        assert!(last >= 100 * DramConfig::default().service_cycles);
    }

    #[test]
    fn latency_vs_queueing() {
        let mut d = dram();
        let t1 = d.access(0, 0, DramOp::Read);
        assert_eq!(t1, DramConfig::default().row_miss_cycles);
        // Far-future request sees an idle channel: pure latency again.
        let t2 = d.access(1_000_000, 1, DramOp::Read);
        assert_eq!(t2, 1_000_000 + DramConfig::default().row_hit_cycles);
    }

    #[test]
    fn partial_writes_occupy_longer() {
        let cfg = DramConfig::default();
        let mut d = Dram::new(cfg);
        d.access(0, 0, DramOp::WritePartial);
        assert_eq!(d.next_free(), cfg.write_service_cycles * cfg.partial_write_penalty);
    }

    #[test]
    fn achieved_bandwidth_accounting() {
        let mut d = dram();
        for l in 0..10 {
            d.access(0, l, DramOp::Read);
        }
        let bpc = d.achieved_bytes_per_cycle(100);
        assert!((bpc - 6.4).abs() < 1e-9);
    }
}
