//! TLB model (L1 dTLB + unified STLB) with page-walk cost.
//!
//! The paper's §4 micro-benchmarks run with huge pages enabled, while the §6
//! kernel experiments use the default 4 KiB pages. With 4 KiB pages, every
//! concurrent stride advances through its own page stream; once the number
//! of concurrent page streams pressures the small set-associative dTLB —
//! and in particular once the stride spacing aliases dTLB sets — page walks
//! appear on the critical path. This is one of the mechanisms behind the
//! decline of kernel throughput at high stride-unroll counts in Figure 6
//! (while Figure 2, with huge pages, keeps scaling to 32 strides).

use super::addr::{Addr, HUGE_PAGE_SHIFT, PAGE_SHIFT};

/// Geometry and costs of the two-level TLB.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TlbConfig {
    /// L1 dTLB entries (e.g. 64 on Coffee Lake).
    pub l1_entries: u32,
    /// L1 dTLB associativity (4-way on Coffee Lake).
    pub l1_ways: u32,
    /// Unified second-level TLB entries (1536 on Coffee Lake).
    pub l2_entries: u32,
    /// STLB associativity (12-way on Coffee Lake).
    pub l2_ways: u32,
    /// Added latency (cycles) of an L1-dTLB miss that hits the STLB.
    pub stlb_hit_cycles: u64,
    /// Added latency (cycles) of a full page walk.
    pub walk_cycles: u64,
    /// Translate at 2 MiB granularity (huge pages on) instead of 4 KiB.
    pub huge_pages: bool,
}

impl Default for TlbConfig {
    fn default() -> Self {
        Self {
            l1_entries: 64,
            l1_ways: 4,
            l2_entries: 1536,
            l2_ways: 12,
            stlb_hit_cycles: 7,
            walk_cycles: 70,
            huge_pages: false,
        }
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct TlbEntry {
    page: u64,
    valid: bool,
    stamp: u64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TlbStats {
    pub accesses: u64,
    pub l1_misses: u64,
    pub walks: u64,
}

/// Two-level data TLB with LRU sets and a flat page-walk cost.
pub struct Tlb {
    cfg: TlbConfig,
    l1: Vec<TlbEntry>,
    l2: Vec<TlbEntry>,
    l1_sets: u64,
    l2_sets: u64,
    clock: u64,
    page_shift: u32,
    pub stats: TlbStats,
}

impl Tlb {
    pub fn new(cfg: TlbConfig) -> Self {
        let l1_sets = (cfg.l1_entries / cfg.l1_ways) as u64;
        let l2_sets = (cfg.l2_entries / cfg.l2_ways) as u64;
        assert!(l1_sets.is_power_of_two() && l2_sets.is_power_of_two());
        Self {
            cfg,
            l1: vec![TlbEntry::default(); cfg.l1_entries as usize],
            l2: vec![TlbEntry::default(); cfg.l2_entries as usize],
            l1_sets,
            l2_sets,
            clock: 0,
            page_shift: if cfg.huge_pages { HUGE_PAGE_SHIFT } else { PAGE_SHIFT },
            stats: TlbStats::default(),
        }
    }

    /// Translate `addr`; returns the added latency in cycles (0 on dTLB hit).
    pub fn translate(&mut self, addr: Addr) -> u64 {
        self.stats.accesses += 1;
        self.clock += 1;
        let page = addr >> self.page_shift;

        if Self::probe(&mut self.l1, self.l1_sets, self.cfg.l1_ways, page, self.clock) {
            return 0;
        }
        self.stats.l1_misses += 1;
        if Self::probe(&mut self.l2, self.l2_sets, self.cfg.l2_ways, page, self.clock) {
            Self::fill(&mut self.l1, self.l1_sets, self.cfg.l1_ways, page, self.clock);
            return self.cfg.stlb_hit_cycles;
        }
        self.stats.walks += 1;
        Self::fill(&mut self.l2, self.l2_sets, self.cfg.l2_ways, page, self.clock);
        Self::fill(&mut self.l1, self.l1_sets, self.cfg.l1_ways, page, self.clock);
        self.cfg.walk_cycles
    }

    fn probe(arr: &mut [TlbEntry], sets: u64, ways: u32, page: u64, clock: u64) -> bool {
        let set = (page & (sets - 1)) as usize * ways as usize;
        for e in &mut arr[set..set + ways as usize] {
            if e.valid && e.page == page {
                e.stamp = clock;
                return true;
            }
        }
        false
    }

    fn fill(arr: &mut [TlbEntry], sets: u64, ways: u32, page: u64, clock: u64) {
        let set = (page & (sets - 1)) as usize * ways as usize;
        let slice = &mut arr[set..set + ways as usize];
        // Reuse resident / invalid way, else LRU.
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for (i, e) in slice.iter().enumerate() {
            if e.valid && e.page == page {
                return;
            }
            if !e.valid {
                victim = i;
                break;
            }
            if e.stamp < best {
                best = e.stamp;
                victim = i;
            }
        }
        slice[victim] = TlbEntry { page, valid: true, stamp: clock };
    }

    pub fn reset(&mut self) {
        self.l1.fill(TlbEntry::default());
        self.l2.fill(TlbEntry::default());
        self.clock = 0;
        self.stats = TlbStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Tlb {
        Tlb::new(TlbConfig {
            l1_entries: 8,
            l1_ways: 4,
            l2_entries: 32,
            l2_ways: 4,
            stlb_hit_cycles: 7,
            walk_cycles: 70,
            huge_pages: false,
        })
    }

    #[test]
    fn first_touch_walks_then_hits() {
        let mut t = small();
        assert_eq!(t.translate(0), 70);
        assert_eq!(t.translate(64), 0, "same page hits dTLB");
        assert_eq!(t.stats.walks, 1);
    }

    #[test]
    fn stlb_catches_l1_capacity_misses() {
        let mut t = small();
        // Touch 9 distinct pages: > 8 L1 entries, < 32 STLB entries.
        for p in 0..9u64 {
            t.translate(p * 4096);
        }
        // Re-touch page 0: L1-evicted (same set pressure) but STLB-resident.
        let lat = t.translate(0);
        assert!(lat == 0 || lat == 7, "never a full walk: {lat}");
        assert_eq!(t.stats.walks, 9);
    }

    #[test]
    fn set_aliased_page_streams_thrash() {
        let mut t = small(); // 2 L1 sets, 4 ways
        // 8 page streams spaced 2 pages apart: all even pages -> set 0.
        // Round-robin touching 8 distinct even pages with only 4 ways
        // guarantees L1 misses every round.
        for _round in 0..4 {
            for s in 0..8u64 {
                t.translate(s * 2 * 4096);
            }
        }
        assert!(t.stats.l1_misses > 16, "aliased streams must thrash L1 dTLB");
    }

    #[test]
    fn huge_pages_collapse_page_streams() {
        let mut t = Tlb::new(TlbConfig { huge_pages: true, ..TlbConfig::default() });
        // 16 MiB touched at 4 KiB steps = 8 huge pages -> at most 8 walks.
        for a in (0..16 * 1024 * 1024u64).step_by(4096) {
            t.translate(a);
        }
        assert!(t.stats.walks <= 8);
    }
}
