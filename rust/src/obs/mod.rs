//! Unified observability: metrics registry, timing spans, Chrome-trace
//! export, and Prometheus/JSON exposition.
//!
//! The paper's argument is made of counters (hit ratios, prefetch
//! usefulness, effective bandwidth — §4); this layer gives the *repo's
//! own operation* the same treatment. Every subsystem folds what it
//! already counts into one process-wide [`metrics::Registry`]:
//!
//! * `exec` — [`crate::exec::ExecStats`] via [`fold_exec_stats`], plus
//!   per-run engine counters via [`fold_run_result`];
//! * `serve` — [`crate::serve::ServeStats`] via [`fold_serve_stats`],
//!   plus per-endpoint latency histograms recorded at request end;
//! * `tune` / `coordinator` / grid — counters and [`span::span`]s at
//!   their stage boundaries.
//!
//! Nothing here runs in the sim hot loop: folds happen per engine run,
//! per request, per rung, per render — never per access.
//!
//! Exposition surfaces: `GET /metrics` (Prometheus text), `--trace
//! out.json` (Chrome trace events + `out.counters.json` deterministic
//! snapshot), and `repro obs report` (tables from a trace run).
//! The metric naming contract is `subsystem_name_unit`; see
//! `ARCHITECTURE.md` §Observability for the add-a-metric checklist.

pub mod export;
pub mod metrics;
pub mod span;
pub mod trace;

use std::path::{Path, PathBuf};

pub use metrics::{global, Registry, Snapshot};
pub use span::{span, SpanAgg, SpanRecord};

use crate::exec::ExecStats;
use crate::serve::ServeStats;
use crate::sim::RunResult;
use crate::Result;

/// Fold a [`ExecStats`] snapshot into `reg` and return the registry
/// snapshot taken under the same lock. `ExecStats` is monotonic over a
/// store's lifetime, so absolute sets are the correct fold.
pub fn fold_exec_stats(reg: &Registry, s: &ExecStats) -> Snapshot {
    reg.with(|v| {
        v.counter_set("exec_requests_total", s.requests);
        v.counter_set("exec_mem_hits_total", s.mem_hits);
        v.counter_set("exec_disk_hits_total", s.disk_hits);
        v.counter_set("exec_legacy_hits_total", s.legacy_hits);
        v.counter_set("exec_misses_total", s.misses);
        v.counter_set("exec_deduped_total", s.deduped);
        v.counter_set("exec_engine_runs_total", s.engine_runs);
        v.counter_set("exec_disk_writes_total", s.disk_writes);
        v.counter_set("exec_corrupt_discards_total", s.corrupt_discards);
        v.counter_set("exec_verified_hits_total", s.verified_hits);
        v.counter_set("exec_disk_errors_total", s.disk_errors);
        v.counter_set("exec_dropped_unsimulatable_total", s.dropped_unsimulatable);
        v.gauge_set("store_degraded", u64::from(s.degraded));
        v.snapshot()
    })
}

/// Fold a [`ServeStats`] snapshot into `reg` and return the registry
/// snapshot taken under the same lock.
pub fn fold_serve_stats(reg: &Registry, s: &ServeStats) -> Snapshot {
    reg.with(|v| {
        v.counter_set("serve_pool_requests_total", s.pool.requests);
        v.counter_set("serve_pool_hits_total", s.pool.hits);
        v.counter_set("serve_pool_misses_total", s.pool.misses);
        v.counter_set("serve_pool_insertions_total", s.pool.insertions);
        v.counter_set("serve_pool_evictions_total", s.pool.evictions);
        v.counter_set("serve_pool_oversize_rejects_total", s.pool.rejected_oversize);
        v.gauge_set("serve_pool_bytes", s.pool.current_bytes);
        v.gauge_set("serve_pool_entries", s.pool.current_entries);
        v.gauge_set("serve_pool_capacity_bytes", s.pool.capacity_bytes);
        v.counter_set("serve_disk_plans_total", s.disk_loads);
        v.counter_set("serve_tunes_total", s.tunes);
        v.counter_set("serve_tune_failures_total", s.tune_failures);
        v.counter_set("serve_single_flight_waits_total", s.single_flight_waits);
        v.counter_set("serve_not_found_total", s.not_found);
        v.counter_set("serve_bad_requests_total", s.bad_requests);
        v.snapshot()
    })
}

/// Fold one engine run's simulator counters into `reg`. Called once
/// per [`crate::exec::ResultStore::get_or_run`] miss — the aggregation
/// the simulator already did is reused, so the per-access hot path
/// never sees the registry.
pub fn fold_run_result_into(reg: &Registry, r: &RunResult) {
    reg.with(|v| {
        v.counter_add("sim_engine_runs_total", 1);
        v.counter_add("sim_accesses_total", r.counters.accesses);
        v.counter_add("sim_cycles_total", r.counters.cycles);
        v.counter_add("sim_stall_cycles_total", r.counters.stalls_total);
        v.counter_add("sim_bytes_read_total", r.counters.bytes_read);
        v.counter_add("sim_bytes_written_total", r.counters.bytes_written);
        v.counter_add("sim_dram_demand_lines_total", r.counters.dram_demand_lines);
        v.counter_add("prefetch_lines_total", r.counters.prefetch_lines);
        v.counter_add("prefetch_merges_total", r.counters.prefetch_merges);
        v.counter_add("prefetch_streams_allocated_total", r.streamer.streams_allocated);
        v.counter_add("prefetch_streams_evicted_total", r.streamer.streams_evicted);
        v.counter_add("prefetch_issued_total", r.streamer.prefetches_issued);
    });
}

/// [`fold_run_result_into`] against the process-global registry.
pub fn fold_run_result(r: &RunResult) {
    fold_run_result_into(global(), r);
}

/// What `--trace` wrote and where.
pub struct TraceArtifacts {
    pub trace: PathBuf,
    pub counters: PathBuf,
    pub spans: usize,
}

/// Sibling counter-snapshot path for a trace file: `out.json` →
/// `out.counters.json`.
pub fn counters_path_for(trace: &Path) -> PathBuf {
    trace.with_extension("counters.json")
}

/// Write both `--trace` artifacts through the default I/O: the Chrome
/// trace at `trace_path` and the deterministic counter snapshot next
/// to it. The snapshot is counters/gauges only — reruns byte-match.
pub fn write_trace_artifacts(trace_path: &Path) -> Result<TraceArtifacts> {
    let io = crate::exec::vfs::default_io();
    let spans = trace::write_chrome_trace_with(&io, trace_path)?;
    let counters = counters_path_for(trace_path);
    let body = export::json_snapshot(&global().snapshot());
    crate::exec::vfs::with_retry(|| io.write(&counters, body.as_bytes()))
        .map_err(|e| crate::format_err!("writing counter snapshot {}: {e}", counters.display()))?;
    Ok(TraceArtifacts { trace: trace_path.to_path_buf(), counters, spans })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exec_stats() -> ExecStats {
        ExecStats {
            requests: 10,
            mem_hits: 4,
            disk_hits: 3,
            legacy_hits: 1,
            misses: 3,
            deduped: 2,
            engine_runs: 3,
            disk_writes: 3,
            corrupt_discards: 1,
            verified_hits: 0,
            disk_errors: 5,
            dropped_unsimulatable: 1,
            degraded: true,
        }
    }

    #[test]
    fn exec_fold_maps_every_field() {
        let r = Registry::new();
        let s = fold_exec_stats(&r, &exec_stats());
        assert_eq!(s.counter("exec_requests_total"), 10);
        assert_eq!(s.counter("exec_mem_hits_total"), 4);
        assert_eq!(s.counter("exec_disk_hits_total"), 3);
        assert_eq!(s.counter("exec_engine_runs_total"), 3);
        assert_eq!(s.counter("exec_disk_errors_total"), 5);
        assert_eq!(s.gauge("store_degraded"), 1);
    }

    #[test]
    fn exec_fold_is_idempotent() {
        let r = Registry::new();
        let first = fold_exec_stats(&r, &exec_stats());
        let second = fold_exec_stats(&r, &exec_stats());
        assert_eq!(first, second, "absolute sets must not accumulate across folds");
    }

    #[test]
    fn counters_path_is_a_sibling() {
        assert_eq!(
            counters_path_for(Path::new("/tmp/out.json")),
            Path::new("/tmp/out.counters.json")
        );
        assert_eq!(counters_path_for(Path::new("trace")), Path::new("trace.counters.json"));
    }
}
