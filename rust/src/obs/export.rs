//! Registry exposition: Prometheus text format and the deterministic
//! JSON counter snapshot.
//!
//! Two consumers, two formats:
//!
//! * `GET /metrics` on the serve daemon returns [`prometheus_text`] —
//!   the standard text exposition (`# TYPE` headers, cumulative
//!   histogram buckets with `le` labels) any Prometheus scraper reads.
//! * `--trace out.json` also writes `out.counters.json` via
//!   [`json_snapshot`] — counters and gauges only, **no histograms and
//!   no timings**, so two identical cold runs produce byte-identical
//!   files. That property is pinned by tests and CI.

use crate::obs::metrics::{bucket_bound, Snapshot};
use crate::{format_err, Result};

/// Render a snapshot in the Prometheus text exposition format.
pub fn prometheus_text(snap: &Snapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
    }
    for (name, h) in &snap.hists {
        out.push_str(&format!("# TYPE {name} histogram\n"));
        let last_used = h.counts.iter().rposition(|&c| c > 0);
        let mut cumulative = 0u64;
        if let Some(last) = last_used {
            for (i, &c) in h.counts.iter().enumerate().take(last + 1) {
                cumulative += c;
                match bucket_bound(i) {
                    Some(le) => {
                        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cumulative}\n"));
                    }
                    None => break,
                }
            }
        }
        out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
        out.push_str(&format!("{name}_sum {}\n", h.sum));
        out.push_str(&format!("{name}_count {}\n", h.count));
    }
    out
}

/// Counters whose value depends on thread/fleet scheduling, not on the
/// work performed: how often the pool stole, how the fleet's batches
/// happened to be cut, which worker raced a lease. They stay visible
/// in [`prometheus_text`] (live operators want them) but are excluded
/// from the deterministic snapshot, which pins "identical cold runs
/// produce byte-identical files".
pub const SCHEDULING_COUNTERS: &[&str] = &[
    "pool_steals_total",
    "grid_batches_granted_total",
    "grid_points_leased_total",
    "grid_duplicate_results_total",
    "grid_lease_reassignments_total",
];

/// Is `name` on the [`SCHEDULING_COUNTERS`] exclusion list?
pub fn is_scheduling_dependent(name: &str) -> bool {
    SCHEDULING_COUNTERS.contains(&name)
}

/// Render the deterministic JSON snapshot: counters and gauges only,
/// sorted by name, one entry per line. Histograms (timings) and
/// [`SCHEDULING_COUNTERS`] are excluded by contract — they are the
/// nondeterministic half.
pub fn json_snapshot(snap: &Snapshot) -> String {
    let counters: Vec<(String, u64)> = snap
        .counters
        .iter()
        .filter(|(name, _)| !is_scheduling_dependent(name))
        .cloned()
        .collect();
    let mut out = String::from("{\n  \"counters\": {\n");
    push_section(&mut out, &counters);
    out.push_str("  },\n  \"gauges\": {\n");
    push_section(&mut out, &snap.gauges);
    out.push_str("  }\n}\n");
    out
}

fn push_section(out: &mut String, entries: &[(String, u64)]) {
    for (i, (name, v)) in entries.iter().enumerate() {
        let comma = if i + 1 < entries.len() { "," } else { "" };
        out.push_str(&format!("    \"{name}\": {v}{comma}\n"));
    }
}

/// Read back a [`json_snapshot`] file: `(name, value)` pairs from both
/// sections, in file order. Line-based on our own emission grammar —
/// the crate is dependency-free, so no general JSON parser.
pub fn parse_json_snapshot(text: &str) -> Result<Vec<(String, u64)>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        let Some(rest) = line.strip_prefix('"') else { continue };
        let Some((name, value)) = rest.split_once("\": ") else { continue };
        let value: u64 = value
            .trim()
            .parse()
            .map_err(|_| format_err!("bad snapshot value for {name:?}: {value:?}"))?;
        out.push((name.to_string(), value));
    }
    if out.is_empty() {
        return Err(format_err!("no counters found — not a snapshot file, or a torn write"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;

    fn sample() -> Snapshot {
        let r = Registry::new();
        r.counter_set("exec_requests_total", 12);
        r.counter_set("sim_accesses_total", 34_000);
        r.gauge_set("store_degraded", 1);
        r.observe("serve_plan_request_us", 3);
        r.observe("serve_plan_request_us", 100);
        r.snapshot()
    }

    #[test]
    fn prometheus_text_exposes_all_three_kinds() {
        let text = prometheus_text(&sample());
        assert!(text.contains("# TYPE exec_requests_total counter\nexec_requests_total 12\n"));
        assert!(text.contains("# TYPE store_degraded gauge\nstore_degraded 1\n"));
        assert!(text.contains("# TYPE serve_plan_request_us histogram\n"));
        assert!(text.contains("serve_plan_request_us_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("serve_plan_request_us_sum 103\n"));
        assert!(text.contains("serve_plan_request_us_count 2\n"));
    }

    #[test]
    fn prometheus_histogram_buckets_are_cumulative() {
        let r = Registry::new();
        r.observe("h_us", 1); // bucket 0 (le=1)
        r.observe("h_us", 2); // bucket 1 (le=2)
        let text = prometheus_text(&r.snapshot());
        assert!(text.contains("h_us_bucket{le=\"1\"} 1\n"), "got:\n{text}");
        assert!(text.contains("h_us_bucket{le=\"2\"} 2\n"), "got:\n{text}");
    }

    #[test]
    fn json_snapshot_excludes_histograms_and_round_trips() {
        let json = json_snapshot(&sample());
        assert!(!json.contains("serve_plan_request_us"), "timings must be excluded");
        let parsed = parse_json_snapshot(&json).unwrap();
        assert_eq!(
            parsed,
            vec![
                ("exec_requests_total".to_string(), 12),
                ("sim_accesses_total".to_string(), 34_000),
                ("store_degraded".to_string(), 1),
            ]
        );
    }

    #[test]
    fn json_snapshot_is_byte_identical_for_equal_registries() {
        assert_eq!(json_snapshot(&sample()), json_snapshot(&sample()));
    }

    #[test]
    fn scheduling_counters_are_snapshot_excluded_but_scrapable() {
        let r = Registry::new();
        r.counter_set("pool_jobs_claimed_total", 9);
        r.counter_set("pool_steals_total", 3);
        r.counter_set("grid_lease_reassignments_total", 1);
        let snap = r.snapshot();
        let json = json_snapshot(&snap);
        for name in SCHEDULING_COUNTERS {
            assert!(is_scheduling_dependent(name));
            assert!(!json.contains(name), "{name} must not reach the snapshot:\n{json}");
        }
        assert!(json.contains("\"pool_jobs_claimed_total\": 9"), "got:\n{json}");
        // Trailing-comma hygiene survives the filter: the last surviving
        // counter line has none.
        assert!(json.contains("\"pool_jobs_claimed_total\": 9\n"), "got:\n{json}");
        let prom = prometheus_text(&snap);
        assert!(prom.contains("pool_steals_total 3\n"), "got:\n{prom}");
        assert!(prom.contains("grid_lease_reassignments_total 1\n"), "got:\n{prom}");
    }

    #[test]
    fn parse_rejects_non_snapshot_text() {
        assert!(parse_json_snapshot("{\"traceEvents\":[]}").is_err());
    }
}
