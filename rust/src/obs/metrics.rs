//! The process-wide metrics registry: named counters, gauges, and
//! log2-bucket histograms.
//!
//! Design constraints (see `ARCHITECTURE.md` §Observability):
//!
//! * **No atomics or locks in the sim hot loop.** The registry is only
//!   touched at *stage boundaries* — an engine run completing, a batch
//!   resolving, an HTTP request finishing, a summary line rendering.
//!   Engine counters fold in from the already-aggregated
//!   [`crate::sim::RunResult`] at run end, so the issue→fill→stall path
//!   is untouched.
//! * **Deterministic snapshots.** Counters and gauges carry only values
//!   that are deterministic for a given workload (request counts,
//!   simulated accesses, bytes moved); wall-clock observations go into
//!   histograms, which the JSON snapshot excludes
//!   ([`crate::obs::export::json_snapshot`]) — that is what makes "two
//!   identical cold runs produce byte-identical snapshots" a testable
//!   contract.
//! * **Names follow `subsystem_name_unit`** (`exec_requests_total`,
//!   `serve_plan_request_us`, `store_degraded`), so the Prometheus
//!   exposition needs no relabeling.
//!
//! One [`Registry`] is process-global ([`global`]); tests that assert
//! exact values construct their own so parallel test threads cannot
//! interleave.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

/// Log2 histogram bucket count: bucket `i` holds values in
/// `(2^(i-1), 2^i]` (bucket 0 holds 0 and 1); the last bucket is the
/// overflow/`+Inf` catch-all for values above `2^63`.
pub const HIST_BUCKETS: usize = 65;

/// One log2-bucket histogram: per-bucket counts plus count and sum.
#[derive(Clone)]
pub struct Hist {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self { counts: [0; HIST_BUCKETS], count: 0, sum: 0 }
    }
}

impl Hist {
    fn observe(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }
}

/// Which log2 bucket `v` lands in (see [`HIST_BUCKETS`]).
pub fn bucket_index(v: u64) -> usize {
    if v <= 1 {
        0
    } else {
        64 - (v - 1).leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `i` (`None` for the `+Inf` bucket).
pub fn bucket_bound(i: usize) -> Option<u64> {
    if i >= 64 {
        None
    } else {
        Some(1u64 << i)
    }
}

/// The registry's mutable interior: every update and the snapshot walk
/// happen through one of these, under one lock — callers that need a
/// fold and a snapshot to be mutually atomic use [`Registry::with`].
#[derive(Default)]
pub struct Values {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, u64>,
    hists: BTreeMap<String, Hist>,
}

impl Values {
    /// Add `v` to counter `name` (created at 0).
    pub fn counter_add(&mut self, name: &str, v: u64) {
        *self.entry_counter(name) += v;
    }

    /// Set counter `name` to an absolute value — the fold path for
    /// sources that already aggregate (e.g. [`crate::exec::ExecStats`]
    /// is itself monotonic over a store's lifetime).
    pub fn counter_set(&mut self, name: &str, v: u64) {
        *self.entry_counter(name) = v;
    }

    /// Set gauge `name`.
    pub fn gauge_set(&mut self, name: &str, v: u64) {
        match self.gauges.get_mut(name) {
            Some(slot) => *slot = v,
            None => {
                self.gauges.insert(name.to_string(), v);
            }
        }
    }

    /// Record one observation into histogram `name`.
    pub fn observe(&mut self, name: &str, v: u64) {
        match self.hists.get_mut(name) {
            Some(h) => h.observe(v),
            None => {
                let mut h = Hist::default();
                h.observe(v);
                self.hists.insert(name.to_string(), h);
            }
        }
    }

    fn entry_counter(&mut self, name: &str) -> &mut u64 {
        if !self.counters.contains_key(name) {
            self.counters.insert(name.to_string(), 0);
        }
        self.counters.get_mut(name).expect("just inserted")
    }

    /// Immutable snapshot, deterministically ordered (BTreeMap order =
    /// lexicographic by name).
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self.counters.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            gauges: self.gauges.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            hists: self
                .hists
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        HistSnapshot { counts: h.counts.to_vec(), count: h.count, sum: h.sum },
                    )
                })
                .collect(),
        }
    }
}

/// Point-in-time copy of one histogram.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket (non-cumulative) counts, [`HIST_BUCKETS`] long.
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

/// Point-in-time copy of the whole registry, lexicographically sorted.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub hists: Vec<(String, HistSnapshot)>,
}

impl Snapshot {
    /// Counter value by name (0 when absent).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(k, _)| k == name).map_or(0, |(_, v)| *v)
    }

    /// Gauge value by name (0 when absent).
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.iter().find(|(k, _)| k == name).map_or(0, |(_, v)| *v)
    }
}

/// A named-metric registry. Cheap to share (`&Registry` is `Sync`);
/// all methods take `&self` and lock internally.
pub struct Registry {
    values: Mutex<Values>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    pub fn new() -> Self {
        Self { values: Mutex::new(Values::default()) }
    }

    /// Run `f` against the registry interior under the lock — how fold
    /// functions make "write these values, snapshot the result" atomic
    /// with respect to concurrent updaters.
    pub fn with<R>(&self, f: impl FnOnce(&mut Values) -> R) -> R {
        f(&mut self.values.lock().expect("metrics lock"))
    }

    pub fn counter_add(&self, name: &str, v: u64) {
        self.with(|vals| vals.counter_add(name, v));
    }

    pub fn counter_set(&self, name: &str, v: u64) {
        self.with(|vals| vals.counter_set(name, v));
    }

    pub fn gauge_set(&self, name: &str, v: u64) {
        self.with(|vals| vals.gauge_set(name, v));
    }

    pub fn observe(&self, name: &str, v: u64) {
        self.with(|vals| vals.observe(name, v));
    }

    pub fn snapshot(&self) -> Snapshot {
        self.with(|vals| vals.snapshot())
    }

    /// Drop every metric (tests and long-lived daemons that rotate).
    pub fn reset(&self) {
        self.with(|vals| *vals = Values::default());
    }
}

/// The process-wide registry every subsystem folds into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_add_set_and_snapshot_sorted() {
        let r = Registry::new();
        r.counter_add("b_total", 2);
        r.counter_add("a_total", 1);
        r.counter_add("b_total", 3);
        r.counter_set("c_total", 7);
        let s = r.snapshot();
        assert_eq!(
            s.counters,
            vec![("a_total".into(), 1), ("b_total".into(), 5), ("c_total".into(), 7)]
        );
        assert_eq!(s.counter("b_total"), 5);
        assert_eq!(s.counter("absent"), 0);
    }

    #[test]
    fn gauges_overwrite() {
        let r = Registry::new();
        r.gauge_set("store_degraded", 1);
        r.gauge_set("store_degraded", 0);
        assert_eq!(r.snapshot().gauge("store_degraded"), 0);
    }

    #[test]
    fn log2_buckets_partition_the_u64_range() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(5), 3);
        assert_eq!(bucket_index(u64::MAX), 64);
        // Every value lands in exactly the bucket whose bound covers it.
        for v in [0u64, 1, 2, 3, 7, 8, 9, 1024, 1025, u64::MAX / 2] {
            let i = bucket_index(v);
            if let Some(bound) = bucket_bound(i) {
                assert!(v <= bound, "v={v} bucket={i} bound={bound}");
            }
            if i > 0 {
                let below = bucket_bound(i - 1).unwrap();
                assert!(v > below, "v={v} must exceed the previous bound {below}");
            }
        }
    }

    #[test]
    fn histogram_count_and_sum() {
        let r = Registry::new();
        for v in [1u64, 2, 3, 1000] {
            r.observe("x_us", v);
        }
        let s = r.snapshot();
        let (name, h) = &s.hists[0];
        assert_eq!(name, "x_us");
        assert_eq!(h.count, 4);
        assert_eq!(h.sum, 1006);
        assert_eq!(h.counts.iter().sum::<u64>(), 4);
        assert_eq!(h.counts.len(), HIST_BUCKETS);
    }

    #[test]
    fn with_makes_fold_plus_snapshot_atomic() {
        let r = Registry::new();
        let s = r.with(|v| {
            v.counter_set("a_total", 1);
            v.gauge_set("g", 2);
            v.snapshot()
        });
        assert_eq!((s.counter("a_total"), s.gauge("g")), (1, 2));
    }

    #[test]
    fn reset_clears_everything() {
        let r = Registry::new();
        r.counter_add("a", 1);
        r.observe("h", 1);
        r.reset();
        let s = r.snapshot();
        assert!(s.counters.is_empty() && s.gauges.is_empty() && s.hists.is_empty());
    }
}
