//! Chrome trace-event JSON export.
//!
//! `repro <cmd> --trace out.json` serializes every finished span into
//! the Chrome trace-event format — complete events (`"ph":"X"`) with
//! microsecond timestamps — loadable in `about:tracing` or Perfetto.
//! A sibling `out.counters.json` carries the deterministic counter
//! snapshot ([`crate::obs::export::json_snapshot`]).
//!
//! Writes go through the [`StoreIo`] seam so FaultIo chaos schedules
//! cover them: a failed or torn trace write is reported as an error to
//! the caller (who downgrades it to a warning — traces are telemetry,
//! never part of the result contract) and the span buffer is left
//! untouched, so nothing is lost or corrupted.
//!
//! The emitter writes one event per line inside the `traceEvents`
//! array. That is both valid JSON for Perfetto and a stable line
//! grammar [`parse_chrome_trace`] can read back without a JSON parser
//! (the crate is dependency-free).

use std::path::Path;
use std::sync::Arc;

use crate::exec::vfs::{with_retry, StoreIo};
use crate::obs::span::{self, SpanRecord};
use crate::{format_err, Result};

/// Serialize spans as Chrome trace-event JSON. Events are sorted by
/// (start, thread, name) so the file is deterministic for a given set
/// of records.
pub fn chrome_trace_json(records: &[SpanRecord]) -> String {
    let mut recs: Vec<&SpanRecord> = records.iter().collect();
    recs.sort_by(|a, b| {
        a.start_us.cmp(&b.start_us).then(a.tid.cmp(&b.tid)).then(a.name.cmp(b.name))
    });
    let mut out = String::with_capacity(128 + recs.len() * 96);
    out.push_str("{\"traceEvents\":[\n");
    for (i, r) in recs.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"cat\":\"obs\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\"pid\":1,\"tid\":{},\"args\":{{\"depth\":{}}}}}",
            escape_json(r.name),
            r.start_us,
            r.dur_us,
            r.tid,
            r.depth,
        ));
    }
    out.push_str("\n],\"displayTimeUnit\":\"ms\"}\n");
    out
}

fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write the current span buffer as a Chrome trace through `io`.
/// Returns the number of events written. The buffer is *snapshotted*,
/// not drained: a failed write under a chaos schedule loses nothing.
pub fn write_chrome_trace_with(io: &Arc<dyn StoreIo>, path: &Path) -> Result<usize> {
    let records = span::snapshot();
    let body = chrome_trace_json(&records);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            with_retry(|| io.create_dir_all(parent))
                .map_err(|e| format_err!("creating trace dir {}: {e}", parent.display()))?;
        }
    }
    with_retry(|| io.write(path, body.as_bytes()))
        .map_err(|e| format_err!("writing trace file {}: {e}", path.display()))?;
    Ok(records.len())
}

/// [`write_chrome_trace_with`] through the default (real) filesystem.
pub fn write_chrome_trace(path: &Path) -> Result<usize> {
    write_chrome_trace_with(&crate::exec::vfs::default_io(), path)
}

/// One event read back from a trace file — just the fields the
/// `repro obs report` rollup needs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParsedEvent {
    pub name: String,
    pub dur_us: u64,
}

/// Read back a trace file written by [`chrome_trace_json`]: one event
/// object per line, `"name"` and `"dur"` extracted per line. Lines
/// that are not complete events (the envelope, metadata) are skipped;
/// a file with no parseable events is an error — it is either not a
/// trace file or a torn write.
pub fn parse_chrome_trace(text: &str) -> Result<Vec<ParsedEvent>> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim().trim_end_matches(',');
        if !line.starts_with('{') || !line.contains("\"ph\":\"X\"") {
            continue;
        }
        let (Some(name), Some(dur)) = (field_str(line, "name"), field_u64(line, "dur")) else {
            continue;
        };
        out.push(ParsedEvent { name, dur_us: dur });
    }
    if out.is_empty() {
        return Err(format_err!("no trace events found — not a trace file, or a torn write"));
    }
    Ok(out)
}

fn field_str(line: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\":\"");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    let mut out = String::new();
    let mut chars = rest.chars();
    while let Some(c) = chars.next() {
        match c {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'u' => {
                    let hex: String = chars.by_ref().take(4).collect();
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                other => out.push(other),
            },
            c => out.push(c),
        }
    }
    None
}

fn field_u64(line: &str, key: &str) -> Option<u64> {
    let tag = format!("\"{key}\":");
    let start = line.find(&tag)? + tag.len();
    let digits: String = line[start..].chars().take_while(char::is_ascii_digit).collect();
    digits.parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(name: &'static str, start_us: u64, dur_us: u64, tid: u64) -> SpanRecord {
        SpanRecord { name, start_us, dur_us, tid, depth: 0 }
    }

    #[test]
    fn trace_json_has_the_chrome_envelope_and_sorted_events() {
        let json = chrome_trace_json(&[rec("b", 20, 5, 1), rec("a", 10, 3, 2)]);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("],\"displayTimeUnit\":\"ms\"}"));
        let a = json.find("\"name\":\"a\"").unwrap();
        let b = json.find("\"name\":\"b\"").unwrap();
        assert!(a < b, "events must be sorted by start time");
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ts\":10"));
        assert!(json.contains("\"dur\":5"));
    }

    #[test]
    fn trace_json_is_deterministic_for_the_same_records() {
        let recs = [rec("x", 1, 2, 1), rec("y", 3, 4, 2)];
        assert_eq!(chrome_trace_json(&recs), chrome_trace_json(&recs));
    }

    #[test]
    fn parse_round_trips_names_and_durations() {
        let json = chrome_trace_json(&[rec("pool_task", 10, 42, 1), rec("engine_run", 12, 7, 1)]);
        let events = parse_chrome_trace(&json).unwrap();
        assert_eq!(events.len(), 2);
        assert!(events.iter().any(|e| e.name == "pool_task" && e.dur_us == 42));
        assert!(events.iter().any(|e| e.name == "engine_run" && e.dur_us == 7));
    }

    #[test]
    fn parse_rejects_non_trace_text() {
        assert!(parse_chrome_trace("{\"counters\":{}}\n").is_err());
        assert!(parse_chrome_trace("").is_err());
    }

    #[test]
    fn string_escaping_survives_the_round_trip() {
        assert_eq!(escape_json("a\"b\\c"), "a\\\"b\\\\c");
        let line = "{\"name\":\"a\\\"b\\\\c\",\"ph\":\"X\",\"dur\":3}";
        let events = parse_chrome_trace(line).unwrap();
        assert_eq!(events[0].name, "a\"b\\c");
    }

    #[test]
    fn chaos_faulted_write_fails_cleanly_and_keeps_the_buffer() {
        use crate::exec::vfs::{FaultIo, FaultPlan, RealIo};
        drop(crate::obs::span("obs_trace_chaos_probe"));
        let before = crate::obs::span::snapshot().len();
        let io: Arc<dyn StoreIo> =
            Arc::new(FaultIo::new(Arc::new(RealIo), FaultPlan::dead_disk()));
        let dir = std::env::temp_dir()
            .join(format!("multistride_obs_trace_{}", std::process::id()));
        let err = write_chrome_trace_with(&io, &dir.join("t.json"));
        assert!(err.is_err(), "dead disk must surface as an error, not a panic");
        assert!(
            crate::obs::span::snapshot().len() >= before,
            "a failed write must not lose span records"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
