//! Hierarchical timing spans with thread-local stacks.
//!
//! A span covers one coarse unit of work — a coordinator pool task, a
//! tuner rung, a store disk probe, an engine run, a serve request —
//! never anything per-access. Opening one is a thread-local push plus
//! an `Instant::now()`; closing is a push onto a global mutex-guarded
//! vector. Both are nanoseconds against work that takes microseconds
//! to seconds, so spans are safe to leave enabled by default.
//!
//! Records accumulate until [`drain`]/[`snapshot`] and are bounded by
//! [`MAX_SPANS`]: a long-lived serve daemon cannot grow without limit —
//! once full, new records are dropped and `obs_spans_dropped_total`
//! counts them.
//!
//! ```ignore
//! let _span = crate::obs::span("engine_run");
//! // ... work; the record is filed when _span drops ...
//! ```

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Hard cap on buffered finished spans (records, not bytes). At ~64 B
/// a record this bounds the buffer near 16 MiB.
pub const MAX_SPANS: usize = 262_144;

/// One finished span, timestamped in microseconds relative to the
/// first obs activity in the process (a stable epoch for the whole
/// trace file).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanRecord {
    pub name: &'static str,
    /// Microseconds since the process obs epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Small dense per-thread id (1-based, first-use order).
    pub tid: u64,
    /// Nesting depth on its thread at open time (0 = top level).
    pub depth: u32,
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn finished() -> &'static Mutex<Vec<SpanRecord>> {
    static FINISHED: OnceLock<Mutex<Vec<SpanRecord>>> = OnceLock::new();
    FINISHED.get_or_init(|| Mutex::new(Vec::new()))
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Open a span; the record is filed when the guard drops. Names are
/// `&'static str` by design: opening a span must not allocate.
pub fn span(name: &'static str) -> SpanGuard {
    let start = Instant::now();
    let start_us = start.duration_since(epoch()).as_micros() as u64;
    let depth = DEPTH.with(|d| {
        let cur = d.get();
        d.set(cur + 1);
        cur
    });
    SpanGuard { name, start, start_us, depth, tid: TID.with(|t| *t) }
}

/// RAII handle returned by [`span`].
pub struct SpanGuard {
    name: &'static str,
    start: Instant,
    start_us: u64,
    depth: u32,
    tid: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let rec = SpanRecord {
            name: self.name,
            start_us: self.start_us,
            dur_us: self.start.elapsed().as_micros() as u64,
            tid: self.tid,
            depth: self.depth,
        };
        let mut buf = finished().lock().expect("span buffer lock");
        if buf.len() < MAX_SPANS {
            buf.push(rec);
        } else {
            drop(buf);
            crate::obs::metrics::global().counter_add("obs_spans_dropped_total", 1);
        }
    }
}

/// Copy out every finished span, leaving the buffer intact — export
/// paths use this so a failed trace write (chaos schedules!) loses
/// nothing.
pub fn snapshot() -> Vec<SpanRecord> {
    finished().lock().expect("span buffer lock").clone()
}

/// Take every finished span, emptying the buffer.
pub fn drain() -> Vec<SpanRecord> {
    std::mem::take(&mut *finished().lock().expect("span buffer lock"))
}

/// Per-name rollup for the `repro obs report` table.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpanAgg {
    pub name: String,
    pub count: u64,
    pub total_us: u64,
    pub max_us: u64,
}

impl SpanAgg {
    pub fn mean_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_us / self.count
        }
    }
}

/// Aggregate `(name, dur_us)` pairs into per-name rollups, sorted by
/// total time descending (name ascending as the tiebreak, so reports
/// are deterministic).
pub fn aggregate<'a>(spans: impl IntoIterator<Item = (&'a str, u64)>) -> Vec<SpanAgg> {
    let mut by_name: std::collections::BTreeMap<&str, SpanAgg> = std::collections::BTreeMap::new();
    for (name, dur_us) in spans {
        match by_name.get_mut(name) {
            Some(agg) => {
                agg.count += 1;
                agg.total_us += dur_us;
                agg.max_us = agg.max_us.max(dur_us);
            }
            None => {
                by_name.insert(
                    name,
                    SpanAgg { name: name.to_string(), count: 1, total_us: dur_us, max_us: dur_us },
                );
            }
        }
    }
    let mut out: Vec<SpanAgg> = by_name.into_values().collect();
    out.sort_by(|a, b| b.total_us.cmp(&a.total_us).then_with(|| a.name.cmp(&b.name)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_name_thread_and_nesting() {
        {
            let _outer = span("obs_test_outer");
            let _inner = span("obs_test_inner");
        }
        let recs = snapshot();
        let inner = recs.iter().find(|r| r.name == "obs_test_inner").expect("inner recorded");
        let outer = recs.iter().find(|r| r.name == "obs_test_outer").expect("outer recorded");
        assert_eq!(inner.tid, outer.tid, "same thread");
        assert_eq!(inner.depth, outer.depth + 1, "inner nests one level deeper");
        assert!(inner.start_us >= outer.start_us);
    }

    #[test]
    fn threads_get_distinct_ids() {
        let _main = span("obs_test_tid_main");
        std::thread::spawn(|| {
            let _child = span("obs_test_tid_child");
        })
        .join()
        .unwrap();
        let recs = snapshot();
        let main_tid =
            recs.iter().find(|r| r.name == "obs_test_tid_main").map(|r| r.tid).unwrap_or(0);
        let child = recs.iter().find(|r| r.name == "obs_test_tid_child").expect("child recorded");
        assert_ne!(child.tid, 0);
        assert_ne!(child.tid, main_tid);
    }

    #[test]
    fn aggregate_rolls_up_and_sorts_by_total() {
        let aggs = aggregate([("b", 10u64), ("a", 3), ("b", 20), ("a", 1)]);
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].name, "b");
        assert_eq!(aggs[0].count, 2);
        assert_eq!(aggs[0].total_us, 30);
        assert_eq!(aggs[0].max_us, 20);
        assert_eq!(aggs[0].mean_us(), 15);
        assert_eq!(aggs[1].name, "a");
        assert_eq!(aggs[1].total_us, 4);
    }

    #[test]
    fn aggregate_breaks_total_ties_by_name() {
        let aggs = aggregate([("z", 5u64), ("a", 5)]);
        assert_eq!(aggs[0].name, "a");
        assert_eq!(aggs[1].name, "z");
    }
}
