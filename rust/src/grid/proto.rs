//! The fleet wire protocol: length-prefixed, checksummed frames over
//! `std::net::TcpStream` — dependency-free, little-endian, in the
//! style of the segment record format (`exec/segment.rs`).
//!
//! Frame grammar (all integers little-endian):
//!
//! ```text
//! frame   := kind: u8 | len: u32 | payload: len bytes | fnv64: u64
//! ```
//!
//! The checksum covers `kind | len | payload`, so a torn or corrupted
//! frame is always detected before its payload is interpreted; the
//! frame length is capped at [`MAX_FRAME_BYTES`] so a garbage peer
//! cannot ask the reader to allocate the moon.
//!
//! Message payloads:
//!
//! ```text
//! HELLO    (0x01, worker → coordinator): version: u32 | fingerprint: u64
//! WELCOME  (0x02, coordinator → worker): worker_id: u64 | fingerprint: u64
//! REQUEST  (0x03, worker → coordinator): max_points: u32
//! BATCH    (0x04, coordinator → worker): lease: u64 | n: u32 | n × key: u64
//! RESULTS  (0x05, worker → coordinator): lease: u64 | n: u32
//!                                        | n × (key: u64 | bin: 416 bytes)
//! ACK      (0x06, coordinator → worker): lease: u64 | fresh: u32 | dup: u32
//! DRAINED  (0x07, coordinator → worker): done: u8
//! ERROR    (0x08, either direction):     utf-8 message
//! BYE      (0x09, worker → coordinator): empty
//! ```
//!
//! `RESULTS` records carry [`crate::exec::format::encode_result_bin`]
//! payloads — the same 416-byte binary twin the segment store appends,
//! which is what makes a fleet-populated store record-identical to a
//! single-host cold run.
//!
//! Both sides derive the plan independently (same `repro all` plan
//! builder, same flags) and exchange [`plan_fingerprint`]s in the
//! handshake: a worker launched with a different machine, scale, or
//! prefetch setting is refused before any batch moves.

use std::io::{Read, Write};

use crate::exec::format::RESULT_BIN_BYTES;
use crate::tune::plan::fnv64;
use crate::{ensure, format_err, Result};

/// Bumped when the frame grammar changes incompatibly.
pub const PROTO_VERSION: u32 = 1;
/// Upper bound on a frame's payload length.
pub const MAX_FRAME_BYTES: usize = 16 << 20;

/// One protocol message (see the module docs for the grammar).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    Hello { version: u32, fingerprint: u64 },
    Welcome { worker_id: u64, fingerprint: u64 },
    Request { max_points: u32 },
    Batch { lease: u64, keys: Vec<u64> },
    Results { lease: u64, records: Vec<(u64, Vec<u8>)> },
    Ack { lease: u64, fresh: u32, dup: u32 },
    Drained { done: bool },
    Error { msg: String },
    Bye,
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Hello { .. } => 0x01,
            Frame::Welcome { .. } => 0x02,
            Frame::Request { .. } => 0x03,
            Frame::Batch { .. } => 0x04,
            Frame::Results { .. } => 0x05,
            Frame::Ack { .. } => 0x06,
            Frame::Drained { .. } => 0x07,
            Frame::Error { .. } => 0x08,
            Frame::Bye => 0x09,
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut p = Vec::new();
        match self {
            Frame::Hello { version, fingerprint } => {
                p.extend_from_slice(&version.to_le_bytes());
                p.extend_from_slice(&fingerprint.to_le_bytes());
            }
            Frame::Welcome { worker_id, fingerprint } => {
                p.extend_from_slice(&worker_id.to_le_bytes());
                p.extend_from_slice(&fingerprint.to_le_bytes());
            }
            Frame::Request { max_points } => p.extend_from_slice(&max_points.to_le_bytes()),
            Frame::Batch { lease, keys } => {
                p.extend_from_slice(&lease.to_le_bytes());
                p.extend_from_slice(&(keys.len() as u32).to_le_bytes());
                for k in keys {
                    p.extend_from_slice(&k.to_le_bytes());
                }
            }
            Frame::Results { lease, records } => {
                p.extend_from_slice(&lease.to_le_bytes());
                p.extend_from_slice(&(records.len() as u32).to_le_bytes());
                for (k, bin) in records {
                    debug_assert_eq!(bin.len(), RESULT_BIN_BYTES);
                    p.extend_from_slice(&k.to_le_bytes());
                    p.extend_from_slice(bin);
                }
            }
            Frame::Ack { lease, fresh, dup } => {
                p.extend_from_slice(&lease.to_le_bytes());
                p.extend_from_slice(&fresh.to_le_bytes());
                p.extend_from_slice(&dup.to_le_bytes());
            }
            Frame::Drained { done } => p.push(u8::from(*done)),
            Frame::Error { msg } => p.extend_from_slice(msg.as_bytes()),
            Frame::Bye => {}
        }
        p
    }
}

/// Serialize one frame (header + payload + trailing checksum).
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let payload = f.payload();
    let mut out = Vec::with_capacity(5 + payload.len() + 8);
    out.push(f.kind());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    let sum = fnv64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Write one frame and flush it — every message is a complete unit on
/// the wire, so the peer never blocks on a half-buffered frame.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> std::io::Result<()> {
    w.write_all(&encode_frame(f))?;
    w.flush()
}

fn read_u32(b: &[u8], at: usize) -> u32 {
    u32::from_le_bytes(b[at..at + 4].try_into().expect("bounds checked by caller"))
}

fn read_u64(b: &[u8], at: usize) -> u64 {
    u64::from_le_bytes(b[at..at + 8].try_into().expect("bounds checked by caller"))
}

/// Read one frame, verifying length bound and checksum. A short read
/// (peer died mid-frame) or a checksum mismatch (torn/corrupted frame)
/// is an error — the connection is no longer trustworthy.
pub fn read_frame(r: &mut impl Read) -> Result<Frame> {
    let mut kind = [0u8; 1];
    r.read_exact(&mut kind).map_err(|e| format_err!("reading frame header: {e}"))?;
    read_frame_after_kind(kind[0], r)
}

/// Finish reading a frame whose kind byte the caller already consumed
/// (the coordinator peeks one byte so an idle-socket timeout between
/// frames is distinguishable from a death mid-frame).
pub fn read_frame_after_kind(kind: u8, r: &mut impl Read) -> Result<Frame> {
    let mut len_bytes = [0u8; 4];
    r.read_exact(&mut len_bytes).map_err(|e| format_err!("reading frame length: {e}"))?;
    let len = u32::from_le_bytes(len_bytes) as usize;
    ensure!(len <= MAX_FRAME_BYTES, "frame payload of {len} bytes exceeds {MAX_FRAME_BYTES}");
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload).map_err(|e| format_err!("reading frame payload: {e}"))?;
    let mut sum_bytes = [0u8; 8];
    r.read_exact(&mut sum_bytes).map_err(|e| format_err!("reading frame checksum: {e}"))?;
    let mut body = Vec::with_capacity(5 + len);
    body.push(kind);
    body.extend_from_slice(&len_bytes);
    body.extend_from_slice(&payload);
    ensure!(
        fnv64(&body) == u64::from_le_bytes(sum_bytes),
        "frame checksum mismatch (kind 0x{kind:02x}, {len} payload byte(s))"
    );
    parse_payload(kind, &payload)
}

fn parse_payload(kind: u8, p: &[u8]) -> Result<Frame> {
    let exact = |want: usize| -> Result<()> {
        ensure!(p.len() == want, "frame 0x{kind:02x} payload: {} byte(s), want {want}", p.len());
        Ok(())
    };
    match kind {
        0x01 => {
            exact(12)?;
            Ok(Frame::Hello { version: read_u32(p, 0), fingerprint: read_u64(p, 4) })
        }
        0x02 => {
            exact(16)?;
            Ok(Frame::Welcome { worker_id: read_u64(p, 0), fingerprint: read_u64(p, 8) })
        }
        0x03 => {
            exact(4)?;
            Ok(Frame::Request { max_points: read_u32(p, 0) })
        }
        0x04 => {
            ensure!(p.len() >= 12, "BATCH payload too short: {} byte(s)", p.len());
            let lease = read_u64(p, 0);
            let n = read_u32(p, 8) as usize;
            exact(12 + n * 8)?;
            let keys = (0..n).map(|i| read_u64(p, 12 + i * 8)).collect();
            Ok(Frame::Batch { lease, keys })
        }
        0x05 => {
            ensure!(p.len() >= 12, "RESULTS payload too short: {} byte(s)", p.len());
            let lease = read_u64(p, 0);
            let n = read_u32(p, 8) as usize;
            let rec = 8 + RESULT_BIN_BYTES;
            exact(12 + n * rec)?;
            let records = (0..n)
                .map(|i| {
                    let at = 12 + i * rec;
                    (read_u64(p, at), p[at + 8..at + rec].to_vec())
                })
                .collect();
            Ok(Frame::Results { lease, records })
        }
        0x06 => {
            exact(16)?;
            Ok(Frame::Ack { lease: read_u64(p, 0), fresh: read_u32(p, 8), dup: read_u32(p, 12) })
        }
        0x07 => {
            exact(1)?;
            Ok(Frame::Drained { done: p[0] != 0 })
        }
        0x08 => Ok(Frame::Error {
            msg: String::from_utf8(p.to_vec())
                .map_err(|_| format_err!("ERROR frame message is not UTF-8"))?,
        }),
        0x09 => {
            exact(0)?;
            Ok(Frame::Bye)
        }
        other => Err(format_err!("unknown frame kind 0x{other:02x}")),
    }
}

/// Content fingerprint of a plan: FNV-1a over the count and the sorted
/// content keys. Both ends compute it from their own plan, so mismatched
/// flags (machine, scale, `--max-total`, prefetch) are caught in the
/// handshake rather than surfacing as unknown-key errors mid-run.
pub fn plan_fingerprint(keys: &[u64]) -> u64 {
    let mut sorted: Vec<u64> = keys.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut bytes = Vec::with_capacity(8 + sorted.len() * 8);
    bytes.extend_from_slice(&(sorted.len() as u64).to_le_bytes());
    for k in &sorted {
        bytes.extend_from_slice(&k.to_le_bytes());
    }
    fnv64(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: Frame) {
        let bytes = encode_frame(&f);
        let got = read_frame(&mut bytes.as_slice()).expect("frame parses");
        assert_eq!(got, f);
    }

    #[test]
    fn every_frame_kind_round_trips() {
        round_trip(Frame::Hello { version: PROTO_VERSION, fingerprint: 0xDEAD_BEEF });
        round_trip(Frame::Welcome { worker_id: 3, fingerprint: 7 });
        round_trip(Frame::Request { max_points: 16 });
        round_trip(Frame::Batch { lease: 42, keys: vec![1, u64::MAX, 9] });
        round_trip(Frame::Results {
            lease: 42,
            records: vec![(5, vec![0xAB; RESULT_BIN_BYTES]), (6, vec![0x01; RESULT_BIN_BYTES])],
        });
        round_trip(Frame::Ack { lease: 42, fresh: 2, dup: 1 });
        round_trip(Frame::Drained { done: true });
        round_trip(Frame::Drained { done: false });
        round_trip(Frame::Error { msg: "plan fingerprint mismatch".into() });
        round_trip(Frame::Bye);
    }

    #[test]
    fn corrupted_byte_fails_the_checksum() {
        let mut bytes = encode_frame(&Frame::Batch { lease: 1, keys: vec![2, 3] });
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = read_frame(&mut bytes.as_slice()).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("payload"),
            "corruption must be detected, got: {err}"
        );
    }

    #[test]
    fn truncated_frame_is_an_error_not_a_hang() {
        let bytes = encode_frame(&Frame::Request { max_points: 8 });
        for cut in 1..bytes.len() {
            assert!(
                read_frame(&mut bytes[..cut].to_vec().as_slice()).is_err(),
                "prefix of {cut} byte(s) must not parse"
            );
        }
    }

    #[test]
    fn oversized_length_is_rejected_before_allocation() {
        let mut bytes = vec![0x04u8];
        bytes.extend_from_slice(&(u32::MAX).to_le_bytes());
        let err = read_frame(&mut bytes.as_slice()).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "got: {err}");
    }

    #[test]
    fn fingerprint_is_order_independent_and_content_sensitive() {
        let a = plan_fingerprint(&[1, 2, 3]);
        assert_eq!(a, plan_fingerprint(&[3, 1, 2]), "order must not matter");
        assert_ne!(a, plan_fingerprint(&[1, 2, 4]), "content must matter");
        assert_ne!(a, plan_fingerprint(&[1, 2]), "count must matter");
    }
}
