//! Dynamic fleet execution: `repro grid coordinator` / `repro grid
//! worker` — one repro-all plan drained over sockets by however many
//! workers show up.
//!
//! This module is the *dynamic* half of grid execution; the static
//! half (`repro grid --shard k/n`, ownership manifests, `repro store
//! merge`) lives in [`crate::exec::grid`] and remains the right tool
//! when hosts cannot reach each other. Layout:
//!
//! * [`proto`] — the length-prefixed, FNV-checksummed frame grammar on
//!   `std::net::TcpStream`, plus the plan fingerprint handshake;
//! * [`coordinator`] — lease table, batch handout, reassignment from
//!   dead/slow workers, and the single store-append path;
//! * [`worker`] — plan mirror, batch simulation on the local
//!   work-stealing pool, result streaming;
//! * [`fault`] — [`fault::FaultStream`], the seeded wire-fault
//!   injector the chaos wall drives.
//!
//! The CLI surface mirrors `serve`: [`parse_fleet_cli`] pulls the
//! fleet-specific flags out and leaves the generic ones (`--results`,
//! `--smoke`, `--machine`, …) for the caller's option parser. See
//! `ARCHITECTURE.md` §Grid & merge for the protocol walkthrough and
//! the add-a-worker recipe.

pub mod coordinator;
pub mod fault;
pub mod proto;
pub mod worker;

pub use coordinator::{Coordinator, CoordinatorConfig, FleetReport, DEFAULT_BATCH, DEFAULT_LEASE_MS, DEFAULT_PORT};
pub use fault::FaultStream;
pub use proto::{plan_fingerprint, Frame, PROTO_VERSION};
pub use worker::{parse_connect, run_worker, WorkerConfig, WorkerReport};

use crate::{format_err, Result};

/// Which fleet role `repro grid <role>` was asked to play, with its
/// role-specific flags parsed out.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FleetRole {
    Coordinator { port: u16, cfg: CoordinatorConfig },
    Worker { host: String, port: u16, cfg: WorkerConfig },
}

/// Parse `repro grid coordinator|worker` flags (mirroring
/// `serve::parse_serve_cli`): fleet flags out, generic flags returned
/// for `Opts::parse`. `args[0]` must be the role name. Errors are
/// malformed invocations — the CLI maps them to exit 2.
pub fn parse_fleet_cli(args: &[String]) -> Result<(FleetRole, Vec<String>)> {
    fn value<'a>(it: &mut std::slice::Iter<'a, String>, flag: &str) -> Result<&'a String> {
        it.next().ok_or_else(|| format_err!("grid: {flag} needs a value"))
    }
    fn number<T: std::str::FromStr>(v: &str, flag: &str) -> Result<T> {
        v.parse().map_err(|_| format_err!("grid: {flag} needs a number, got {v:?}"))
    }
    let role = args.first().map(String::as_str).unwrap_or_default();
    let is_coordinator = match role {
        "coordinator" => true,
        "worker" => false,
        other => return Err(format_err!("grid: unknown role {other:?} (coordinator|worker)")),
    };
    let mut port: u16 = DEFAULT_PORT;
    let mut connect: Option<(String, u16)> = None;
    let mut batch: u32 = DEFAULT_BATCH;
    let mut lease_ms: u64 = DEFAULT_LEASE_MS;
    let mut max_batches: Option<u64> = None;
    let mut abandon_after: Option<u64> = None;
    let mut rest = Vec::new();
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--port" if is_coordinator => {
                port = number(value(&mut it, "--port")?, "--port")?;
            }
            "--connect" if !is_coordinator => {
                connect = Some(parse_connect(value(&mut it, "--connect")?)?);
            }
            "--batch" => {
                batch = number::<u32>(value(&mut it, "--batch")?, "--batch")?.max(1);
            }
            "--lease-ms" if is_coordinator => {
                lease_ms = number::<u64>(value(&mut it, "--lease-ms")?, "--lease-ms")?.max(1);
            }
            "--max-batches" if !is_coordinator => {
                max_batches = Some(number(value(&mut it, "--max-batches")?, "--max-batches")?);
            }
            "--abandon-after" if !is_coordinator => {
                abandon_after =
                    Some(number(value(&mut it, "--abandon-after")?, "--abandon-after")?);
            }
            _ => rest.push(a.clone()),
        }
    }
    let role = if is_coordinator {
        FleetRole::Coordinator { port, cfg: CoordinatorConfig { lease_ms, batch } }
    } else {
        let (host, port) = connect
            .ok_or_else(|| format_err!("grid worker requires --connect HOST:PORT"))?;
        let cfg = WorkerConfig {
            batch,
            local_workers: crate::coordinator::pool::default_workers(),
            max_batches,
            abandon_after,
        };
        FleetRole::Worker { host, port, cfg }
    };
    Ok((role, rest))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    #[test]
    fn coordinator_flags_parse_and_generic_flags_pass_through() {
        let (role, rest) = parse_fleet_cli(&s(&[
            "coordinator", "--port", "0", "--lease-ms", "200", "--batch", "4", "--smoke",
            "--results", "/tmp/r",
        ]))
        .expect("parses");
        assert_eq!(
            role,
            FleetRole::Coordinator { port: 0, cfg: CoordinatorConfig { lease_ms: 200, batch: 4 } }
        );
        assert_eq!(rest, s(&["--smoke", "--results", "/tmp/r"]));
    }

    #[test]
    fn worker_requires_and_validates_connect() {
        let (role, _) =
            parse_fleet_cli(&s(&["worker", "--connect", "10.0.0.7:7879"])).expect("parses");
        match role {
            FleetRole::Worker { host, port, .. } => {
                assert_eq!((host.as_str(), port), ("10.0.0.7", 7879));
            }
            other => panic!("expected worker, got {other:?}"),
        }
        for bad in ["worker"] {
            let err = parse_fleet_cli(&s(&[bad])).unwrap_err().to_string();
            assert!(err.contains("--connect"), "got: {err}");
        }
        for bad in ["nohost", ":7879", "h:", "h:0", "h:70000", "h:abc"] {
            let err =
                parse_fleet_cli(&s(&["worker", "--connect", bad])).unwrap_err().to_string();
            assert!(err.contains("--connect"), "{bad:?} must be malformed, got: {err}");
        }
    }

    #[test]
    fn unknown_role_and_role_mismatched_flags_are_errors_or_passthrough() {
        assert!(parse_fleet_cli(&s(&["shard"])).is_err());
        // A coordinator-only flag on a worker is not consumed — it falls
        // through to the generic parser, which rejects it (exit 2 there).
        let (_, rest) =
            parse_fleet_cli(&s(&["worker", "--connect", "h:1", "--lease-ms", "5"])).expect("parses");
        assert_eq!(rest, s(&["--lease-ms", "5"]));
    }

    #[test]
    fn abandon_and_max_batches_are_worker_knobs() {
        let (role, rest) = parse_fleet_cli(&s(&[
            "worker", "--connect", "h:1", "--abandon-after", "1", "--max-batches", "3",
        ]))
        .expect("parses");
        assert!(rest.is_empty());
        match role {
            FleetRole::Worker { cfg, .. } => {
                assert_eq!(cfg.abandon_after, Some(1));
                assert_eq!(cfg.max_batches, Some(3));
            }
            other => panic!("expected worker, got {other:?}"),
        }
    }
}
