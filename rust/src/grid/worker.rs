//! The fleet worker: connects to a coordinator, leases batches of
//! content keys, simulates them on the local pool, and streams the
//! records back.
//!
//! The worker derives the *same* repro-all plan the coordinator did
//! (same plan builder, same flags) and keeps a key → [`SimPoint`] map;
//! the wire only ever carries content keys and result records, never
//! simulation inputs. A worker launched with different flags fails the
//! fingerprint handshake instead of silently simulating the wrong grid.
//!
//! Each leased batch runs through the ordinary [`Planner`] against the
//! worker's own store — an ephemeral one by default (`--cold`), or a
//! local persistent store (`--results DIR`) whose hits turn leased
//! work into pure lookups. Either way the bytes shipped back are
//! [`encode_result_bin`] records, bit-identical to what a single-host
//! run would have appended, by the determinism contract.
//!
//! Two test/bench knobs ride along: `max_batches` stops a worker
//! cleanly after N batches (bench pacing), and `abandon_after` drops
//! the connection *without* returning the Nth batch — the scripted
//! mid-run crash the chaos wall and the CI kill-a-worker job use.

use std::collections::HashMap;
use std::net::TcpStream;
use std::time::Duration;

use crate::coordinator::pool::default_workers;
use crate::exec::format::encode_result_bin;
use crate::exec::{Planner, ResultStore, SimPoint};
use crate::grid::coordinator::DEFAULT_BATCH;
use crate::grid::proto::{plan_fingerprint, read_frame, write_frame, Frame, PROTO_VERSION};
use crate::{ensure, format_err, Result};

/// Handshake/ack patience. Coordinator replies are immediate; this
/// bounds how long a dead coordinator can hang a worker.
const REPLY_TIMEOUT: Duration = Duration::from_secs(30);
/// Idle nap between polls once the pending queue is empty but other
/// workers still hold leases that might yet be requeued.
const IDLE_NAP: Duration = Duration::from_millis(20);

/// Knobs for one worker run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerConfig {
    /// Points to request per batch.
    pub batch: u32,
    /// Local pool width for simulating a batch.
    pub local_workers: usize,
    /// Stop cleanly (BYE) after this many batches.
    pub max_batches: Option<u64>,
    /// Crash deliberately: receive the Nth batch, then drop the
    /// connection without results. The coordinator must requeue.
    pub abandon_after: Option<u64>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self { batch: DEFAULT_BATCH, local_workers: default_workers(), max_batches: None, abandon_after: None }
    }
}

/// What one worker run did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerReport {
    pub worker_id: u64,
    /// Batches received (including an abandoned one).
    pub batches: u64,
    /// Points simulated and acknowledged.
    pub points: u64,
    /// True when `abandon_after` cut the run short.
    pub abandoned: bool,
}

/// Parse and validate a `HOST:PORT` connect target. Malformed input is
/// a usage error (the CLI maps it to exit 2).
pub fn parse_connect(s: &str) -> Result<(String, u16)> {
    let (host, port) = s
        .rsplit_once(':')
        .ok_or_else(|| format_err!("--connect wants HOST:PORT, got {s:?}"))?;
    ensure!(!host.is_empty(), "--connect wants HOST:PORT, got {s:?} (empty host)");
    let port: u16 = port
        .parse()
        .map_err(|_| format_err!("--connect port must be 1..=65535, got {port:?}"))?;
    ensure!(port != 0, "--connect port must be nonzero");
    Ok((host.to_string(), port))
}

/// Work one coordinator's plan to completion (or to a configured
/// stop). `points` must be the same plan the coordinator holds.
pub fn run_worker(
    host: &str,
    port: u16,
    store: &ResultStore,
    points: &[SimPoint],
    cfg: &WorkerConfig,
) -> Result<WorkerReport> {
    let _span = crate::obs::span("grid_worker_run");
    let by_key: HashMap<u64, &SimPoint> = points.iter().map(|p| (p.key(), p)).collect();
    let keys: Vec<u64> = points.iter().map(|p| p.key()).collect();
    let fingerprint = plan_fingerprint(&keys);

    let stream = TcpStream::connect((host, port))
        .map_err(|e| format_err!("connecting to coordinator {host}:{port}: {e}"))?;
    stream.set_read_timeout(Some(REPLY_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    let mut reader = stream.try_clone().map_err(|e| format_err!("cloning stream: {e}"))?;
    let mut writer = stream;

    write_frame(&mut writer, &Frame::Hello { version: PROTO_VERSION, fingerprint })
        .map_err(|e| format_err!("sending HELLO: {e}"))?;
    let worker_id = match read_frame(&mut reader)? {
        Frame::Welcome { worker_id, fingerprint: fp } => {
            ensure!(fp == fingerprint, "coordinator echoed fingerprint {fp:#018x}, sent {fingerprint:#018x}");
            worker_id
        }
        Frame::Error { msg } => return Err(format_err!("coordinator refused: {msg}")),
        other => return Err(format_err!("expected WELCOME, got {other:?}")),
    };

    let mut report = WorkerReport { worker_id, batches: 0, points: 0, abandoned: false };
    loop {
        if cfg.max_batches.is_some_and(|max| report.batches >= max) {
            let _ = write_frame(&mut writer, &Frame::Bye);
            break;
        }
        write_frame(&mut writer, &Frame::Request { max_points: cfg.batch.max(1) })
            .map_err(|e| format_err!("sending REQUEST: {e}"))?;
        match read_frame(&mut reader)? {
            Frame::Batch { lease, keys } => {
                report.batches += 1;
                if cfg.abandon_after.is_some_and(|n| report.batches >= n) {
                    // Scripted crash: vanish mid-batch, results unsent.
                    report.abandoned = true;
                    break;
                }
                let batch_points: Vec<SimPoint> = keys
                    .iter()
                    .map(|k| {
                        by_key
                            .get(k)
                            .map(|&p| p.clone())
                            .ok_or_else(|| format_err!("leased unknown key {k:#018x}"))
                    })
                    .collect::<Result<_>>()?;
                let results = {
                    let _span = crate::obs::span("grid_worker_batch");
                    Planner::new(store).with_workers(cfg.local_workers).run(&batch_points)?
                };
                let records: Vec<(u64, Vec<u8>)> = keys
                    .iter()
                    .zip(&results)
                    .map(|(&k, r)| (k, encode_result_bin(r).to_vec()))
                    .collect();
                write_frame(&mut writer, &Frame::Results { lease, records })
                    .map_err(|e| format_err!("sending RESULTS: {e}"))?;
                match read_frame(&mut reader)? {
                    Frame::Ack { lease: acked, fresh, dup } => {
                        ensure!(acked == lease, "ACK for lease {acked}, sent {lease}");
                        report.points += u64::from(fresh) + u64::from(dup);
                    }
                    Frame::Error { msg } => return Err(format_err!("coordinator rejected results: {msg}")),
                    other => return Err(format_err!("expected ACK, got {other:?}")),
                }
            }
            Frame::Drained { done: true } => {
                let _ = write_frame(&mut writer, &Frame::Bye);
                break;
            }
            Frame::Drained { done: false } => {
                // Others still hold leases; their keys may yet requeue.
                std::thread::sleep(IDLE_NAP);
            }
            Frame::Error { msg } => return Err(format_err!("coordinator: {msg}")),
            other => return Err(format_err!("expected BATCH or DRAINED, got {other:?}")),
        }
    }
    Ok(report)
}
