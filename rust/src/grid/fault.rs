//! Seeded fault injection for the wire — the socket twin of
//! [`crate::exec::vfs::FaultIo`].
//!
//! [`FaultStream`] wraps any `Read + Write` byte stream and injects a
//! deterministic fault schedule: the decision for operation `i` is a
//! pure function of `(seed, i)` (same FNV scheme as the store's fault
//! injector), so a failing chaos run replays exactly from its seed.
//!
//! Fault classes, chosen from the hash bits when an operation is
//! scheduled to fault:
//!
//! * **short read** — `read` returns fewer bytes than asked (≥ 1).
//!   Benign for correct `read_exact` loops; fatal for code that
//!   assumes one `read` returns one frame.
//! * **EINTR** — `ErrorKind::Interrupted` with no side effect;
//!   `read_exact`/`write_all` retry these by contract.
//! * **torn write** — a prefix of the buffer reaches the peer, then
//!   the call errors and the stream is poisoned: the frame-level
//!   checksum (`proto.rs`) is what turns this into a clean reject on
//!   the far side.
//! * **disconnect** — `ConnectionReset` and the stream is poisoned
//!   (every later call fails), modelling a peer dying mid-batch.
//!
//! The chaos wall in `tests/grid_fleet.rs` drives a worker through a
//! `FaultStream` and asserts the coordinator's invariant: a crash
//! mid-batch never loses a point (the lease is reassigned) and never
//! duplicates one in the store (content keys are idempotent).

use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use crate::exec::vfs::FaultPlan;
use crate::tune::plan::fnv64;

/// What the schedule injects for one faulting operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WireFault {
    ShortRead,
    Eintr,
    TornWrite,
    Disconnect,
}

/// A `Read + Write` stream with a deterministic seeded fault schedule.
pub struct FaultStream<T> {
    inner: T,
    plan: FaultPlan,
    ops: AtomicU64,
    injected: AtomicU64,
    poisoned: AtomicBool,
}

impl<T> FaultStream<T> {
    pub fn new(inner: T, plan: FaultPlan) -> Self {
        Self {
            inner,
            plan,
            ops: AtomicU64::new(0),
            injected: AtomicU64::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Schedule derived from a bare seed (see [`FaultPlan::from_seed`]).
    pub fn seeded(inner: T, seed: u64) -> Self {
        Self::new(inner, FaultPlan::from_seed(seed))
    }

    /// Faults injected so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    /// True once a disconnect/torn-write fault has killed the stream.
    pub fn poisoned(&self) -> bool {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// The fault (if any) scheduled for the next operation. A
    /// crash-point in the plan becomes a hard disconnect; scheduled
    /// faults pick their class from the hash bits.
    fn next_fault(&self) -> Option<WireFault> {
        let i = self.ops.fetch_add(1, Ordering::SeqCst);
        if self.poisoned() {
            return Some(WireFault::Disconnect);
        }
        if let Some(at) = self.plan.crash_at {
            if i >= at {
                return Some(WireFault::Disconnect);
            }
        }
        if self.plan.period == 0 {
            return None;
        }
        let h = fnv64(&[self.plan.seed.to_le_bytes(), i.to_le_bytes()].concat());
        if h % self.plan.period != 0 {
            return None;
        }
        self.injected.fetch_add(1, Ordering::SeqCst);
        Some(match (h >> 16) % 4 {
            0 => WireFault::ShortRead,
            1 => WireFault::Eintr,
            2 => WireFault::TornWrite,
            _ => WireFault::Disconnect,
        })
    }

    fn disconnect_err(&self) -> io::Error {
        self.poisoned.store(true, Ordering::SeqCst);
        io::Error::new(io::ErrorKind::ConnectionReset, "injected disconnect: peer is gone")
    }
}

impl<T: Read> Read for FaultStream<T> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self.next_fault() {
            Some(WireFault::Disconnect) => Err(self.disconnect_err()),
            Some(WireFault::Eintr) => {
                Err(io::Error::new(io::ErrorKind::Interrupted, "injected EINTR"))
            }
            Some(WireFault::ShortRead) if buf.len() > 1 => {
                let half = buf.len() / 2;
                self.inner.read(&mut buf[..half])
            }
            Some(WireFault::ShortRead) | Some(WireFault::TornWrite) | None => {
                self.inner.read(buf)
            }
        }
    }
}

impl<T: Write> Write for FaultStream<T> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self.next_fault() {
            Some(WireFault::Disconnect) => Err(self.disconnect_err()),
            Some(WireFault::Eintr) => {
                Err(io::Error::new(io::ErrorKind::Interrupted, "injected EINTR"))
            }
            Some(WireFault::TornWrite) => {
                // A prefix lands on the wire, then the stream dies: the
                // peer sees a frame that cannot checksum.
                if buf.len() > 1 {
                    let _ = self.inner.write(&buf[..buf.len() / 2]);
                    let _ = self.inner.flush();
                }
                Err(self.disconnect_err())
            }
            Some(WireFault::ShortRead) | None => self.inner.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.poisoned() {
            return Err(self.disconnect_err());
        }
        self.inner.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::proto::{encode_frame, read_frame, Frame};

    #[test]
    fn fault_free_plan_passes_frames_through_untouched() {
        let frame = Frame::Batch { lease: 9, keys: vec![1, 2, 3] };
        let bytes = encode_frame(&frame);
        let mut s = FaultStream::new(bytes.as_slice(), FaultPlan { seed: 0, period: 0, crash_at: None });
        assert_eq!(read_frame(&mut s).expect("clean read"), frame);
        assert_eq!(s.injected(), 0);
    }

    #[test]
    fn short_reads_are_absorbed_by_read_exact_loops() {
        // Period 1 with a seed whose hash class is ShortRead on most ops
        // is not guaranteed, so force the class: every op faults, and we
        // accept any mix of ShortRead/Eintr (both absorbed by read_exact)
        // by scanning seeds for a plan with no kill class early on.
        let frame = Frame::Batch { lease: 7, keys: vec![11, 22, 33, 44] };
        let bytes = encode_frame(&frame);
        let mut tested = 0;
        for seed in 0..64u64 {
            let plan = FaultPlan { seed, period: 2, crash_at: None };
            let probe = FaultStream::new(std::io::empty(), plan);
            // Peek the schedule: usable only if the first 64 ops never
            // disconnect (reads don't write, so TornWrite on a read op
            // degrades to a plain read — only Disconnect kills). 64 ops
            // comfortably covers one frame read's worst case.
            let classes: Vec<_> = (0..64).map(|_| probe.next_fault()).collect();
            if classes.iter().any(|c| matches!(c, Some(WireFault::Disconnect))) {
                continue;
            }
            let mut s = FaultStream::new(bytes.as_slice(), plan);
            let got = read_frame(&mut s).expect("short reads and EINTR must be survivable");
            assert_eq!(got, frame);
            tested += 1;
        }
        assert!(tested > 0, "at least one seed in 0..64 yields a survivable schedule");
    }

    #[test]
    fn disconnect_poisons_the_stream_for_good() {
        let bytes = encode_frame(&Frame::Bye);
        let mut wire: Vec<u8> = Vec::new();
        let mut s = FaultStream::new(&mut wire, FaultPlan::crash_after(0));
        assert!(s.write_all(&bytes).is_err(), "the stream is dead from op 0");
        assert!(s.poisoned());
        assert!(s.write_all(&bytes).is_err(), "poisoned streams stay dead");
        assert!(s.flush().is_err());
    }

    #[test]
    fn torn_write_lands_a_prefix_the_peer_rejects() {
        // Find a seed whose very first scheduled fault is a torn write,
        // so the tear hits the frame body deterministically.
        let torn_seed = (0..512u64)
            .find(|&seed| {
                let probe =
                    FaultStream::new(std::io::empty(), FaultPlan { seed, period: 1, crash_at: None });
                probe.next_fault() == Some(WireFault::TornWrite)
            })
            .expect("some seed in 0..512 tears on its first op");
        let frame = Frame::Results {
            lease: 1,
            records: vec![(2, vec![0u8; crate::exec::format::RESULT_BIN_BYTES])],
        };
        let bytes = encode_frame(&frame);
        let mut wire: Vec<u8> = Vec::new();
        {
            let mut s =
                FaultStream::new(&mut wire, FaultPlan { seed: torn_seed, period: 1, crash_at: None });
            assert!(s.write_all(&bytes).is_err(), "torn write must surface as an error");
            assert!(s.poisoned(), "a tear kills the stream");
        }
        assert!(!wire.is_empty(), "a prefix reached the wire");
        assert!(wire.len() < bytes.len(), "but not the whole frame");
        assert!(read_frame(&mut wire.as_slice()).is_err(), "the prefix must not parse clean");
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let run = |seed: u64| -> Vec<Option<WireFault>> {
            let s = FaultStream::new(std::io::empty(), FaultPlan { seed, period: 3, crash_at: None });
            (0..64).map(|_| s.next_fault()).collect()
        };
        assert_eq!(run(42), run(42), "same seed, same schedule");
        assert_ne!(run(42), run(43), "different seeds diverge");
    }
}
