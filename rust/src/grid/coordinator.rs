//! The fleet coordinator: owns one repro-all plan and drains it over
//! sockets to any number of workers, dynamically.
//!
//! Where `repro grid --shard k/n` (exec/grid.rs) partitions the plan
//! *statically* — a fleet's wall-clock gated by its slowest shard —
//! the coordinator hands out small batches of content keys on demand,
//! so a fast worker simply comes back for more. Three properties make
//! this safe without any distributed-systems machinery:
//!
//! * **Results are content-addressed.** A `SimPoint` key pins the
//!   entire simulation input, and the engine is deterministic, so two
//!   workers simulating the same key produce bit-identical records.
//!   Handing a key out twice is wasted work, never a conflict.
//! * **Leases, not assignments.** A batch is leased, and a lease that
//!   expires ([`CoordinatorConfig::lease_ms`]) or whose connection
//!   dies is requeued. Late results from the original holder are still
//!   accepted (first write wins; the rest count as duplicates).
//! * **One writer.** Workers never touch the store; they stream
//!   records back and the coordinator appends through the ordinary
//!   [`ResultStore::insert`] path under one lock — each key is written
//!   exactly once, so a fleet-populated store is record-identical to a
//!   single-host cold run.
//!
//! The accept loop mirrors `serve/http.rs`: thread-per-connection,
//! port 0 for tests, shutdown by flag plus a self-dial to unpark
//! `accept`. Connection reads use a short timeout as an idle tick —
//! one peeked byte distinguishes "worker is busy simulating" from
//! "worker died mid-frame".

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::exec::format::decode_result_bin;
use crate::exec::{ResultStore, SimPoint};
use crate::grid::proto::{self, read_frame_after_kind, write_frame, Frame, PROTO_VERSION};
use crate::{format_err, Result};

/// Default coordinator port (one above the serve daemon's 7878).
pub const DEFAULT_PORT: u16 = 7879;
/// Default batch size: big enough to amortize a round trip, small
/// enough that a dead worker strands little work.
pub const DEFAULT_BATCH: u32 = 8;
/// Default lease timeout before a batch is requeued from a silent
/// worker. Generous: an expiry costs only duplicate simulation.
pub const DEFAULT_LEASE_MS: u64 = 30_000;

/// Idle tick while waiting for a worker's next frame: long enough to
/// avoid spinning, short enough that shutdown and lease math stay
/// responsive.
const READ_TICK: Duration = Duration::from_millis(250);

/// Knobs for one coordinator run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoordinatorConfig {
    pub lease_ms: u64,
    pub batch: u32,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { lease_ms: DEFAULT_LEASE_MS, batch: DEFAULT_BATCH }
    }
}

/// What one fleet drain did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetReport {
    /// Deduplicated plan size.
    pub plan_points: usize,
    /// Plan points already in the store before any worker connected.
    pub already_present: usize,
    /// Fresh results received and appended.
    pub results: u64,
    /// Result records for keys that were already done (late or twice-
    /// leased work) — discarded, never re-appended.
    pub duplicates: u64,
    /// Leases requeued from dead or silent workers.
    pub reassigned: u64,
    /// Batches granted (including re-grants of requeued keys).
    pub batches: u64,
    /// Workers that completed the handshake.
    pub workers: u64,
}

struct Lease {
    keys: Vec<u64>,
    worker: u64,
    issued: Instant,
}

struct FleetState {
    plan: HashSet<u64>,
    pending: VecDeque<u64>,
    done: HashSet<u64>,
    leases: HashMap<u64, Lease>,
    next_lease: u64,
    next_worker: u64,
    workers: u64,
    batches: u64,
    leased_points: u64,
    results: u64,
    duplicates: u64,
    reassigned: u64,
}

impl FleetState {
    fn complete(&self) -> bool {
        self.done.len() == self.plan.len()
    }

    /// Requeue every lease that predates `cutoff` (counted once per
    /// lease). Keys that completed under a sibling lease stay done.
    fn reap_expired(&mut self, lease_ms: u64) {
        let expired: Vec<u64> = self
            .leases
            .iter()
            .filter(|(_, l)| l.issued.elapsed() >= Duration::from_millis(lease_ms))
            .map(|(&id, _)| id)
            .collect();
        for id in expired {
            self.requeue_lease(id);
        }
    }

    fn requeue_lease(&mut self, id: u64) {
        if let Some(lease) = self.leases.remove(&id) {
            let mut requeued = false;
            for k in lease.keys {
                if !self.done.contains(&k) && !self.pending.contains(&k) {
                    self.pending.push_front(k);
                    requeued = true;
                }
            }
            if requeued {
                self.reassigned += 1;
            }
        }
    }

    /// Requeue everything a dying connection still holds.
    fn requeue_worker(&mut self, worker: u64) {
        let held: Vec<u64> =
            self.leases.iter().filter(|(_, l)| l.worker == worker).map(|(&id, _)| id).collect();
        for id in held {
            self.requeue_lease(id);
        }
    }
}

/// A bound coordinator listener (port 0 picks a free port for tests).
pub struct Coordinator {
    listener: TcpListener,
    port: u16,
}

impl Coordinator {
    pub fn bind(port: u16) -> Result<Self> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .map_err(|e| format_err!("binding 127.0.0.1:{port}: {e}"))?;
        let port = listener.local_addr().map_err(|e| format_err!("local_addr: {e}"))?.port();
        Ok(Self { listener, port })
    }

    pub fn port(&self) -> u16 {
        self.port
    }

    /// Drain `points` through however many workers connect, appending
    /// every fresh result to `store`, and return when the plan is done.
    /// Points already in the store are resolved up front (ordinary
    /// lookups, so they show in the `[exec]` summary as store hits);
    /// if nothing is left the call returns without waiting for anyone.
    pub fn run(
        &self,
        store: &ResultStore,
        points: &[SimPoint],
        cfg: &CoordinatorConfig,
    ) -> Result<FleetReport> {
        let _span = crate::obs::span("grid_fleet_drain");
        let keys: Vec<u64> = points.iter().map(|p| p.key()).collect();
        let fingerprint = proto::plan_fingerprint(&keys);
        let mut st = FleetState {
            plan: HashSet::new(),
            pending: VecDeque::new(),
            done: HashSet::new(),
            leases: HashMap::new(),
            next_lease: 1,
            next_worker: 1,
            workers: 0,
            batches: 0,
            leased_points: 0,
            results: 0,
            duplicates: 0,
            reassigned: 0,
        };
        for &k in &keys {
            if st.plan.insert(k) {
                if store.lookup(k).is_some() {
                    st.done.insert(k);
                } else {
                    st.pending.push_back(k);
                }
            }
        }
        let already_present = st.done.len();
        if !st.complete() {
            let state = Mutex::new(st);
            let stop = AtomicBool::new(false);
            let state_ref = &state;
            let stop_ref = &stop;
            let port = self.port;
            std::thread::scope(|scope| {
                for conn in self.listener.incoming() {
                    if stop_ref.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    scope.spawn(move || {
                        let _ = serve_worker(
                            stream, state_ref, stop_ref, store, cfg, fingerprint, port,
                        );
                    });
                }
            });
            st = state.into_inner().expect("fleet state lock");
        }
        let report = FleetReport {
            plan_points: st.plan.len(),
            already_present,
            results: st.results,
            duplicates: st.duplicates,
            reassigned: st.reassigned,
            batches: st.batches,
            workers: st.workers,
        };
        // Fold at the stage boundary, once per drain. Scheduling-shaped
        // counts (batches, re-leases, duplicates) are on the snapshot
        // exclusion list — see obs::export::SCHEDULING_COUNTERS.
        crate::obs::global().with(|v| {
            v.counter_add("grid_fleet_drains_total", 1);
            v.counter_add("grid_batches_granted_total", report.batches);
            v.counter_add("grid_points_leased_total", st.leased_points);
            v.counter_add("grid_results_received_total", report.results);
            v.counter_add("grid_duplicate_results_total", report.duplicates);
            v.counter_add("grid_lease_reassignments_total", report.reassigned);
            v.counter_add("grid_workers_total", report.workers);
        });
        store.flush();
        Ok(report)
    }
}

/// One worker connection, handshake to goodbye. Any exit path requeues
/// whatever the worker still held.
fn serve_worker(
    stream: TcpStream,
    state: &Mutex<FleetState>,
    stop: &AtomicBool,
    store: &ResultStore,
    cfg: &CoordinatorConfig,
    fingerprint: u64,
    port: u16,
) -> Result<()> {
    stream.set_read_timeout(Some(READ_TICK)).ok();
    let mut reader = stream.try_clone().map_err(|e| format_err!("cloning stream: {e}"))?;
    let mut writer = stream;
    let mut worker_id: Option<u64> = None;
    let outcome = (|| -> Result<()> {
        loop {
            // Peek one byte: a timeout here is an idle worker (keep
            // waiting unless the drain finished), not a dead one.
            let mut kind = [0u8; 1];
            match reader.read_exact(&mut kind) {
                Ok(()) => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if stop.load(Ordering::SeqCst) {
                        return Ok(());
                    }
                    continue;
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return Ok(()), // peer is gone; leases requeue below
            }
            let frame = read_frame_after_kind(kind[0], &mut reader)?;
            match frame {
                Frame::Hello { version, fingerprint: fp } => {
                    if version != PROTO_VERSION {
                        let msg = format!("protocol v{version} not spoken here (v{PROTO_VERSION})");
                        let _ = write_frame(&mut writer, &Frame::Error { msg });
                        return Ok(());
                    }
                    if fp != fingerprint {
                        let msg = format!(
                            "plan fingerprint mismatch: worker {fp:#018x}, \
                             coordinator {fingerprint:#018x} — same flags on both ends?"
                        );
                        let _ = write_frame(&mut writer, &Frame::Error { msg });
                        return Ok(());
                    }
                    let id = {
                        let mut st = state.lock().expect("fleet state lock");
                        st.workers += 1;
                        let id = st.next_worker;
                        st.next_worker += 1;
                        id
                    };
                    worker_id = Some(id);
                    write_frame(&mut writer, &Frame::Welcome { worker_id: id, fingerprint })
                        .map_err(|e| format_err!("writing WELCOME: {e}"))?;
                }
                Frame::Request { max_points } => {
                    let Some(id) = worker_id else {
                        let _ = write_frame(&mut writer, &Frame::Error {
                            msg: "REQUEST before HELLO".into(),
                        });
                        return Ok(());
                    };
                    let _span = crate::obs::span("grid_grant_batch");
                    let reply = {
                        let mut st = state.lock().expect("fleet state lock");
                        st.reap_expired(cfg.lease_ms);
                        let want = max_points.min(cfg.batch).max(1) as usize;
                        let mut batch = Vec::with_capacity(want);
                        while batch.len() < want {
                            match st.pending.pop_front() {
                                Some(k) if st.done.contains(&k) => continue,
                                Some(k) => batch.push(k),
                                None => break,
                            }
                        }
                        if batch.is_empty() {
                            Frame::Drained { done: st.complete() }
                        } else {
                            let lease = st.next_lease;
                            st.next_lease += 1;
                            st.batches += 1;
                            st.leased_points += batch.len() as u64;
                            st.leases.insert(
                                lease,
                                Lease { keys: batch.clone(), worker: id, issued: Instant::now() },
                            );
                            Frame::Batch { lease, keys: batch }
                        }
                    };
                    write_frame(&mut writer, &reply)
                        .map_err(|e| format_err!("writing batch: {e}"))?;
                }
                Frame::Results { lease, records } => {
                    let _span = crate::obs::span("grid_apply_results");
                    let (ack, finished) = {
                        let mut st = state.lock().expect("fleet state lock");
                        let mut fresh = 0u32;
                        let mut dup = 0u32;
                        for (key, bin) in &records {
                            if !st.plan.contains(key) {
                                let _ = write_frame(&mut writer, &Frame::Error {
                                    msg: format!("result for unknown key {key:#018x}"),
                                });
                                return Ok(());
                            }
                            if st.done.contains(key) {
                                dup += 1;
                                continue;
                            }
                            let result = decode_result_bin(bin).map_err(|e| {
                                format_err!("undecodable result for key {key:#018x}: {e}")
                            })?;
                            store.insert(*key, Arc::new(result));
                            st.done.insert(*key);
                            fresh += 1;
                        }
                        let stx = &mut *st;
                        let done = &stx.done;
                        let mut lease_empty = false;
                        if let Some(l) = stx.leases.get_mut(&lease) {
                            l.keys.retain(|k| !done.contains(k));
                            lease_empty = l.keys.is_empty();
                        }
                        if lease_empty {
                            stx.leases.remove(&lease);
                        }
                        st.results += u64::from(fresh);
                        st.duplicates += u64::from(dup);
                        (Frame::Ack { lease, fresh, dup }, st.complete())
                    };
                    write_frame(&mut writer, &ack)
                        .map_err(|e| format_err!("writing ACK: {e}"))?;
                    if finished {
                        request_stop(stop, port);
                    }
                }
                Frame::Bye => return Ok(()),
                Frame::Error { msg } => {
                    return Err(format_err!("worker reported: {msg}"));
                }
                other => {
                    let _ = write_frame(&mut writer, &Frame::Error {
                        msg: format!("unexpected frame {other:?} from a worker"),
                    });
                    return Ok(());
                }
            }
        }
    })();
    if let Some(id) = worker_id {
        state.lock().expect("fleet state lock").requeue_worker(id);
    }
    outcome
}

/// Flag the accept loop down and unpark it with a throwaway dial (the
/// serve/http.rs shutdown idiom).
fn request_stop(stop: &AtomicBool, port: u16) {
    stop.store(true, Ordering::SeqCst);
    let _ = TcpStream::connect(("127.0.0.1", port));
}
