//! # multistride
//!
//! Reproduction of *Multi-Strided Access Patterns to Boost Hardware
//! Prefetching* (Blom, Rietveld, van Nieuwpoort — ICPE'25).
//!
//! The paper's claim: transforming a kernel's memory access pattern from a
//! single contiguous stride into several **concurrent** strides primes
//! multiple hardware prefetch streams at once, raising effective single-core
//! memory bandwidth and speeding up memory-bound kernels.
//!
//! This crate contains the full system described in `DESIGN.md`:
//!
//! * [`kernels`] — a loop-nest IR plus the kernel universe: the paper's six
//!   surveyed compute kernels and an extended PolyBench-style family (3mm,
//!   atax, fdtd2d, jacobi1d, stridedcopy, triad), the Figure-2
//!   micro-benchmarks and access-pattern models of the reference
//!   implementations (CLang / Polly / MKL / OpenBLAS / Halide / OpenCV).
//! * [`transform`] — the multi-striding code transformation: critical-access
//!   selection, loop interchange, vectorization, loop blocking, portion /
//!   stride unroll enumeration, redundant-access elimination and the
//!   register-pressure feasibility check — plus [`transform::variants`],
//!   which mechanically derives every spec's single-stride baseline and
//!   S ∈ {2, 4, 8} multi-strided family (no per-kernel lowering anywhere).
//! * [`trace`] — expands a transformed kernel configuration into the exact
//!   stream of vector memory accesses the generated AVX2 assembly would
//!   perform.
//! * [`mem`] + [`prefetch`] + [`sim`] — a timestamp-driven simulator of a
//!   Coffee-Lake-class memory subsystem, organized as a layered pipeline
//!   (see `ARCHITECTURE.md`):
//!   - [`sim::issue`] — the core front: issue cursor, out-of-order window
//!     and in-order retirement;
//!   - [`sim::fills`] — outstanding-fill tracking: the in-flight line map,
//!     line-fill-buffer occupancy and the lazy harvest of landed fills;
//!   - [`sim::stalls`] — stall attribution, emulating the
//!     `CYCLE_ACTIVITY.STALLS_*` counter family;
//!   - [`sim::engine`] — the orchestrator walking each access through
//!     TLB → L1 → L2 → L3 → DRAM against [`mem`]'s models;
//!   - [`prefetch`] — hardware prefetch engines (L2 streamer,
//!     adjacent-line, DCU next-line, IP-stride) behind the pluggable
//!     [`prefetch::PrefetchEngine`] trait, so new prefetcher models
//!     register with the engine without modifying it.
//! * [`exec`] — the execution layer: every experiment expands into
//!   content-addressed [`exec::SimPoint`] jobs resolved through the
//!   two-tier, deduplicating [`exec::ResultStore`] (in-memory +
//!   `<artifacts>/results/`), so identical simulation points run once
//!   per store lifetime instead of once per request.
//! * [`coordinator`] — parallel experiment orchestration: config sweeps
//!   fan out over a work-stealing worker-thread pool, each worker
//!   reusing one warm [`sim::Engine`] allocation across the sweep
//!   points it claims via [`sim::Engine::prepare`].
//! * [`grid`] — dynamic fleet execution (`repro grid coordinator` /
//!   `repro grid worker`): one repro-all plan drained over a framed
//!   TCP protocol with leased batches, dead-worker reassignment, and
//!   store appends bit-identical to a single-host cold run.
//! * [`tune`] — the auto-tuning planner: successive-halving search over
//!   each kernel's derived variant family with the simulator as cost
//!   model, winning [`tune::TunedPlan`]s persisted to an on-disk
//!   [`tune::PlanCache`] keyed by (spec hash, machine fingerprint,
//!   budget class) so repeated requests are cache hits and stale plans
//!   are re-tuned, never silently served.
//! * [`obs`] — unified observability: a process-wide metrics registry
//!   (counters / gauges / log2 histograms), hierarchical timing spans,
//!   Chrome trace-event export (`--trace out.json`) and Prometheus text
//!   exposition (`GET /metrics` on the serve daemon). Everything folds
//!   in at stage boundaries — the sim hot loop is untouched.
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas kernel
//!   artifacts (`artifacts/*.hlo.txt`) and executes them numerically.
//! * [`native`] — real memory-bandwidth probes that run single- vs
//!   multi-strided sweeps on the *host* CPU.
//! * [`report`] / [`config`] / [`util`] — figure renderers, machine presets,
//!   a TOML-subset parser and small utilities (PRNG, stats, timing).

pub mod config;
pub mod coordinator;
pub mod error;
pub mod exec;
pub mod grid;
pub mod kernels;
pub mod mem;
pub mod native;
pub mod obs;
pub mod prefetch;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod trace;
pub mod transform;
pub mod tune;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, error::Error>;
