//! Lowering a transformed kernel to its vector access trace.
//!
//! This plays the role of the paper's parametrized assembly templates
//! (§5.1.2): given a [`Transformed`] kernel (interchanged, vectorized,
//! portion/stride unrolled), [`KernelTrace`] enumerates the exact sequence
//! of 32-byte vector loads and stores the generated AVX2 loop would issue —
//! lazily, so multi-GiB footprints never materialize.
//!
//! Emission rules:
//!
//! * Accesses that depend on the vectorized loop form the loop body; they
//!   are emitted for every (stride replica × portion slot), in grouped or
//!   interleaved arrangement (§4.1).
//! * Accesses independent of the vectorized loop (reduction targets like
//!   `C[i]`, broadcast operands like `r[i]`) are emitted once per iteration
//!   of their deepest loop — modelling their register residency across the
//!   inner loop, as the paper's generated kernels do.
//! * With `eliminate_redundant` set, duplicate addresses within one body
//!   iteration are emitted once (§5.1.2's redundancy elimination); without
//!   it every unroll replica performs its loads/stores "even when
//!   redundant" (the §6.3 isolated-experiment protocol).

use std::collections::HashSet;

use crate::kernels::spec::AccessMode;
use crate::transform::{Transformed, VEC_ELEMS};
use crate::trace::{Access, Arrangement, Op};

/// A lazily-enumerable kernel trace.
pub struct KernelTrace {
    t: Transformed,
    /// Indices of accesses that depend on the vectorized loop, split by
    /// whether they also depend on the stride loop.
    body_strided: Vec<usize>,
    body_shared: Vec<usize>,
    /// Accesses independent of the vectorized loop.
    outer: Vec<usize>,
}

impl KernelTrace {
    pub fn new(t: Transformed) -> Self {
        let vec_loop = t.vector_loop;
        let stride_loop = t.stride_loop;
        let mut body_strided = Vec::new();
        let mut body_shared = Vec::new();
        let mut outer = Vec::new();
        for (i, a) in t.spec.accesses.iter().enumerate() {
            let on_vec = a.idx.iter().any(|e| e.uses(vec_loop));
            let on_stride = a.idx.iter().any(|e| e.uses(stride_loop));
            if on_vec {
                if on_stride {
                    body_strided.push(i);
                } else {
                    body_shared.push(i);
                }
            } else {
                outer.push(i);
            }
        }
        Self { t, body_strided, body_shared, outer }
    }

    pub fn transformed(&self) -> &Transformed {
        &self.t
    }

    /// Estimated number of accesses (exact when no elimination applies).
    pub fn len_estimate(&self) -> u64 {
        let t = &self.t;
        let s = t.config.stride_unroll as u64;
        let p = t.config.portion_unroll as u64;
        let mut outer_iters = 1u64;
        for &l in &t.order[..t.order.len() - 1] {
            let e = t.spec.loops[l].extent;
            outer_iters *= if l == t.stride_loop { e / s } else { e };
        }
        let inner_iters = t.spec.loops[t.vector_loop].extent / (VEC_ELEMS * p);
        // ReadWrite accesses emit a load and a store each.
        let weight = |&i: &usize| -> u64 {
            match t.spec.accesses[i].mode {
                AccessMode::ReadWrite => 2,
                _ => 1,
            }
        };
        let strided_w: u64 = self.body_strided.iter().map(weight).sum();
        let shared_w: u64 = self.body_shared.iter().map(weight).sum();
        let shared_reps = if t.config.eliminate_redundant { 1 } else { s };
        let body = (strided_w * s + shared_w * shared_reps) * p;
        // Outer accesses fire once per outer iteration per replica (RW = 2).
        let outer_per: u64 = self
            .outer
            .iter()
            .map(|&i| match self.t.spec.accesses[i].mode {
                AccessMode::ReadWrite => 2 * s,
                _ => s,
            })
            .sum();
        outer_iters * (inner_iters * body + outer_per)
    }

    /// Iterate the trace.
    pub fn iter(&self) -> TraceCursor<'_> {
        TraceCursor::new(self)
    }
}

/// Iterator over a [`KernelTrace`].
pub struct TraceCursor<'a> {
    kt: &'a KernelTrace,
    /// Trip counters for every loop in `order` (outermost first). The
    /// stride loop counts in steps of `stride_unroll`, the vector loop in
    /// steps of `VEC_ELEMS · portion_unroll`.
    counters: Vec<u64>,
    /// Concrete loop values (element units) derived from counters.
    vals: Vec<u64>,
    buf: Vec<Access>,
    buf_pos: usize,
    done: bool,
    seen: HashSet<(u64, bool)>,
}

impl<'a> TraceCursor<'a> {
    fn new(kt: &'a KernelTrace) -> Self {
        let n = kt.t.order.len();
        let mut c = Self {
            kt,
            counters: vec![0; n],
            vals: vec![0; kt.t.spec.loops.len()],
            buf: Vec::with_capacity(256),
            buf_pos: 0,
            done: false,
            seen: HashSet::new(),
        };
        // Empty iteration space?
        for &l in &kt.t.order {
            if kt.t.spec.loops[l].extent == 0 {
                c.done = true;
            }
        }
        if !c.done {
            c.refill();
        }
        c
    }

    /// Trip count of order-position `pos`.
    fn trips(&self, pos: usize) -> u64 {
        let t = &self.kt.t;
        let l = t.order[pos];
        let e = t.spec.loops[l].extent;
        if l == t.stride_loop {
            e / t.config.stride_unroll as u64
        } else if l == t.vector_loop {
            e / (VEC_ELEMS * t.config.portion_unroll as u64)
        } else {
            e
        }
    }

    /// Recompute `vals` from `counters`.
    fn sync_vals(&mut self) {
        let t = &self.kt.t;
        for (pos, &l) in t.order.iter().enumerate() {
            let c = self.counters[pos];
            self.vals[l] = if l == t.stride_loop {
                c * t.config.stride_unroll as u64
            } else if l == t.vector_loop {
                c * VEC_ELEMS * t.config.portion_unroll as u64
            } else {
                c
            };
        }
    }

    fn emit(&mut self, addr: u64, store: bool, ip: u32) {
        if self.kt.t.config.eliminate_redundant && !self.seen.insert((addr, store)) {
            return;
        }
        let op = match (store, addr % 32 == 0) {
            (false, true) => Op::Load,
            (false, false) => Op::LoadU,
            (true, true) => Op::Store,
            (true, false) => Op::StoreU,
        };
        self.buf.push(Access::new(addr, op, 32, ip));
    }

    fn emit_access(&mut self, acc_idx: usize, vals: &[u64], ip: u32) {
        let t = &self.kt.t;
        let acc = &t.spec.accesses[acc_idx];
        if let Some(addr) = t.spec.address(acc, vals) {
            match acc.mode {
                AccessMode::Read => self.emit(addr, false, ip),
                AccessMode::Write => self.emit(addr, true, ip),
                AccessMode::ReadWrite => {
                    self.emit(addr, false, ip);
                    self.emit(addr, true, ip);
                }
            }
        } else {
            debug_assert!(false, "library kernels are sized in-bounds");
        }
    }

    /// Fill the buffer with one innermost-loop iteration's accesses.
    fn refill(&mut self) {
        self.buf.clear();
        self.buf_pos = 0;
        if self.kt.t.config.eliminate_redundant {
            self.seen.clear();
        }
        self.sync_vals();

        let t = &self.kt.t;
        let s = t.config.stride_unroll as u64;
        let p = t.config.portion_unroll as u64;
        let vec_loop = t.vector_loop;
        let stride_loop = t.stride_loop;
        let inner_pos = t.order.len() - 1;
        let at_inner_start = self.counters[inner_pos] == 0;
        let base_vals = self.vals.clone();
        let n_acc = t.spec.accesses.len() as u32;

        // `kt` is a plain shared reference held by the cursor: copying it
        // out lets the emit calls below borrow `self` mutably without
        // cloning the access-index vectors every refill (§Perf: refill is
        // the trace generator's hot path).
        let kt = self.kt;

        // Outer accesses (register-resident across the inner loop): fire at
        // the first inner iteration, once per stride replica.
        if at_inner_start {
            let mut vals = base_vals.clone();
            for k in 0..s {
                vals[stride_loop] = base_vals[stride_loop] + k;
                for &ai in &kt.outer {
                    let ip = ai as u32 + (k as u32) * n_acc;
                    self.emit_access(ai, &vals, ip);
                }
            }
        }

        // Body: shared accesses once per portion slot; strided accesses per
        // (replica × portion slot) in the configured arrangement.
        let eliminate = t.config.eliminate_redundant;
        let arrangement = t.config.arrangement;

        // Shared operands (e.g. x[j] in mxv): one load per portion slot
        // when eliminating; otherwise each replica re-loads them.
        let shared_reps = if eliminate { 1 } else { s };
        let mut vals = base_vals.clone();
        match arrangement {
            Arrangement::Grouped => {
                for k in 0..shared_reps {
                    for q in 0..p {
                        vals[vec_loop] = base_vals[vec_loop] + q * VEC_ELEMS;
                        vals[stride_loop] = base_vals[stride_loop] + k;
                        for &ai in &kt.body_shared {
                            let ip = ai as u32 + (q as u32) * 64;
                            self.emit_access(ai, &vals, ip);
                        }
                    }
                }
                for k in 0..s {
                    for q in 0..p {
                        vals[stride_loop] = base_vals[stride_loop] + k;
                        vals[vec_loop] = base_vals[vec_loop] + q * VEC_ELEMS;
                        for &ai in &kt.body_strided {
                            let ip = 128 + ai as u32 + (k as u32 * p as u32 + q as u32) * 16;
                            self.emit_access(ai, &vals, ip);
                        }
                    }
                }
            }
            Arrangement::Interleaved => {
                for q in 0..p {
                    for k in 0..shared_reps {
                        vals[vec_loop] = base_vals[vec_loop] + q * VEC_ELEMS;
                        vals[stride_loop] = base_vals[stride_loop] + k;
                        for &ai in &kt.body_shared {
                            let ip = ai as u32 + (q as u32) * 64;
                            self.emit_access(ai, &vals, ip);
                        }
                    }
                    for k in 0..s {
                        vals[stride_loop] = base_vals[stride_loop] + k;
                        vals[vec_loop] = base_vals[vec_loop] + q * VEC_ELEMS;
                        for &ai in &kt.body_strided {
                            let ip = 128 + ai as u32 + (k as u32 * p as u32 + q as u32) * 16;
                            self.emit_access(ai, &vals, ip);
                        }
                    }
                }
            }
        }

        // Advance the loop nest (innermost fastest).
        let mut pos = inner_pos as isize;
        while pos >= 0 {
            self.counters[pos as usize] += 1;
            if self.counters[pos as usize] < self.trips(pos as usize) {
                return;
            }
            self.counters[pos as usize] = 0;
            pos -= 1;
        }
        self.done = true;
    }
}

impl Iterator for TraceCursor<'_> {
    type Item = Access;

    fn next(&mut self) -> Option<Access> {
        loop {
            if self.buf_pos < self.buf.len() {
                let a = self.buf[self.buf_pos];
                self.buf_pos += 1;
                return Some(a);
            }
            if self.done {
                return None;
            }
            self.refill();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::library::{self, paper_kernels};
    use crate::transform::{transform, StridingConfig};

    const MIB: u64 = 1 << 20;

    fn trace_of(name: &str, budget: u64, cfg: StridingConfig) -> Vec<Access> {
        let k = library::kernel_by_name(name, budget).unwrap();
        let t = transform(&k.spec, cfg).unwrap();
        KernelTrace::new(t).iter().collect()
    }

    #[test]
    fn mxv_trace_covers_matrix_exactly_once() {
        let budget = 4 * MIB;
        let k = library::kernel_by_name("mxv", budget).unwrap();
        let n = k.spec.loops[0].extent;
        for cfg in [StridingConfig::new(1, 4), StridingConfig::new(4, 1), StridingConfig::new(2, 2)]
        {
            let t = transform(&k.spec, cfg).unwrap();
            let a_base = t.spec.arrays[0].base;
            let a_bytes = t.spec.arrays[0].bytes();
            let mut a_accesses = 0u64;
            for acc in KernelTrace::new(t).iter() {
                if acc.addr >= a_base && acc.addr < a_base + a_bytes {
                    a_accesses += 1;
                }
            }
            assert_eq!(
                a_accesses,
                n * n / 8,
                "cfg ({},{}) must touch every A vector once",
                cfg.stride_unroll,
                cfg.portion_unroll
            );
        }
    }

    #[test]
    fn stride_replicas_walk_adjacent_rows() {
        // Listing 2: stride unroll 3 over j touches rows jj, jj+1, jj+2.
        let v = trace_of("gemvermxv1", 4 * MIB, StridingConfig::new(3, 1));
        // First body accesses: three A-row loads far apart, plus y/x.
        let k = library::kernel_by_name("gemvermxv1", 4 * MIB).unwrap();
        let row_bytes = k.spec.arrays[0].dims[1] * 4;
        let a_base = k.spec.arrays[0].base;
        let a_rows: Vec<u64> = v
            .iter()
            .filter(|a| a.addr >= a_base && a.addr < a_base + k.spec.arrays[0].bytes())
            .take(3)
            .map(|a| (a.addr - a_base) / row_bytes)
            .collect();
        assert_eq!(a_rows, vec![0, 1, 2], "adjacent rows per paper Listing 2");
    }

    #[test]
    fn elimination_reduces_shared_loads() {
        let k = library::kernel_by_name("mxv", 4 * MIB).unwrap();
        let mut cfg = StridingConfig::new(4, 1);
        let plain = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().count();
        cfg.eliminate_redundant = true;
        let elim = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().count();
        assert!(
            elim < plain,
            "eliminating x[j] reloads must shrink the trace: {elim} vs {plain}"
        );
    }

    #[test]
    fn len_estimate_matches_exact_count_without_elimination() {
        for name in ["mxv", "bicg", "gemverouter", "gemversum", "init", "writeback"] {
            for cfg in [StridingConfig::new(1, 2), StridingConfig::new(4, 2)] {
                let k = library::kernel_by_name(name, 4 * MIB).unwrap();
                let t = transform(&k.spec, cfg).unwrap();
                let kt = KernelTrace::new(t);
                let est = kt.len_estimate();
                let exact = kt.iter().count() as u64;
                assert_eq!(est, exact, "{name} cfg ({},{})", cfg.stride_unroll, cfg.portion_unroll);
            }
        }
    }

    #[test]
    fn stencils_emit_unaligned_accesses() {
        let v = trace_of("jacobi2d", 4 * MIB, StridingConfig::new(2, 1));
        assert!(
            v.iter().any(|a| matches!(a.op, Op::LoadU)),
            "jacobi2d's ±1 offsets must produce unaligned loads"
        );
    }

    #[test]
    fn grouped_vs_interleaved_reorders_but_same_set() {
        let k = library::kernel_by_name("writeback", 4 * MIB).unwrap();
        let mut cfg = StridingConfig::new(4, 2);
        let g: Vec<Access> = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().collect();
        cfg.arrangement = Arrangement::Interleaved;
        let i: Vec<Access> = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().collect();
        assert_ne!(
            g.iter().map(|a| a.addr).collect::<Vec<_>>(),
            i.iter().map(|a| a.addr).collect::<Vec<_>>(),
            "orderings differ"
        );
        let mut gs: Vec<(u64, bool)> = g.iter().map(|a| (a.addr, a.op.is_store())).collect();
        let mut is_: Vec<(u64, bool)> = i.iter().map(|a| (a.addr, a.op.is_store())).collect();
        gs.sort_unstable();
        is_.sort_unstable();
        assert_eq!(gs, is_, "same multiset of accesses");
    }

    #[test]
    fn reduction_target_emitted_once_per_row() {
        // mxv's y[i]: one load + one store per row (register accumulator).
        let budget = 4 * MIB;
        let k = library::kernel_by_name("mxv", budget).unwrap();
        let t = transform(&k.spec, StridingConfig::new(2, 1)).unwrap();
        let y_base = t.spec.arrays[2].base;
        let y_bytes = t.spec.arrays[2].bytes();
        let rows = t.spec.loops[0].extent;
        let y_accesses = KernelTrace::new(t)
            .iter()
            .filter(|a| a.addr >= y_base && a.addr < y_base + y_bytes)
            .count() as u64;
        assert_eq!(y_accesses, rows * 2, "load+store once per row");
    }

    #[test]
    fn prop_trace_addresses_in_bounds() {
        use crate::util::proptest::{check, Config};
        let ks = paper_kernels(2 * MIB);
        check(
            Config { cases: 48, seed: 0x7ACE },
            |r, _size| {
                let ki = r.below(ks.len() as u64) as usize;
                let s = [1u32, 2, 4, 5, 8][r.below(5) as usize];
                let p = [1u32, 2, 3, 4][r.below(4) as usize];
                (ki, s, p)
            },
            |&(ki, s, p)| {
                let k = &ks[ki];
                let t = match transform(&k.spec, StridingConfig::new(s, p)) {
                    Ok(t) => t,
                    Err(_) => return true, // infeasible extent: fine
                };
                let hi: u64 = t
                    .spec
                    .arrays
                    .iter()
                    .map(|a| a.base + a.bytes())
                    .max()
                    .unwrap();
                KernelTrace::new(t).iter().take(50_000).all(|a| a.addr + a.size as u64 <= hi)
            },
        );
    }
}
