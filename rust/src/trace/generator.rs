//! Lowering a transformed kernel to its vector access trace.
//!
//! This plays the role of the paper's parametrized assembly templates
//! (§5.1.2): given a [`Transformed`] kernel (interchanged, vectorized,
//! portion/stride unrolled), [`KernelTrace`] enumerates the exact sequence
//! of 32-byte vector loads and stores the generated AVX2 loop would issue —
//! lazily, so multi-GiB footprints never materialize.
//!
//! Emission rules:
//!
//! * Accesses that depend on the vectorized loop form the loop body; they
//!   are emitted for every (stride replica × portion slot), in grouped or
//!   interleaved arrangement (§4.1).
//! * Accesses independent of the vectorized loop (reduction targets like
//!   `C[i]`, broadcast operands like `r[i]`) are emitted once per iteration
//!   of their deepest loop — modelling their register residency across the
//!   inner loop, as the paper's generated kernels do.
//! * With `eliminate_redundant` set, duplicate addresses within one body
//!   iteration are emitted once (§5.1.2's redundancy elimination); without
//!   it every unroll replica performs its loads/stores "even when
//!   redundant" (the §6.3 isolated-experiment protocol).
//!
//! §Perf: the body of one inner-loop iteration is the same (access ×
//! replica × portion slot × ip) sequence every time — only the loop-base
//! values change. [`KernelTrace::new`] therefore compiles the body into an
//! **emission plan** once: per access a flattened affine address form
//! (`base + Σ coef·loop_val`, with the subscript bounds proven over the
//! whole iteration domain so per-emission `Option` checks disappear), and
//! per planned emission a precomputed address delta. `refill` evaluates
//! each access's affine base once per iteration and then streams the plan
//! with one add per emission — no `Vec` clones, no per-call bounds checks.

use std::collections::HashSet;

use crate::kernels::spec::AccessMode;
use crate::transform::{Transformed, VEC_ELEMS};
use crate::trace::{Access, Arrangement, Op};

/// One array access as a flattened affine byte-address form:
/// `addr(vals) = base + Σ coefs[l]·vals[l]`.
struct FlatAccess {
    base: i64,
    /// One coefficient per spec loop (bytes per unit of the loop value).
    coefs: Vec<i64>,
    /// Every subscript proven in-bounds over the full iteration domain, so
    /// evaluation can skip the per-dimension checks of
    /// `KernelSpec::address`. The rare unproven access falls back to the
    /// checked path.
    safe: bool,
}

/// One planned emission of the per-iteration body (or outer prologue).
struct PlanStep {
    /// Index into `spec.accesses` / the `flat` table.
    acc: u32,
    /// Synthetic instruction pointer (unroll-slot id).
    ip: u32,
    /// Stride-loop delta (unroll replica index, in elements).
    dk: u64,
    /// Vector-loop delta (portion slot × [`VEC_ELEMS`], in elements).
    dq: u64,
    /// Precomputed `coefs[stride]·dk + coefs[vec]·dq` for the safe path.
    daddr: i64,
    mode: AccessMode,
}

/// A lazily-enumerable kernel trace.
pub struct KernelTrace {
    t: Transformed,
    /// Indices of accesses that depend on the vectorized loop, split by
    /// whether they also depend on the stride loop.
    body_strided: Vec<usize>,
    body_shared: Vec<usize>,
    /// Accesses independent of the vectorized loop.
    outer: Vec<usize>,
    /// Affine address form per access (parallel to `t.spec.accesses`).
    flat: Vec<FlatAccess>,
    /// Emissions fired once per *outer* iteration (inner-loop start).
    outer_plan: Vec<PlanStep>,
    /// Emissions fired every inner-loop iteration, in arrangement order.
    body_plan: Vec<PlanStep>,
}

impl KernelTrace {
    pub fn new(t: Transformed) -> Self {
        let vec_loop = t.vector_loop;
        let stride_loop = t.stride_loop;
        debug_assert_ne!(vec_loop, stride_loop, "transform guarantees distinct loops");
        let mut body_strided = Vec::new();
        let mut body_shared = Vec::new();
        let mut outer = Vec::new();
        for (i, a) in t.spec.accesses.iter().enumerate() {
            let on_vec = a.idx.iter().any(|e| e.uses(vec_loop));
            let on_stride = a.idx.iter().any(|e| e.uses(stride_loop));
            if on_vec {
                if on_stride {
                    body_strided.push(i);
                } else {
                    body_shared.push(i);
                }
            } else {
                outer.push(i);
            }
        }

        // ---- flatten every access to an affine byte-address form --------
        let n_loops = t.spec.loops.len();
        let flat: Vec<FlatAccess> = t
            .spec
            .accesses
            .iter()
            .map(|acc| {
                let arr = &t.spec.arrays[acc.array];
                let eb = arr.elem_bytes as i64;
                let mut base = arr.base as i64;
                let mut coefs = vec![0i64; n_loops];
                let mut safe = true;
                for (d, e) in acc.idx.iter().enumerate() {
                    let ds = arr.dim_stride(d) as i64;
                    base += e.offset * ds * eb;
                    for &(l, c) in &e.terms {
                        coefs[l] += c * ds * eb;
                    }
                    // Interval bound of the subscript over the full domain
                    // (loop values in [0, extent-1], conservatively).
                    let (mut lo, mut hi) = (e.offset, e.offset);
                    for &(l, c) in &e.terms {
                        let max_v = t.spec.loops[l].extent.saturating_sub(1) as i64;
                        if c >= 0 {
                            hi += c * max_v;
                        } else {
                            lo += c * max_v;
                        }
                    }
                    safe &= lo >= 0 && hi < arr.dims[d] as i64;
                }
                FlatAccess { base, coefs, safe }
            })
            .collect();

        // ---- compile the emission plans ----------------------------------
        let s = t.config.stride_unroll as u64;
        let p = t.config.portion_unroll as u64;
        let n_acc = t.spec.accesses.len() as u32;
        let step = |ai: usize, dk: u64, dq: u64, ip: u32| PlanStep {
            acc: ai as u32,
            ip,
            dk,
            dq,
            daddr: flat[ai].coefs[stride_loop] * dk as i64 + flat[ai].coefs[vec_loop] * dq as i64,
            mode: t.spec.accesses[ai].mode,
        };

        // Outer accesses (register-resident across the inner loop): once
        // per stride replica at the first inner iteration.
        let mut outer_plan = Vec::new();
        for k in 0..s {
            for &ai in &outer {
                outer_plan.push(step(ai, k, 0, ai as u32 + (k as u32) * n_acc));
            }
        }

        // Body: shared accesses once per portion slot (per replica unless
        // eliminating); strided accesses per (replica × portion slot), in
        // the configured arrangement.
        let shared_reps = if t.config.eliminate_redundant { 1 } else { s };
        let mut body_plan = Vec::new();
        let push_shared = |plan: &mut Vec<PlanStep>, k: u64, q: u64| {
            for &ai in &body_shared {
                plan.push(step(ai, k, q * VEC_ELEMS, ai as u32 + (q as u32) * 64));
            }
        };
        let push_strided = |plan: &mut Vec<PlanStep>, k: u64, q: u64| {
            for &ai in &body_strided {
                let ip = 128 + ai as u32 + (k as u32 * p as u32 + q as u32) * 16;
                plan.push(step(ai, k, q * VEC_ELEMS, ip));
            }
        };
        match t.config.arrangement {
            Arrangement::Grouped => {
                for k in 0..shared_reps {
                    for q in 0..p {
                        push_shared(&mut body_plan, k, q);
                    }
                }
                for k in 0..s {
                    for q in 0..p {
                        push_strided(&mut body_plan, k, q);
                    }
                }
            }
            Arrangement::Interleaved => {
                for q in 0..p {
                    for k in 0..shared_reps {
                        push_shared(&mut body_plan, k, q);
                    }
                    for k in 0..s {
                        push_strided(&mut body_plan, k, q);
                    }
                }
            }
        }

        Self { t, body_strided, body_shared, outer, flat, outer_plan, body_plan }
    }

    pub fn transformed(&self) -> &Transformed {
        &self.t
    }

    /// Estimated number of accesses (exact when no elimination applies).
    pub fn len_estimate(&self) -> u64 {
        let t = &self.t;
        let s = t.config.stride_unroll as u64;
        let p = t.config.portion_unroll as u64;
        let mut outer_iters = 1u64;
        for &l in &t.order[..t.order.len() - 1] {
            let e = t.spec.loops[l].extent;
            outer_iters *= if l == t.stride_loop { e / s } else { e };
        }
        let inner_iters = t.spec.loops[t.vector_loop].extent / (VEC_ELEMS * p);
        // ReadWrite accesses emit a load and a store each.
        let weight = |&i: &usize| -> u64 {
            match t.spec.accesses[i].mode {
                AccessMode::ReadWrite => 2,
                _ => 1,
            }
        };
        let strided_w: u64 = self.body_strided.iter().map(weight).sum();
        let shared_w: u64 = self.body_shared.iter().map(weight).sum();
        let shared_reps = if t.config.eliminate_redundant { 1 } else { s };
        let body = (strided_w * s + shared_w * shared_reps) * p;
        // Outer accesses fire once per outer iteration per replica (RW = 2).
        let outer_per: u64 = self
            .outer
            .iter()
            .map(|&i| match self.t.spec.accesses[i].mode {
                AccessMode::ReadWrite => 2 * s,
                _ => s,
            })
            .sum();
        outer_iters * (inner_iters * body + outer_per)
    }

    /// Iterate the trace.
    pub fn iter(&self) -> TraceCursor<'_> {
        TraceCursor::new(self)
    }
}

/// Iterator over a [`KernelTrace`].
pub struct TraceCursor<'a> {
    kt: &'a KernelTrace,
    /// Trip counters for every loop in `order` (outermost first). The
    /// stride loop counts in steps of `stride_unroll`, the vector loop in
    /// steps of `VEC_ELEMS · portion_unroll`.
    counters: Vec<u64>,
    /// Concrete loop values (element units) derived from counters.
    vals: Vec<u64>,
    /// Per-access affine base address at the refill-base loop values.
    base_scratch: Vec<i64>,
    buf: Vec<Access>,
    buf_pos: usize,
    done: bool,
    seen: HashSet<(u64, bool)>,
}

impl<'a> TraceCursor<'a> {
    fn new(kt: &'a KernelTrace) -> Self {
        let n = kt.t.order.len();
        let mut c = Self {
            kt,
            counters: vec![0; n],
            vals: vec![0; kt.t.spec.loops.len()],
            base_scratch: Vec::with_capacity(kt.t.spec.accesses.len()),
            buf: Vec::with_capacity(256),
            buf_pos: 0,
            done: false,
            seen: HashSet::new(),
        };
        // Empty iteration space?
        for &l in &kt.t.order {
            if kt.t.spec.loops[l].extent == 0 {
                c.done = true;
            }
        }
        if !c.done {
            c.refill();
        }
        c
    }

    /// Trip count of order-position `pos`.
    fn trips(&self, pos: usize) -> u64 {
        let t = &self.kt.t;
        let l = t.order[pos];
        let e = t.spec.loops[l].extent;
        if l == t.stride_loop {
            e / t.config.stride_unroll as u64
        } else if l == t.vector_loop {
            e / (VEC_ELEMS * t.config.portion_unroll as u64)
        } else {
            e
        }
    }

    /// Recompute `vals` from `counters`.
    fn sync_vals(&mut self) {
        let t = &self.kt.t;
        for (pos, &l) in t.order.iter().enumerate() {
            let c = self.counters[pos];
            self.vals[l] = if l == t.stride_loop {
                c * t.config.stride_unroll as u64
            } else if l == t.vector_loop {
                c * VEC_ELEMS * t.config.portion_unroll as u64
            } else {
                c
            };
        }
    }

    fn emit(&mut self, addr: u64, store: bool, ip: u32) {
        if self.kt.t.config.eliminate_redundant && !self.seen.insert((addr, store)) {
            return;
        }
        let op = match (store, addr % 32 == 0) {
            (false, true) => Op::Load,
            (false, false) => Op::LoadU,
            (true, true) => Op::Store,
            (true, false) => Op::StoreU,
        };
        self.buf.push(Access::new(addr, op, 32, ip));
    }

    /// Fire one planned emission. `base_stride`/`base_vec` are the
    /// refill-base values of the stride/vector loops (the only loop values
    /// a plan step displaces).
    fn emit_step(&mut self, step: &PlanStep, base_stride: u64, base_vec: u64) {
        let kt = self.kt;
        let ai = step.acc as usize;
        let addr = if kt.flat[ai].safe {
            // Affine fast path: per-iteration base + per-step delta.
            (self.base_scratch[ai] + step.daddr) as u64
        } else {
            // Checked fallback (unproven bounds): evaluate like the
            // pre-plan generator did, skipping out-of-bounds silently.
            let t = &kt.t;
            self.vals[t.stride_loop] = base_stride + step.dk;
            self.vals[t.vector_loop] = base_vec + step.dq;
            match t.spec.address(&t.spec.accesses[ai], &self.vals) {
                Some(a) => a,
                None => {
                    debug_assert!(false, "library kernels are sized in-bounds");
                    return;
                }
            }
        };
        match step.mode {
            AccessMode::Read => self.emit(addr, false, step.ip),
            AccessMode::Write => self.emit(addr, true, step.ip),
            AccessMode::ReadWrite => {
                self.emit(addr, false, step.ip);
                self.emit(addr, true, step.ip);
            }
        }
    }

    /// Fill the buffer with one innermost-loop iteration's accesses by
    /// streaming the precompiled emission plan.
    fn refill(&mut self) {
        self.buf.clear();
        self.buf_pos = 0;
        if self.kt.t.config.eliminate_redundant {
            self.seen.clear();
        }
        self.sync_vals();

        // `kt` is a plain shared reference held by the cursor: copying it
        // out lets the plan iteration below borrow `self` mutably.
        let kt = self.kt;
        let t = &kt.t;
        let inner_pos = t.order.len() - 1;
        let at_inner_start = self.counters[inner_pos] == 0;

        // Per-access affine bases at the refill-base loop values.
        self.base_scratch.clear();
        for fa in &kt.flat {
            let mut a = fa.base;
            for (l, &c) in fa.coefs.iter().enumerate() {
                if c != 0 {
                    a += c * self.vals[l] as i64;
                }
            }
            self.base_scratch.push(a);
        }
        let base_stride = self.vals[t.stride_loop];
        let base_vec = self.vals[t.vector_loop];

        // Outer accesses (register-resident across the inner loop): fire at
        // the first inner iteration, once per stride replica.
        if at_inner_start {
            for step in &kt.outer_plan {
                self.emit_step(step, base_stride, base_vec);
            }
        }
        for step in &kt.body_plan {
            self.emit_step(step, base_stride, base_vec);
        }

        // Advance the loop nest (innermost fastest).
        let mut pos = inner_pos as isize;
        while pos >= 0 {
            self.counters[pos as usize] += 1;
            if self.counters[pos as usize] < self.trips(pos as usize) {
                return;
            }
            self.counters[pos as usize] = 0;
            pos -= 1;
        }
        self.done = true;
    }
}

impl Iterator for TraceCursor<'_> {
    type Item = Access;

    fn next(&mut self) -> Option<Access> {
        loop {
            if self.buf_pos < self.buf.len() {
                let a = self.buf[self.buf_pos];
                self.buf_pos += 1;
                return Some(a);
            }
            if self.done {
                return None;
            }
            self.refill();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::library::{self, all_kernels};
    use crate::transform::{transform, StridingConfig};

    const MIB: u64 = 1 << 20;

    fn trace_of(name: &str, budget: u64, cfg: StridingConfig) -> Vec<Access> {
        let k = library::kernel_by_name(name, budget).unwrap();
        let t = transform(&k.spec, cfg).unwrap();
        KernelTrace::new(t).iter().collect()
    }

    #[test]
    fn mxv_trace_covers_matrix_exactly_once() {
        let budget = 4 * MIB;
        let k = library::kernel_by_name("mxv", budget).unwrap();
        let n = k.spec.loops[0].extent;
        for cfg in [StridingConfig::new(1, 4), StridingConfig::new(4, 1), StridingConfig::new(2, 2)]
        {
            let t = transform(&k.spec, cfg).unwrap();
            let a_base = t.spec.arrays[0].base;
            let a_bytes = t.spec.arrays[0].bytes();
            let mut a_accesses = 0u64;
            for acc in KernelTrace::new(t).iter() {
                if acc.addr >= a_base && acc.addr < a_base + a_bytes {
                    a_accesses += 1;
                }
            }
            assert_eq!(
                a_accesses,
                n * n / 8,
                "cfg ({},{}) must touch every A vector once",
                cfg.stride_unroll,
                cfg.portion_unroll
            );
        }
    }

    #[test]
    fn stride_replicas_walk_adjacent_rows() {
        // Listing 2: stride unroll 3 over j touches rows jj, jj+1, jj+2.
        let v = trace_of("gemvermxv1", 4 * MIB, StridingConfig::new(3, 1));
        // First body accesses: three A-row loads far apart, plus y/x.
        let k = library::kernel_by_name("gemvermxv1", 4 * MIB).unwrap();
        let row_bytes = k.spec.arrays[0].dims[1] * 4;
        let a_base = k.spec.arrays[0].base;
        let a_rows: Vec<u64> = v
            .iter()
            .filter(|a| a.addr >= a_base && a.addr < a_base + k.spec.arrays[0].bytes())
            .take(3)
            .map(|a| (a.addr - a_base) / row_bytes)
            .collect();
        assert_eq!(a_rows, vec![0, 1, 2], "adjacent rows per paper Listing 2");
    }

    #[test]
    fn elimination_reduces_shared_loads() {
        let k = library::kernel_by_name("mxv", 4 * MIB).unwrap();
        let mut cfg = StridingConfig::new(4, 1);
        let plain = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().count();
        cfg.eliminate_redundant = true;
        let elim = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().count();
        assert!(
            elim < plain,
            "eliminating x[j] reloads must shrink the trace: {elim} vs {plain}"
        );
    }

    #[test]
    fn len_estimate_matches_exact_count_without_elimination() {
        for name in
            ["mxv", "bicg", "gemverouter", "gemversum", "init", "writeback", "stridedcopy", "triad"]
        {
            for cfg in [StridingConfig::new(1, 2), StridingConfig::new(4, 2)] {
                let k = library::kernel_by_name(name, 4 * MIB).unwrap();
                let t = transform(&k.spec, cfg).unwrap();
                let kt = KernelTrace::new(t);
                let est = kt.len_estimate();
                let exact = kt.iter().count() as u64;
                assert_eq!(est, exact, "{name} cfg ({},{})", cfg.stride_unroll, cfg.portion_unroll);
            }
        }
    }

    #[test]
    fn stencils_emit_unaligned_accesses() {
        let v = trace_of("jacobi2d", 4 * MIB, StridingConfig::new(2, 1));
        assert!(
            v.iter().any(|a| matches!(a.op, Op::LoadU)),
            "jacobi2d's ±1 offsets must produce unaligned loads"
        );
    }

    #[test]
    fn grouped_vs_interleaved_reorders_but_same_set() {
        let k = library::kernel_by_name("writeback", 4 * MIB).unwrap();
        let mut cfg = StridingConfig::new(4, 2);
        let g: Vec<Access> = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().collect();
        cfg.arrangement = Arrangement::Interleaved;
        let i: Vec<Access> = KernelTrace::new(transform(&k.spec, cfg).unwrap()).iter().collect();
        assert_ne!(
            g.iter().map(|a| a.addr).collect::<Vec<_>>(),
            i.iter().map(|a| a.addr).collect::<Vec<_>>(),
            "orderings differ"
        );
        let mut gs: Vec<(u64, bool)> = g.iter().map(|a| (a.addr, a.op.is_store())).collect();
        let mut is_: Vec<(u64, bool)> = i.iter().map(|a| (a.addr, a.op.is_store())).collect();
        gs.sort_unstable();
        is_.sort_unstable();
        assert_eq!(gs, is_, "same multiset of accesses");
    }

    #[test]
    fn reduction_target_emitted_once_per_row() {
        // mxv's y[i]: one load + one store per row (register accumulator).
        let budget = 4 * MIB;
        let k = library::kernel_by_name("mxv", budget).unwrap();
        let t = transform(&k.spec, StridingConfig::new(2, 1)).unwrap();
        let y_base = t.spec.arrays[2].base;
        let y_bytes = t.spec.arrays[2].bytes();
        let rows = t.spec.loops[0].extent;
        let y_accesses = KernelTrace::new(t)
            .iter()
            .filter(|a| a.addr >= y_base && a.addr < y_base + y_bytes)
            .count() as u64;
        assert_eq!(y_accesses, rows * 2, "load+store once per row");
    }

    /// The pre-plan lowering, reimplemented on the checked
    /// `KernelSpec::address` evaluator: nested (replica × portion) loops
    /// over cloned loop-value vectors, exactly as `refill` worked before
    /// the emission plan existed. The differential oracle for the plan.
    fn reference_trace(kt: &KernelTrace, limit: usize) -> Vec<Access> {
        let t = &kt.t;
        let s = t.config.stride_unroll as u64;
        let p = t.config.portion_unroll as u64;
        let vec_loop = t.vector_loop;
        let stride_loop = t.stride_loop;
        let n_acc = t.spec.accesses.len() as u32;
        let n = t.order.len();
        if t.order.iter().any(|&l| t.spec.loops[l].extent == 0) {
            return Vec::new();
        }

        let trips = |pos: usize| -> u64 {
            let l = t.order[pos];
            let e = t.spec.loops[l].extent;
            if l == t.stride_loop {
                e / s
            } else if l == t.vector_loop {
                e / (VEC_ELEMS * p)
            } else {
                e
            }
        };

        fn emit_ref(
            t: &Transformed,
            seen: &mut HashSet<(u64, bool)>,
            out: &mut Vec<Access>,
            addr: u64,
            store: bool,
            ip: u32,
        ) {
            if t.config.eliminate_redundant && !seen.insert((addr, store)) {
                return;
            }
            let op = match (store, addr % 32 == 0) {
                (false, true) => Op::Load,
                (false, false) => Op::LoadU,
                (true, true) => Op::Store,
                (true, false) => Op::StoreU,
            };
            out.push(Access::new(addr, op, 32, ip));
        }

        fn emit_access_ref(
            t: &Transformed,
            seen: &mut HashSet<(u64, bool)>,
            out: &mut Vec<Access>,
            ai: usize,
            vals: &[u64],
            ip: u32,
        ) {
            let acc = &t.spec.accesses[ai];
            let addr = t.spec.address(acc, vals).expect("in-bounds by library sizing");
            match acc.mode {
                AccessMode::Read => emit_ref(t, seen, out, addr, false, ip),
                AccessMode::Write => emit_ref(t, seen, out, addr, true, ip),
                AccessMode::ReadWrite => {
                    emit_ref(t, seen, out, addr, false, ip);
                    emit_ref(t, seen, out, addr, true, ip);
                }
            }
        }

        let mut out: Vec<Access> = Vec::new();
        let mut counters = vec![0u64; n];
        let mut seen: HashSet<(u64, bool)> = HashSet::new();
        'nest: loop {
            // sync_vals
            let mut base_vals = vec![0u64; t.spec.loops.len()];
            for (pos, &l) in t.order.iter().enumerate() {
                let c = counters[pos];
                base_vals[l] = if l == t.stride_loop {
                    c * s
                } else if l == t.vector_loop {
                    c * VEC_ELEMS * p
                } else {
                    c
                };
            }
            seen.clear();

            if counters[n - 1] == 0 {
                let mut vals = base_vals.clone();
                for k in 0..s {
                    vals[stride_loop] = base_vals[stride_loop] + k;
                    for &ai in &kt.outer {
                        let ip = ai as u32 + (k as u32) * n_acc;
                        emit_access_ref(t, &mut seen, &mut out, ai, &vals, ip);
                    }
                }
            }
            let shared_reps = if t.config.eliminate_redundant { 1 } else { s };
            let mut vals = base_vals.clone();
            // (k, q, strided?) emission order per arrangement.
            let mut slots: Vec<(u64, u64, bool)> = Vec::new();
            match t.config.arrangement {
                Arrangement::Grouped => {
                    for k in 0..shared_reps {
                        for q in 0..p {
                            slots.push((k, q, false));
                        }
                    }
                    for k in 0..s {
                        for q in 0..p {
                            slots.push((k, q, true));
                        }
                    }
                }
                Arrangement::Interleaved => {
                    for q in 0..p {
                        for k in 0..shared_reps {
                            slots.push((k, q, false));
                        }
                        for k in 0..s {
                            slots.push((k, q, true));
                        }
                    }
                }
            }
            for (k, q, is_strided) in slots {
                vals[stride_loop] = base_vals[stride_loop] + k;
                vals[vec_loop] = base_vals[vec_loop] + q * VEC_ELEMS;
                if is_strided {
                    for &ai in &kt.body_strided {
                        let ip = 128 + ai as u32 + (k as u32 * p as u32 + q as u32) * 16;
                        emit_access_ref(t, &mut seen, &mut out, ai, &vals, ip);
                    }
                } else {
                    for &ai in &kt.body_shared {
                        let ip = ai as u32 + (q as u32) * 64;
                        emit_access_ref(t, &mut seen, &mut out, ai, &vals, ip);
                    }
                }
            }
            if out.len() >= limit {
                break 'nest;
            }
            // advance
            let mut pos = n as isize - 1;
            loop {
                if pos < 0 {
                    break 'nest;
                }
                counters[pos as usize] += 1;
                if counters[pos as usize] < trips(pos as usize) {
                    break;
                }
                counters[pos as usize] = 0;
                pos -= 1;
            }
        }
        out.truncate(limit);
        out
    }

    /// The emission plan (affine fast path + precompiled step order) must
    /// reproduce the checked pre-plan lowering access-for-access — address,
    /// op, ip and order — over the **entire kernel registry** (Table 1 plus
    /// the extended universe), the full derived stride family S ∈
    /// {1, 2, 4, 8} plus a mixed odd config, both arrangements and
    /// redundancy elimination on/off.
    #[test]
    fn planned_addresses_match_checked_evaluation() {
        const LIMIT: usize = 12_000;
        let ks = all_kernels(2 * MIB);
        assert!(ks.len() >= 16, "registry must span paper + extended kernels");
        for k in &ks {
            for (s, p) in [(1, 1), (2, 1), (3, 2), (4, 1), (8, 1)] {
                for arrangement in [Arrangement::Grouped, Arrangement::Interleaved] {
                    for eliminate in [false, true] {
                        let mut cfg = StridingConfig::new(s, p);
                        cfg.arrangement = arrangement;
                        cfg.eliminate_redundant = eliminate;
                        let t = match transform(&k.spec, cfg) {
                            Ok(t) => t,
                            Err(_) => continue,
                        };
                        let kt = KernelTrace::new(t);
                        let want = reference_trace(&kt, LIMIT);
                        let got: Vec<Access> = kt.iter().take(want.len()).collect();
                        assert_eq!(
                            got, want,
                            "{} s={s} p={p} {arrangement:?} elim={eliminate}: \
                             plan diverged from checked lowering",
                            k.name
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn prop_trace_addresses_in_bounds() {
        use crate::util::proptest::{check, Config};
        let ks = all_kernels(2 * MIB);
        check(
            Config { cases: 48, seed: 0x7ACE },
            |r, _size| {
                let ki = r.below(ks.len() as u64) as usize;
                let s = [1u32, 2, 4, 5, 8][r.below(5) as usize];
                let p = [1u32, 2, 3, 4][r.below(4) as usize];
                (ki, s, p)
            },
            |&(ki, s, p)| {
                let k = &ks[ki];
                let t = match transform(&k.spec, StridingConfig::new(s, p)) {
                    Ok(t) => t,
                    Err(_) => return true, // infeasible extent: fine
                };
                let hi: u64 = t
                    .spec
                    .arrays
                    .iter()
                    .map(|a| a.base + a.bytes())
                    .max()
                    .unwrap();
                KernelTrace::new(t).iter().take(50_000).all(|a| a.addr + a.size as u64 <= hi)
            },
        );
    }
}
