//! Vector memory-access traces.
//!
//! A *trace* is the sequence of data-movement operations the generated AVX2
//! assembly of a kernel configuration performs. The simulator consumes
//! traces; the [`generator`] expands kernel specs + striding configurations
//! into them lazily (a 4 GiB-problem trace never materializes in memory).

pub mod generator;

pub use generator::{KernelTrace, TraceCursor};

/// The AVX2 data-movement instruction classes of §3 of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// `vmovaps` load: aligned 32 B read.
    Load,
    /// `vmovups` load at a +4 B offset: may straddle a line.
    LoadU,
    /// `vmovntdqa`: non-temporal (streaming) load.
    LoadNt,
    /// `vmovaps` store: aligned 32 B write (write-allocate, RFO).
    Store,
    /// `vmovups` store at a +4 B offset.
    StoreU,
    /// `vmovntdq`: non-temporal store (no-write-allocate, write-combining).
    StoreNt,
}

impl Op {
    /// Is this any kind of store?
    pub fn is_store(self) -> bool {
        matches!(self, Op::Store | Op::StoreU | Op::StoreNt)
    }

    /// Is this a non-temporal operation?
    pub fn is_nt(self) -> bool {
        matches!(self, Op::LoadNt | Op::StoreNt)
    }

    /// Byte offset this op applies to a nominally aligned address
    /// (the paper's unaligned benchmarks use a fixed +4 B offset).
    pub fn addr_offset(self) -> u64 {
        match self {
            Op::LoadU | Op::StoreU => 4,
            _ => 0,
        }
    }
}

/// One vector memory access as issued by the core.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    /// Byte address.
    pub addr: u64,
    /// Operation class.
    pub op: Op,
    /// Access width in bytes (32 for AVX2 ymm operations).
    pub size: u32,
    /// Synthetic instruction pointer: the unroll-slot index within the loop
    /// body. Drives the IP-stride prefetcher and debugging.
    pub ip: u32,
}

impl Access {
    pub fn new(addr: u64, op: Op, size: u32, ip: u32) -> Self {
        Self { addr, op, size, ip }
    }
}

/// Arrangement of the unrolled accesses inside the loop body (§4.1/§4.4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Arrangement {
    /// All accesses of one stride issue consecutively before the next
    /// stride's ("grouped" — higher throughput for most ops).
    #[default]
    Grouped,
    /// Strides are visited round-robin per offset ("interleaved" — the
    /// arrangement that collapses NT-store throughput in §4.4).
    Interleaved,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_classification() {
        assert!(Op::Store.is_store() && Op::StoreNt.is_store() && Op::StoreU.is_store());
        assert!(!Op::Load.is_store());
        assert!(Op::LoadNt.is_nt() && Op::StoreNt.is_nt());
        assert!(!Op::LoadU.is_nt());
        assert_eq!(Op::LoadU.addr_offset(), 4);
        assert_eq!(Op::Load.addr_offset(), 0);
    }
}
