//! Mechanical derivation of a kernel's striding **variant family**.
//!
//! The paper's claim is that the multi-stride rewrite generalizes across a
//! whole family of memory-bound kernels, not a handful of hand-tuned
//! specializations. This module makes that a first-class artifact: given
//! *any* dependence-free affine [`KernelSpec`], [`variant_set`] derives the
//! single-stride baseline plus the S ∈ {2, 4, 8} multi-strided variants
//! ([`STRIDE_FAMILY`]) — unroll the stride axis by S, interchange so the S
//! copies issue concurrently — purely through the generic
//! [`transform`](super::transform). There is **no per-kernel lowering**:
//! every variant flows through the same emission-plan compiler in
//! [`crate::trace::generator`], and the differential test wall
//! (`tests/transform_oracle.rs`, the registry-wide planned-vs-checked test)
//! pins each variant's trace against the baseline.

use super::{is_feasible, transform, StridingConfig, Transformed};
use crate::kernels::spec::KernelSpec;
use crate::Result;

/// The stride-unroll counts every kernel derives beyond its baseline.
/// **Single source of truth for family membership**: sweeps
/// (`coordinator::experiments::variant_sweep`), the trajectory renderer
/// and the test wall all derive their configs from this constant via
/// [`variant_configs`]; only the feasibility *lens* may differ per
/// machine (see [`variant_set_on`]).
pub const STRIDE_FAMILY: [u32; 3] = [2, 4, 8];

/// Architectural SIMD register file the feasibility flag is computed
/// against (16 ymm registers on every Table 2 machine).
pub const SIMD_REGISTERS: u32 = 16;

/// One derived variant of a kernel.
#[derive(Debug, Clone)]
pub struct KernelVariant {
    pub config: StridingConfig,
    pub transformed: Transformed,
    /// Fits the architectural register file ([`SIMD_REGISTERS`])? High
    /// stride counts on accumulator-heavy kernels (e.g. bicg at S=8) are
    /// derivable but not realizable; sweeps skip them, tests still lower
    /// them (the trace machinery is register-agnostic).
    pub feasible: bool,
}

impl KernelVariant {
    /// Stride-unroll count (1 for the baseline).
    pub fn strides(&self) -> u32 {
        self.config.stride_unroll
    }
}

/// A kernel's full derived family: baseline first, then one variant per
/// [`STRIDE_FAMILY`] entry.
#[derive(Debug, Clone)]
pub struct VariantSet {
    pub kernel: String,
    pub variants: Vec<KernelVariant>,
}

impl VariantSet {
    /// The single-stride baseline (S = 1).
    pub fn baseline(&self) -> &KernelVariant {
        &self.variants[0]
    }

    /// The multi-strided variants (S ∈ [`STRIDE_FAMILY`]).
    pub fn multi(&self) -> &[KernelVariant] {
        &self.variants[1..]
    }
}

/// The configurations a variant set derives, in order: the baseline
/// `(1, portion)` followed by `(S, portion)` for each family member.
pub fn variant_configs(portion: u32) -> Vec<StridingConfig> {
    std::iter::once(1)
        .chain(STRIDE_FAMILY)
        .map(|s| StridingConfig::new(s, portion))
        .collect()
}

/// Derive the full variant family for `spec` mechanically. Fails only if
/// the *baseline* is untransformable (loop-carried dependence, gather);
/// a family member the spec's extents cannot host is skipped with a
/// visible notice — the same no-silent-coverage policy as the runtime
/// sweeps. Feasibility is judged against [`SIMD_REGISTERS`]; use
/// [`variant_set_on`] for a machine with a different register file (the
/// sweep path already uses the machine's own `simd_registers`).
pub fn variant_set(spec: &KernelSpec, portion: u32) -> Result<VariantSet> {
    variant_set_on(spec, portion, SIMD_REGISTERS)
}

/// [`variant_set`] with an explicit SIMD register-file size, so variant
/// feasibility cannot diverge from a machine-config-driven sweep.
pub fn variant_set_on(spec: &KernelSpec, portion: u32, simd_registers: u32) -> Result<VariantSet> {
    let mut variants = Vec::with_capacity(1 + STRIDE_FAMILY.len());
    for config in variant_configs(portion) {
        let transformed = match transform(spec, config) {
            Ok(t) => t,
            Err(e) if config.stride_unroll > 1 => {
                eprintln!(
                    "[variant_set] SKIPPED {} S={}: {e}",
                    spec.name, config.stride_unroll
                );
                continue;
            }
            Err(e) => return Err(e),
        };
        let feasible = is_feasible(&transformed, simd_registers);
        variants.push(KernelVariant { config, transformed, feasible });
    }
    Ok(VariantSet { kernel: spec.name.clone(), variants })
}

/// Derive variant sets for the whole kernel universe at `budget` bytes —
/// the "every registered spec derives its family" invariant, pinned by
/// this module's tests. The trace-level oracle
/// (`tests/transform_oracle.rs`) derives per-kernel via [`variant_set`]
/// on extent-shrunk specs instead, and runtime sweeps go through
/// `coordinator::experiments::variant_sweep`; all three share
/// [`variant_configs`], so family membership cannot drift.
pub fn universe_variants(budget: u64, portion: u32) -> Result<Vec<VariantSet>> {
    crate::kernels::library::all_kernels(budget)
        .iter()
        .map(|k| variant_set(&k.spec, portion))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::library::all_kernels;
    use crate::transform::VEC_ELEMS;

    const MIB: u64 = 1 << 20;

    #[test]
    fn every_kernel_derives_the_full_family() {
        let sets = universe_variants(2 * MIB, 1).expect("universe derives");
        assert_eq!(sets.len(), all_kernels(2 * MIB).len());
        for set in &sets {
            assert_eq!(set.variants.len(), 1 + STRIDE_FAMILY.len(), "{}", set.kernel);
            assert_eq!(set.baseline().strides(), 1, "{}", set.kernel);
            for (v, s) in set.multi().iter().zip(STRIDE_FAMILY) {
                assert_eq!(v.strides(), s, "{}", set.kernel);
                assert_eq!(v.config.stride_unroll, s);
            }
        }
    }

    #[test]
    fn family_preserves_iteration_domain_at_portion_1() {
        // Library extents are multiples of 64, so no variant trims its
        // stride or vector axis at portion 1 — the permutation oracle
        // relies on this.
        for set in universe_variants(2 * MIB, 1).unwrap() {
            let base = &set.baseline().transformed;
            let domain = |t: &Transformed| -> u64 {
                t.spec.loops.iter().map(|l| l.extent).product()
            };
            for v in set.multi() {
                assert_eq!(
                    domain(&v.transformed),
                    domain(base),
                    "{} S={} trimmed its domain",
                    set.kernel,
                    v.strides()
                );
                let t = &v.transformed;
                assert_eq!(t.spec.loops[t.stride_loop].extent % v.strides() as u64, 0);
                assert_eq!(t.spec.loops[t.vector_loop].extent % VEC_ELEMS, 0);
            }
        }
    }

    #[test]
    fn baselines_are_feasible_everywhere() {
        for set in universe_variants(2 * MIB, 1).unwrap() {
            assert!(set.baseline().feasible, "{} baseline must fit 16 ymm", set.kernel);
        }
    }

    #[test]
    fn feasibility_flag_reflects_register_pressure() {
        use crate::transform::register_pressure;
        for set in universe_variants(2 * MIB, 1).unwrap() {
            for v in &set.variants {
                assert_eq!(
                    v.feasible,
                    register_pressure(&v.transformed) <= SIMD_REGISTERS,
                    "{} S={}",
                    set.kernel,
                    v.strides()
                );
            }
        }
    }
}
