//! Stride-stream profiling of a transformed kernel.
//!
//! Computes the number of concurrent memory streams (load / store /
//! load-store) a configuration generates — the "Strides" columns of the
//! paper's Table 1. Two unroll replicas contribute *distinct* streams when
//! their addresses are far apart (different rows of a matrix); replicas
//! whose addresses fall within a small window (adjacent elements of a
//! vector, e.g. `C[i]`, `C[i+1]`) coalesce into one stream.

use std::collections::BTreeMap;

use super::Transformed;
use crate::kernels::spec::AccessMode;

/// Stream counts, matching Table 1's `L` / `S` / `L/S` columns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StrideProfile {
    pub loads: u32,
    pub stores: u32,
    pub loadstores: u32,
}

impl StrideProfile {
    pub fn total(&self) -> u32 {
        self.loads + self.stores + self.loadstores
    }
}

/// Addresses within this window coalesce into one stream (the prefetcher
/// cannot distinguish accesses within a couple of cache lines).
const COALESCE_BYTES: u64 = 256;

/// Compute the stream profile of a transformed kernel configuration.
pub fn stride_profile(t: &Transformed) -> StrideProfile {
    // Evaluate every (access, stride-replica) instance at the start of the
    // iteration space and cluster by address proximity.
    let s = t.config.stride_unroll as u64;
    let n_loops = t.spec.loops.len();
    let mut vals = vec![0u64; n_loops];

    // Cluster key: array id → sorted list of (start address, mode).
    let mut by_array: BTreeMap<usize, Vec<(u64, AccessMode)>> = BTreeMap::new();

    for rep in 0..s {
        vals[t.stride_loop] = rep;
        for acc in &t.spec.accesses {
            // Evaluate at the second vector iteration so stencil offsets
            // stay in bounds.
            vals[t.vector_loop] = super::VEC_ELEMS;
            for l in 0..n_loops {
                if l != t.stride_loop && l != t.vector_loop {
                    vals[l] = 1; // interior point
                }
            }
            if let Some(addr) = t.spec.address(acc, &vals) {
                by_array.entry(acc.array).or_default().push((addr, acc.mode));
            }
        }
    }

    let (mut loads, mut stores, mut loadstores) = (0u32, 0u32, 0u32);
    for (_arr, mut insts) in by_array {
        insts.sort_by_key(|&(a, _)| a);
        // Greedy clustering by gap.
        let mut i = 0;
        while i < insts.len() {
            let start = insts[i].0;
            let mut has_read = false;
            let mut has_write = false;
            let mut end = start;
            while i < insts.len() && insts[i].0 - end <= COALESCE_BYTES {
                match insts[i].1 {
                    AccessMode::Read => has_read = true,
                    AccessMode::Write => has_write = true,
                    AccessMode::ReadWrite => {
                        has_read = true;
                        has_write = true;
                    }
                }
                end = insts[i].0;
                i += 1;
            }
            match (has_read, has_write) {
                (true, true) => loadstores += 1,
                (true, false) => loads += 1,
                (false, true) => stores += 1,
                (false, false) => unreachable!(),
            }
        }
    }
    StrideProfile { loads, stores, loadstores }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::library::paper_kernels;
    use crate::transform::{transform, StridingConfig};

    /// Table 1 of the paper, as a function of the stride-unroll count `n`.
    fn table1_expected(name: &str, n: u32) -> Option<StrideProfile> {
        Some(match name {
            "bicg" => StrideProfile { loads: n + 2, stores: 1, loadstores: 1 },
            "conv" => StrideProfile { loads: n + 2, stores: n, loadstores: 0 },
            "doitgen" => StrideProfile { loads: n + 1, stores: 0, loadstores: 1 },
            "gemverouter" => StrideProfile { loads: 4, stores: 0, loadstores: n },
            "gemvermxv1" => StrideProfile { loads: n + 1, stores: 0, loadstores: 1 },
            // Table 1 lists gemversum's x stream under separate L and S
            // columns (L:n, S:n); our profiler reports a read-modify-write
            // position as one combined L/S stream — same information.
            "gemversum" => StrideProfile { loads: n, stores: 0, loadstores: n },
            "gemvermxv2" => StrideProfile { loads: n + 1, stores: 0, loadstores: 1 },
            "jacobi2d" => StrideProfile { loads: n + 2, stores: n, loadstores: 0 },
            "mxv" => StrideProfile { loads: n + 1, stores: 0, loadstores: 1 },
            "init" => StrideProfile { loads: 0, stores: n, loadstores: 0 },
            "writeback" => StrideProfile { loads: n, stores: n, loadstores: 0 },
            _ => return None,
        })
    }

    #[test]
    fn table1_stride_columns_reproduced() {
        for n in [1u32, 2, 4, 8] {
            for pk in paper_kernels(1 << 24) {
                let expect = match table1_expected(&pk.name, n) {
                    Some(e) => e,
                    None => continue,
                };
                let t = transform(&pk.spec, StridingConfig::new(n, 2))
                    .unwrap_or_else(|e| panic!("{} n={n}: {e}", pk.name));
                let got = stride_profile(&t);
                assert_eq!(
                    got, expect,
                    "Table 1 mismatch for {} at n={n}: got {got:?}, expected {expect:?}",
                    pk.name
                );
            }
        }
    }

    #[test]
    fn adjacent_outputs_coalesce() {
        // mxv's C[i], C[i+1], ... for adjacent stride replicas are one
        // stream: total = (n+1) loads + 1 L/S regardless of n.
        for pk in paper_kernels(1 << 24) {
            if pk.name != "mxv" {
                continue;
            }
            let t4 = transform(&pk.spec, StridingConfig::new(4, 1)).unwrap();
            let t8 = transform(&pk.spec, StridingConfig::new(8, 1)).unwrap();
            assert_eq!(stride_profile(&t4).loadstores, 1);
            assert_eq!(stride_profile(&t8).loadstores, 1);
        }
    }
}
