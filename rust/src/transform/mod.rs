//! The multi-striding code transformation (§5 of the paper).
//!
//! Pipeline, exactly as §5.1 describes:
//!
//! 1. **Critical-access selection** ([`critical_access`]): pick the array
//!    with the highest dimensionality whose last indexing variable appears
//!    *exclusively* as the last dimension in every array indexed with it.
//!    That variable's axis becomes the **contiguous data axis**.
//! 2. **Loop interchange** ([`transform`]): make the contiguous axis the
//!    innermost loop (always legal — specs are dependence-free).
//! 3. **Vectorization**: the innermost loop advances in 8-float AVX2
//!    vectors.
//! 4. **Loop blocking** for one-dimensional kernels: the single loop is
//!    split so a stride axis exists (Table 1's "LB" column).
//! 5. **Portion / stride unrolling**: `portion_unroll` vectors of each
//!    stride per iteration; `stride_unroll` concurrent strides via
//!    unrolling the next-outer loop.
//! 6. **Redundant-access elimination** + **register-pressure feasibility**
//!    ([`register_pressure`]): configurations needing more than the
//!    architectural 16 ymm registers are rejected ([`is_feasible`]).

pub mod profile;
pub mod variants;

pub use profile::{stride_profile, StrideProfile};
pub use variants::{
    universe_variants, variant_configs, variant_set, variant_set_on, KernelVariant, VariantSet,
    STRIDE_FAMILY,
};

use crate::bail;
use crate::kernels::spec::{AccessMode, IndexExpr, KernelSpec, LoopVar};
use crate::trace::Arrangement;
use crate::Result;

/// AVX2 single-precision vector width in elements.
pub const VEC_ELEMS: u64 = 8;
/// Vector width in bytes.
pub const VEC_BYTES: u64 = VEC_ELEMS * 4;

/// One point of the paper's optimization space (§5.1.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StridingConfig {
    /// Concurrent strides (unroll factor of the stride axis).
    pub stride_unroll: u32,
    /// Vectors of each stride processed per iteration (unroll factor of the
    /// contiguous axis).
    pub portion_unroll: u32,
    /// Eliminate redundant loads/stores between unroll replicas (§5.1.2's
    /// optimization; the isolated §6.3 experiments keep them).
    pub eliminate_redundant: bool,
    /// Arrangement of accesses within the loop body (§4.1).
    pub arrangement: Arrangement,
}

impl StridingConfig {
    pub fn new(stride_unroll: u32, portion_unroll: u32) -> Self {
        Self {
            stride_unroll,
            portion_unroll,
            eliminate_redundant: false,
            arrangement: Arrangement::Grouped,
        }
    }

    /// Single-strided baseline with `unrolls` portion unrolls.
    pub fn single(unrolls: u32) -> Self {
        Self::new(1, unrolls)
    }

    /// Total unroll slots this configuration occupies.
    pub fn total_unrolls(&self) -> u32 {
        self.stride_unroll * self.portion_unroll
    }
}

/// The transformed kernel the trace generator lowers.
#[derive(Debug, Clone)]
pub struct Transformed {
    /// Spec after any loop blocking (extents may be trimmed to step-size
    /// multiples).
    pub spec: KernelSpec,
    /// Loop execution order after interchange, outermost first, as indices
    /// into `spec.loops`.
    pub order: Vec<usize>,
    /// The vectorized (contiguous-axis) loop — always `order.last()`.
    pub vector_loop: usize,
    /// The stride-unrolled loop — second-innermost in `order`.
    pub stride_loop: usize,
    pub config: StridingConfig,
    /// Index of the critical access in `spec.accesses`.
    pub critical: usize,
}

/// §5.1.1: find the critical memory access. Returns `(access index,
/// contiguous-axis loop index)`.
pub fn critical_access(spec: &KernelSpec) -> Result<(usize, usize)> {
    if spec.loop_carried_dep {
        bail!("kernel {} has loop-carried dependencies; multi-striding inapplicable", spec.name);
    }
    // Candidates ordered by array dimensionality (highest first).
    let mut cands: Vec<usize> = (0..spec.accesses.len()).collect();
    cands.sort_by_key(|&a| std::cmp::Reverse(spec.arrays[spec.accesses[a].array].dims.len()));

    for &a in &cands {
        let acc = &spec.accesses[a];
        let last = match acc.idx.last() {
            Some(e) => e,
            None => continue,
        };
        // The last indexing variable of this access…
        let var = match last.terms.iter().rev().find(|&&(_, c)| c != 0) {
            Some(&(v, _)) => v,
            None => continue,
        };
        // …must appear exclusively as the last dimension in every array
        // indexed with it (otherwise vectorizing over it would gather).
        let ok = spec.accesses.iter().all(|other| {
            other.idx.iter().enumerate().all(|(d, e)| {
                !e.uses(var) || d == other.idx.len() - 1
            })
        });
        if ok {
            return Ok((a, var));
        }
    }
    bail!("kernel {}: no valid critical access (gather required)", spec.name)
}

/// Apply the full §5.1 transformation for one configuration.
pub fn transform(spec: &KernelSpec, config: StridingConfig) -> Result<Transformed> {
    if config.stride_unroll == 0 || config.portion_unroll == 0 {
        bail!("unroll factors must be ≥ 1");
    }
    let (critical, vec_loop) = critical_access(spec)?;
    let mut spec = spec.clone();
    let mut vec_loop = vec_loop;

    // One-dimensional kernels need loop blocking to create a stride axis.
    if spec.loops.len() == 1 {
        block_single_loop(&mut spec, config.stride_unroll)?;
        vec_loop = 1; // the inner loop of the blocked pair
    }

    // Loop interchange: contiguous axis innermost, others keep order.
    let mut order: Vec<usize> = (0..spec.loops.len()).filter(|&l| l != vec_loop).collect();
    order.push(vec_loop);
    let stride_loop = order[order.len() - 2];

    // Divisibility: trim extents to multiples of the step sizes (the paper
    // "prevents the need to process leftover array parts").
    let vstep = VEC_ELEMS * config.portion_unroll as u64;
    let ve = &mut spec.loops[vec_loop].extent;
    *ve = (*ve / vstep) * vstep;
    let se = &mut spec.loops[stride_loop].extent;
    *se = (*se / config.stride_unroll as u64) * config.stride_unroll as u64;
    if spec.loops[vec_loop].extent == 0 || spec.loops[stride_loop].extent == 0 {
        bail!(
            "kernel {}: extents too small for config s={} p={}",
            spec.name,
            config.stride_unroll,
            config.portion_unroll
        );
    }

    Ok(Transformed { spec, order, vector_loop: vec_loop, stride_loop, config, critical })
}

/// Loop blocking for 1-D kernels (§5.1.1 last paragraph): split loop 0 of
/// extent `N` into an outer partition loop (extent `n`, the stride count)
/// and an inner loop of `N/n`, rewriting every subscript
/// `j → part·(N/n) + j'`.
fn block_single_loop(spec: &mut KernelSpec, n: u32) -> Result<()> {
    let total = spec.loops[0].extent;
    let inner = total / n as u64;
    if inner == 0 {
        bail!("kernel {}: extent {} too small to block into {} strides", spec.name, total, n);
    }
    let name = spec.loops[0].name.clone();
    spec.loops = vec![
        LoopVar::new(&format!("{name}_blk"), n as u64),
        LoopVar::new(&format!("{name}_in"), inner),
    ];
    for acc in &mut spec.accesses {
        for e in &mut acc.idx {
            let mut terms = Vec::with_capacity(2);
            let mut offset = e.offset;
            for &(v, c) in &e.terms {
                debug_assert_eq!(v, 0, "1-D kernel has a single loop var");
                let _ = v;
                terms.push((0usize, c * inner as i64)); // partition term
                terms.push((1usize, c)); // inner term
                offset = e.offset;
            }
            *e = IndexExpr { terms, offset };
        }
    }
    Ok(())
}

/// §5.1.2 register-pressure model of a configuration, in ymm registers.
///
/// Mirrors what the paper's generated assembly keeps live (cf. Listing 2,
/// which at stride unroll 3 holds `b0..b2` broadcasts and `c0..c2`
/// accumulators):
///
/// * **Accumulators** — accesses written but independent of the contiguous
///   axis (`C[i]`, `q[i]`): one vector register per stride replica, held
///   across the whole inner loop.
/// * **Broadcast operands** — reads independent of the contiguous axis
///   (`B[j]`, `r[i]`): one broadcast register per stride replica.
/// * **Shared vector operands** — reads that advance with the contiguous
///   axis but are identical across stride replicas (`x[j]` in mxv): with
///   redundant-access elimination they are loaded once and pinned, one
///   register per portion slot; without it they re-load per use.
/// * Two scratch registers for addresses/temporaries.
pub fn register_pressure(t: &Transformed) -> u32 {
    let s = t.config.stride_unroll;
    let p = t.config.portion_unroll;
    let mut regs = 2u32; // scratch

    let on_vec =
        |a: &crate::kernels::spec::ArrayAccess| a.idx.iter().any(|e| e.uses(t.vector_loop));
    let on_stride =
        |a: &crate::kernels::spec::ArrayAccess| a.idx.iter().any(|e| e.uses(t.stride_loop));

    for a in &t.spec.accesses {
        if !on_vec(a) {
            // Broadcast operand (`B[j]`) or scalar accumulator (`q[i]`,
            // `y[i]`): one register per stride replica, live across the
            // entire inner loop.
            regs += s;
        } else if !on_stride(a) && a.mode != AccessMode::Read {
            // Vector accumulator shared across replicas (`C[i:i+8]` in
            // Listing 2): one register per portion slot, live across the
            // body.
            regs += p;
        } else if !on_stride(a)
            && a.mode == AccessMode::Read
            && t.config.eliminate_redundant
        {
            // Shared vector operand (`x[j]` in mxv): pinned per portion
            // slot once redundant reloads are eliminated.
            regs += p;
        }
        // Strided vector operands (`A` rows) stream through a transient.
    }
    if !t.config.eliminate_redundant {
        regs += 1; // transient operand register, reused per slot
    }
    regs
}

/// Is the configuration realizable within the architectural register file?
pub fn is_feasible(t: &Transformed, simd_registers: u32) -> bool {
    register_pressure(t) <= simd_registers
}

/// Enumerate the §6.3 optimization space: all `(stride, portion)` pairs
/// whose product is `total`, for each `total` in `1..=max_total`.
pub fn enumerate_configs(max_total: u32) -> Vec<StridingConfig> {
    let mut out = Vec::new();
    for total in 1..=max_total {
        for d in 1..=total {
            if total % d == 0 {
                out.push(StridingConfig::new(d, total / d));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::library::{paper_kernels, PaperKernel};
    use crate::kernels::spec::{AccessMode, Array, ArrayAccess, IndexExpr, KernelSpec, LoopVar};

    fn mxv(n: u64, m: u64) -> KernelSpec {
        let mut k = KernelSpec {
            name: "mxv".into(),
            loops: vec![LoopVar::new("i", n), LoopVar::new("j", m)],
            arrays: vec![
                Array::new("A", &[n, m], 4),
                Array::new("x", &[m], 4),
                Array::new("y", &[n], 4),
            ],
            accesses: vec![
                ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Read),
                ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
                ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
            ],
            loop_carried_dep: false,
        };
        k.layout();
        k
    }

    /// Transposed mxv: C[i] += A[j][i] * B[j] (the paper's Listing 1).
    fn tmxv(n: u64, m: u64) -> KernelSpec {
        let mut k = KernelSpec {
            name: "tmxv".into(),
            loops: vec![LoopVar::new("i", n), LoopVar::new("j", m)],
            arrays: vec![
                Array::new("A", &[m, n], 4),
                Array::new("B", &[m], 4),
                Array::new("C", &[n], 4),
            ],
            accesses: vec![
                ArrayAccess::new(0, vec![IndexExpr::var(1), IndexExpr::var(0)], AccessMode::Read),
                ArrayAccess::new(1, vec![IndexExpr::var(1)], AccessMode::Read),
                ArrayAccess::new(2, vec![IndexExpr::var(0)], AccessMode::ReadWrite),
            ],
            loop_carried_dep: false,
        };
        k.layout();
        k
    }

    /// Matrix transpose: A[i][j] = B[j][i] — must be rejected (§5.1.1's
    /// gather example).
    fn transpose(n: u64) -> KernelSpec {
        let mut k = KernelSpec {
            name: "transpose".into(),
            loops: vec![LoopVar::new("i", n), LoopVar::new("j", n)],
            arrays: vec![Array::new("A", &[n, n], 4), Array::new("B", &[n, n], 4)],
            accesses: vec![
                ArrayAccess::new(0, vec![IndexExpr::var(0), IndexExpr::var(1)], AccessMode::Write),
                ArrayAccess::new(1, vec![IndexExpr::var(1), IndexExpr::var(0)], AccessMode::Read),
            ],
            loop_carried_dep: false,
        };
        k.layout();
        k
    }

    #[test]
    fn critical_access_picks_matrix_contiguous_axis() {
        let k = mxv(256, 256);
        let (a, v) = critical_access(&k).unwrap();
        assert_eq!(a, 0, "A[i][j] is critical");
        assert_eq!(v, 1, "contiguous axis is j");
    }

    #[test]
    fn transposed_mxv_vectorizes_over_i_and_interchanges() {
        let k = tmxv(256, 256);
        let (a, v) = critical_access(&k).unwrap();
        assert_eq!(a, 0, "A[j][i] is critical");
        assert_eq!(v, 0, "contiguous axis is i (last dim of A)");
        let t = transform(&k, StridingConfig::new(3, 2)).unwrap();
        assert_eq!(*t.order.last().unwrap(), 0, "i innermost after interchange");
        assert_eq!(t.stride_loop, 1, "j is the stride axis (paper's Listing 2)");
    }

    #[test]
    fn transpose_kernel_rejected() {
        let k = transpose(64);
        assert!(critical_access(&k).is_err(), "transpose requires gathers");
    }

    #[test]
    fn dependence_rejected() {
        let mut k = mxv(64, 64);
        k.loop_carried_dep = true;
        assert!(critical_access(&k).is_err());
    }

    #[test]
    fn extent_trimming_to_step_multiples() {
        let k = mxv(250, 250); // not divisible by most steps
        let t = transform(&k, StridingConfig::new(4, 3)).unwrap();
        assert_eq!(t.spec.loops[1].extent % (8 * 3), 0);
        assert_eq!(t.spec.loops[0].extent % 4, 0);
    }

    #[test]
    fn blocking_creates_stride_axis_for_1d() {
        // init kernel: A[j] = 0 over one loop.
        let mut k = KernelSpec {
            name: "init".into(),
            loops: vec![LoopVar::new("j", 4096)],
            arrays: vec![Array::new("A", &[4096], 4)],
            accesses: vec![ArrayAccess::new(0, vec![IndexExpr::var(0)], AccessMode::Write)],
            loop_carried_dep: false,
        };
        k.layout();
        let t = transform(&k, StridingConfig::new(4, 1)).unwrap();
        assert_eq!(t.spec.loops.len(), 2, "blocked into partition × inner");
        assert_eq!(t.spec.loops[0].extent, 4);
        assert_eq!(t.spec.loops[1].extent, 1024);
        // Subscript rewrite: j -> part*1024 + j'.
        let e = &t.spec.accesses[0].idx[0];
        assert_eq!(e.eval(&[2, 5]), 2 * 1024 + 5);
    }

    #[test]
    fn register_pressure_grows_with_unrolls() {
        // tmxv holds an accumulator (C) and a broadcast (B) per replica.
        let k = tmxv(512, 512);
        let small = transform(&k, StridingConfig::new(2, 1)).unwrap();
        let large = transform(&k, StridingConfig::new(16, 4)).unwrap();
        assert!(register_pressure(&small) < register_pressure(&large));
        assert!(is_feasible(&small, 16));
        assert!(!is_feasible(&large, 16), "16 broadcasts + 4 slots cannot fit 16 ymm");
    }

    #[test]
    fn listing2_configuration_is_feasible() {
        // The paper's Listing 2: stride 3, portion 2 on transposed mxv —
        // b0..b2 + c0..c2 + scratch fits 16 ymm comfortably.
        let k = tmxv(512, 512);
        let t = transform(&k, StridingConfig::new(3, 2)).unwrap();
        // 2 scratch + 3 b-broadcasts + 2 c-accumulator slots + 1 transient.
        assert_eq!(register_pressure(&t), 2 + 3 + 2 + 1);
        assert!(is_feasible(&t, 16));
    }

    #[test]
    fn elimination_raises_pressure() {
        // mxv's x[j] is a shared vector operand: pinning it costs one
        // register per portion slot.
        let k = mxv(512, 512);
        let mut cfg = StridingConfig::new(2, 2);
        let plain = transform(&k, cfg).unwrap();
        cfg.eliminate_redundant = true;
        let elim = transform(&k, cfg).unwrap();
        assert!(
            register_pressure(&elim) > register_pressure(&plain),
            "elim {} vs plain {}",
            register_pressure(&elim),
            register_pressure(&plain)
        );
    }

    #[test]
    fn enumerate_covers_divisor_structure() {
        let cfgs = enumerate_configs(6);
        // For total=6: (1,6),(2,3),(3,2),(6,1) present.
        for (s, p) in [(1, 6), (2, 3), (3, 2), (6, 1)] {
            assert!(cfgs.iter().any(|c| c.stride_unroll == s && c.portion_unroll == p));
        }
        // No non-divisor pairs.
        assert!(cfgs.iter().all(|c| c.total_unrolls() <= 6));
    }

    #[test]
    fn all_paper_kernels_transform() {
        for pk in paper_kernels(1 << 22) {
            if pk.name == "gemverouter" {
                // outer product vectorizes over j; still must transform.
            }
            let t = transform(&pk.spec, StridingConfig::new(2, 2));
            assert!(t.is_ok(), "{} failed: {:?}", pk.name, t.err());
        }
    }

    #[test]
    fn zero_unroll_rejected() {
        let k = mxv(64, 64);
        assert!(transform(&k, StridingConfig::new(0, 1)).is_err());
        assert!(transform(&k, StridingConfig::new(1, 0)).is_err());
    }

    // Property: every enumerated feasible config transforms and the
    // product decomposition is preserved.
    #[test]
    fn prop_transform_preserves_unroll_product() {
        use crate::util::proptest::{check, Config};
        let k = tmxv(2048, 2048);
        check(
            Config { cases: 64, seed: 0x57A1DE },
            |r, size| {
                let total = r.range(1, size as u64).max(1) as u32;
                let divs: Vec<u32> = (1..=total).filter(|d| total % d == 0).collect();
                let s = divs[r.below(divs.len() as u64) as usize];
                (s, total / s)
            },
            |&(s, p)| {
                let t = transform(&k, StridingConfig::new(s, p)).unwrap();
                t.config.total_unrolls() == s * p
                    && t.spec.loops[t.vector_loop].extent % (8 * p as u64) == 0
            },
        );
    }
}
