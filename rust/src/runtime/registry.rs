//! Runtime registry: the kernel universe (simulator specs from
//! [`crate::kernels::library`]) joined with artifact discovery
//! (`artifacts/*.hlo.txt` files for the PJRT execution path).

use std::path::{Path, PathBuf};

use crate::kernels::library::{all_kernels, PaperKernel};

/// Which family a registered kernel belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFamily {
    /// One of the paper's Table 1 kernels.
    Paper,
    /// The extended (beyond-Table-1) universe.
    Extended,
}

/// One registered kernel: simulator spec metadata plus whether a compiled
/// PJRT artifact exists for numeric execution.
#[derive(Debug, Clone)]
pub struct RegisteredKernel {
    pub name: String,
    pub family: KernelFamily,
    pub description: &'static str,
    /// Number of loops in the (untransformed) nest.
    pub loop_depth: usize,
    /// Total data footprint in bytes at the registry's budget.
    pub footprint: u64,
    /// An `artifacts/<name>.hlo.txt` file exists.
    pub has_artifact: bool,
}

/// The simulator-side universe's kernel names at `budget` bytes, in
/// registry order. **The single name source** for every registry-driven
/// kernel list: [`kernel_universe`] joins it with artifacts, and the
/// coordinator's `figure6_kernels`/`figure7_kernels`/`tune_universe`
/// derive from it (with documented filters), so the lists cannot drift.
pub fn universe_names(budget: u64) -> Vec<String> {
    all_kernels(budget).iter().map(|k| k.name.clone()).collect()
}

/// Enumerate the whole kernel universe at `budget` bytes, marking which
/// kernels also have a compiled artifact in `artifacts` — the registry
/// view joining simulator specs with runtime executability (rendered by
/// `repro universe`). Sweeps and benches enumerate the simulator-side
/// universe directly via `kernels::library::all_kernels`; this function
/// adds the artifact dimension on top and must stay a pure view (no
/// filtering), or the two enumerations would diverge.
pub fn kernel_universe(artifacts: &ArtifactRegistry, budget: u64) -> Vec<RegisteredKernel> {
    all_kernels(budget)
        .iter()
        .map(|k: &PaperKernel| RegisteredKernel {
            name: k.name.clone(),
            family: if k.extended { KernelFamily::Extended } else { KernelFamily::Paper },
            description: k.description,
            loop_depth: k.spec.loops.len(),
            footprint: k.spec.footprint(),
            has_artifact: artifacts.has(&k.name),
        })
        .collect()
}

/// The artifact directory scanner.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
}

impl ArtifactRegistry {
    /// Default location: `$MULTISTRIDE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MULTISTRIDE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path an artifact for `name` would live at.
    pub fn path_for(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Does the artifact exist?
    pub fn has(&self, name: &str) -> bool {
        self.path_for(name).is_file()
    }

    /// All available artifact names (sorted).
    pub fn list(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let p = e.path();
                if let Some(fname) = p.file_name().and_then(|f| f.to_str()) {
                    if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_scans_dir() {
        let dir = std::env::temp_dir().join("multistride_reg_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mxv.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("ignore.bin"), "x").unwrap();
        let reg = ArtifactRegistry::new(&dir);
        assert_eq!(reg.list(), vec!["mxv".to_string()]);
        assert!(reg.has("mxv"));
        assert!(!reg.has("conv"));
        assert!(reg.path_for("conv").to_string_lossy().ends_with("conv.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_empty() {
        let reg = ArtifactRegistry::new("/nonexistent/multistride");
        assert!(reg.list().is_empty());
    }

    #[test]
    fn kernel_universe_covers_both_families() {
        let reg = ArtifactRegistry::new("/nonexistent/multistride");
        let universe = kernel_universe(&reg, 1 << 22);
        assert!(universe.iter().any(|k| k.name == "mxv" && k.family == KernelFamily::Paper));
        assert!(universe.iter().any(|k| k.name == "3mm" && k.family == KernelFamily::Extended));
        assert!(universe.iter().any(|k| k.loop_depth == 3), "3-deep nest registered");
        assert!(universe.iter().all(|k| !k.has_artifact), "no artifacts on disk");
        assert!(universe.iter().all(|k| k.footprint > 0));
    }

    #[test]
    fn universe_names_is_the_registry_projection() {
        let reg = ArtifactRegistry::new("/nonexistent/multistride");
        let universe = kernel_universe(&reg, 1 << 22);
        assert_eq!(
            universe_names(1 << 22),
            universe.iter().map(|k| k.name.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn kernel_universe_sees_artifacts() {
        // Per-process dir: two concurrent `cargo test` runs must not race.
        let dir = std::env::temp_dir()
            .join(format!("multistride_universe_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mxv.hlo.txt"), "x").unwrap();
        let reg = ArtifactRegistry::new(&dir);
        let universe = kernel_universe(&reg, 1 << 22);
        let mxv = universe.iter().find(|k| k.name == "mxv").unwrap();
        assert!(mxv.has_artifact);
        let triad = universe.iter().find(|k| k.name == "triad").unwrap();
        assert!(!triad.has_artifact);
        std::fs::remove_dir_all(&dir).ok();
    }
}
