//! Artifact discovery: maps kernel names to `artifacts/*.hlo.txt` files.

use std::path::{Path, PathBuf};

/// The artifact directory scanner.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    dir: PathBuf,
}

impl ArtifactRegistry {
    /// Default location: `$MULTISTRIDE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("MULTISTRIDE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Path an artifact for `name` would live at.
    pub fn path_for(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Does the artifact exist?
    pub fn has(&self, name: &str) -> bool {
        self.path_for(name).is_file()
    }

    /// All available artifact names (sorted).
    pub fn list(&self) -> Vec<String> {
        let mut names = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let p = e.path();
                if let Some(fname) = p.file_name().and_then(|f| f.to_str()) {
                    if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                        names.push(stem.to_string());
                    }
                }
            }
        }
        names.sort();
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_scans_dir() {
        let dir = std::env::temp_dir().join("multistride_reg_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mxv.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("ignore.bin"), "x").unwrap();
        let reg = ArtifactRegistry::new(&dir);
        assert_eq!(reg.list(), vec!["mxv".to_string()]);
        assert!(reg.has("mxv"));
        assert!(!reg.has("conv"));
        assert!(reg.path_for("conv").to_string_lossy().ends_with("conv.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_empty() {
        let reg = ArtifactRegistry::new("/nonexistent/multistride");
        assert!(reg.list().is_empty());
    }
}
