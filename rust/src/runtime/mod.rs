//! PJRT runtime: loads the AOT-compiled JAX/Pallas kernel artifacts
//! (`artifacts/*.hlo.txt`) and executes them numerically from Rust.
//!
//! This is the L3↔L2 bridge of the three-layer architecture: Python runs
//! only at build time (`make artifacts`); the request path is this module.
//! Interchange format is **HLO text** — jax ≥ 0.5 serializes protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects, while the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The PJRT bridge needs the external `xla` crate, which offline builds do
//! not have; it compiles only under the `pjrt` cargo feature. Without the
//! feature an API-compatible stub is provided whose [`Runtime::new`]
//! returns an error, so callers (which already skip gracefully when no
//! artifacts are present) degrade cleanly.

pub mod registry;

pub use registry::{
    kernel_universe, universe_names, ArtifactRegistry, KernelFamily, RegisteredKernel,
};

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;

#[cfg(feature = "pjrt")]
use crate::error::Context;
use crate::{format_err, Result};

/// A loaded, compiled kernel executable.
#[cfg(feature = "pjrt")]
pub struct LoadedKernel {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact path (diagnostics).
    pub path: std::path::PathBuf,
}

/// The PJRT CPU runtime with a cache of compiled kernels.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    kernels: HashMap<String, LoadedKernel>,
}

/// Stub runtime compiled without the `pjrt` feature: construction fails
/// with a clear error and nothing else is reachable.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    _private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Always fails: the PJRT backend is not compiled in.
    pub fn new() -> Result<Self> {
        Err(format_err!(
            "built without the `pjrt` feature: PJRT execution requires the external `xla` crate"
        ))
    }

    /// Platform diagnostics string.
    pub fn platform(&self) -> String {
        "unavailable (pjrt feature disabled)".into()
    }

    /// Stub: always fails.
    pub fn load(&mut self, _name: &str, _path: &Path) -> Result<()> {
        Err(format_err!("pjrt feature disabled"))
    }

    /// Names of loaded kernels (always empty in the stub).
    pub fn loaded(&self) -> Vec<&str> {
        Vec::new()
    }

    /// Stub: always fails.
    pub fn execute_f32(&self, _name: &str, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(format_err!("pjrt feature disabled"))
    }
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Construct a CPU PJRT client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| format_err!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, kernels: HashMap::new() })
    }

    /// Platform diagnostics string.
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Load and compile an HLO-text artifact under `name`.
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().context("utf8 path")?)
            .map_err(|e| format_err!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format_err!("compile {path:?}: {e:?}"))?;
        self.kernels.insert(name.to_string(), LoadedKernel { exe, path: path.to_path_buf() });
        Ok(())
    }

    /// Names of loaded kernels.
    pub fn loaded(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.kernels.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Execute kernel `name` on f32 inputs with the given shapes; returns
    /// the flattened f32 outputs (artifacts are lowered with
    /// `return_tuple=True`, outputs unwrapped in declaration order).
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let k = self.kernels.get(name).ok_or_else(|| format_err!("kernel {name} not loaded"))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(|e| format_err!("reshape input to {shape:?}: {e:?}"))?;
            lits.push(lit);
        }
        let result = k
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| format_err!("execute {name}: {e:?}"))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| format_err!("fetch result: {e:?}"))?;
        // Lowered with return_tuple=True: decompose the tuple.
        let elems = out.decompose_tuple().map_err(|e| format_err!("untuple: {e:?}"))?;
        let mut vecs = Vec::with_capacity(elems.len());
        for e in elems {
            vecs.push(e.to_vec::<f32>().map_err(|e2| format_err!("to_vec: {e2:?}"))?);
        }
        Ok(vecs)
    }
}

/// Pure-Rust oracles for the numeric kernels — used by the integration
/// tests and the e2e example to validate the PJRT-executed artifacts.
pub mod oracle {
    /// y = A · x (row-major A of shape m×n).
    pub fn mxv(a: &[f32], x: &[f32], m: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0f32; m];
        for i in 0..m {
            let mut acc = 0f32;
            for j in 0..n {
                acc += a[i * n + j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    /// bicg: s = Aᵀ·r, q = A·p.
    pub fn bicg(a: &[f32], r: &[f32], p: &[f32], m: usize, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut s = vec![0f32; n];
        let mut q = vec![0f32; m];
        for i in 0..m {
            let mut acc = 0f32;
            for j in 0..n {
                s[j] += r[i] * a[i * n + j];
                acc += a[i * n + j] * p[j];
            }
            q[i] = acc;
        }
        (s, q)
    }

    /// 3×3 valid convolution with weights w (row-major 3×3).
    pub fn conv3x3(inp: &[f32], w: &[f32; 9], h: usize, wdt: usize) -> Vec<f32> {
        let (oh, ow) = (h - 2, wdt - 2);
        let mut out = vec![0f32; oh * ow];
        for i in 0..oh {
            for j in 0..ow {
                let mut acc = 0f32;
                for di in 0..3 {
                    for dj in 0..3 {
                        acc += w[di * 3 + dj] * inp[(i + di) * wdt + (j + dj)];
                    }
                }
                out[i * ow + j] = acc;
            }
        }
        out
    }

    /// One interior Jacobi sweep: b = 0.2·(c + n + s + e + w), borders copied.
    pub fn jacobi2d(a: &[f32], h: usize, w: usize) -> Vec<f32> {
        let mut b = a.to_vec();
        for i in 1..h - 1 {
            for j in 1..w - 1 {
                b[i * w + j] = 0.2
                    * (a[i * w + j]
                        + a[i * w + j - 1]
                        + a[i * w + j + 1]
                        + a[(i - 1) * w + j]
                        + a[(i + 1) * w + j]);
            }
        }
        b
    }

    /// Relative max-abs error between two vectors.
    pub fn max_rel_err(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        a.iter()
            .zip(b)
            .map(|(x, y)| {
                let denom = x.abs().max(y.abs()).max(1e-6);
                (x - y).abs() / denom
            })
            .fold(0f32, f32::max)
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn mxv_identity() {
            // 2x2 identity times [3, 4].
            let y = mxv(&[1.0, 0.0, 0.0, 1.0], &[3.0, 4.0], 2, 2);
            assert_eq!(y, vec![3.0, 4.0]);
        }

        #[test]
        fn bicg_shapes() {
            let (s, q) = bicg(&[1.0; 6], &[1.0, 2.0], &[1.0, 1.0, 1.0], 2, 3);
            assert_eq!(s, vec![3.0, 3.0, 3.0]);
            assert_eq!(q, vec![3.0, 3.0]);
        }

        #[test]
        fn conv_averages() {
            let inp = vec![1.0f32; 16];
            let w = [1.0f32 / 9.0; 9];
            let out = conv3x3(&inp, &w, 4, 4);
            assert_eq!(out.len(), 4);
            for v in out {
                assert!((v - 1.0).abs() < 1e-6);
            }
        }

        #[test]
        fn jacobi_preserves_constant() {
            let a = vec![2.0f32; 25];
            let b = jacobi2d(&a, 5, 5);
            for v in b {
                assert!((v - 2.0).abs() < 1e-6);
            }
        }

        #[test]
        fn rel_err() {
            assert_eq!(max_rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
            assert!(max_rel_err(&[1.0], &[1.1]) > 0.05);
        }
    }
}
