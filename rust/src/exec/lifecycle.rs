//! Store lifecycle tooling: the library side of `repro store
//! {stats,gc,verify,compact}` (the CLI parser here also covers `store
//! merge`, whose implementation lives in [`super::grid`]).
//!
//! Each operation works on a results *directory* (not a live
//! [`super::ResultStore`]) and composes the segment tier's own
//! primitives:
//!
//! * **stats** — one [`SegmentStore`] open plus a legacy-shard walk;
//!   pure read (the only writes are the open's own self-healing).
//! * **gc** — bounded eviction. Refused without an explicit bound (the
//!   CLI enforces this): age (`--max-age-days`) drops records stamped
//!   older than the cutoff, size (`--max-bytes`) evicts oldest-first
//!   until the live payload fits. Evicted segment records become dead
//!   bytes until `compact`; evicted legacy shards are deleted outright.
//! * **verify** — two phases. Integrity: every live segment record must
//!   validate and decode, every legacy shard must parse; failures are
//!   dropped/reported (self-healing misses). Semantics: the canonical
//!   experiment plan is re-simulated point by point and compared
//!   bit-for-bit against what the store would serve — the release-build
//!   equivalent of the debug-build verify-every-hit wall. Mismatches
//!   are healed with the fresh result and reported as an error.
//! * **compact** — folds legacy shards into segments (stamped with their
//!   file mtime), rewrites live records into fresh segments, deletes the
//!   old segments and the now-redundant legacy tree. This is the
//!   explicit end of the PR-5 → segment migration; until it runs, old
//!   directories serve through the read-only legacy fallback.
//!
//! Because a rebuild-from-scan resurrects gc'd records (the bytes are
//! still there), eviction is durable only after `compact` — the docs
//! and CLI recipe pair them. That is safe cache semantics either way:
//! a resurrected record can only re-serve what a simulation would
//! recompute.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::config::{MachineConfig, ScaleConfig};
use crate::coordinator::experiments::{EngineCache, MICRO_STRIDES};
use crate::coordinator::pool::{default_workers, parallel_map_with};
use crate::kernels::library::kernel_by_name;
use crate::kernels::micro::MicroOp;
use crate::runtime::universe_names;
use crate::transform::{transform, variant_configs};
use crate::{ensure, format_err, Result};

use super::format::{parse_result, serialize_result};
use super::point::SimPoint;
use super::segment::{unix_now, SegmentStore, DEFAULT_ROLL_BYTES};
use super::store::ResultStore;
use super::vfs::{default_io, DirEntryInfo, StoreIo};

/// A parsed `repro store` subcommand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreCommand {
    Stats,
    Gc { max_bytes: Option<u64>, max_age_days: Option<u64> },
    Verify,
    Compact,
    Merge { sources: Vec<PathBuf>, into: PathBuf },
}

/// The valid subcommand set, for error messages and usage text.
pub const STORE_SUBCOMMANDS: &[&str] = &["stats", "gc", "verify", "compact", "merge"];

/// Parse `repro store …` argv: the subcommand plus the store-specific
/// flags, returning the leftover args for the generic option parser
/// (`--results`, `--machine`, `--smoke`, …).
pub fn parse_store_cli(args: &[String]) -> Result<(StoreCommand, Vec<String>)> {
    let sub = args.first().ok_or_else(|| {
        format_err!("store: missing subcommand (expected one of: {})", STORE_SUBCOMMANDS.join(", "))
    })?;
    if sub == "merge" {
        // Merge names its directories explicitly, so it takes no
        // generic options: SRC... positionals plus the required --into.
        let mut sources = Vec::new();
        let mut into = None;
        let mut it = args[1..].iter();
        while let Some(a) = it.next() {
            match a.as_str() {
                "--into" => {
                    let v = it.next().ok_or_else(|| format_err!("--into needs a value"))?;
                    into = Some(PathBuf::from(v));
                }
                s if s.starts_with("--") => {
                    return Err(format_err!(
                        "store merge: unknown flag {s} (usage: store merge SRC... --into DST)"
                    ))
                }
                _ => sources.push(PathBuf::from(a)),
            }
        }
        ensure!(!sources.is_empty(), "store merge: at least one SRC directory is required");
        let into = into.ok_or_else(|| format_err!("store merge: --into DST is required"))?;
        return Ok((StoreCommand::Merge { sources, into }, Vec::new()));
    }
    let mut max_bytes = None;
    let mut max_age_days = None;
    let mut rest = Vec::new();
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--max-bytes" => {
                let v = it.next().ok_or_else(|| format_err!("--max-bytes needs a value"))?;
                max_bytes =
                    Some(v.parse().map_err(|_| format_err!("--max-bytes: not a number: {v}"))?);
            }
            "--max-age-days" => {
                let v = it.next().ok_or_else(|| format_err!("--max-age-days needs a value"))?;
                max_age_days =
                    Some(v.parse().map_err(|_| format_err!("--max-age-days: not a number: {v}"))?);
            }
            _ => rest.push(a.clone()),
        }
    }
    let cmd = match sub.as_str() {
        "stats" => StoreCommand::Stats,
        "gc" => {
            ensure!(
                max_bytes.is_some() || max_age_days.is_some(),
                "store gc refuses to run without an explicit bound: \
                 pass --max-bytes N and/or --max-age-days N"
            );
            StoreCommand::Gc { max_bytes, max_age_days }
        }
        "verify" => StoreCommand::Verify,
        "compact" => StoreCommand::Compact,
        other => {
            return Err(format_err!(
                "store: unknown subcommand `{other}` (expected one of: {})",
                STORE_SUBCOMMANDS.join(", ")
            ))
        }
    };
    if !matches!(cmd, StoreCommand::Gc { .. }) {
        ensure!(
            max_bytes.is_none() && max_age_days.is_none(),
            "--max-bytes/--max-age-days only apply to `store gc`"
        );
    }
    Ok((cmd, rest))
}

/// Directory-wide inventory, as `repro store stats` renders it.
#[derive(Debug, Clone, Copy, Default)]
pub struct DirStats {
    pub segments: u64,
    pub segment_bytes: u64,
    pub sealed_segments: u64,
    pub live_records: u64,
    pub live_bytes: u64,
    pub dead_bytes: u64,
    pub legacy_files: u64,
    pub legacy_bytes: u64,
    /// Whether the index file was usable (vs. rebuilt from scans).
    pub index_loaded: bool,
}

/// Take stock of a results directory.
pub fn dir_stats(dir: &Path) -> DirStats {
    dir_stats_with(default_io(), dir)
}

/// [`dir_stats`] over an explicit I/O backend.
pub fn dir_stats_with(io: Arc<dyn StoreIo>, dir: &Path) -> DirStats {
    let seg = SegmentStore::open_with(dir, DEFAULT_ROLL_BYTES, Arc::clone(&io));
    let mut s = DirStats {
        segments: seg.segment_count(),
        segment_bytes: seg.segment_bytes(),
        sealed_segments: seg.sealed_count(),
        live_records: seg.entry_count(),
        live_bytes: seg.live_bytes(),
        dead_bytes: seg.dead_bytes(),
        index_loaded: seg.index_loaded(),
        ..DirStats::default()
    };
    walk_legacy(&*io, dir, |_p, e| {
        s.legacy_files += 1;
        s.legacy_bytes += e.len;
    });
    s
}

/// What `repro store gc` did.
#[derive(Debug, Clone, Copy, Default)]
pub struct GcReport {
    /// Segment records dropped from the index.
    pub evicted_records: u64,
    /// Legacy shard files deleted.
    pub deleted_legacy: u64,
    /// Live records remaining.
    pub live_records: u64,
    /// Live payload bytes remaining (segment + legacy).
    pub live_bytes: u64,
    /// Dead segment bytes a `compact` would reclaim.
    pub reclaimable_bytes: u64,
}

/// Bounded eviction. At least one bound must be given (the CLI parser
/// guarantees it; this function also refuses). Age first, then
/// oldest-first down to the size bound, counting segment records and
/// legacy shards against the same budget.
pub fn gc(dir: &Path, max_bytes: Option<u64>, max_age_days: Option<u64>) -> Result<GcReport> {
    gc_with(default_io(), dir, max_bytes, max_age_days)
}

/// [`gc`] over an explicit I/O backend.
pub fn gc_with(
    io: Arc<dyn StoreIo>,
    dir: &Path,
    max_bytes: Option<u64>,
    max_age_days: Option<u64>,
) -> Result<GcReport> {
    ensure!(max_bytes.is_some() || max_age_days.is_some(), "gc needs an explicit bound");
    let mut seg = SegmentStore::open_with(dir, DEFAULT_ROLL_BYTES, Arc::clone(&io));
    let mut report = GcReport::default();
    // (path, stamp, bytes) for every legacy shard still standing.
    let mut legacy: Vec<(PathBuf, u64, u64)> = Vec::new();
    walk_legacy(&*io, dir, |p, e| {
        legacy.push((p.to_path_buf(), e.mtime_secs, e.len));
    });

    if let Some(days) = max_age_days {
        let cutoff = unix_now().saturating_sub(days.saturating_mul(86_400));
        for (key, loc) in seg.entries() {
            if loc.stamp < cutoff {
                seg.remove(key);
                report.evicted_records += 1;
            }
        }
        legacy.retain(|(p, stamp, _)| {
            if *stamp < cutoff {
                if io.remove_file(p).is_ok() {
                    report.deleted_legacy += 1;
                }
                false
            } else {
                true
            }
        });
    }

    if let Some(bound) = max_bytes {
        enum Victim {
            Record { key: u64, bytes: u64 },
            Shard { at: usize, bytes: u64 },
        }
        let mut victims: Vec<(u64, Victim)> = seg
            .entries()
            .into_iter()
            .map(|(key, loc)| (loc.stamp, Victim::Record { key, bytes: loc.len as u64 }))
            .collect();
        for (at, (_, stamp, bytes)) in legacy.iter().enumerate() {
            victims.push((*stamp, Victim::Shard { at, bytes: *bytes }));
        }
        let mut total: u64 = victims
            .iter()
            .map(|(_, v)| match v {
                Victim::Record { bytes, .. } | Victim::Shard { bytes, .. } => *bytes,
            })
            .sum();
        victims.sort_unstable_by_key(|&(stamp, _)| stamp);
        for (_, victim) in victims {
            if total <= bound {
                break;
            }
            match victim {
                Victim::Record { key, bytes } => {
                    if seg.remove(key) {
                        report.evicted_records += 1;
                        total -= bytes;
                    }
                }
                Victim::Shard { at, bytes } => {
                    if io.remove_file(&legacy[at].0).is_ok() {
                        report.deleted_legacy += 1;
                    }
                    total -= bytes;
                }
            }
        }
    }

    seg.flush_index()?;
    report.live_records = seg.entry_count();
    report.live_bytes = seg.live_bytes();
    walk_legacy(&*io, dir, |_p, e| report.live_bytes += e.len);
    report.reclaimable_bytes = seg.dead_bytes();
    Ok(report)
}

/// What `repro store verify` found.
#[derive(Debug, Clone, Copy, Default)]
pub struct VerifyReport {
    /// Segment records that validated and decoded.
    pub records_ok: u64,
    /// Segment records dropped as corrupt (now misses).
    pub records_corrupt: u64,
    /// Legacy shards that parsed.
    pub legacy_ok: u64,
    /// Legacy shards that failed to parse (served as misses anyway).
    pub legacy_corrupt: u64,
    /// Canonical-plan points checked against a fresh simulation.
    pub resimulated: u64,
    /// … of which the stored bytes matched exactly.
    pub verified: u64,
    /// … of which diverged (healed with the fresh result; an error).
    pub mismatched: u64,
    /// … of which the store simply does not hold (not an error).
    pub absent: u64,
}

impl VerifyReport {
    /// A verify run is clean when nothing was corrupt and nothing
    /// diverged from a fresh simulation.
    pub fn is_clean(&self) -> bool {
        self.records_corrupt == 0 && self.legacy_corrupt == 0 && self.mismatched == 0
    }
}

/// The re-simulate-and-compare sweep (phase 1: integrity over every
/// stored byte; phase 2: bit-exact comparison against fresh simulations
/// of the canonical plan for `machine` at `scale`).
pub fn verify(dir: &Path, machine: MachineConfig, scale: ScaleConfig) -> Result<VerifyReport> {
    verify_with(default_io(), dir, machine, scale)
}

/// [`verify`] over an explicit I/O backend.
pub fn verify_with(
    io: Arc<dyn StoreIo>,
    dir: &Path,
    machine: MachineConfig,
    scale: ScaleConfig,
) -> Result<VerifyReport> {
    let mut report = VerifyReport::default();
    {
        let mut seg = SegmentStore::open_with(dir, DEFAULT_ROLL_BYTES, Arc::clone(&io));
        for (key, _) in seg.entries() {
            match seg.lookup_result(key) {
                Some(Ok(_)) => report.records_ok += 1,
                Some(Err(e)) => {
                    report.records_corrupt += 1;
                    eprintln!("[store] corrupt record {key:#018x} dropped: {e}");
                }
                None => {}
            }
        }
        seg.flush_index()?; // persist any drops (self-healed index)
    }
    walk_legacy(&*io, dir, |p, _e| {
        let ok = io
            .read(p)
            .ok()
            .and_then(|b| String::from_utf8(b).ok())
            .and_then(|t| parse_result(&t).ok())
            .is_some();
        if ok {
            report.legacy_ok += 1;
        } else {
            report.legacy_corrupt += 1;
            eprintln!("[store] corrupt legacy shard {} (serves as a miss)", p.display());
        }
    });

    let store = ResultStore::persistent_with_io(dir, DEFAULT_ROLL_BYTES, Arc::clone(&io));
    let points = canonical_points(machine, scale);
    report.resimulated = points.len() as u64;
    enum Outcome {
        Verified,
        Mismatched(String),
        Absent,
    }
    let outcomes = parallel_map_with(points, default_workers(), EngineCache::new, |engines, p| {
        let Some(hit) = store.lookup(p.key()) else { return Ok(Outcome::Absent) };
        let fresh = super::planner::simulate(engines, p)?;
        if serialize_result(p.key(), &fresh) == serialize_result(p.key(), &hit) {
            Ok(Outcome::Verified)
        } else {
            // Heal with the truth; still reported as a mismatch.
            store.insert(p.key(), Arc::new(fresh));
            Ok(Outcome::Mismatched(p.label()))
        }
    });
    for outcome in outcomes {
        match outcome? {
            Outcome::Verified => report.verified += 1,
            Outcome::Absent => report.absent += 1,
            Outcome::Mismatched(label) => {
                report.mismatched += 1;
                eprintln!("[store] MISMATCH: stored result for {label} diverged (healed)");
            }
        }
    }
    store.flush();
    Ok(report)
}

/// What `repro store compact` did.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompactReport {
    /// Live records rewritten into fresh segments.
    pub rewritten: u64,
    /// Records dropped during the rewrite (failed validation).
    pub dropped: u64,
    /// Legacy shards folded into the segment tier.
    pub migrated_legacy: u64,
    /// Legacy files deleted after migration.
    pub deleted_legacy: u64,
    /// On-disk bytes reclaimed.
    pub reclaimed_bytes: u64,
    /// Segments and bytes after compaction.
    pub segments: u64,
    pub segment_bytes: u64,
}

/// Fold legacy shards into the segment tier, rewrite live records into
/// fresh segments, and delete the dead weight. The durable form of gc's
/// eviction and the final step of the PR-5 → segment migration.
pub fn compact(dir: &Path) -> Result<CompactReport> {
    compact_with(default_io(), dir)
}

/// [`compact`] over an explicit I/O backend.
pub fn compact_with(io: Arc<dyn StoreIo>, dir: &Path) -> Result<CompactReport> {
    let mut seg = SegmentStore::open_with(dir, DEFAULT_ROLL_BYTES, Arc::clone(&io));
    let mut report = CompactReport::default();
    let mut legacy: Vec<(PathBuf, u64, u64)> = Vec::new();
    walk_legacy(&*io, dir, |p, e| legacy.push((p.to_path_buf(), e.mtime_secs, e.len)));
    let legacy_bytes: u64 = legacy.iter().map(|(_, _, b)| b).sum();
    for (path, stamp, _) in &legacy {
        let Ok(bytes) = io.read(path) else { continue };
        let Ok(text) = String::from_utf8(bytes) else { continue };
        let Ok((key, result)) = parse_result(&text) else { continue };
        // The segment copy wins on conflict — identical content by
        // determinism, and segments are the write tier.
        if !seg.contains(key) {
            seg.append_result(key, *stamp, &result)?;
            report.migrated_legacy += 1;
        }
    }
    let stats = seg.compact()?;
    report.rewritten = stats.rewritten;
    report.dropped = stats.dropped;
    report.reclaimed_bytes = stats.reclaimed_bytes + legacy_bytes;
    for (path, ..) in &legacy {
        if io.remove_file(path).is_ok() {
            report.deleted_legacy += 1;
        }
    }
    prune_empty_shard_dirs(&*io, dir);
    report.segments = seg.segment_count();
    report.segment_bytes = seg.segment_bytes();
    Ok(report)
}

/// The canonical verification plan: the micro grids `repro all` stores
/// (figure2's non-pow2 size and figure5's pow2 size, every op × stride ×
/// prefetch setting) plus the kernel-universe variant family at the
/// paper's default portion. Points for other machines or sweeps are
/// covered by the integrity phase only.
pub fn canonical_points(machine: MachineConfig, scale: ScaleConfig) -> Vec<SimPoint> {
    let mut points = Vec::new();
    for bytes in [scale.micro_bytes, scale.micro_pow2_bytes] {
        for prefetch in [true, false] {
            for op in MicroOp::all() {
                for &s in &MICRO_STRIDES {
                    points.push(SimPoint::micro(machine, op, s, bytes, prefetch, false));
                    if op == MicroOp::StoreNt {
                        points.push(SimPoint::micro(machine, op, s, bytes, prefetch, true));
                    }
                }
            }
        }
    }
    let budget = scale.kernel_bytes;
    for name in universe_names(budget) {
        let Some(pk) = kernel_by_name(&name, budget) else { continue };
        for config in variant_configs(2) {
            if transform(&pk.spec, config).is_ok() {
                let p =
                    SimPoint::kernel_from_spec(machine, &name, budget, config, true, &pk.spec);
                points.push(p);
            }
        }
    }
    points
}

/// Visit every legacy `<xx>/<16-hex-key>.simres` shard under `dir`.
pub(crate) fn walk_legacy(io: &dyn StoreIo, dir: &Path, mut f: impl FnMut(&Path, &DirEntryInfo)) {
    let Ok(entries) = io.list_dir(dir) else { return };
    for sub in entries {
        let Some(name) = sub.name.to_str() else { continue };
        if !sub.is_dir || name.len() != 2 || !name.bytes().all(|b| b.is_ascii_hexdigit()) {
            continue;
        }
        let subdir = dir.join(&sub.name);
        let Ok(files) = io.list_dir(&subdir) else { continue };
        for fe in files {
            let path = subdir.join(&fe.name);
            if !fe.is_dir && path.extension().and_then(|e| e.to_str()) == Some("simres") {
                f(&path, &fe);
            }
        }
    }
}

/// Best-effort removal of shard directories compaction emptied
/// (`remove_dir` refuses non-empty ones, which is exactly right).
fn prune_empty_shard_dirs(io: &dyn StoreIo, dir: &Path) {
    let Ok(entries) = io.list_dir(dir) else { return };
    for sub in entries {
        let Some(name) = sub.name.to_str() else { continue };
        if sub.is_dir && name.len() == 2 && name.bytes().all(|b| b.is_ascii_hexdigit()) {
            let _ = io.remove_dir(&dir.join(&sub.name));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn cli_parses_every_subcommand_and_passes_leftovers_through() {
        let (cmd, rest) = parse_store_cli(&args(&["stats", "--results", "x"])).unwrap();
        assert_eq!(cmd, StoreCommand::Stats);
        assert_eq!(rest, args(&["--results", "x"]));

        let (cmd, rest) =
            parse_store_cli(&args(&["gc", "--max-bytes", "1024", "--smoke"])).unwrap();
        assert_eq!(cmd, StoreCommand::Gc { max_bytes: Some(1024), max_age_days: None });
        assert_eq!(rest, args(&["--smoke"]));

        let (cmd, _) = parse_store_cli(&args(&["gc", "--max-age-days", "30"])).unwrap();
        assert_eq!(cmd, StoreCommand::Gc { max_bytes: None, max_age_days: Some(30) });

        assert_eq!(parse_store_cli(&args(&["verify"])).unwrap().0, StoreCommand::Verify);
        assert_eq!(parse_store_cli(&args(&["compact"])).unwrap().0, StoreCommand::Compact);
    }

    #[test]
    fn cli_merge_takes_sources_and_a_required_destination() {
        let (cmd, rest) =
            parse_store_cli(&args(&["merge", "a", "b", "--into", "dst"])).unwrap();
        assert_eq!(
            cmd,
            StoreCommand::Merge {
                sources: vec![PathBuf::from("a"), PathBuf::from("b")],
                into: PathBuf::from("dst"),
            }
        );
        assert!(rest.is_empty(), "merge consumes its whole argv");

        // --into is required, sources are required, stray flags refused.
        assert!(parse_store_cli(&args(&["merge", "a", "b"])).is_err());
        assert!(parse_store_cli(&args(&["merge", "--into", "dst"])).is_err());
        assert!(parse_store_cli(&args(&["merge", "a", "--into"])).is_err());
        assert!(parse_store_cli(&args(&["merge", "a", "--smoke", "--into", "d"])).is_err());
    }

    #[test]
    fn cli_unknown_subcommand_lists_the_valid_set() {
        for bad in [&["frobnicate"][..], &[][..]] {
            let e = parse_store_cli(&args(bad)).unwrap_err().to_string();
            for sub in STORE_SUBCOMMANDS {
                assert!(e.contains(sub), "error {e:?} must list {sub}");
            }
        }
    }

    #[test]
    fn cli_gc_refuses_to_run_without_an_explicit_bound() {
        let e = parse_store_cli(&args(&["gc"])).unwrap_err().to_string();
        assert!(e.contains("refuses"), "got: {e}");
        assert!(e.contains("--max-bytes") && e.contains("--max-age-days"), "got: {e}");
        // …and the bounds are rejected where they make no sense.
        assert!(parse_store_cli(&args(&["stats", "--max-bytes", "1"])).is_err());
        assert!(parse_store_cli(&args(&["gc", "--max-bytes", "NaN"])).is_err());
        assert!(parse_store_cli(&args(&["gc", "--max-bytes"])).is_err());
    }

    #[test]
    fn canonical_plan_covers_both_micro_sizes_and_the_universe() {
        let scale = ScaleConfig::smoke();
        let points = canonical_points(crate::config::coffee_lake(), scale);
        assert!(points.len() > 200, "got {}", points.len());
        use crate::exec::point::Workload;
        let pow2 = scale.micro_pow2_bytes;
        let micro_pow2 = points
            .iter()
            .filter(|p| matches!(p.workload, Workload::Micro { bytes, .. } if bytes == pow2))
            .count();
        assert!(micro_pow2 >= MicroOp::all().len() * MICRO_STRIDES.len() * 2);
        let kernels = points
            .iter()
            .filter(|p| matches!(p.workload, Workload::Kernel { .. }))
            .count();
        assert!(kernels > 0, "universe kernels must be in the canonical plan");
        // Content keys must be unique across the plan.
        let mut keys: Vec<u64> = points.iter().map(|p| p.key()).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), points.len());
    }
}
