//! The two-tier, deduplicating result store.
//!
//! Tier 1 is an in-process map (`key → Arc<RunResult>`): every result
//! simulated or loaded during this process is served from memory for the
//! rest of the run, which is what collapses the overlap between `repro
//! all`'s sweeps (figure6 and `universe` share family points; the tuner's
//! full rung re-visits `universe`'s measurements) from re-simulation to a
//! map probe.
//!
//! Tier 2 is persistent (by default `<artifacts>/results/`), packed into
//! append-only **segment files** with a per-directory index — see
//! [`super::segment`] for the on-disk format and recovery contract.
//! Writes are write-through appends; reads validate the per-record
//! checksum in place (memory-mapped under the default `mmap` feature)
//! instead of the PR-5 file-open-read-parse round trip per point. Any
//! damage — torn record, corrupt index, mis-keyed bytes — degrades to a
//! **miss**, the same recoverability contract as
//! [`crate::tune::cache::PlanCache`]. Disk *write* failures are reported
//! on stderr and tolerated (persistence is an optimization; losing it
//! must never fail an experiment). Every disk touch goes through the
//! [`super::vfs::StoreIo`] seam with bounded retry; after
//! [`DISK_FAILURE_LIMIT`] *consecutive* hard failures the persistent
//! tier is disabled for the rest of the run — the store keeps serving
//! memory-only, counts the degradation in [`ExecStats::degraded`], and
//! the `[exec]` summary line warns about it.
//!
//! The PR-5 sharded file-per-point format
//! (`results/<xx>/<16-hex-key>.simres`) remains readable as a **legacy
//! fallback tier**: a key absent from the segments is probed there, so
//! old stores keep serving with zero engine runs. New results are only
//! ever appended to segments, and `repro store compact` folds legacy
//! shards in wholesale — that pair is the transparent migration path.
//!
//! Safety net: the simulator is deterministic, so a store hit must be
//! bit-identical to a fresh simulation. Debug builds re-simulate every
//! hit and assert exactly that (serialized-byte equality); release
//! builds trust the determinism wall (`tests/golden_determinism.rs`,
//! `tests/result_store_roundtrip.rs`). Verification runs are counted
//! separately from [`ExecStats::engine_runs`] so the fewer-sims-when-warm
//! property stays observable in any build.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::experiments::EngineCache;
use crate::sim::RunResult;
use crate::{format_err, Result};

use super::format::{parse_result, serialize_result};
use super::planner::simulate;
use super::point::SimPoint;
use super::segment::{unix_now, SegmentStore, DEFAULT_ROLL_BYTES};
use super::vfs::{default_io, with_retry, StoreIo};

/// Consecutive hard disk failures after which the persistent tier is
/// disabled for the rest of the run (memory-only degradation).
pub const DISK_FAILURE_LIMIT: u64 = 3;

/// Counter snapshot of one store's traffic (all monotonically increasing
/// over the store's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Point requests answered (hit or simulated), including batch
    /// duplicates.
    pub requests: u64,
    /// Hits served from the in-memory tier.
    pub mem_hits: u64,
    /// Hits served from the persistent tier (promoted to memory),
    /// segment and legacy combined.
    pub disk_hits: u64,
    /// The subset of `disk_hits` served by legacy file-per-point shards
    /// (a migrated directory should drive this to zero).
    pub legacy_hits: u64,
    /// Requests that found nothing and simulated.
    pub misses: u64,
    /// Duplicate points inside one batch, served from the first
    /// occurrence without a separate lookup.
    pub deduped: u64,
    /// Fresh engine simulations performed (excludes debug verification).
    pub engine_runs: u64,
    /// Results written to the persistent tier.
    pub disk_writes: u64,
    /// Disk entries discarded as corrupt/stale (each counted as a miss).
    pub corrupt_discards: u64,
    /// Debug-build hit verifications performed (each one a re-simulation
    /// compared bit-for-bit against the served result).
    pub verified_hits: u64,
    /// Persistent-tier operations that failed even after bounded retry.
    pub disk_errors: u64,
    /// Stored hits dropped because their point no longer simulates (a
    /// stale cache entry, healed to a plain miss).
    pub dropped_unsimulatable: u64,
    /// The persistent tier was disabled after [`DISK_FAILURE_LIMIT`]
    /// consecutive failures; the store is serving memory-only.
    pub degraded: bool,
}

impl ExecStats {
    /// Total hits across both tiers.
    pub fn hits(&self) -> u64 {
        self.mem_hits + self.disk_hits
    }
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    mem_hits: AtomicU64,
    disk_hits: AtomicU64,
    legacy_hits: AtomicU64,
    misses: AtomicU64,
    deduped: AtomicU64,
    engine_runs: AtomicU64,
    disk_writes: AtomicU64,
    corrupt_discards: AtomicU64,
    verified_hits: AtomicU64,
    disk_errors: AtomicU64,
    dropped_unsimulatable: AtomicU64,
}

/// The store. Cheap to share across the worker pool (`&ResultStore` is
/// `Sync`); one instance should live for a whole CLI invocation so the
/// memory tier spans every experiment in it.
pub struct ResultStore {
    mem: Mutex<HashMap<u64, Arc<RunResult>>>,
    /// Persistent tier root; `None` = memory-only (ephemeral) store.
    dir: Option<PathBuf>,
    /// Segment tier over `dir`; present exactly when `dir` is.
    seg: Option<Mutex<SegmentStore>>,
    io: Arc<dyn StoreIo>,
    /// Set once [`DISK_FAILURE_LIMIT`] consecutive disk failures occur;
    /// the persistent tier is skipped from then on.
    degraded: AtomicBool,
    consecutive_disk_failures: AtomicU64,
    stats: Counters,
}

impl ResultStore {
    /// Memory-only store: in-run dedup and cross-request reuse, nothing
    /// on disk. What `--cold` gives the CLI, and what the compatibility
    /// wrappers in `coordinator::experiments` use.
    pub fn ephemeral() -> Self {
        Self {
            mem: Mutex::new(HashMap::new()),
            dir: None,
            seg: None,
            io: default_io(),
            degraded: AtomicBool::new(false),
            consecutive_disk_failures: AtomicU64::new(0),
            stats: Counters::default(),
        }
    }

    /// Store with a persistent tier rooted at `dir`. The segment index
    /// is loaded (or rebuilt from scans) once, here; a missing directory
    /// just means every disk probe misses until the first write creates
    /// it.
    pub fn persistent(dir: impl Into<PathBuf>) -> Self {
        Self::persistent_with_roll(dir, DEFAULT_ROLL_BYTES)
    }

    /// [`ResultStore::persistent`] with an explicit segment roll size;
    /// tests use small rolls to exercise multi-segment layouts cheaply.
    pub fn persistent_with_roll(dir: impl Into<PathBuf>, roll_bytes: u64) -> Self {
        Self::persistent_with_io(dir, roll_bytes, default_io())
    }

    /// [`ResultStore::persistent_with_roll`] over an explicit
    /// [`StoreIo`] — how the chaos wall injects faults under every disk
    /// operation this store performs.
    pub fn persistent_with_io(
        dir: impl Into<PathBuf>,
        roll_bytes: u64,
        io: Arc<dyn StoreIo>,
    ) -> Self {
        let dir = dir.into();
        let mut seg = SegmentStore::open_with(&dir, roll_bytes, Arc::clone(&io));
        let damage = seg.take_open_corruption();
        let store = Self {
            mem: Mutex::new(HashMap::new()),
            dir: Some(dir),
            seg: Some(Mutex::new(seg)),
            io,
            degraded: AtomicBool::new(false),
            consecutive_disk_failures: AtomicU64::new(0),
            stats: Counters::default(),
        };
        store.stats.corrupt_discards.fetch_add(damage, Ordering::Relaxed);
        store
    }

    /// The conventional location under an artifact directory
    /// (`<artifacts>/results`), next to the tuner's `plans/`.
    pub fn default_under(artifacts_dir: &Path) -> Self {
        Self::persistent(artifacts_dir.join("results"))
    }

    /// Persistent-tier root, when one is configured.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// Where `key`'s **legacy** (PR-5 file-per-point) shard would live
    /// (`None` for ephemeral stores). New results never land here; the
    /// path exists for the fallback read tier, migration tests and the
    /// bench's baseline.
    pub fn legacy_shard_path(&self, key: u64) -> Option<PathBuf> {
        self.dir
            .as_ref()
            .map(|d| d.join(format!("{:02x}", key >> 56)).join(format!("{key:016x}.simres")))
    }

    /// Physical location of `key`'s segment record, for tests and
    /// tooling: `(segment path, byte offset, frame length)`.
    pub fn segment_location(&self, key: u64) -> Option<(PathBuf, u64, u32)> {
        self.seg.as_ref()?.lock().expect("segment lock").locate(key)
    }

    /// Counter snapshot.
    pub fn stats(&self) -> ExecStats {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed);
        ExecStats {
            requests: g(&self.stats.requests),
            mem_hits: g(&self.stats.mem_hits),
            disk_hits: g(&self.stats.disk_hits),
            legacy_hits: g(&self.stats.legacy_hits),
            misses: g(&self.stats.misses),
            deduped: g(&self.stats.deduped),
            engine_runs: g(&self.stats.engine_runs),
            disk_writes: g(&self.stats.disk_writes),
            corrupt_discards: g(&self.stats.corrupt_discards),
            verified_hits: g(&self.stats.verified_hits),
            disk_errors: g(&self.stats.disk_errors),
            dropped_unsimulatable: g(&self.stats.dropped_unsimulatable),
            degraded: self.degraded.load(Ordering::Relaxed),
        }
    }

    /// The I/O implementation this store runs on (shared with grid
    /// tooling so manifests land through the same seam).
    pub(crate) fn io(&self) -> Arc<dyn StoreIo> {
        Arc::clone(&self.io)
    }

    /// Whether the persistent tier has been disabled after repeated
    /// failures (memory tier keeps serving either way).
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    fn note_disk_failure(&self, what: &str, e: &dyn std::fmt::Display) {
        self.stats.disk_errors.fetch_add(1, Ordering::Relaxed);
        eprintln!("[exec] {what}: {e}");
        let n = self.consecutive_disk_failures.fetch_add(1, Ordering::Relaxed) + 1;
        if n >= DISK_FAILURE_LIMIT && !self.degraded.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[exec] persistent tier DISABLED after {n} consecutive disk failures — \
                 continuing memory-only; results from this run will not be stored"
            );
        }
    }

    fn note_disk_ok(&self) {
        self.consecutive_disk_failures.store(0, Ordering::Relaxed);
    }

    pub(crate) fn note_dedup(&self) {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        self.stats.deduped.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_miss(&self) {
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn note_engine_run(&self) {
        self.stats.engine_runs.fetch_add(1, Ordering::Relaxed);
    }

    /// Probe every tier: memory, then segments, then legacy shards.
    /// Counts the request and the hit/nothing outcome; a disk hit is
    /// promoted into the memory tier.
    pub fn lookup(&self, key: u64) -> Option<Arc<RunResult>> {
        self.stats.requests.fetch_add(1, Ordering::Relaxed);
        if let Some(r) = self.mem.lock().expect("store lock").get(&key) {
            self.stats.mem_hits.fetch_add(1, Ordering::Relaxed);
            return Some(Arc::clone(r));
        }
        let r = self.load_disk(key)?;
        self.stats.disk_hits.fetch_add(1, Ordering::Relaxed);
        self.mem.lock().expect("store lock").insert(key, Arc::clone(&r));
        Some(r)
    }

    /// Disk probe only (no counters beyond corruption and the legacy
    /// split): absent, corrupt, or mis-keyed entries are all a `None`.
    /// A degraded store skips the disk entirely.
    fn load_disk(&self, key: u64) -> Option<Arc<RunResult>> {
        if self.is_degraded() {
            return None;
        }
        let _span = crate::obs::span("store_disk_probe");
        if let Some(seg) = &self.seg {
            match seg.lock().expect("segment lock").lookup_result(key) {
                Some(Ok(r)) => return Some(Arc::new(r)),
                Some(Err(e)) => {
                    // The entry was dropped by the segment store; fall
                    // through to the legacy tier, then (usually) miss.
                    self.stats.corrupt_discards.fetch_add(1, Ordering::Relaxed);
                    eprintln!(
                        "[exec] corrupt segment record for {key:#018x}: {e} — treating as miss"
                    );
                }
                None => {}
            }
        }
        let r = self.load_legacy(key)?;
        self.stats.legacy_hits.fetch_add(1, Ordering::Relaxed);
        Some(r)
    }

    /// Legacy file-per-point probe (read-only tier).
    fn load_legacy(&self, key: u64) -> Option<Arc<RunResult>> {
        let path = self.legacy_shard_path(key)?;
        let io = &self.io;
        let bytes = match with_retry(|| io.read(&path)) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return None,
            Err(e) => {
                self.note_disk_failure(
                    &format!("unreadable result shard {path:?} — treating as miss"),
                    &e,
                );
                return None;
            }
        };
        let text = match String::from_utf8(bytes) {
            Ok(t) => t,
            Err(_) => {
                self.stats.corrupt_discards.fetch_add(1, Ordering::Relaxed);
                eprintln!("[exec] result shard {path:?} is not UTF-8 — treating as miss");
                return None;
            }
        };
        match parse_result(&text) {
            Ok((stored_key, r)) if stored_key == key => Some(Arc::new(r)),
            Ok((stored_key, _)) => {
                self.stats.corrupt_discards.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "[exec] result shard {path:?} carries key {stored_key:#x}, expected {key:#x} — treating as miss"
                );
                None
            }
            Err(e) => {
                self.stats.corrupt_discards.fetch_add(1, Ordering::Relaxed);
                eprintln!("[exec] corrupt result shard {path:?}: {e} — treating as miss");
                None
            }
        }
    }

    /// Insert into the memory tier and append to the segment tier. Disk
    /// failures are reported and swallowed (see the module docs). The
    /// index itself is flushed by [`ResultStore::flush`]/`Drop` — a
    /// crash before that loses only the index, which the next open
    /// rebuilds from the already-durable records.
    pub fn insert(&self, key: u64, result: Arc<RunResult>) {
        self.mem.lock().expect("store lock").insert(key, Arc::clone(&result));
        let Some(seg) = &self.seg else { return };
        if self.is_degraded() {
            return;
        }
        let r = seg.lock().expect("segment lock").append_result(key, unix_now(), &result);
        match r {
            Ok(()) => {
                self.stats.disk_writes.fetch_add(1, Ordering::Relaxed);
                self.note_disk_ok();
            }
            Err(e) => {
                self.note_disk_failure(&format!("could not persist result {key:#x}"), &e);
            }
        }
    }

    /// Drop `key` from every tier (memory map and segment index); the
    /// next request for it is a plain miss. Returns whether anything was
    /// dropped. Used when a stored record turns out to be stale — e.g. a
    /// hit whose point no longer simulates.
    pub fn invalidate(&self, key: u64) -> bool {
        let mem_hit = self.mem.lock().expect("store lock").remove(&key).is_some();
        let seg_hit = match &self.seg {
            Some(seg) => seg.lock().expect("segment lock").remove(key),
            None => false,
        };
        mem_hit || seg_hit
    }

    /// Write `result` in the **legacy** file-per-point format. Not on
    /// any hot path: exists so the bench can build a PR-5-shaped
    /// baseline and migration tests can fabricate old directories.
    pub fn write_legacy_shard(&self, key: u64, result: &RunResult) -> Result<PathBuf> {
        let path = self
            .legacy_shard_path(key)
            .ok_or_else(|| format_err!("ephemeral store has no disk tier"))?;
        let shard_dir = path.parent().expect("shard path has a parent");
        let io = &self.io;
        with_retry(|| io.create_dir_all(shard_dir))?;
        // Unique temp name per process: two processes landing the same
        // key concurrently each rename their own complete file.
        let tmp = shard_dir.join(format!("{key:016x}.tmp{}", std::process::id()));
        let text = serialize_result(key, result);
        with_retry(|| io.write(&tmp, text.as_bytes()))?;
        with_retry(|| io.rename(&tmp, &path))?;
        self.stats.disk_writes.fetch_add(1, Ordering::Relaxed);
        Ok(path)
    }

    /// Flush the segment index to disk. Called on drop; callers that
    /// outlive interesting work (the CLI, benches) may flush earlier so
    /// a later crash cannot cost the index.
    pub fn flush(&self) {
        if let Some(seg) = &self.seg {
            if self.is_degraded() {
                return;
            }
            if let Err(e) = seg.lock().expect("segment lock").flush_index() {
                self.note_disk_failure("could not flush segment index", &e);
            }
        }
    }

    /// Serve `point` from the store, simulating (and inserting) on a
    /// miss. The single-point entry path: `run_kernel_with`, the micro
    /// drivers and the tuner's cost model all come through here; batch
    /// callers use [`super::Planner`], which dedups first.
    pub fn get_or_run(
        &self,
        engines: &mut EngineCache,
        point: &SimPoint,
    ) -> Result<Arc<RunResult>> {
        if let Some(hit) = self.lookup(point.key()) {
            #[cfg(debug_assertions)]
            self.verify_hit(engines, point, &hit)?;
            return Ok(hit);
        }
        self.note_miss();
        self.note_engine_run();
        let r = {
            let _span = crate::obs::span("engine_run");
            Arc::new(simulate(engines, point)?)
        };
        // Fold the run's simulator counters into the obs registry here —
        // once per fresh simulation, at the stage boundary, so the
        // per-access hot path never touches the registry.
        crate::obs::fold_run_result(&r);
        self.insert(point.key(), Arc::clone(&r));
        Ok(r)
    }

    /// Debug-build safety net: a served hit must be bit-identical to a
    /// fresh simulation. Panics on mismatch — a divergence here means
    /// either the simulator lost determinism or the store served the
    /// wrong bytes, and both must fail loudly, not skew results.
    ///
    /// A hit whose point no longer *simulates at all* (e.g. a kernel
    /// renamed out of the registry after its result was stored) is a
    /// stale cache entry, not a determinism breach: the record is
    /// dropped from every tier, counted, and surfaced as a recoverable
    /// error so the caller's point becomes a plain miss from now on.
    #[cfg(debug_assertions)]
    pub(crate) fn verify_hit(
        &self,
        engines: &mut EngineCache,
        point: &SimPoint,
        hit: &RunResult,
    ) -> Result<()> {
        self.stats.verified_hits.fetch_add(1, Ordering::Relaxed);
        let fresh = match simulate(engines, point) {
            Ok(r) => r,
            Err(e) => {
                self.invalidate(point.key());
                self.stats.dropped_unsimulatable.fetch_add(1, Ordering::Relaxed);
                return Err(format_err!(
                    "store hit for unsimulatable point {} dropped ({e}); \
                     the key now degrades to a miss",
                    point.label()
                ));
            }
        };
        let key = point.key();
        assert_eq!(
            serialize_result(key, &fresh),
            serialize_result(key, hit),
            "store hit diverged from a fresh simulation for {} (key {key:#x})",
            point.label()
        );
        Ok(())
    }
}

impl Drop for ResultStore {
    fn drop(&mut self) {
        self.flush();
    }
}

/// `Debug` renders the tier configuration + live counters (the map
/// contents are not interesting and may be huge).
impl std::fmt::Debug for ResultStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResultStore")
            .field("dir", &self.dir)
            .field("stats", &self.stats())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::coffee_lake;
    use crate::kernels::micro::MicroOp;

    const MIB: u64 = 1 << 20;

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("multistride_resultstore_{tag}_{}", std::process::id()))
    }

    fn point() -> SimPoint {
        SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, 2, MIB, true, false)
    }

    #[test]
    fn miss_simulates_then_memory_hit_serves_same_arc() {
        let store = ResultStore::ephemeral();
        let mut engines = EngineCache::new();
        let p = point();
        let a = store.get_or_run(&mut engines, &p).unwrap();
        let s = store.stats();
        assert_eq!((s.misses, s.engine_runs, s.hits()), (1, 1, 0));
        let b = store.get_or_run(&mut engines, &p).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "memory tier serves the stored allocation");
        let s = store.stats();
        assert_eq!((s.misses, s.engine_runs, s.mem_hits), (1, 1, 1));
        assert_eq!(s.disk_writes, 0, "ephemeral store never touches disk");
    }

    #[test]
    fn disk_tier_round_trips_across_store_instances() {
        let dir = tmp("roundtrip");
        std::fs::remove_dir_all(&dir).ok();
        let p = point();
        let cold = ResultStore::persistent(&dir);
        let a = cold.get_or_run(&mut EngineCache::new(), &p).unwrap();
        assert_eq!(cold.stats().disk_writes, 1);
        let (seg_path, offset, len) = cold.segment_location(p.key()).unwrap();
        assert!(seg_path.starts_with(&dir) && seg_path.exists());
        assert!(offset >= 8 && len > 0, "record sits past the segment magic");

        // A fresh store over the same dir, opened while the writer is
        // still alive (appends are unbuffered): pure disk hit, zero sims.
        let warm = ResultStore::persistent(&dir);
        let b = warm.get_or_run(&mut EngineCache::new(), &p).unwrap();
        assert_eq!(
            serialize_result(p.key(), &a),
            serialize_result(p.key(), &b),
            "disk round trip is bit-identical"
        );
        let s = warm.stats();
        assert_eq!((s.disk_hits, s.legacy_hits, s.engine_runs), (1, 0, 0));
        drop((cold, warm));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_record_with_live_index_degrades_at_lookup() {
        let dir = tmp("corrupt");
        std::fs::remove_dir_all(&dir).ok();
        let p = point();
        let first = {
            let store = ResultStore::persistent(&dir);
            store.get_or_run(&mut EngineCache::new(), &p).unwrap()
        };

        // Flip a payload byte. The index still covers the record, so the
        // damage surfaces at lookup (checksum validation in place), not
        // at open; the point degrades to a miss that re-simulates
        // bit-identically and re-appends.
        let (seg_path, offset, _) =
            ResultStore::persistent(&dir).segment_location(p.key()).unwrap();
        let mut bytes = std::fs::read(&seg_path).unwrap();
        bytes[offset as usize + 21] ^= 0x01;
        std::fs::write(&seg_path, &bytes).unwrap();

        let healed = ResultStore::persistent(&dir);
        assert_eq!(healed.stats().corrupt_discards, 0, "index hides in-record damage until read");
        let again = healed.get_or_run(&mut EngineCache::new(), &p).unwrap();
        let s = healed.stats();
        assert_eq!((s.corrupt_discards, s.misses, s.engine_runs, s.legacy_hits), (1, 1, 1, 0));
        assert_eq!(serialize_result(p.key(), &first), serialize_result(p.key(), &again));
        drop(healed); // flushes the index with the re-appended record

        let warm = ResultStore::persistent(&dir);
        let served = warm.get_or_run(&mut EngineCache::new(), &p).unwrap();
        assert_eq!(serialize_result(p.key(), &first), serialize_result(p.key(), &served));
        assert_eq!((warm.stats().disk_hits, warm.stats().engine_runs), (1, 0));
        drop(warm);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_without_index_is_sealed_at_open() {
        let dir = tmp("torn");
        std::fs::remove_dir_all(&dir).ok();
        let p = point();
        let first = {
            let store = ResultStore::persistent(&dir);
            store.get_or_run(&mut EngineCache::new(), &p).unwrap()
        };
        let (seg_path, ..) = ResultStore::persistent(&dir).segment_location(p.key()).unwrap();
        std::fs::remove_file(dir.join(crate::exec::segment::INDEX_FILE)).unwrap();
        let bytes = std::fs::read(&seg_path).unwrap();
        std::fs::write(&seg_path, &bytes[..bytes.len() - 5]).unwrap();

        // No index: the open-time scan hits the torn record, seals the
        // segment, and the re-simulated record rolls to a fresh one.
        let healed = ResultStore::persistent(&dir);
        assert_eq!(healed.stats().corrupt_discards, 1, "scan detects the torn tail");
        let again = healed.get_or_run(&mut EngineCache::new(), &p).unwrap();
        let s = healed.stats();
        assert_eq!((s.misses, s.engine_runs, s.legacy_hits), (1, 1, 0));
        assert_eq!(serialize_result(p.key(), &first), serialize_result(p.key(), &again));
        let (new_seg, ..) = healed.segment_location(p.key()).unwrap();
        assert_ne!(new_seg, seg_path, "writer must not append to a sealed segment");
        drop(healed);
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Satellite fix pin: a store hit for a point the engine can no
    /// longer simulate used to panic inside the debug verifier. It must
    /// instead drop the stale record, count it, and surface a
    /// recoverable error — the key heals to a plain miss.
    #[cfg(debug_assertions)]
    #[test]
    fn unsimulatable_hit_heals_to_a_miss_instead_of_panicking() {
        use crate::kernels::library::kernel_by_name;
        use crate::transform::StridingConfig;

        let store = ResultStore::ephemeral();
        let mut engines = EngineCache::new();
        // A "ghost" point: keyed like a kernel that is not in the
        // registry, as if the store outlived a kernel rename.
        let donor = kernel_by_name("mxv", MIB).expect("mxv is registered");
        let ghost = SimPoint::kernel_from_spec(
            coffee_lake(),
            "ghost",
            MIB,
            StridingConfig::new(1, 1),
            true,
            &donor.spec,
        );
        // Smuggle any valid result under the ghost key.
        let r = store.get_or_run(&mut engines, &point()).unwrap();
        store.insert(ghost.key(), Arc::clone(&r));

        let out = store.get_or_run(&mut engines, &ghost);
        assert!(out.is_err(), "stale hit must be an error, not a panic");
        let s = store.stats();
        assert_eq!(s.dropped_unsimulatable, 1);
        assert!(store.lookup(ghost.key()).is_none(), "the record was dropped: now a plain miss");
    }

    /// A dead disk must never fail simulation: after
    /// [`DISK_FAILURE_LIMIT`] consecutive failures the store flips to
    /// memory-only, keeps serving, and reports the degradation.
    #[test]
    fn dead_disk_degrades_to_memory_only_and_keeps_serving() {
        use crate::exec::vfs::{FaultIo, FaultPlan, RealIo};

        let dir = tmp("deaddisk");
        std::fs::remove_dir_all(&dir).ok();
        let io: Arc<dyn crate::exec::vfs::StoreIo> =
            Arc::new(FaultIo::new(Arc::new(RealIo), FaultPlan::dead_disk()));
        let store = ResultStore::persistent_with_io(&dir, DEFAULT_ROLL_BYTES, io);
        let mut engines = EngineCache::new();
        let mut first = None;
        for strides in [1u32, 2, 4, 8] {
            let p = SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, strides, MIB, true, false);
            let r = store.get_or_run(&mut engines, &p);
            assert!(r.is_ok(), "a dead disk must not fail simulation (strides {strides})");
            first.get_or_insert((p, r.unwrap()));
        }
        let s = store.stats();
        assert!(s.degraded, "repeated failures must flip the store to memory-only");
        assert!(store.is_degraded());
        assert!(s.disk_errors >= DISK_FAILURE_LIMIT);
        assert_eq!(s.engine_runs, 4);
        assert_eq!(s.disk_writes, 0, "nothing can land on a dead disk");
        // The memory tier still serves bit-identical results.
        let (p, r) = first.unwrap();
        let served = store.lookup(p.key()).expect("memory tier survives degradation");
        assert_eq!(serialize_result(p.key(), &r), serialize_result(p.key(), &served));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn legacy_shards_serve_and_mis_keyed_ones_do_not() {
        let dir = tmp("legacy");
        std::fs::remove_dir_all(&dir).ok();
        let p = point();
        // Fabricate a PR-5-shaped directory: legacy shard, no segments.
        let r = {
            let store = ResultStore::persistent(&dir);
            let r = store.get_or_run(&mut EngineCache::new(), &p).unwrap();
            store.write_legacy_shard(p.key(), &r).unwrap();
            r
        };
        let seg_path = {
            let probe = ResultStore::persistent(&dir);
            probe.segment_location(p.key()).unwrap().0
        };
        std::fs::remove_file(&seg_path).unwrap();
        std::fs::remove_file(dir.join(crate::exec::segment::INDEX_FILE)).unwrap();

        let old = ResultStore::persistent(&dir);
        let served = old.lookup(p.key()).expect("legacy shard serves");
        let s = old.stats();
        assert_eq!((s.disk_hits, s.legacy_hits, s.engine_runs), (1, 1, 0));
        assert_eq!(serialize_result(p.key(), &r), serialize_result(p.key(), &served));

        // Mis-keyed: copy the (valid) shard under a different point's key.
        let q = SimPoint::micro(coffee_lake(), MicroOp::LoadAligned, 4, MIB, true, false);
        let path = old.legacy_shard_path(p.key()).unwrap();
        let qpath = old.legacy_shard_path(q.key()).unwrap();
        std::fs::create_dir_all(qpath.parent().unwrap()).unwrap();
        std::fs::copy(&path, &qpath).unwrap();
        let fresh = ResultStore::persistent(&dir);
        assert!(fresh.lookup(q.key()).is_none(), "smuggled shard must not serve");
        assert_eq!(fresh.stats().corrupt_discards, 1);
        drop((old, fresh));
        std::fs::remove_dir_all(&dir).ok();
    }
}
